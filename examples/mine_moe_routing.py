"""The paper's technique as a first-class framework feature: mine
triclusters of MoE routing decisions (DESIGN.md §5).

    PYTHONPATH=src python examples/mine_moe_routing.py [--arch mixtral-8x7b]

Runs a reduced-config MoE forward over the synthetic motif corpus,
collects the (token × expert × layer) Boolean routing tensor, and mines
OAC triclusters from it: each pattern is a token group that the router
sends to the same expert group across a layer group — the expert
co-activation structure the routing aux-loss is supposed to spread out.
"""
import argparse
import sys
sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core import BatchMiner
from repro.core import postprocess as PP
from repro.data.tokens import TokenPipeline
from repro.models.api import get_model
from repro.models.telemetry import collect_moe_routing, routing_context


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b",
                    choices=["mixtral-8x7b", "granite-moe-3b-a800m"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--theta", type=float, default=0.2)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    pipeline = TokenPipeline(cfg, args.batch, args.seq, seed=0)
    tokens = pipeline.batch_at(0)["tokens"]

    routes = collect_moe_routing(cfg, params, tokens)
    ctx = routing_context(cfg, tokens, routes)
    print(f"routing context: vocab={ctx.sizes[0]} experts={ctx.sizes[1]} "
          f"layers={ctx.sizes[2]}, |I|={ctx.num_tuples} "
          f"(density {ctx.density:.4f})")

    miner = BatchMiner(ctx.sizes, theta=args.theta)
    res = miner(ctx.tuples)
    n = int(np.asarray(res.is_unique).sum())
    kept = int(np.asarray(res.keep).sum())
    print(f"{n} routing triclusters, {kept} with density >= {args.theta}")

    clusters = miner.materialise(res, ctx.tuples, only_kept=False)
    # rank by support (density × volume); show expert/layer groups compactly
    clusters.sort(key=lambda cd: -cd[1] * float(np.prod(
        [len(c) for c in cd[0]])))
    print("\ntop co-activation patterns (tokens | experts | layers):")
    for comps, dens in clusters[:4]:
        toks, experts, layers = comps
        tk = sorted(toks)
        tks = (f"{len(tk)} tokens e.g. {tk[:6]}" if len(tk) > 6
               else str(tk))
        print(f"  {tks} | experts {sorted(experts)} | layers "
              f"{sorted(layers)} | ρ̂={dens:.3f}")


if __name__ == "__main__":
    main()
