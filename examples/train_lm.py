"""End-to-end training driver: train a reduced-config LM for a few hundred
steps on the synthetic motif language, with checkpointing and resume.

    PYTHONPATH=src python examples/train_lm.py [--arch qwen3-0.6b] [--steps 200]

Asserts the loss actually decreases, then kills and resumes from the last
checkpoint to demonstrate the restart path (the supervisor does this
automatically on real failures — see examples/fault_tolerance_demo.py).
The paper's kind is a mining pipeline, so the *primary* end-to-end driver
is quickstart/tricluster; this driver exercises the LM substrate the
assigned architectures run on (full-size training is the dry-run's job).
"""
import argparse
import json
import os
import sys
import tempfile
sys.path.insert(0, "src")

from repro.launch import train as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as d:
        ckpt = os.path.join(d, "ckpt")
        metrics = os.path.join(d, "metrics.json")
        rc = T.main(["--arch", args.arch, "--smoke",
                     "--steps", str(args.steps),
                     "--global-batch", str(args.global_batch),
                     "--seq", str(args.seq),
                     "--ckpt-dir", ckpt, "--ckpt-every", "50",
                     "--metrics-out", metrics, "--log-every", "20"])
        assert rc == 0
        rows = json.load(open(metrics))
        first, last = rows[0]["loss"], rows[-1]["loss"]
        print(f"\nloss: {first:.3f} -> {last:.3f}")
        assert last < first, "loss did not decrease"

        print("\n-- resume from checkpoint (+20 steps) --")
        rc = T.main(["--arch", args.arch, "--smoke",
                     "--steps", str(args.steps + 20),
                     "--global-batch", str(args.global_batch),
                     "--seq", str(args.seq),
                     "--ckpt-dir", ckpt, "--resume", "auto",
                     "--log-every", "10"])
        assert rc == 0
    print("train_lm: OK")


if __name__ == "__main__":
    main()
