"""Batched serving demo: ragged prompts through prefill + decode.

    PYTHONPATH=src python examples/serve_batch.py [--arch mixtral-8x7b]

Uses the reduced config of the chosen family (CPU-sized) and the same
ServeEngine / decode_step the decode_32k dry-run cells lower at
production size.
"""
import argparse
import sys
sys.path.insert(0, "src")

from repro.launch import serve as S


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    args = ap.parse_args()
    rc = S.main(["--arch", args.arch, "--smoke", "--batch", "4",
                 "--prompt-len", "24", "--new-tokens", "12",
                 "--max-len", "128"])
    assert rc == 0
    print("serve_batch: OK")


if __name__ == "__main__":
    main()
