"""Quickstart: mine OAC triclusters from the IMDB-like dataset.

    PYTHONPATH=src python examples/quickstart.py

Mirrors the paper's §5 experiment end to end: build a movies×tags×genres
tricontext, run the three-stage pipeline, and print the top patterns in
the paper's §5.2 output format — then cross-check the batch engine
against the pure-python reference oracle.
"""
import sys
sys.path.insert(0, "src")

import numpy as np

from repro.core import BatchMiner
from repro.core import postprocess as PP
from repro.core.reference import multimodal_clusters
from repro.data import synthetic


def main():
    ctx = synthetic.imdb_like(seed=0)
    print(f"IMDB-like context: {ctx.sizes[0]} movies × {ctx.sizes[1]} tags"
          f" × {ctx.sizes[2]} genres, |I|={ctx.num_tuples}")

    miner = BatchMiner(ctx.sizes, theta=0.0)
    result = miner(ctx.tuples)
    n = int(np.asarray(result.is_unique).sum())
    print(f"three-stage pipeline: {n} unique triclusters")

    # cross-check vs the dict-based reference (paper Alg. 2-7 semantics)
    _, unique, _, _ = multimodal_clusters(ctx)
    assert n == len(unique), (n, len(unique))
    print("reference check: OK (cluster count matches oracle)")

    clusters = miner.materialise(result, ctx.tuples)
    # rank by support (density × volume = triples covered), then density
    clusters.sort(key=lambda cd: (-cd[1] * np.prod(
        [len(c) for c in cd[0]]), -cd[1]))
    print("\ntop patterns (§5.2 format):")
    for comps, dens in clusters[:4]:
        print(PP.format_cluster(comps, names=ctx.names, density=dens))


if __name__ == "__main__":
    main()
