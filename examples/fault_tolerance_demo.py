"""Fault-tolerance demo: supervised training that survives a crash.

    PYTHONPATH=src python examples/fault_tolerance_demo.py

Launches the trainer under the Supervisor with an injected hard crash at
step 30; the supervisor restarts it, the trainer resumes from the last
atomic checkpoint, and the run completes. This is the paper's JobTracker
re-execution story at the worker granularity (DESIGN.md §8).
"""
import os
import sys
import tempfile
sys.path.insert(0, "src")

from repro.train.fault_tolerance import Supervisor


def main():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    with tempfile.TemporaryDirectory() as d:
        ckpt = os.path.join(d, "ckpt")
        hb = os.path.join(d, "heartbeat")
        base = [sys.executable, "-m", "repro.launch.train",
                "--arch", "qwen3-0.6b", "--smoke", "--steps", "60",
                "--global-batch", "4", "--seq", "64",
                "--ckpt-dir", ckpt, "--ckpt-every", "20",
                "--resume", "auto", "--heartbeat", hb,
                "--log-every", "10"]
        env = {"PYTHONPATH": os.path.join(root, "src")}
        # first attempt crashes at step 30; the restart must resume >= 20
        sup = Supervisor(base + ["--crash-at", "30"], heartbeat=hb,
                         heartbeat_timeout=120, max_restarts=0, env=env)
        rc = sup.run()
        assert rc != 0, "expected the injected crash"
        print("\n-- supervisor restart (no crash flag) --\n")
        sup = Supervisor(base, heartbeat=hb, heartbeat_timeout=120,
                         max_restarts=2, env=env)
        rc = sup.run()
        assert rc == 0, f"supervised run failed rc={rc}"
    print("fault_tolerance_demo: OK")


if __name__ == "__main__":
    main()
