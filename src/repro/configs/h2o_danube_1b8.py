"""h2o-danube-1.8b — llama/mistral-mix dense model with sliding window.

[arXiv:2401.16818; hf] 24 layers, d_model=2560, 32 heads (GQA kv=8,
head_dim=80), d_ff=6912, vocab=32000, sliding-window attention
(trained with window 4096 per the H2O-Danube report).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    head_dim=80,
    d_ff=6912,
    vocab_size=32000,
    window=4096,
    rope_theta=10_000.0,
    source="arXiv:2401.16818 (hf tier)",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="danube-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
        window=32, rope_theta=1e4)
