"""granite-3-8b — dense GQA. [hf:ibm-granite/granite-3.0-2b-base family; hf]

40 layers, d_model=4096, 32 heads (GQA kv=8, head_dim=128), d_ff=12800,
vocab=49155.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-8b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=12800,
    vocab_size=49155,
    rope_theta=10_000.0,
    source="hf:ibm-granite/granite-3.0-2b-base config family (hf tier)",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite3-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
        rope_theta=1e4)
