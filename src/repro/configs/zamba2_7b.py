"""zamba2-7b — Mamba2 backbone + one shared attention+MLP block.

[arXiv:2411.15242; unverified] 81 Mamba2 layers, d_model=3584; the single
shared full-attention+MLP block (Zamba weight-sharing scheme) is invoked
after every 6th Mamba2 layer. 32 heads (MHA: kv=32, head_dim=112),
d_ff=14336 for the shared MLP, vocab=32000, ssm_state=64.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid_ssm",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    attn_every=6,
    rope_theta=10_000.0,
    source="arXiv:2411.15242 (unverified tier)",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke", family="hybrid_ssm", n_layers=7, d_model=64,
        n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=256,
        ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_chunk=16,
        attn_every=3, rope_theta=10_000.0)
