"""qwen3-0.6b — dense GQA with QK-norm. [hf:Qwen/Qwen3-8B family; hf]

28 layers, d_model=1024, 16 heads (GQA kv=8) with explicit head_dim=128
(16×128=2048 ≠ 1024, Qwen3 decouples head width), d_ff=3072,
vocab=151936, per-head RMS QK-norm, tied embeddings.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab_size=151936,
    qk_norm=True,
    tie_embeddings=True,
    rope_theta=1e6,
    source="hf:Qwen/Qwen3-8B config family (hf tier)",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen3-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=32, d_ff=128, vocab_size=256,
        qk_norm=True, tie_embeddings=True, rope_theta=1e4)
