"""xlstm-125m — sLSTM + mLSTM blocks. [arXiv:2405.04517; unverified]

12 layers, d_model=768, 4 heads, vocab=50304, d_ff=0: feed-forward capacity
lives inside the LSTM blocks (mLSTM up-projection factor 2, sLSTM
gated-MLP factor 4/3, per the xLSTM paper). One sLSTM block every 4th
layer (positions 3, 7, 11), the rest mLSTM.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="xlstm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab_size=50304,
    slstm_every=4,
    mlstm_proj=2.0,
    slstm_proj=4.0 / 3.0,
    source="arXiv:2405.04517 (unverified tier)",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="xlstm-smoke", family="xlstm", n_layers=4, d_model=64,
        n_heads=2, n_kv_heads=2, head_dim=32, d_ff=0, vocab_size=256,
        slstm_every=2, mlstm_proj=2.0, slstm_proj=4.0 / 3.0)
