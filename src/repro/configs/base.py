"""Config system: model architectures, input shapes, mesh/runtime knobs.

Every assigned architecture is one ``ModelConfig`` in its own module
(``repro/configs/<id>.py``) with the exact dimensions from the brief and a
``smoke()`` reduced config of the same family for CPU tests. The shape
registry defines the four assigned input shapes; ``cells()`` enumerates the
(architecture × shape) dry-run grid with applicability rules (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid_ssm | xlstm | encdec
    modality: str = "text"         # text | audio | vision
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    norm_eps: float = 1e-5
    rope_theta: float = 1e6
    qk_norm: bool = False
    window: Optional[int] = None   # sliding-window attention width
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    moe_impl: str = "shard_map"    # shard_map (per-shard dispatch + psum)
                                   # | gspmd (partitioner-replicated baseline)
    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_width: int = 4
    # hybrid (Zamba2): one shared attention+MLP block every `attn_every`
    attn_every: int = 0
    # xLSTM
    slstm_every: int = 0           # sLSTM block period (others are mLSTM)
    mlstm_proj: float = 2.0
    slstm_proj: float = 4.0 / 3.0
    # encoder-decoder
    enc_layers: int = 0
    # modality frontend stub (precomputed features via input_specs())
    frontend: Optional[str] = None  # "fbank" | "patch"
    frontend_dim: int = 0
    frontend_len: int = 0
    # numerics / performance knobs (the hillclimb surface)
    dtype: str = "bfloat16"
    remat: str = "block"           # none | block
    use_pallas: bool = False       # True: Pallas kernels on the hot paths
    microbatch: int = 1            # grad-accumulation inside train_step
    logits_fp32: bool = True
    fsdp: bool = False             # shard params over data axis (ZeRO-3-ish)
    hier_allreduce: bool = False   # pod-hierarchical gradient reduction
    scan_layers: bool = True       # scan-over-layers (False: unrolled)
    attn_impl: str = "blocked"     # einsum | blocked | pallas (einsum = naive
                                   # baseline; blocked tiles q so 32k prefill
                                   # scores fit HBM; identical when s<=q_block)
    q_block: int = 2048            # blocked-attention query tile
    source: str = ""               # provenance note

    # -- derived -------------------------------------------------------------

    def padded_vocab(self, model_shards: int) -> int:
        mult = 128 * model_shards
        return -(-self.vocab_size // mult) * mult

    @property
    def d_inner(self) -> int:      # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def sub_quadratic(self) -> bool:
        """Supports 500k-token decode state (DESIGN.md §5): recurrent state
        or bounded sliding-window KV."""
        return (self.family in ("hybrid_ssm", "xlstm")
                or self.window is not None)

    @property
    def layer_pattern_period(self) -> int:
        """Periodicity of the block pattern (for scan grouping and the
        layer-cost accounting in unrolled analyses)."""
        if self.family == "hybrid_ssm" and self.attn_every:
            return self.attn_every
        if self.family == "xlstm" and self.slstm_every:
            return self.slstm_every
        return 1

    def n_params(self) -> int:
        """Exact parameter count from the model's declaration table."""
        from ..models.api import get_model
        from ..models.params import count_params
        return count_params(get_model(self).param_defs(self))

    def n_active_params(self) -> int:
        """Parameters touched per token (MoE experts scaled to top_k/E)."""
        from ..models.api import get_model
        from ..models.params import count_params, map_defs
        import numpy as np
        defs = get_model(self).param_defs(self)
        if not self.is_moe:
            return count_params(defs)
        total = count_params(defs)
        expert = 0
        for key in ("w_gate", "w_up", "w_down"):
            d = defs["layers"]["moe"][key]
            expert += int(np.prod(d.shape))
        return total - expert + expert * self.top_k // self.n_experts


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(runs?, reason-if-skip) per DESIGN.md §5."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "SKIP(full-attention): 512k dense KV has no sub-quadratic path"
    return True, ""
