"""mistral-nemo-12b — dense GQA, 128k context.

[hf:mistralai/Mistral-Nemo-Base-2407; hf] 40 layers, d_model=5120,
32 heads with explicit head_dim=128 (32×128=4096 ≠ 5120 by design),
GQA kv=8, d_ff=14336, vocab=131072, rope_theta=1e6 for 128k context.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1e6,
    source="hf:mistralai/Mistral-Nemo-Base-2407 (hf tier)",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="nemo-smoke", family="dense", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=24,  # head_dim ≠ d/h, like nemo
        d_ff=128, vocab_size=256, rope_theta=1e4)
