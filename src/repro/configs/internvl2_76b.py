"""internvl2-76b — InternViT frontend + InternLM2/Llama3-70B-class LLM.

[arXiv:2404.16821; unverified] 80-layer dense decoder, d_model=8192,
64 heads (GQA kv=8, head_dim=128), d_ff=28672, vocab=128256. The
InternViT frontend is a STUB per the brief: ``input_specs()`` provides
precomputed patch embeddings (B, 256, 3200); a linear adapter projects
them to d_model and they are prepended to the token sequence.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="dense",
    modality="vision",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    frontend="patch",
    frontend_dim=3200,    # InternViT-6B output width
    frontend_len=256,     # patch tokens per image
    rope_theta=5e5,
    source="arXiv:2404.16821 (unverified tier)",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="internvl-smoke", family="dense", modality="vision",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256, frontend="patch", frontend_dim=48,
        frontend_len=8, rope_theta=1e4)
