"""mixtral-8x7b — 8-expert top-2 MoE with sliding-window attention.

[arXiv:2401.04088; hf] 32 layers, d_model=4096, 32 heads (GQA kv=8,
head_dim=128), expert d_ff=14336, vocab=32000, SWA window 4096.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    n_experts=8,
    top_k=2,
    window=4096,
    rope_theta=1e6,
    source="arXiv:2401.04088 (hf tier)",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mixtral-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
        n_experts=4, top_k=2, window=32, rope_theta=1e4)
