"""granite-moe-3b-a800m — fine-grained MoE, 40 experts top-8.

[hf:ibm-granite/granite-3.0-1b-a400m-base; hf] 32 layers, d_model=1536,
24 heads (GQA kv=8, head_dim=64), expert d_ff=512, vocab=49155,
40 experts top-8 (the structured config line supersedes the free-text
"32 experts" — DESIGN.md §5).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    n_experts=40,
    top_k=8,
    tie_embeddings=True,
    rope_theta=10_000.0,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base (hf tier)",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-smoke", family="moe", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16, d_ff=32, vocab_size=256,
        n_experts=8, top_k=4, tie_embeddings=True, rope_theta=1e4)
