"""Architecture registry: ``--arch <id>`` resolution for every driver."""
from __future__ import annotations

from .base import SHAPES, ModelConfig, ShapeConfig, shape_applicable
from . import (granite_3_8b, granite_moe_3b, h2o_danube_1b8, internvl2_76b,
               mistral_nemo_12b, mixtral_8x7b, qwen3_0b6, seamless_m4t_l2,
               xlstm_125m, zamba2_7b)

_MODULES = {
    "zamba2-7b": zamba2_7b,
    "xlstm-125m": xlstm_125m,
    "mixtral-8x7b": mixtral_8x7b,
    "granite-moe-3b-a800m": granite_moe_3b,
    "mistral-nemo-12b": mistral_nemo_12b,
    "h2o-danube-1.8b": h2o_danube_1b8,
    "qwen3-0.6b": qwen3_0b6,
    "granite-3-8b": granite_3_8b,
    "seamless-m4t-large-v2": seamless_m4t_l2,
    "internvl2-76b": internvl2_76b,
}

ARCHS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCHS}")
    return _MODULES[arch].CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _MODULES[arch].smoke()


def cells():
    """All (arch, shape, runs, skip_reason) dry-run grid cells — 40 total."""
    out = []
    for arch in ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            runs, why = shape_applicable(cfg, shape)
            out.append((arch, shape.name, runs, why))
    return out


__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "ARCHS", "get_config",
           "get_smoke_config", "cells", "shape_applicable"]
