"""seamless-m4t-large-v2 — speech-encoder / text-decoder (enc-dec).

[arXiv:2308.11596; hf] 24 encoder + 24 decoder layers, d_model=1024,
16 heads (MHA: kv=16, head_dim=64), d_ff=8192, vocab=256206. The audio
frontend is a STUB per the brief: ``input_specs()`` provides precomputed
80-dim filterbank frames; a linear adapter embeds them (DESIGN.md §5).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    modality="audio",
    n_layers=24,          # decoder layers
    enc_layers=24,        # speech-encoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    frontend="fbank",
    frontend_dim=80,
    frontend_len=4096,    # encoder frames for decode-shape serving
    rope_theta=10_000.0,
    source="arXiv:2308.11596 (hf tier)",
)


def smoke() -> ModelConfig:
    return ModelConfig(
        name="seamless-smoke", family="encdec", modality="audio",
        n_layers=2, enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=256, frontend="fbank",
        frontend_dim=20, frontend_len=32, rope_theta=1e4)
