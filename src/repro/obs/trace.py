"""Cross-process trace spans for the serving plane (DESIGN.md §11).

A *span* is one timed unit of work — a router dispatch, one per-shard
attempt, a replica's handler — recorded on ``time.monotonic`` (duration
is exact within a process) plus a wall-clock start (rough cross-process
ordering).  Spans carry a **trace id** minted at the plane's edge and
propagated to backends via the :data:`TRACE_HEADER` HTTP header, so
the spans one logical request produced in the router process, the
shard writers and the replica readers stitch back together by that one
id — including the attempts that *failed*: retries, circuit-breaker
skips and degraded drops each leave a span with a ``status`` saying
why.

Header contract: ``X-Repro-Trace: <trace_id>/<parent_span_id>`` — both
lowercase hex, minted by :meth:`Tracer.new_id`.  A server receiving
the header adopts the trace id and records the parent span id; a
server receiving none mints a fresh trace (it is the edge).  The
header is advisory: a malformed value means "no trace", never an
error.

Each process keeps its spans in a bounded ring (old spans fall off;
tracing a long-running plane must not leak).  ``spans()`` filters by
trace id, ``export_jsonl()`` dumps the ring for offline stitching, and
the ``/debug/trace`` endpoints expose it over HTTP.  A disabled tracer
(:data:`NULL_TRACER`) hands every caller the same no-op span — the
hot-path cost of tracing-off is one attribute test.

The slow-query log rides on the same ids: a bounded
:class:`SlowQueryLog` keeps the N *slowest* requests past a threshold
with their trace id, shard coverage and queue-wait/handler split, so
"what was that 2-second query?" is answerable from ``/debug/slow``
without scraping every span.
"""
from __future__ import annotations

import collections
import heapq
import json
import os
import random
import threading
import time
from typing import List, Optional

__all__ = ["TRACE_HEADER", "Span", "Tracer", "SlowQueryLog",
           "NULL_TRACER", "parse_trace_header", "format_trace_header"]

#: the propagation header: ``<trace_id>/<parent_span_id>``
TRACE_HEADER = "X-Repro-Trace"

#: per-process id stream, seeded once from the OS entropy pool — ids
#: only need uniqueness, not unpredictability, and a PRNG draw is a
#: few times cheaper than an os.urandom syscall per span
_ids = random.Random(os.urandom(16))
_ids_lock = threading.Lock()


def format_trace_header(trace_id: str, span_id: str) -> str:
    return f"{trace_id}/{span_id}"


def parse_trace_header(value) -> tuple:
    """``(trace_id, parent_span_id)`` — ``(None, None)`` for a missing
    or malformed header (advisory: never raises)."""
    if not value or not isinstance(value, str):
        return None, None
    tid, _, pid = value.partition("/")
    tid, pid = tid.strip(), pid.strip()
    if not tid or not all(c in "0123456789abcdef" for c in tid):
        return None, None
    return tid, (pid or None)


class Span:
    """One open span; close it via the ``Tracer.span`` context manager
    (or :meth:`finish`).  ``set(k, v)`` attaches attributes (shard,
    attempt, endpoint, outcome...); ``error(msg)`` marks failure."""

    __slots__ = ("tracer", "name", "trace_id", "span_id", "parent_id",
                 "service", "start_wall", "_t0", "attrs", "status",
                 "dur_ms")

    def __init__(self, tracer: "Tracer", name: str, trace_id: str,
                 span_id: str, parent_id: Optional[str]):
        self.tracer = tracer
        self.name = name
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.service = tracer.service
        self.start_wall = time.time()
        self._t0 = time.monotonic()
        self.attrs: dict = {}
        self.status = "ok"
        self.dur_ms: Optional[float] = None

    def set(self, key: str, value) -> "Span":
        self.attrs[str(key)] = value
        return self

    def error(self, message: str) -> "Span":
        self.status = "error"
        self.attrs["error"] = str(message)
        return self

    def header(self) -> str:
        """Header value that makes downstream spans children of this
        one."""
        return format_trace_header(self.trace_id, self.span_id)

    def finish(self) -> None:
        # hot path: just stamp the duration and enqueue the object —
        # the dict view is materialised lazily at read time (spans())
        if self.dur_ms is None:
            self.dur_ms = (time.monotonic() - self._t0) * 1e3
            self.tracer._record(self)

    def to_dict(self) -> dict:
        return {"trace_id": self.trace_id, "span_id": self.span_id,
                "parent_id": self.parent_id, "name": self.name,
                "service": self.service, "status": self.status,
                "start_wall": self.start_wall, "dur_ms": self.dur_ms,
                "pid": os.getpid(), "attrs": dict(self.attrs)}


class _NullSpan:
    """Shared no-op span: same surface, nothing recorded, no ids."""

    __slots__ = ()
    trace_id = ""
    span_id = ""
    parent_id = None
    status = "ok"

    def set(self, key, value):
        return self

    def error(self, message):
        return self

    def header(self) -> Optional[str]:
        return None

    def finish(self) -> None:
        pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _SpanCtx:
    __slots__ = ("_span",)

    def __init__(self, span: Span):
        self._span = span

    def __enter__(self) -> Span:
        return self._span

    def __exit__(self, etype, evalue, tb) -> bool:
        if etype is not None and self._span.status == "ok":
            self._span.error(f"{etype.__name__}: {evalue}")
        self._span.finish()
        return False


class Tracer:
    """Per-process span factory + bounded ring."""

    def __init__(self, service: str = "", enabled: bool = True,
                 ring: int = 4096):
        self.service = str(service)
        self.enabled = bool(enabled)
        self._ring: collections.deque = collections.deque(
            maxlen=max(16, int(ring)))
        self._lock = threading.Lock()
        self.dropped = 0

    @staticmethod
    def new_id() -> str:
        with _ids_lock:
            return f"{_ids.getrandbits(64):016x}"

    def span(self, name: str, trace_id: Optional[str] = None,
             parent_id: Optional[str] = None, **attrs):
        """Context manager yielding a :class:`Span` (no-op span when
        disabled).  Without an explicit ``trace_id`` a fresh trace is
        minted — this span is the trace's edge/root."""
        if not self.enabled:
            return _NULL_SPAN
        sp = Span(self, str(name),
                  trace_id if trace_id else self.new_id(),
                  self.new_id(), parent_id)
        if attrs:
            sp.attrs.update(attrs)
        return _SpanCtx(sp)

    def start(self, name: str, trace_id: Optional[str] = None,
              parent_id: Optional[str] = None, **attrs):
        """Manual-finish variant (handlers that reply before closing
        the span); returns the no-op span when disabled."""
        if not self.enabled:
            return _NULL_SPAN
        sp = Span(self, str(name),
                  trace_id if trace_id else self.new_id(),
                  self.new_id(), parent_id)
        if attrs:
            sp.attrs.update(attrs)
        return sp

    def _record(self, span: Span) -> None:
        with self._lock:
            if len(self._ring) == self._ring.maxlen:
                self.dropped += 1
            self._ring.append(span)

    # -- views ----------------------------------------------------------------

    def spans(self, trace_id: Optional[str] = None,
              limit: int = 0) -> List[dict]:
        """Finished spans, oldest first; filtered by ``trace_id`` when
        given, tail-truncated to ``limit`` when > 0."""
        with self._lock:
            out = list(self._ring)
        if trace_id:
            out = [s for s in out if s.trace_id == trace_id]
        if limit > 0:
            out = out[-int(limit):]
        return [s.to_dict() for s in out]

    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()

    def export_jsonl(self, path: str,
                     trace_id: Optional[str] = None) -> int:
        """Append the (filtered) ring as JSON lines; returns the number
        of spans written."""
        spans = self.spans(trace_id)
        with open(path, "a", encoding="utf-8") as fh:
            for s in spans:
                fh.write(json.dumps(s) + "\n")
        return len(spans)


#: shared disabled tracer
NULL_TRACER = Tracer(enabled=False, ring=16)


class SlowQueryLog:
    """Bounded record of the ``keep`` slowest requests at or above
    ``threshold_ms``: a min-heap keyed by total latency, so a new slow
    query evicts the *least* slow of the kept set.  Disabled when
    ``threshold_ms < 0``."""

    def __init__(self, threshold_ms: float = 100.0, keep: int = 32):
        self.threshold_ms = float(threshold_ms)
        self.keep = max(1, int(keep))
        self._lock = threading.Lock()
        self._heap: list = []           # (total_ms, seq, record)
        self._seq = 0
        self.recorded = 0

    @property
    def enabled(self) -> bool:
        return self.threshold_ms >= 0

    def record(self, endpoint: str, total_ms: float, *,
               handler_ms: Optional[float] = None,
               wait_ms: Optional[float] = None,
               trace_id: str = "", coverage=None,
               detail: Optional[dict] = None) -> bool:
        """Consider one finished request; returns True when kept."""
        if not self.enabled or total_ms < self.threshold_ms:
            return False
        rec = {"endpoint": str(endpoint), "total_ms": float(total_ms),
               "handler_ms": (None if handler_ms is None
                              else float(handler_ms)),
               "wait_ms": None if wait_ms is None else float(wait_ms),
               "trace_id": str(trace_id), "wall": time.time()}
        if coverage is not None:
            rec["coverage"] = [int(s) for s in coverage]
        if detail:
            rec.update(detail)
        with self._lock:
            self.recorded += 1
            self._seq += 1
            item = (float(total_ms), self._seq, rec)
            if len(self._heap) < self.keep:
                heapq.heappush(self._heap, item)
                return True
            if total_ms > self._heap[0][0]:
                heapq.heapreplace(self._heap, item)
                return True
        return False

    def entries(self) -> List[dict]:
        """Kept records, slowest first."""
        with self._lock:
            items = sorted(self._heap, reverse=True)
        return [rec for _, _, rec in items]

    def stats(self) -> dict:
        with self._lock:
            return {"threshold_ms": self.threshold_ms,
                    "keep": self.keep, "kept": len(self._heap),
                    "recorded": self.recorded}
