"""Process-local metrics registry (DESIGN.md §11).

One :class:`Registry` per process holds every instrument the serving
plane and the mining pipeline report into: **counters** (monotone),
**gauges** (set/add), and **log-bucketed histograms** whose quantiles
(p50/p99) are derived from geometric buckets with a bounded relative
error — ``bucket_ratio ** 0.5 - 1`` (≈ 4.4% at the default ratio of
``2 ** (1/8)``), tight enough to audit tail latency without keeping
raw samples.

Design constraints, in order:

1. **Hot-path cheapness.**  A histogram observation is one lock, one
   ``math.log`` and two integer adds; a counter bump is one lock and
   one add.  Instrument handles are cached by ``(name, labels)`` so
   steady-state callers never re-enter the registry dict.
2. **Zero overhead when disabled.**  A registry built with
   ``enabled=False`` (or the shared :data:`NULL`) hands every caller
   the same no-op instrument — the disabled path is attribute access
   plus one ``if``; nothing is allocated, counted or locked.  Code
   that wants even the attribute access gone holds ``None`` and guards
   with ``is None`` (the convention the mining pipeline uses).
3. **One source of truth.**  Components that already keep counter
   dicts (``TriclusterService._stats``, supervisor event tallies)
   register a *collector* — a callable returning ``(name, labels,
   value)`` rows rendered at scrape time — instead of double-writing.
   /stats keeps reading the dicts; /metrics renders them; nothing is
   stored twice.

Exposition is Prometheus text format 0.0.4 (``expose()``); the same
data is available structurally via ``to_dict()`` for /stats-style JSON
views and tests.
"""
from __future__ import annotations

import math
import threading
from typing import Callable, Dict, Iterable, List, Optional, Tuple

__all__ = ["Registry", "Counter", "Gauge", "Histogram", "NULL",
           "NullInstrument", "DEFAULT_BUCKET_RATIO"]

#: geometric bucket growth factor: 2 ** (1/8) keeps the worst-case
#: quantile relative error at sqrt(ratio) - 1 ≈ 4.4%
DEFAULT_BUCKET_RATIO = 2.0 ** 0.125
#: default bucket span: [lo, hi) in whatever unit the caller observes
#: (the serving plane observes milliseconds: 1 µs .. 100 s)
DEFAULT_LO = 1e-3
DEFAULT_HI = 1e5


def _label_key(labels: dict) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _label_str(items: Tuple[Tuple[str, str], ...]) -> str:
    if not items:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in items) + "}"


class NullInstrument:
    """Shared no-op stand-in for every instrument kind on a disabled
    registry: all mutators do nothing, all readers answer zero."""

    __slots__ = ()

    def inc(self, n: float = 1.0) -> None:
        pass

    def add(self, n: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    @property
    def value(self) -> float:
        return 0.0

    @property
    def count(self) -> int:
        return 0

    @property
    def sum(self) -> float:
        return 0.0

    def quantile(self, q: float) -> Optional[float]:
        return None

    def percentiles(self) -> dict:
        return {"p50": None, "p99": None}


_NULL_INSTRUMENT = NullInstrument()


class Counter:
    """Monotone counter."""

    __slots__ = ("_lock", "_value")

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError("counters only go up")
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Gauge:
    """Set/add instantaneous value."""

    __slots__ = ("_lock", "_value")

    def __init__(self):
        self._lock = threading.Lock()
        self._value = 0.0

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def add(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    inc = add

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Histogram:
    """Log-bucketed histogram: geometric bucket boundaries
    ``lo * ratio**i`` spanning ``[lo, hi)``, plus an underflow bucket
    (everything ``<= lo``, including zeros/negatives) and an overflow
    bucket (``>= hi``).  Tracks exact count/sum/min/max alongside the
    bucket counts, so :meth:`quantile` can clamp its bucket-midpoint
    estimate to the observed range — the p0/p100 ends are exact, the
    middle has relative error ≤ ``sqrt(ratio) - 1``."""

    __slots__ = ("_lock", "lo", "hi", "ratio", "_log_ratio", "_log_lo",
                 "_n_buckets", "_counts", "_count", "_sum", "_min",
                 "_max")

    def __init__(self, lo: float = DEFAULT_LO, hi: float = DEFAULT_HI,
                 ratio: float = DEFAULT_BUCKET_RATIO):
        if not (lo > 0 and hi > lo and ratio > 1.0):
            raise ValueError("need 0 < lo < hi and ratio > 1")
        self._lock = threading.Lock()
        self.lo, self.hi, self.ratio = float(lo), float(hi), float(ratio)
        self._log_ratio = math.log(self.ratio)
        self._log_lo = math.log(self.lo)
        # bucket i covers (lo * r**(i-1), lo * r**i]; bucket 0 is the
        # underflow (<= lo), the last is the overflow (> hi)
        self._n_buckets = int(math.ceil(
            (math.log(self.hi) - self._log_lo) / self._log_ratio)) + 2
        self._counts = [0] * self._n_buckets
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf

    def _index(self, v: float) -> int:
        if v <= self.lo:
            return 0
        if v >= self.hi:
            return self._n_buckets - 1
        i = int(math.ceil((math.log(v) - self._log_lo)
                          / self._log_ratio))
        return min(max(i, 1), self._n_buckets - 2)

    def observe(self, v: float) -> None:
        v = float(v)
        i = self._index(v)
        with self._lock:
            self._counts[i] += 1
            self._count += 1
            self._sum += v
            if v < self._min:
                self._min = v
            if v > self._max:
                self._max = v

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def _upper(self, i: int) -> float:
        """Upper bound of bucket ``i`` (inf for the overflow bucket)."""
        if i <= 0:
            return self.lo
        if i >= self._n_buckets - 1:
            return math.inf
        return self.lo * self.ratio ** i

    def _mid(self, i: int) -> float:
        """Representative value of bucket ``i``: geometric midpoint of
        its bounds (underflow → lo, overflow → observed max)."""
        if i <= 0:
            return self.lo
        if i >= self._n_buckets - 1:
            return self._max if self._max > 0 else self.hi
        hi = self.lo * self.ratio ** i
        return hi / math.sqrt(self.ratio)

    def quantile(self, q: float) -> Optional[float]:
        """Bucket-derived q-quantile (0 ≤ q ≤ 1), clamped to the exact
        observed [min, max]; None when empty."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        with self._lock:
            n = self._count
            if n == 0:
                return None
            rank = q * (n - 1)
            acc = 0
            est = self._max
            for i, c in enumerate(self._counts):
                acc += c
                if acc > rank:
                    est = self._mid(i)
                    break
            return min(max(est, self._min), self._max)

    def percentiles(self) -> dict:
        return {"p50": self.quantile(0.50), "p99": self.quantile(0.99)}

    def snapshot(self) -> dict:
        """Structural view: cumulative Prometheus-style buckets plus
        exact count/sum/min/max and derived p50/p99."""
        with self._lock:
            counts = list(self._counts)
            count, total = self._count, self._sum
            mn = None if count == 0 else self._min
            mx = None if count == 0 else self._max
        cum, buckets = 0, []
        for i, c in enumerate(counts):
            cum += c
            if c or i == len(counts) - 1:
                buckets.append((self._upper(i), cum))
        return {"count": count, "sum": total, "min": mn, "max": mx,
                "buckets": buckets,
                "p50": self.quantile(0.50), "p99": self.quantile(0.99)}


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class Registry:
    """Thread-safe instrument registry with Prometheus text exposition.

    ``counter(name, **labels)`` / ``gauge(...)`` / ``histogram(...)``
    return (and memoise) the instrument for that ``(name, labels)``
    series.  A name is bound to one kind forever — asking for the same
    name as a different kind raises.  ``register_collector(fn)`` adds a
    scrape-time callable yielding ``(name, labels_dict, value)`` rows
    (rendered as gauges) — the bridge that folds existing stats dicts
    into /metrics without double-writing them.
    """

    def __init__(self, enabled: bool = True, namespace: str = "repro"):
        self.enabled = bool(enabled)
        self.namespace = str(namespace)
        self._lock = threading.Lock()
        self._kinds: Dict[str, str] = {}
        self._series: Dict[str, Dict[Tuple, object]] = {}
        self._collectors: List[Callable[[], Iterable[tuple]]] = []

    # -- instrument access ----------------------------------------------------

    def _get(self, kind: str, name: str, labels: dict, **kw):
        if not self.enabled:
            return _NULL_INSTRUMENT
        key = _label_key(labels)
        with self._lock:
            have = self._kinds.get(name)
            if have is None:
                self._kinds[name] = kind
                self._series[name] = {}
            elif have != kind:
                raise ValueError(f"metric {name!r} is a {have}, "
                                 f"not a {kind}")
            inst = self._series[name].get(key)
            if inst is None:
                inst = _KINDS[kind](**kw)
                self._series[name][key] = inst
            return inst

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", name, labels)

    def histogram(self, name: str, lo: float = DEFAULT_LO,
                  hi: float = DEFAULT_HI,
                  ratio: float = DEFAULT_BUCKET_RATIO,
                  **labels) -> Histogram:
        return self._get("histogram", name, labels, lo=lo, hi=hi,
                         ratio=ratio)

    def register_collector(self,
                           fn: Callable[[], Iterable[tuple]]) -> None:
        """``fn()`` yields ``(name, labels_dict, value)`` rows at scrape
        time; non-numeric values are skipped.  No-op when disabled."""
        if not self.enabled:
            return
        with self._lock:
            self._collectors.append(fn)

    # -- views ----------------------------------------------------------------

    def _items(self):
        with self._lock:
            return [(name, self._kinds[name], dict(series))
                    for name, series in sorted(self._series.items())]

    def _collected(self) -> List[tuple]:
        with self._lock:
            collectors = list(self._collectors)
        rows: List[tuple] = []
        for fn in collectors:
            try:
                for name, labels, value in fn():
                    if isinstance(value, bool):
                        value = int(value)
                    if not isinstance(value, (int, float)) or \
                            not math.isfinite(value):
                        continue
                    rows.append((str(name), dict(labels), float(value)))
            except Exception:    # noqa: BLE001 — a broken stats dict
                continue         # must not take down the scrape
        return rows

    def expose(self) -> str:
        """Prometheus text format 0.0.4."""
        ns = self.namespace + "_" if self.namespace else ""
        out: List[str] = []
        for name, kind, series in self._items():
            full = ns + name
            out.append(f"# TYPE {full} {kind}")
            for key, inst in sorted(series.items()):
                ls = _label_str(key)
                if kind == "histogram":
                    snap = inst.snapshot()
                    items = list(key)
                    for ub, cum in snap["buckets"]:
                        le = "+Inf" if math.isinf(ub) else repr(ub)
                        lab = _label_str(tuple(items + [("le", le)]))
                        out.append(f"{full}_bucket{lab} {cum}")
                    out.append(f"{full}_sum{ls} {snap['sum']!r}")
                    out.append(f"{full}_count{ls} {snap['count']}")
                else:
                    out.append(f"{full}{ls} {inst.value!r}")
        seen_types = set()
        for name, labels, value in sorted(
                self._collected(), key=lambda r: (r[0], sorted(r[1].items()))):
            full = ns + name
            if full not in seen_types:
                seen_types.add(full)
                out.append(f"# TYPE {full} gauge")
            out.append(f"{full}{_label_str(_label_key(labels))} "
                       f"{value!r}")
        return "\n".join(out) + ("\n" if out else "")

    def to_dict(self) -> dict:
        """Structural JSON-friendly view (the /stats-side rendering):
        ``{name: {kind, series: [{labels, ...payload}]}}``."""
        doc: Dict[str, dict] = {}
        for name, kind, series in self._items():
            rows = []
            for key, inst in sorted(series.items()):
                row = {"labels": dict(key)}
                if kind == "histogram":
                    snap = inst.snapshot()
                    snap.pop("buckets")
                    row.update(snap)
                else:
                    row["value"] = inst.value
                rows.append(row)
            doc[name] = {"kind": kind, "series": rows}
        for name, labels, value in self._collected():
            ent = doc.setdefault(name, {"kind": "gauge", "series": []})
            ent["series"].append({"labels": labels, "value": value})
        return doc

    def sample_count(self) -> int:
        """Total observations/bumps recorded across every native
        instrument (collectors excluded) — the disabled-path assertion
        surface for tests."""
        n = 0
        for _, kind, series in self._items():
            for inst in series.values():
                n += inst.count if kind == "histogram" else 1
        return n


#: shared disabled registry: every instrument it hands out is the
#: same no-op singleton
NULL = Registry(enabled=False)
