"""Unified observability plane (DESIGN.md §11).

Three instruments, one hub:

* :class:`~repro.obs.metrics.Registry` — process-local counters /
  gauges / log-bucketed histograms with Prometheus text exposition
  (``/metrics``) and scrape-time *collectors* that fold existing stats
  dicts in without double-writing.
* :class:`~repro.obs.trace.Tracer` — structured spans with trace-id
  propagation over the :data:`~repro.obs.trace.TRACE_HEADER` HTTP
  header, kept in a bounded ring (``/debug/trace``).
* :class:`~repro.obs.trace.SlowQueryLog` — the N slowest requests
  with trace id, coverage and queue-wait/handler split
  (``/debug/slow``).

:class:`Obs` bundles the three for threading through the serving
plane; ``Obs.create(...)`` builds an enabled hub, :data:`NULL_OBS` is
the shared disabled hub whose instruments are all no-ops — passing
``obs=None`` anywhere means :data:`NULL_OBS`, and the enabled check is
one attribute test.
"""
from __future__ import annotations

from typing import Optional

from .metrics import (DEFAULT_BUCKET_RATIO, NULL, Counter, Gauge,
                      Histogram, NullInstrument, Registry)
from .trace import (NULL_TRACER, TRACE_HEADER, SlowQueryLog, Span,
                    Tracer, format_trace_header, parse_trace_header)

__all__ = [
    "Obs", "NULL_OBS",
    "Registry", "Counter", "Gauge", "Histogram", "NullInstrument",
    "NULL", "DEFAULT_BUCKET_RATIO",
    "Tracer", "Span", "SlowQueryLog", "TRACE_HEADER", "NULL_TRACER",
    "parse_trace_header", "format_trace_header",
]


class Obs:
    """One process's observability hub: ``metrics`` (Registry),
    ``tracer`` (Tracer) and ``slow`` (SlowQueryLog), plus the
    ``enabled`` flag hot paths test."""

    __slots__ = ("enabled", "metrics", "tracer", "slow", "service")

    def __init__(self, metrics: Registry, tracer: Tracer,
                 slow: SlowQueryLog, enabled: bool = True,
                 service: str = ""):
        self.enabled = bool(enabled)
        self.metrics = metrics
        self.tracer = tracer
        self.slow = slow
        self.service = str(service)

    @staticmethod
    def create(service: str = "", slow_query_ms: float = 100.0,
               slow_keep: int = 32, ring: int = 4096,
               namespace: str = "repro") -> "Obs":
        return Obs(Registry(enabled=True, namespace=namespace),
                   Tracer(service=service, enabled=True, ring=ring),
                   SlowQueryLog(threshold_ms=slow_query_ms,
                                keep=slow_keep),
                   enabled=True, service=service)

    @staticmethod
    def disabled() -> "Obs":
        return NULL_OBS

    def describe(self) -> dict:
        return {"enabled": self.enabled, "service": self.service,
                "spans": len(self.tracer),
                "slow": self.slow.stats() if self.enabled else None}


#: the shared disabled hub — ``obs or NULL_OBS`` is the idiom
NULL_OBS = Obs(NULL, NULL_TRACER, SlowQueryLog(threshold_ms=-1.0),
               enabled=False, service="")


def coalesce(obs: Optional[Obs]) -> Obs:
    """``obs`` or the shared disabled hub."""
    return NULL_OBS if obs is None else obs
