"""Fault tolerance: supervised training with heartbeat watchdog
(DESIGN.md §8).

At cluster scale the unit of failure is a worker process/node. The
``Supervisor`` runs the trainer as a subprocess and implements the
JobTracker semantics the paper leans on (§1, §4.1):

* **crash** → restart from the newest valid checkpoint (the trainer's
  ``--resume auto``), up to ``max_restarts`` times;
* **straggler / hang** → a worker that stops writing its heartbeat for
  ``heartbeat_timeout`` seconds is killed and restarted — the speculative
  re-execution analogue (idempotent steps + atomic checkpoints make
  re-execution safe, exactly the paper's at-least-once argument for
  duplicated M/R tuples);
* restarts are *elastic*: the restarted process may see a different device
  count; checkpoint restore re-shards (see checkpoints.py).

The heartbeat is a file the trainer touches every step — cheap, works over
shared filesystems, and survives the supervisor itself restarting.
"""
from __future__ import annotations

import dataclasses
import os
import signal
import subprocess
import sys
import time
from typing import Optional, Sequence


def beat(path: str, step: int = 0):
    """Touch the heartbeat file (called by the trainer every step)."""
    with open(path, "w") as f:
        f.write(f"{step} {time.time()}\n")


def last_beat(path: str) -> Optional[float]:
    try:
        return os.stat(path).st_mtime
    except OSError:
        return None


@dataclasses.dataclass
class Supervisor:
    argv: Sequence[str]                 # trainer command line
    heartbeat: str                      # heartbeat file path
    heartbeat_timeout: float = 60.0
    max_restarts: int = 3
    grace_period: float = 30.0          # startup slack before watching
    poll_interval: float = 0.5
    env: Optional[dict] = None

    def run(self) -> int:
        """Supervise until clean exit (rc 0) or restart budget exhausted.
        Returns the final return code."""
        restarts = 0
        while True:
            if os.path.exists(self.heartbeat):
                os.unlink(self.heartbeat)
            proc = subprocess.Popen(
                list(self.argv),
                env={**os.environ, **(self.env or {})})
            started = time.time()
            rc = None
            killed_for = None
            while True:
                rc = proc.poll()
                if rc is not None:
                    break
                hb = last_beat(self.heartbeat)
                ref = hb if hb is not None else started
                slack = (self.grace_period if hb is None
                         else self.heartbeat_timeout)
                if time.time() - ref > slack:
                    killed_for = "heartbeat timeout (straggler/hang)"
                    proc.send_signal(signal.SIGKILL)
                    proc.wait()
                    rc = -9
                    break
                time.sleep(self.poll_interval)
            if rc == 0:
                return 0
            restarts += 1
            reason = killed_for or f"crash rc={rc}"
            print(f"[supervisor] worker died ({reason}); "
                  f"restart {restarts}/{self.max_restarts}",
                  file=sys.stderr, flush=True)
            if restarts > self.max_restarts:
                print("[supervisor] restart budget exhausted",
                      file=sys.stderr, flush=True)
                return rc if rc else 1
