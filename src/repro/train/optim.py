"""Hand-rolled AdamW with ZeRO-1 state sharding.

Master parameters and both moments live in fp32 and are additionally
sharded over the data axes (ZeRO-1): ``zero1_shardings`` extends each
parameter's tensor-parallel spec with ``("pod","data")`` on the first
dimension that divides. Under jit, constraining gradients to that layout
makes GSPMD emit a reduce-scatter instead of a full all-reduce, and the
bf16 cast back to the unsharded-over-data layout is the ZeRO-1 all-gather
— the classic overlap-friendly decomposition.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..models.params import map_defs
from ..sharding.rules import MeshRules


def zero1_spec(base: P, shape: tuple, rules: MeshRules) -> P:
    """Extend ``base`` with the data axes on the first divisible free dim."""
    data_axes = tuple(a for a in ("pod", "data")
                      if a in rules.mesh.axis_names)
    if not data_axes:
        return base
    dsize = int(np.prod([rules.mesh.shape[a] for a in data_axes]))
    entries = list(base) + [None] * (len(shape) - len(base))
    used = set()
    for e in entries:
        if e is None:
            continue
        used.update(e if isinstance(e, tuple) else (e,))
    if any(a in used for a in data_axes):
        return base
    for i, e in enumerate(entries):
        if e is None and shape[i] % dsize == 0:
            entries[i] = data_axes if len(data_axes) > 1 else data_axes[0]
            while entries and entries[-1] is None:
                entries.pop()
            return P(*entries)
    return base  # nothing divides: stay TP-sharded/replicated


def zero1_shardings(defs, rules: MeshRules):
    """NamedSharding tree for master params / moments (ZeRO-1 layout)."""
    def one(d):
        base = rules.spec(d.axes, d.shape)
        return NamedSharding(rules.mesh, zero1_spec(base, d.shape, rules))
    return map_defs(one, defs)


def adamw_init(params):
    return {
        "m": jax.tree.map(jnp.zeros_like, params),
        "v": jax.tree.map(jnp.zeros_like, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(params, grads, opt, lr, *, b1: float = 0.9,
                 b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1,
                 grad_clip: Optional[float] = 1.0):
    """One AdamW step on fp32 master params. Returns (params', opt')."""
    step = opt["step"] + 1
    if grad_clip is not None:
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    else:
        gnorm = jnp.zeros(())
    t = step.astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        new_p = p - lr * (mh / (jnp.sqrt(vh) + eps) + weight_decay * p)
        return new_p, m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt["m"])
    flat_v = jax.tree.leaves(opt["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_opt = {"m": jax.tree.unflatten(tdef, [o[1] for o in out]),
               "v": jax.tree.unflatten(tdef, [o[2] for o in out]),
               "step": step}
    return new_params, new_opt, gnorm


def cosine_lr(step, *, peak: float, warmup: int, total: int,
              floor_frac: float = 0.1):
    """Linear warmup then cosine decay to floor_frac·peak."""
    s = step.astype(jnp.float32)
    warm = peak * s / jnp.maximum(warmup, 1)
    prog = jnp.clip((s - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = peak * (floor_frac + (1 - floor_frac)
                  * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(s < warmup, warm, cos)
