"""Checkpointing: atomic, hash-verified, async, elastic (DESIGN.md §8).

Layout of one checkpoint::

    <dir>/ckpt_0000123/
        MANIFEST.json     # tree paths, shapes, dtypes, sha1, user metadata
        <leaf-path>.npy   # one file per leaf, full logical array

Writes go to ``ckpt_0000123.tmp`` and are renamed only after every leaf and
the manifest hit disk — a crash mid-save leaves the previous checkpoint
intact (the M/R analogue: task re-execution never corrupts committed
output). Restore re-shards onto *whatever mesh is alive*: leaves are loaded
as host arrays and ``jax.device_put`` against the target sharding tree, so
save on 8 devices / restore on 4 or 16 works (elastic re-scale).

At real multi-pod scale each host would write only its addressable shards
(per-host files keyed by shard index) — the manifest format already carries
the logical shape + sharding rule needed to reassemble; this single-host
repro gathers full arrays instead, which is the only layout difference.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np

_CKPT_RE = re.compile(r"^ckpt_(\d+)$")


def _flatten(tree) -> dict:
    """{'a/b/0': leaf} with deterministic, filesystem-safe keys."""
    flat = {}

    def walk(node, path):
        if isinstance(node, dict):
            for k in sorted(node):
                walk(node[k], path + (str(k),))
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(v, path + (str(i),))
        else:
            flat["/".join(path)] = node

    walk(tree, ())
    return flat


def _unflatten_into(template, flat: dict):
    """Rebuild a tree shaped like ``template`` from the flat dict."""
    def walk(node, path):
        if isinstance(node, dict):
            return {k: walk(v, path + (str(k),)) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            t = [walk(v, path + (str(i),)) for i, v in enumerate(node)]
            return type(node)(t)
        return flat["/".join(path)]

    return walk(template, ())


def _sha1(arr: np.ndarray) -> str:
    return hashlib.sha1(np.ascontiguousarray(arr).view(np.uint8)).hexdigest()


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep: int = 3
    verify_hashes: bool = True

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- enumeration ---------------------------------------------------------

    def steps(self) -> list:
        out = []
        for name in os.listdir(self.directory):
            m = _CKPT_RE.match(name)
            if m and os.path.exists(os.path.join(self.directory, name,
                                                 "MANIFEST.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    def _path(self, step: int, tmp: bool = False) -> str:
        return os.path.join(self.directory,
                            f"ckpt_{step:07d}" + (".tmp" if tmp else ""))

    # -- save ----------------------------------------------------------------

    def save(self, step: int, tree, metadata: Optional[dict] = None,
             block: bool = True):
        """Write checkpoint for ``step``. With ``block=False`` the disk I/O
        runs on a background thread (device→host transfer still happens
        here, so the step's arrays are snapshotted consistently)."""
        self.wait()
        flat = _flatten(tree)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}

        def write():
            tmp = self._path(step, tmp=True)
            final = self._path(step)
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            manifest = {"step": step, "metadata": metadata or {},
                        "leaves": {}}
            for key, arr in host.items():
                fname = key.replace("/", "__") + ".npy"
                np.save(os.path.join(tmp, fname), arr)
                manifest["leaves"][key] = {
                    "file": fname, "shape": list(arr.shape),
                    "dtype": str(arr.dtype), "sha1": _sha1(arr)}
            with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)        # the atomic commit point
            self._gc()

        if block:
            write()
        else:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            raise self._error

    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._path(s), ignore_errors=True)

    # -- restore -------------------------------------------------------------

    def restore(self, step: Optional[int] = None, template=None,
                shardings=None) -> tuple[int, Any]:
        """Load a checkpoint. ``template`` (any tree of the right structure)
        rebuilds nesting; ``shardings`` (tree of NamedSharding / None)
        re-shards every leaf onto the current mesh — elastic restore."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        path = self._path(step)
        with open(os.path.join(path, "MANIFEST.json")) as f:
            manifest = json.load(f)
        flat = {}
        for key, info in manifest["leaves"].items():
            arr = np.load(os.path.join(path, info["file"]))
            if self.verify_hashes and _sha1(arr) != info["sha1"]:
                raise IOError(f"checkpoint corruption in {key} "
                              f"(sha1 mismatch) at {path}")
            flat[key] = arr
        if template is None:
            tree = _nest(flat)
        else:
            tree = _unflatten_into(template, flat)
        if shardings is not None:
            tree = jax.tree.map(
                lambda a, s: jax.device_put(a, s) if s is not None else a,
                tree, shardings)
        return step, tree

    def metadata(self, step: Optional[int] = None) -> dict:
        if step is None:
            step = self.latest_step()
        with open(os.path.join(self._path(step), "MANIFEST.json")) as f:
            return json.load(f)["metadata"]


def _nest(flat: dict):
    """Rebuild a pure-dict tree from flat 'a/b/c' keys."""
    root: dict = {}
    for key, val in flat.items():
        node = root
        parts = key.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return root
