"""Train-step builder: bf16 compute / fp32 master, grad accumulation,
ZeRO-1 sharded optimizer, optional gradient compression, remat.

The returned ``train_step(state, batch)`` is a single jit-able function
whose input/output shardings are fully pinned — the same function object
is what the multi-pod dry-run lowers.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models.api import get_model
from ..models.params import param_shardings
from ..sharding.rules import MeshRules
from .optim import adamw_init, adamw_update, cosine_lr, zero1_shardings


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    weight_decay: float = 0.1
    grad_clip: Optional[float] = 1.0
    grad_compress: bool = False    # bf16 gradients on the wire
    zero1: bool = True             # shard master/m/v over data axes


def init_train_state(cfg: ModelConfig, key, dtype=jnp.float32):
    """Master params (fp32) + AdamW moments + data cursor."""
    model = get_model(cfg)
    params = model.init(cfg, key, dtype)
    return {"params": params, "opt": adamw_init(params),
            "data_step": jnp.zeros((), jnp.int32)}


def state_shardings(cfg: ModelConfig, rules: MeshRules, tc: TrainConfig):
    """Sharding tree matching init_train_state's structure."""
    model = get_model(cfg)
    defs = model.param_defs(cfg)
    p_shard = (zero1_shardings(defs, rules) if tc.zero1
               else param_shardings(defs, rules))
    from jax.sharding import NamedSharding, PartitionSpec
    scalar = NamedSharding(rules.mesh, PartitionSpec())
    return {"params": p_shard,
            "opt": {"m": p_shard, "v": p_shard, "step": scalar},
            "data_step": scalar}


def state_structs(cfg: ModelConfig, rules: MeshRules,
                  tc: TrainConfig = TrainConfig()):
    """Sharded ShapeDtypeStructs matching ``init_train_state`` — the
    dry-run's stand-in for the training state (no allocation)."""
    model = get_model(cfg)
    defs = model.param_defs(cfg)
    from ..models.params import map_defs
    pshapes = map_defs(
        lambda d: jax.ShapeDtypeStruct(d.shape, jnp.float32), defs)
    scalar = jax.ShapeDtypeStruct((), jnp.int32)
    state = {"params": pshapes,
             "opt": {"m": pshapes, "v": pshapes, "step": scalar},
             "data_step": scalar}
    shard = state_shardings(cfg, rules, tc)
    return jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        state, shard)


def _split_microbatches(batch, n: int):
    return jax.tree.map(
        lambda a: a.reshape(n, a.shape[0] // n, *a.shape[1:]), batch)


def _cast_with_grad_layout(compute_sharding, grad_sharding, compute_dtype,
                           wire_dtype):
    """fp32 master -> compute-dtype TP-layout param whose *cotangent* is
    immediately resharded to the ZeRO-1 layout in ``wire_dtype``.

    Forward: the ZeRO-1 all-gather (cast + TP constraint). Backward: the
    cotangent of a DP-replicated param is an unreduced per-shard sum;
    constraining it to the data-sharded layout makes GSPMD emit a
    reduce-scatter *inside the backward pass* — the full TP-layout fp32
    gradient tree never materialises (§Perf iteration T1: 4·P/TP bytes of
    transient grads -> P/(TP·DP) resident). ``wire_dtype`` controls the
    reduction precision on the wire (bf16 = gradient compression)."""
    @jax.custom_vjp
    def f(p):
        return jax.lax.with_sharding_constraint(
            p.astype(compute_dtype), compute_sharding)

    def fwd(p):
        return f(p), None

    def bwd(_, g):
        g = jax.lax.with_sharding_constraint(
            g.astype(wire_dtype), grad_sharding)
        return (g.astype(jnp.float32),)

    f.defvjp(fwd, bwd)
    return f


def make_train_step(cfg: ModelConfig, rules: MeshRules,
                    tc: TrainConfig = TrainConfig()):
    model = get_model(cfg)
    defs = model.param_defs(cfg)
    compute_shard = param_shardings(defs, rules)   # TP layout, DP-replicated
    grad_shard = (zero1_shardings(defs, rules) if tc.zero1
                  else compute_shard)
    compute_dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    wire_dtype = jnp.bfloat16 if tc.grad_compress else jnp.float32

    def train_step(state, batch):
        master = state["params"]

        def loss_of(p_master, mb):
            # gather-on-use + grad-layout control (see _cast_with_grad_layout)
            cast = jax.tree.map(
                lambda p, cs, gs: _cast_with_grad_layout(
                    cs, gs, compute_dtype, wire_dtype)(p),
                p_master, compute_shard, grad_shard)
            loss, metrics = model.loss(cfg, cast, mb, rules)
            return loss, metrics

        grad_fn = jax.value_and_grad(loss_of, has_aux=True)

        if cfg.microbatch > 1:
            mbs = _split_microbatches(batch, cfg.microbatch)

            def acc_body(carry, mb):
                gsum, lsum = carry
                (loss, metrics), g = grad_fn(master, mb)
                gsum = jax.tree.map(lambda a, b: a + b, gsum, g)
                return (gsum, lsum + loss), metrics

            g0 = jax.tree.map(
                lambda p, s: jax.lax.with_sharding_constraint(
                    jnp.zeros(p.shape, jnp.float32), s),
                master, grad_shard)
            (gsum, lsum), metrics = jax.lax.scan(
                acc_body, (g0, jnp.zeros(())), mbs)
            grads = jax.tree.map(lambda g: g / cfg.microbatch, gsum)
            loss = lsum / cfg.microbatch
            metrics = jax.tree.map(lambda a: a[-1], metrics)
        else:
            (loss, metrics), grads = grad_fn(master, batch)

        # pin the final layout (no-op when the vjp already delivered it)
        grads = jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s),
            grads, grad_shard)

        lr = cosine_lr(state["opt"]["step"], peak=tc.peak_lr,
                       warmup=tc.warmup_steps, total=tc.total_steps)
        new_params, new_opt, gnorm = adamw_update(
            master, grads, state["opt"], lr, b1=tc.b1, b2=tc.b2,
            weight_decay=tc.weight_decay, grad_clip=tc.grad_clip)
        new_state = {"params": new_params, "opt": new_opt,
                     "data_step": state["data_step"] + 1}
        out_metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr,
                       **metrics}
        return new_state, out_metrics

    return train_step


def jit_train_step(cfg: ModelConfig, rules: MeshRules,
                   tc: TrainConfig = TrainConfig()):
    """jit with pinned state shardings (donated) — the production step."""
    step = make_train_step(cfg, rules, tc)
    shard = state_shardings(cfg, rules, tc)
    return jax.jit(step, in_shardings=(shard, None),
                   out_shardings=(shard, None), donate_argnums=(0,))
