from .optim import adamw_init, adamw_update, zero1_shardings
from .step import TrainConfig, make_train_step, init_train_state

__all__ = ["adamw_init", "adamw_update", "zero1_shardings", "TrainConfig",
           "make_train_step", "init_train_state"]
