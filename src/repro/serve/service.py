"""Online cluster-serving service: snapshot-swapped queries over live
streams (DESIGN.md §8).

The paper stops at the mined result set; this module keeps serving it
while the stream keeps mutating.  A :class:`TriclusterService` owns one
streaming-capable miner (``core.streaming.StreamingMiner`` by default,
or an incremental ``core.distributed.DistributedMiner`` whose
``serving_snapshot`` returns the windowed full-table result) and splits
the world into two paths that never contend:

* **writer path** — ``add`` / ``upsert`` / ``delete`` apply to the
  miner's run store under the writer lock and mark the service dirty.
  Writes are cheap (host-side chunk sort into a new run); they block on
  an in-flight re-mine, never on readers.
* **reader path** — queries read one reference, the *current snapshot*:
  an immutable ``(PipelineResult, ClusterIndex, BatchQuerier, version)``
  bundle.  Publication is a single reference swap, so a reader either
  sees the whole previous snapshot or the whole next one — never a torn
  index — and never takes a lock, so queries never block on mining.

A background thread re-mines on a configurable cadence/dirty-threshold:
when ``dirty >= dirty_threshold`` writes have accumulated, or a write is
older than ``refresh_interval`` seconds, it snapshots the miner (the
incremental merged-run path — only changed chunks were ever sorted),
builds the index + ranking arrays *outside* the reader path, and swaps.

**Versions and freshness.**  Every published snapshot carries
``version`` (publish counter, strictly increasing) and
``stream_version`` (the miner's write counter it covers — the snapshot
versioning hooks in ``core.streaming`` / ``core.distributed``).  Reads
take a freshness mode: ``latest`` (default — whatever is published now,
non-blocking) or ``at_least_version=v`` (block up to ``timeout`` until
``version >= v``; the read-your-writes primitive: upsert, ``refresh()``,
then demand the returned version).

**Recency.**  The service remembers the version that first published
each cluster signature; per-cluster ages feed the ranking layer's
recency term, so freshly emerged clusters can be boosted without any
per-cluster timestamps in the mining pipeline.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
import zlib
from typing import Any, List, Optional, Sequence, Tuple

import numpy as np

from ..core import runs as RS
from ..obs import NULL_OBS
from . import ranking as R
from .clusters import ClusterIndex, ClusterView, pack_sig_words


@dataclasses.dataclass(frozen=True)
class Snapshot:
    """One immutable published state; everything a query touches."""
    version: int              # publish counter (1-based, monotonic)
    stream_version: int       # miner writes covered by this snapshot
    result: Any               # the engine's PipelineResult (None on a
                              # shared-memory replica — queries never
                              # touch it)
    index: ClusterIndex
    querier: R.BatchQuerier   # ranked scalar/batch lookups + signatures
    ages: np.ndarray          # per-cluster age in versions (recency)
    published_at: float       # time.monotonic() at swap
    published_wall: float = 0.0   # time.time() at swap — cross-process
                                  # staleness (/health staleness_s)


@dataclasses.dataclass(frozen=True)
class QueryResult:
    """Hits plus the exact snapshot identity they were answered from."""
    version: int
    stream_version: int
    hits: Any      # [(ClusterView, score)] — or one such list per entity


def snapshot_query(snap: Snapshot, entity: Optional[int] = None,
                   mode: Optional[int] = None,
                   signature: Optional[Tuple[int, int]] = None,
                   k: int = 10) -> List[Tuple[ClusterView, float]]:
    """Ranked lookup against one snapshot — the query logic shared by
    the in-process service and shared-memory replica readers
    (``serve.shm.ReplicaService``), so both answer bit-identically.

    ``signature=(lo, hi)``: exact resolution (≤ 1 hit, score attached).
    ``entity=e [, mode=m]``: top-``k`` by the snapshot's scores.
    Neither: the snapshot's global top-``k``."""
    if signature is not None:
        row = int(snap.querier.lookup_signatures([signature])[0])
        hits: List[Tuple[ClusterView, float]] = []
        if row >= 0:
            view = snap.index.view_at(row)
            if entity is None or view.contains(int(entity), mode):
                hits = [(view, float(snap.querier.scores[row]))]
        return hits
    if entity is not None:
        return snap.querier.topk(int(entity), mode, k)
    return R.top_from_scores(snap.index, snap.querier.scores, k)


def snapshot_query_batch(snap: Snapshot, entities,
                         mode: Optional[int] = None, k: int = 10):
    """Batched twin of :func:`snapshot_query` (one stacked-window
    pass; ``hits[i]`` equals the scalar hits for ``entities[i]``)."""
    return snap.querier.topk_batch(entities, mode, k)


class TriclusterService:
    """Long-lived serving front-end over one streaming-capable miner.

    Lifecycle: construct, ``add`` initial data, ``start()`` (publishes
    the first snapshot synchronously and starts the re-mine thread),
    serve, ``stop()``.  Usable as a context manager.
    """

    def __init__(self, sizes: Sequence[int], *, backend: str = "streaming",
                 theta: float = 0.0, delta: Optional[float] = None,
                 rho_min: float = 0.0, minsup: int = 0, seed: int = 0x5EED,
                 refresh_interval: float = 0.25, dirty_threshold: int = 64,
                 policy: R.RankingPolicy = R.DEFAULT_POLICY,
                 min_density: float = 0.0, recency_horizon: int = 512,
                 delta_index: bool = True, publisher=None,
                 recover_dir: Optional[str] = None,
                 checkpoint_every: int = 64, fsync_wal: bool = False,
                 version_base: int = 0, fault=None,
                 scrub_interval: float = 0.5,
                 event_dir: Optional[str] = None,
                 event_name: str = "writer",
                 obs=None,
                 mesh=None, miner=None, **miner_kw):
        self.sizes = tuple(int(s) for s in sizes)
        self.refresh_interval = float(refresh_interval)
        self.dirty_threshold = max(1, int(dirty_threshold))
        #: delta-maintain the ClusterIndex across swaps (diff by packed
        #: signature, splice only dirty clusters — serve.clusters);
        #: False forces a full ``from_result`` rebuild every swap (the
        #: oracle / benchmark baseline)
        self.delta_index = bool(delta_index)
        #: optional ``serve.shm.ShmPublisher`` — every published
        #: snapshot is mirrored into shared memory for replica readers
        self.publisher = publisher
        #: versions a vanished signature keeps its first-seen record;
        #: past it the record is evicted (bounded memory on churning
        #: streams) and a re-emerging cluster counts as fresh again
        self.recency_horizon = max(1, int(recency_horizon))
        self.policy = policy
        self.min_density = float(min_density)
        if miner is not None:
            self.miner = miner
        elif backend == "streaming":
            from ..core.streaming import StreamingMiner
            self.miner = StreamingMiner(self.sizes, theta=theta, delta=delta,
                                        rho_min=rho_min, minsup=minsup,
                                        seed=seed, **miner_kw)
        elif backend == "distributed":
            from ..core.distributed import DistributedMiner
            if mesh is None:
                from ..launch.mesh import make_local_mesh
                mesh = make_local_mesh()
            self.miner = DistributedMiner(self.sizes, mesh, theta=theta,
                                          delta=delta, rho_min=rho_min,
                                          minsup=minsup, seed=seed,
                                          **miner_kw)
        else:
            raise ValueError(f"backend must be 'streaming' or "
                             f"'distributed', got {backend!r}")
        # the distributed serving path needs the windowed full-table
        # result; the streaming snapshot already is one
        self._mine = getattr(self.miner, "serving_snapshot",
                             getattr(self.miner, "snapshot"))
        # per-snapshot dirty-signature sets (core.streaming /
        # core.distributed): surfaces the delta-index workload as the
        # ``dirty_clusters`` backlog in stats//health
        if hasattr(self.miner, "track_dirty_sigs"):
            self.miner.track_dirty_sigs = True
        self._ingest = getattr(self.miner, "ingest", None) or self.miner.add
        #: fault injector (``serve.faults``) — fires the ``write`` site
        #: with every new stream version; shared with the publisher's
        #: ``publish``/``torn`` sites unless it carries its own
        self._fault = fault
        if (fault is not None and publisher is not None
                and getattr(publisher, "fault", None) is None):
            publisher.fault = fault
        #: publish-version floor: the first published snapshot gets
        #: ``version_base + 1``, so a restarted writer's versions (and
        #: the read-your-writes tokens minted before the crash) stay
        #: monotone across the restart
        self.version_base = max(0, int(version_base))
        #: durable recovery (``recover_dir``): every write is appended
        #: to a WAL *before* it is applied; on publish cadence the run
        #: store's checkpoint blob is persisted (atomic replace) and the
        #: WAL truncated to the tail it does not cover.  Construction
        #: with an existing recover_dir restores + replays (see
        #: :meth:`_recover`).
        self.recover_dir = recover_dir
        self.checkpoint_every = max(1, int(checkpoint_every))
        self.fsync_wal = bool(fsync_wal)
        self._wal = None
        self._writes_since_ckpt = 0
        self._recovered = {}
        #: background scrubber cadence (s); 0 disables the thread.  The
        #: scrubber walks each newly published snapshot verifying
        #: cross-structure invariants (see :meth:`scrub`)
        self.scrub_interval = float(scrub_interval)
        self._scrub_thread: Optional[threading.Thread] = None
        #: integrity events are mirrored to ``{event_dir}/{event_name}
        #: .events`` for the supervisor to adopt into its log
        #: (``serve.supervise.write_event``); None keeps them local
        self.event_dir = event_dir
        self.event_name = event_name
        self._wlock = threading.Lock()      # miner store + dirty counter
        self._remine_lock = threading.Lock()  # one re-mine at a time
        self._cv = threading.Condition()    # snapshot publication + waits
        self._snap: Optional[Snapshot] = None
        self._dirty = 0
        self._first_seen: dict = {}   # signature -> [first_v, last_seen_v]
        self._last_mine = 0.0
        self._stop_evt = threading.Event()
        self._wake = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._stats = {"writes": 0, "publishes": 0, "mine_errors": 0,
                       "last_mine_ms": 0.0, "total_mine_ms": 0.0,
                       "delta_builds": 0, "full_builds": 0,
                       "last_index_build_ms": 0.0, "publish_errors": 0,
                       "checkpoints": 0, "wal_records": 0,
                       "recovered_ops": 0,
                       # integrity plane (DESIGN.md §9, fail-silent half)
                       "wal_crc_errors": 0, "wal_torn_tail": 0,
                       "wal_quarantined": 0, "checkpoint_quarantined": 0,
                       "checkpoint_generation_fallbacks": 0,
                       "scrubs": 0, "scrub_errors": 0,
                       "last_scrub_ms": 0.0, "last_scrub_version": 0,
                       "scrub_violations": []}
        #: observability hub (DESIGN.md §11): swap-path timings land in
        #: its histograms, and ``_stats`` is folded into /metrics via a
        #: scrape-time collector — the dict stays the single source,
        #: the registry renders it
        self.obs = obs if obs is not None else NULL_OBS
        if self.obs.enabled:
            self.obs.metrics.register_collector(self._collect_metrics)
            # per-stage pipeline profiling rides the same hub (the
            # miner's hook is duck-typed; see core.pipeline)
            if hasattr(self.miner, "obs"):
                self.miner.obs = self.obs
        if self.recover_dir:
            self._recover()

    # -- durable recovery (checkpoint + WAL) ---------------------------------

    @property
    def _ckpt_path(self) -> str:
        return os.path.join(self.recover_dir, "ckpt.npz")

    @property
    def _ckpt_prev_path(self) -> str:
        # previous checkpoint generation (N=2 policy): rotated into
        # place right before a new blob is persisted, so a corrupt or
        # torn current generation always has a verified fallback
        return os.path.join(self.recover_dir, "ckpt.prev.npz")

    @property
    def _wal_path(self) -> str:
        return os.path.join(self.recover_dir, "wal.jsonl")

    def _quarantine(self, path: str) -> str:
        """Move a poisoned file aside as ``{path}.quarantine.<epoch>``
        (never clobbering an earlier quarantine) and return the new
        path — the evidence survives for post-mortem, and recovery
        never re-reads it."""
        epoch = int(time.time())
        q = f"{path}.quarantine.{epoch}"
        n = 0
        while os.path.exists(q):
            n += 1
            q = f"{path}.quarantine.{epoch}.{n}"
        os.replace(path, q)
        return q

    def _integrity_event(self, event: str, detail: str) -> None:
        """Record a corruption/scrub event locally and mirror it to the
        supervisor's event log when this writer runs supervised."""
        self._stats.setdefault("integrity_events", []).append(
            [event, detail])
        if self.event_dir:
            try:
                from .supervise import write_event
                write_event(self.event_dir, self.event_name, event,
                            detail)
            except Exception:   # noqa: BLE001 — reporting must never
                pass            # take the data path down

    def _wal_append(self, op: str, rows, values, sv: int) -> None:
        if self._wal is None:
            self._wal = open(self._wal_path, "a", encoding="utf-8")
        rec = {"op": op, "rows": np.asarray(rows).tolist(), "sv": int(sv)}
        if values is not None:
            rec["values"] = np.asarray(values, np.float64).tolist()
        payload = json.dumps(rec)
        crc = zlib.crc32(payload.encode("utf-8"))
        if self._fault is not None:
            f = self._fault.corrupt("wal", int(sv))
            if f is not None:
                # injected bit rot *after* the CRC was taken: the
                # in-memory apply proceeds untouched, only replay-time
                # verification can tell this record is a lie
                i = len(payload) // 2
                payload = (payload[:i] + chr(ord(payload[i]) ^ 0x01)
                           + payload[i + 1:])
        self._wal.write(f"{crc:08x} {payload}\n")
        self._wal.flush()
        if self.fsync_wal:
            os.fsync(self._wal.fileno())
        self._stats["wal_records"] += 1

    def _checkpoint_locked(self, version: int) -> bool:
        """Persist the run store (atomic, CRC-framed) and truncate the
        WAL to the uncovered tail; the prior blob is rotated to the
        previous generation first.  Caller holds ``_wlock``.  Returns
        False when the miner has no checkpointable run store (then the
        WAL alone carries the whole stream — recovery replays from
        op 1)."""
        state = getattr(self.miner, "state", None)
        if not isinstance(state, RS.RunStore):
            return False
        sv = int(self.miner.stream_version)
        if os.path.exists(self._ckpt_path):
            os.replace(self._ckpt_path, self._ckpt_prev_path)
        RS.save_checkpoint(state.checkpoint(), self._ckpt_path,
                           meta={"stream_version": sv,
                                 "version": int(version)})
        if self._fault is not None:
            f = self._fault.corrupt("checkpoint", int(version))
            if f is not None:
                # injected truncation of the just-persisted blob: the
                # frame header survives but promises more bytes than
                # the file holds — load must reject, recovery must
                # fall back to the rotated previous generation
                size = os.path.getsize(self._ckpt_path)
                with open(self._ckpt_path, "r+b") as fh:
                    fh.truncate(max(1, size // 2))
        # the checkpoint covers every op ≤ sv: start a fresh WAL
        if self._wal is not None:
            self._wal.close()
            self._wal = None
        with open(self._wal_path, "w", encoding="utf-8"):
            pass
        self._writes_since_ckpt = 0
        self._stats["checkpoints"] += 1
        return True

    def final_checkpoint(self) -> bool:
        """Graceful-shutdown hook: persist the store so the next boot
        restores instead of replaying (no-op without a recover_dir)."""
        if not self.recover_dir:
            return False
        with self._wlock:
            return self._checkpoint_locked(self.version)

    @staticmethod
    def _parse_wal_line(raw: bytes) -> Optional[dict]:
        """One WAL line → its record, or ``None`` when the frame fails
        verification (bit rot / torn write).  Framed lines are
        ``crc32-hex SP json``; legacy unframed JSON lines verify by
        parse alone."""
        try:
            s = raw.decode("utf-8")
        except UnicodeDecodeError:
            return None
        if len(s) > 9 and s[8] == " ":
            try:
                crc = int(s[:8], 16)
            except ValueError:
                crc = None
            if crc is not None:
                payload = s[9:]
                if zlib.crc32(payload.encode("utf-8")) != crc:
                    return None
                try:
                    return json.loads(payload)
                except json.JSONDecodeError:
                    return None
        if s.lstrip().startswith("{"):
            try:
                return json.loads(s)
            except json.JSONDecodeError:
                return None
        return None

    def _recover(self) -> None:
        """Restore the store from the newest *verified* checkpoint
        generation, replay the verified WAL prefix through the miner,
        and floor the publish version — the crashed predecessor's
        writes and read-your-writes tokens survive into this
        incarnation.

        Corruption handling (DESIGN.md §9): a checkpoint generation
        that fails its CRC frame is quarantined and recovery falls back
        to the previous generation (bounding data loss to the ops
        between the two).  A WAL whose *last* record fails is torn —
        truncate to the verified prefix and resume in place.  A WAL
        with verified records *after* a failed one is poisoned — the
        ordering across the lost record is unknowable, so the whole
        file is quarantined, the verified prefix replayed, and a fresh
        checkpoint cut so the prefix stays durable."""
        os.makedirs(self.recover_dir, exist_ok=True)
        ckpt_sv = 0
        ckpt_gen = ""
        for path, gen in ((self._ckpt_path, "current"),
                          (self._ckpt_prev_path, "previous")):
            if not os.path.exists(path):
                continue
            try:
                blob, meta = RS.load_checkpoint(path)
                store = RS.RunStore.restore(blob)
            except Exception as e:  # noqa: BLE001 — CRC frame, torn
                # zip, or un-restorable blob: all poison this
                # generation; quarantine it and fall back
                q = self._quarantine(path)
                self._stats["checkpoint_quarantined"] += 1
                self._integrity_event(
                    "checkpoint_quarantined",
                    f"{gen} generation unreadable ({e!r}); "
                    f"-> {os.path.basename(q)}")
                continue
            self.miner.state = store
            ckpt_sv = int(meta.get("stream_version", 0))
            ckpt_gen = gen
            self.miner.stream_version = ckpt_sv
            self.version_base = max(self.version_base,
                                    int(meta.get("version", 0)))
            # re-adopt plans/stats (and validate) through the miner
            if hasattr(self.miner, "_store"):
                self.miner._store()
            break
        if ckpt_gen == "previous":
            self._stats["checkpoint_generation_fallbacks"] += 1
            self._integrity_event(
                "checkpoint_generation_fallback",
                f"restored previous generation at sv={ckpt_sv}")
        replayed = 0
        wal_quarantined = ""
        if os.path.exists(self._wal_path):
            with open(self._wal_path, "rb") as f:
                raw = f.read()
            entries: List[Tuple[int, bytes]] = []
            off = 0
            for ln in raw.split(b"\n"):
                entries.append((off, ln))
                off += len(ln) + 1
            recs: List[Tuple[int, dict]] = []
            bad: List[Tuple[int, int]] = []      # (line no, byte offset)
            for i, (o, ln) in enumerate(entries):
                if not ln.strip():
                    continue
                rec = self._parse_wal_line(ln)
                if rec is None:
                    bad.append((i, o))
                else:
                    recs.append((i, rec))
            cut = len(entries)
            if bad:
                first_bad, bad_off = bad[0]
                self._stats["wal_crc_errors"] += len(bad)
                cut = first_bad
                if any(i > first_bad for i, _ in recs):
                    # interior poison: verified records beyond the rot
                    # exist, but their ordering against the lost op is
                    # unknowable — quarantine the whole file, keep the
                    # verified prefix
                    wal_quarantined = self._quarantine(self._wal_path)
                    self._stats["wal_quarantined"] += 1
                    self._integrity_event(
                        "wal_quarantined",
                        f"interior record corrupt at line "
                        f"{first_bad + 1}; -> "
                        f"{os.path.basename(wal_quarantined)}")
                else:
                    # torn tail: the crash interrupted the last append;
                    # drop the half-record, resume appending in place
                    self._stats["wal_torn_tail"] += 1
                    with open(self._wal_path, "r+b") as f:
                        f.truncate(bad_off)
                    self._integrity_event(
                        "wal_torn_tail",
                        f"truncated to {bad_off} bytes "
                        f"(line {first_bad + 1} torn)")
            for i, rec in recs:
                if i >= cut:
                    continue
                if int(rec.get("sv", 0)) <= ckpt_sv:
                    continue
                rows = np.asarray(rec["rows"])
                vals = rec.get("values")
                op = rec.get("op", "add")
                if op == "delete":
                    self.miner.delete(rows)
                elif op == "upsert":
                    self.miner.upsert(rows, vals)
                else:
                    self._ingest(rows, vals)
                # replay lands exactly at the logged version even
                # if an op maps to a different number of bumps
                self.miner.stream_version = int(rec["sv"])
                replayed += 1
        self._stats["recovered_ops"] = replayed
        if wal_quarantined:
            # the quarantined file no longer backs the replayed prefix:
            # cut a checkpoint now so those ops survive the next crash
            try:
                with self._wlock:
                    self._checkpoint_locked(self.version_base)
            except Exception as e:  # noqa: BLE001 — recovery proceeds;
                # worst case the prefix replays again from older state
                self._stats["checkpoint_errors"] = \
                    self._stats.get("checkpoint_errors", 0) + 1
                self._stats["last_checkpoint_error"] = repr(e)
        if (ckpt_sv or replayed or wal_quarantined
                or self._stats["checkpoint_quarantined"]):
            if ckpt_sv or replayed:
                self._dirty = 1              # force a publish on start()
            self._recovered = {
                "checkpoint_stream_version": ckpt_sv,
                "checkpoint_generation": ckpt_gen or "none",
                "replayed_ops": replayed,
                "stream_version": self.miner.stream_version,
                "version_base": self.version_base,
                "wal_crc_errors": self._stats["wal_crc_errors"],
                "wal_torn_tail": self._stats["wal_torn_tail"],
                "wal_quarantined": (os.path.basename(wal_quarantined)
                                    if wal_quarantined else ""),
                "checkpoint_quarantined":
                    self._stats["checkpoint_quarantined"]}

    # -- background scrubber (integrity plane) -------------------------------

    def scrub(self, snap: Optional[Snapshot] = None) -> dict:
        """Walk one published snapshot verifying the cross-structure
        invariants that tie index, result, ranking and store together
        (DESIGN.md §9): the index carries exactly ``result.keep``'s
        signatures, packed signatures are sorted, the overlay lut is a
        consistent bijection over live rows, run keys are monotone, and
        every score/age is finite.  Violations mean a structure was
        mutated after publish (or built from corrupt inputs) — they are
        recorded in stats and flip ``scrub_clean`` so ``/health`` goes
        503 and the balancer stops routing here."""
        snap = self._snap if snap is None else snap
        if snap is None:
            return {"version": 0, "violations": [], "ms": 0.0}
        t0 = time.perf_counter()
        v: List[str] = []
        idx = snap.index
        ps = getattr(idx, "packed_sigs", None)
        if ps is not None and ps.size > 1 and not bool(
                np.all(ps[:-1] <= ps[1:])):
            v.append("index packed_sigs not sorted")
        res = snap.result
        if res is not None and ps is not None:
            keep = np.asarray(res.keep, bool)
            if self.min_density:
                keep = keep & (np.asarray(res.density)
                               >= self.min_density)
            want = np.sort(pack_sig_words(
                np.asarray(res.sig_lo)[keep],
                np.asarray(res.sig_hi)[keep]))
            if want.size != ps.size or not bool(np.array_equal(want,
                                                               ps)):
                v.append(f"index/result divergence: index carries "
                         f"{ps.size} signatures, result.keep "
                         f"{want.size} (or contents differ)")
        lut = getattr(idx, "_lut", None)
        if lut is not None and len(idx):
            id_of_row = getattr(idx, "_id_of_row", None)
            live = lut[lut >= 0]
            if live.size != len(idx) or not bool(np.array_equal(
                    np.sort(live), np.arange(len(idx)))):
                v.append("overlay lut is not a bijection onto rows")
            elif id_of_row is not None and not bool(np.array_equal(
                    lut[id_of_row], np.arange(len(idx)))):
                v.append("overlay lut/id_of_row not inverse")
        sc = getattr(snap.querier, "scores", None)
        if sc is not None and not bool(np.all(np.isfinite(sc))):
            v.append("non-finite ranking scores")
        if snap.ages is not None and not bool(
                np.all(np.isfinite(np.asarray(snap.ages)))):
            v.append("non-finite cluster ages")
        state = getattr(self.miner, "state", None)
        if isinstance(state, RS.RunStore):
            with self._wlock:
                runs = list(state.runs)
            for r in runs:
                if any(k.size > 1 and not bool(np.all(k[:-1] <= k[1:]))
                       for k in r.keys):
                    v.append("run store: sorted-run keys not monotone")
                    break
        ms = (time.perf_counter() - t0) * 1e3
        self._stats["scrubs"] += 1
        self._stats["last_scrub_ms"] = ms
        self._stats["last_scrub_version"] = snap.version
        if self.obs.enabled:
            self.obs.metrics.histogram("service_scrub_ms").observe(ms)
        if v:
            self._stats["scrub_errors"] += len(v)
            self._stats["scrub_violations"] = v   # rebind, never mutate
            for msg in v:
                self._integrity_event("scrub_violation",
                                      f"v{snap.version}: {msg}")
        return {"version": snap.version, "violations": v, "ms": ms}

    def _scrub_loop(self):
        last = -1
        while not self._stop_evt.is_set():
            snap = self._snap
            if snap is not None and snap.version != last:
                try:
                    self.scrub(snap)
                    last = snap.version
                except Exception as e:  # noqa: BLE001 — the scrubber
                    # must survive anything; a scrub crash is itself
                    # recorded, never fatal
                    self._stats["scrub_errors"] += 1
                    self._stats["last_scrub_error"] = repr(e)
                    last = snap.version
            self._stop_evt.wait(max(self.scrub_interval, 1e-3))

    @property
    def scrub_clean(self) -> bool:
        """False once the scrubber found an invariant violation — the
        /health 503 condition for silent corruption."""
        return not self._stats["scrub_violations"]

    def resilience_stats(self) -> dict:
        """Integrity/recovery counters: the scrubber + quarantine
        surface (mirrors the router's ``resilience_stats`` contract)."""
        s = self._stats
        return {k: s[k] for k in (
            "scrubs", "scrub_errors", "last_scrub_ms",
            "last_scrub_version", "scrub_violations", "wal_crc_errors",
            "wal_torn_tail", "wal_quarantined",
            "checkpoint_quarantined",
            "checkpoint_generation_fallbacks")}

    # -- writer path ---------------------------------------------------------

    def _write(self, op, rows, values=None, name: str = "add") -> int:
        with self._wlock:
            if self.recover_dir:
                # write-ahead: the record is durable before the store
                # mutates, so a crash at any later point replays it
                self._wal_append(name, rows, values,
                                 self.miner.stream_version + 1)
                self._writes_since_ckpt += 1
            if values is None:
                op(rows)
            else:
                op(rows, values)
            self._dirty += 1
            self._stats["writes"] += 1
            v = self.miner.stream_version
        self._wake.set()
        if self.publisher is not None:
            try:                       # advisory backlog slot (no swap)
                self.publisher.update_dirty(self._dirty)
            except Exception:          # noqa: BLE001 — never fail a write
                pass
        if self._fault is not None:
            self._fault.fire("write", v)
        return v

    def add(self, rows, values=None) -> int:
        """Append a chunk; returns the miner's new stream_version."""
        return self._write(self._ingest, rows, values, name="add")

    def upsert(self, rows, values=None) -> int:
        return self._write(self.miner.upsert, rows, values, name="upsert")

    def delete(self, rows) -> int:
        return self._write(self.miner.delete, rows, name="delete")

    @property
    def recovered(self) -> dict:
        """Recovery summary when this service restored a predecessor's
        checkpoint/WAL at construction; empty on a fresh boot."""
        return dict(self._recovered)

    @property
    def dirty(self) -> int:
        """Writes not yet covered by the published snapshot."""
        return self._dirty

    @property
    def stream_version(self) -> int:
        return self.miner.stream_version

    @property
    def version(self) -> int:
        """Version of the currently published snapshot (0: none yet)."""
        snap = self._snap
        return 0 if snap is None else snap.version

    @property
    def dirty_clusters(self) -> int:
        """Clusters whose signature changed at the last snapshot (the
        miner's per-snapshot dirty-signature set — the delta-index
        workload)."""
        return int(getattr(self.miner, "last_dirty_sigs", 0))

    def staleness_s(self) -> float:
        """Seconds since the current snapshot was published (inf before
        the first publish) — the /health freshness signal."""
        snap = self._snap
        if snap is None:
            return float("inf")
        return max(0.0, time.monotonic() - snap.published_at)

    @property
    def thread_alive(self) -> bool:
        """False only when the re-mine thread was started and died (it
        is written to survive exceptions, so death means something
        catastrophic) — the /health 503 condition."""
        if not getattr(self, "_started", False) or self._stop_evt.is_set():
            return True
        t = self._thread
        return t is not None and t.is_alive()

    def stats(self) -> dict:
        out = dict(self._stats)
        snap = self._snap
        out.update(version=self.version, dirty=self._dirty,
                   stream_version=self.miner.stream_version,
                   clusters=0 if snap is None else len(snap.index),
                   dirty_clusters=self.dirty_clusters,
                   staleness_s=self.staleness_s(),
                   thread_alive=self.thread_alive,
                   sizes=list(self.sizes))
        if self._recovered:
            out["recovered"] = dict(self._recovered)
        return out

    def _collect_metrics(self):
        """Scrape-time collector: every numeric ``stats()`` entry as a
        ``service_<key>{role=...}`` gauge — /stats and /metrics render
        the same counters from the same dict."""
        role = "replica" if getattr(self, "read_only", False) \
            else "writer"
        for k, val in self.stats().items():
            yield f"service_{k}", {"role": role}, val

    # -- mining / publication ------------------------------------------------

    def refresh(self) -> Snapshot:
        """Synchronously mine + publish a new snapshot (even when clean:
        an explicit refresh always advances the version, giving callers
        a version number that provably covers their writes)."""
        return self._remine(force=True)

    def _remine(self, force: bool = False) -> Snapshot:
        with self._remine_lock:
            snap = self._snap
            if not force and snap is not None and self._dirty == 0:
                return snap
            t0 = time.perf_counter()
            # no-op span when tracing is off; covers the whole swap
            sp = self.obs.tracer.start("service.swap")
            with self._wlock:
                # the store mutates under snapshot() (compaction/merge):
                # writers hold off while we mine, readers don't care
                covered = self.miner.stream_version
                result = self._mine()
                np.asarray(result.keep)      # block: leave jit-land here
                self._dirty = 0
            mine_ms = (time.perf_counter() - t0) * 1e3
            # index + ranking build off the writer path: writes land
            # freely while we stack windows host-side.  Delta path: diff
            # against the previous snapshot's index by packed signature
            # and splice only dirty clusters — O(changed), the
            # swap-critical-path optimisation; full from_result stays
            # the oracle (and the fallback for the first snapshot)
            t1 = time.perf_counter()
            prev = self._snap
            if (self.delta_index and prev is not None
                    and prev.index.supports_delta):
                index = ClusterIndex.delta_from_result(
                    prev.index, result, min_density=self.min_density)
                self._stats["delta_builds"] += 1
                build_kind = "delta"
            else:
                index = ClusterIndex.from_result(
                    result, min_density=self.min_density)
                self._stats["full_builds"] += 1
                build_kind = "full"
            build_ms = (time.perf_counter() - t1) * 1e3
            self._stats["last_index_build_ms"] = build_ms
            version = (self.version_base if self._snap is None
                       else self._snap.version) + 1
            fs = self._first_seen
            ages = []
            # signature keys straight off the stats arrays — this loop
            # must not force the index's lazy view list (that would
            # re-introduce the O(clusters) build the delta path removed)
            for sig in index.signature_keys():
                rec = fs.get(sig)
                if rec is None:
                    fs[sig] = rec = [version, version]
                else:
                    rec[1] = version
                ages.append(version - rec[0])
            ages = np.asarray(ages, np.float64)
            # evict first-seen records of long-vanished signatures
            # (sweep only when the map clearly outgrew the live set)
            if len(fs) > 2 * len(index) + 1024:
                cut = version - self.recency_horizon
                for sig in [s for s, r in fs.items() if r[1] < cut]:
                    del fs[sig]
            querier = R.BatchQuerier(index, self.policy, ages)
            snap = Snapshot(version=version, stream_version=covered,
                            result=result, index=index, querier=querier,
                            ages=ages, published_at=time.monotonic(),
                            published_wall=time.time())
            # mirror into shared memory BEFORE the in-process swap: by
            # the time a writer-side call (refresh/upsert+wait) returns
            # version v, the shm side already carries v — so a client
            # that then demands at_least_version=v from a replica can
            # only block on the replica's attach latency, never on an
            # unpublished segment
            shm_publish_ms = 0.0
            if self.publisher is not None:
                t2 = time.perf_counter()
                try:
                    self.publisher.publish_snapshot(snap, sizes=self.sizes)
                    self.publisher.update_dirty(self._dirty)
                except Exception as e:        # noqa: BLE001 — serving
                    # must outlive a publish failure; replicas just stay
                    # on the previous segment
                    self._stats["publish_errors"] += 1
                    self._stats["last_publish_error"] = repr(e)
                shm_publish_ms = (time.perf_counter() - t2) * 1e3
                self._stats["last_shm_publish_ms"] = shm_publish_ms
            self._last_mine = time.monotonic()
            self._stats["publishes"] += 1
            self._stats["last_mine_ms"] = mine_ms
            self._stats["total_mine_ms"] += mine_ms
            with self._cv:
                self._snap = snap            # THE atomic swap
                self._cv.notify_all()
            if self.obs.enabled:
                # swap-path profile (DESIGN.md §11): one histogram per
                # stage of the publish — mine, index build (delta vs
                # full), shm mirror, end-to-end — plus the span opened
                # at swap entry, carrying the per-stage split
                m = self.obs.metrics
                swap_ms = (time.perf_counter() - t0) * 1e3
                m.histogram("service_mine_ms").observe(mine_ms)
                m.histogram("service_index_build_ms",
                            kind=build_kind).observe(build_ms)
                if self.publisher is not None:
                    m.histogram("service_shm_publish_ms").observe(
                        shm_publish_ms)
                m.histogram("service_swap_ms").observe(swap_ms)
                sp.set("version", version).set("build", build_kind)
                sp.set("mine_ms", mine_ms)
                sp.set("index_build_ms", build_ms)
                sp.set("shm_publish_ms", shm_publish_ms)
            sp.finish()
            # durable checkpoint on publish cadence: the blob covers
            # everything this snapshot covers, the WAL shrinks to the
            # writes that landed during the mine
            if (self.recover_dir
                    and self._writes_since_ckpt >= self.checkpoint_every):
                try:
                    with self._wlock:
                        self._checkpoint_locked(version)
                except Exception as e:       # noqa: BLE001 — serving
                    # must outlive a checkpoint failure (disk full…);
                    # recovery falls back to a longer WAL replay
                    self._stats["checkpoint_errors"] = \
                        self._stats.get("checkpoint_errors", 0) + 1
                    self._stats["last_checkpoint_error"] = repr(e)
            return snap

    def _loop(self):
        while not self._stop_evt.is_set():
            self._wake.wait(timeout=max(self.refresh_interval, 1e-3))
            if self._stop_evt.is_set():
                break
            self._wake.clear()
            with self._wlock:
                dirty = self._dirty
            due = dirty >= self.dirty_threshold or (
                dirty > 0 and time.monotonic() - self._last_mine
                >= self.refresh_interval)
            if due:
                try:
                    self._remine()
                except Exception as e:   # noqa: BLE001 — the refresh
                    # thread must survive anything (a deleted-empty
                    # stream, a transient XLA error): keep serving the
                    # last published snapshot and record the failure
                    # instead of silently dying ever-staler
                    self._stats["mine_errors"] += 1
                    self._stats["last_mine_error"] = repr(e)

    def start(self) -> "TriclusterService":
        """Publish the initial snapshot (if any data is ingested) and
        start the background re-mine thread."""
        if self._thread is not None:
            return self
        try:
            self._remine(force=True)
        except ValueError:
            pass                              # no data yet: first write mines
        self._stop_evt.clear()
        self._thread = threading.Thread(target=self._loop,
                                        name="tricluster-remine",
                                        daemon=True)
        self._thread.start()
        if self.scrub_interval > 0 and self._scrub_thread is None:
            self._scrub_thread = threading.Thread(
                target=self._scrub_loop, name="tricluster-scrub",
                daemon=True)
            self._scrub_thread.start()
        self._started = True
        return self

    def stop(self) -> None:
        self._stop_evt.set()
        self._wake.set()
        if self._thread is not None:
            self._thread.join(timeout=30)
            self._thread = None
        if self._scrub_thread is not None:
            self._scrub_thread.join(timeout=30)
            self._scrub_thread = None
        if self._wal is not None:
            self._wal.close()
            self._wal = None

    def __enter__(self) -> "TriclusterService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- reader path ---------------------------------------------------------

    def snapshot(self, at_least_version: Optional[int] = None,
                 timeout: Optional[float] = None) -> Snapshot:
        """The current snapshot — one reference read, never blocking on
        mining.  ``at_least_version`` switches freshness mode: wait (up
        to ``timeout`` seconds) until a snapshot with that version or
        newer is published, then return it."""
        snap = self._snap
        if at_least_version is None:
            if snap is None:
                raise RuntimeError("no snapshot published yet — ingest "
                                   "data and start()/refresh() first")
            return snap
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while self._snap is None or \
                    self._snap.version < at_least_version:
                remaining = (None if deadline is None
                             else deadline - time.monotonic())
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(
                        f"version {at_least_version} not published within "
                        f"{timeout}s (current: {self.version})")
                self._cv.wait(timeout=remaining)
            return self._snap

    def query(self, entity: Optional[int] = None,
              mode: Optional[int] = None,
              signature: Optional[Tuple[int, int]] = None,
              k: int = 10, at_least_version: Optional[int] = None,
              timeout: Optional[float] = None) -> QueryResult:
        """Ranked lookup against one consistent snapshot.

        ``signature=(lo, hi)``: exact resolution (≤ 1 hit, score
        attached).  ``entity=e [, mode=m]``: top-``k`` by the ranking
        policy.  Neither: the snapshot's global top-``k``."""
        snap = self.snapshot(at_least_version, timeout)
        hits = snapshot_query(snap, entity=entity, mode=mode,
                              signature=signature, k=k)
        return QueryResult(snap.version, snap.stream_version, hits)

    def query_batch(self, entities, mode: Optional[int] = None,
                    k: int = 10, at_least_version: Optional[int] = None,
                    timeout: Optional[float] = None) -> QueryResult:
        """Vectorised multi-entity top-``k``: one stacked-window pass for
        the whole batch (``ranking.BatchQuerier.topk_batch``) against one
        consistent snapshot; ``hits[i]`` corresponds to ``entities[i]``
        and equals the scalar ``query(entity=entities[i])`` hits."""
        snap = self.snapshot(at_least_version, timeout)
        return QueryResult(snap.version, snap.stream_version,
                           snapshot_query_batch(snap, entities, mode, k))
