"""Online cluster-serving service: snapshot-swapped queries over live
streams (DESIGN.md §8).

The paper stops at the mined result set; this module keeps serving it
while the stream keeps mutating.  A :class:`TriclusterService` owns one
streaming-capable miner (``core.streaming.StreamingMiner`` by default,
or an incremental ``core.distributed.DistributedMiner`` whose
``serving_snapshot`` returns the windowed full-table result) and splits
the world into two paths that never contend:

* **writer path** — ``add`` / ``upsert`` / ``delete`` apply to the
  miner's run store under the writer lock and mark the service dirty.
  Writes are cheap (host-side chunk sort into a new run); they block on
  an in-flight re-mine, never on readers.
* **reader path** — queries read one reference, the *current snapshot*:
  an immutable ``(PipelineResult, ClusterIndex, BatchQuerier, version)``
  bundle.  Publication is a single reference swap, so a reader either
  sees the whole previous snapshot or the whole next one — never a torn
  index — and never takes a lock, so queries never block on mining.

A background thread re-mines on a configurable cadence/dirty-threshold:
when ``dirty >= dirty_threshold`` writes have accumulated, or a write is
older than ``refresh_interval`` seconds, it snapshots the miner (the
incremental merged-run path — only changed chunks were ever sorted),
builds the index + ranking arrays *outside* the reader path, and swaps.

**Versions and freshness.**  Every published snapshot carries
``version`` (publish counter, strictly increasing) and
``stream_version`` (the miner's write counter it covers — the snapshot
versioning hooks in ``core.streaming`` / ``core.distributed``).  Reads
take a freshness mode: ``latest`` (default — whatever is published now,
non-blocking) or ``at_least_version=v`` (block up to ``timeout`` until
``version >= v``; the read-your-writes primitive: upsert, ``refresh()``,
then demand the returned version).

**Recency.**  The service remembers the version that first published
each cluster signature; per-cluster ages feed the ranking layer's
recency term, so freshly emerged clusters can be boosted without any
per-cluster timestamps in the mining pipeline.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, List, Optional, Sequence, Tuple

import numpy as np

from . import ranking as R
from .clusters import ClusterIndex, ClusterView


@dataclasses.dataclass(frozen=True)
class Snapshot:
    """One immutable published state; everything a query touches."""
    version: int              # publish counter (1-based, monotonic)
    stream_version: int       # miner writes covered by this snapshot
    result: Any               # the engine's PipelineResult
    index: ClusterIndex
    querier: R.BatchQuerier   # ranked scalar/batch lookups + signatures
    ages: np.ndarray          # per-cluster age in versions (recency)
    published_at: float       # time.monotonic() at swap


@dataclasses.dataclass(frozen=True)
class QueryResult:
    """Hits plus the exact snapshot identity they were answered from."""
    version: int
    stream_version: int
    hits: Any      # [(ClusterView, score)] — or one such list per entity


class TriclusterService:
    """Long-lived serving front-end over one streaming-capable miner.

    Lifecycle: construct, ``add`` initial data, ``start()`` (publishes
    the first snapshot synchronously and starts the re-mine thread),
    serve, ``stop()``.  Usable as a context manager.
    """

    def __init__(self, sizes: Sequence[int], *, backend: str = "streaming",
                 theta: float = 0.0, delta: Optional[float] = None,
                 rho_min: float = 0.0, minsup: int = 0, seed: int = 0x5EED,
                 refresh_interval: float = 0.25, dirty_threshold: int = 64,
                 policy: R.RankingPolicy = R.DEFAULT_POLICY,
                 min_density: float = 0.0, recency_horizon: int = 512,
                 mesh=None, miner=None, **miner_kw):
        self.sizes = tuple(int(s) for s in sizes)
        self.refresh_interval = float(refresh_interval)
        self.dirty_threshold = max(1, int(dirty_threshold))
        #: versions a vanished signature keeps its first-seen record;
        #: past it the record is evicted (bounded memory on churning
        #: streams) and a re-emerging cluster counts as fresh again
        self.recency_horizon = max(1, int(recency_horizon))
        self.policy = policy
        self.min_density = float(min_density)
        if miner is not None:
            self.miner = miner
        elif backend == "streaming":
            from ..core.streaming import StreamingMiner
            self.miner = StreamingMiner(self.sizes, theta=theta, delta=delta,
                                        rho_min=rho_min, minsup=minsup,
                                        seed=seed, **miner_kw)
        elif backend == "distributed":
            from ..core.distributed import DistributedMiner
            if mesh is None:
                from ..launch.mesh import make_local_mesh
                mesh = make_local_mesh()
            self.miner = DistributedMiner(self.sizes, mesh, theta=theta,
                                          delta=delta, rho_min=rho_min,
                                          minsup=minsup, seed=seed,
                                          **miner_kw)
        else:
            raise ValueError(f"backend must be 'streaming' or "
                             f"'distributed', got {backend!r}")
        # the distributed serving path needs the windowed full-table
        # result; the streaming snapshot already is one
        self._mine = getattr(self.miner, "serving_snapshot",
                             getattr(self.miner, "snapshot"))
        self._ingest = getattr(self.miner, "ingest", None) or self.miner.add
        self._wlock = threading.Lock()      # miner store + dirty counter
        self._remine_lock = threading.Lock()  # one re-mine at a time
        self._cv = threading.Condition()    # snapshot publication + waits
        self._snap: Optional[Snapshot] = None
        self._dirty = 0
        self._first_seen: dict = {}   # signature -> [first_v, last_seen_v]
        self._last_mine = 0.0
        self._stop_evt = threading.Event()
        self._wake = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._stats = {"writes": 0, "publishes": 0, "mine_errors": 0,
                       "last_mine_ms": 0.0, "total_mine_ms": 0.0}

    # -- writer path ---------------------------------------------------------

    def _write(self, op, rows, values=None) -> int:
        with self._wlock:
            if values is None:
                op(rows)
            else:
                op(rows, values)
            self._dirty += 1
            self._stats["writes"] += 1
            v = self.miner.stream_version
        self._wake.set()
        return v

    def add(self, rows, values=None) -> int:
        """Append a chunk; returns the miner's new stream_version."""
        return self._write(self._ingest, rows, values)

    def upsert(self, rows, values=None) -> int:
        return self._write(self.miner.upsert, rows, values)

    def delete(self, rows) -> int:
        return self._write(self.miner.delete, rows)

    @property
    def dirty(self) -> int:
        """Writes not yet covered by the published snapshot."""
        return self._dirty

    @property
    def stream_version(self) -> int:
        return self.miner.stream_version

    @property
    def version(self) -> int:
        """Version of the currently published snapshot (0: none yet)."""
        snap = self._snap
        return 0 if snap is None else snap.version

    def stats(self) -> dict:
        out = dict(self._stats)
        snap = self._snap
        out.update(version=self.version, dirty=self._dirty,
                   stream_version=self.miner.stream_version,
                   clusters=0 if snap is None else len(snap.index),
                   sizes=list(self.sizes))
        return out

    # -- mining / publication ------------------------------------------------

    def refresh(self) -> Snapshot:
        """Synchronously mine + publish a new snapshot (even when clean:
        an explicit refresh always advances the version, giving callers
        a version number that provably covers their writes)."""
        return self._remine(force=True)

    def _remine(self, force: bool = False) -> Snapshot:
        with self._remine_lock:
            snap = self._snap
            if not force and snap is not None and self._dirty == 0:
                return snap
            t0 = time.perf_counter()
            with self._wlock:
                # the store mutates under snapshot() (compaction/merge):
                # writers hold off while we mine, readers don't care
                covered = self.miner.stream_version
                result = self._mine()
                np.asarray(result.keep)      # block: leave jit-land here
                self._dirty = 0
            mine_ms = (time.perf_counter() - t0) * 1e3
            # index + ranking build off the writer path: writes land
            # freely while we stack windows host-side
            index = ClusterIndex.from_result(result,
                                             min_density=self.min_density)
            version = (0 if self._snap is None else self._snap.version) + 1
            fs = self._first_seen
            ages = []
            for c in index.clusters:
                rec = fs.get(c.signature)
                if rec is None:
                    fs[c.signature] = rec = [version, version]
                else:
                    rec[1] = version
                ages.append(version - rec[0])
            ages = np.asarray(ages, np.float64)
            # evict first-seen records of long-vanished signatures
            # (sweep only when the map clearly outgrew the live set)
            if len(fs) > 2 * len(index.clusters) + 1024:
                cut = version - self.recency_horizon
                for sig in [s for s, r in fs.items() if r[1] < cut]:
                    del fs[sig]
            querier = R.BatchQuerier(index, self.policy, ages)
            snap = Snapshot(version=version, stream_version=covered,
                            result=result, index=index, querier=querier,
                            ages=ages, published_at=time.monotonic())
            self._last_mine = time.monotonic()
            self._stats["publishes"] += 1
            self._stats["last_mine_ms"] = mine_ms
            self._stats["total_mine_ms"] += mine_ms
            with self._cv:
                self._snap = snap            # THE atomic swap
                self._cv.notify_all()
            return snap

    def _loop(self):
        while not self._stop_evt.is_set():
            self._wake.wait(timeout=max(self.refresh_interval, 1e-3))
            if self._stop_evt.is_set():
                break
            self._wake.clear()
            with self._wlock:
                dirty = self._dirty
            due = dirty >= self.dirty_threshold or (
                dirty > 0 and time.monotonic() - self._last_mine
                >= self.refresh_interval)
            if due:
                try:
                    self._remine()
                except Exception as e:   # noqa: BLE001 — the refresh
                    # thread must survive anything (a deleted-empty
                    # stream, a transient XLA error): keep serving the
                    # last published snapshot and record the failure
                    # instead of silently dying ever-staler
                    self._stats["mine_errors"] += 1
                    self._stats["last_mine_error"] = repr(e)

    def start(self) -> "TriclusterService":
        """Publish the initial snapshot (if any data is ingested) and
        start the background re-mine thread."""
        if self._thread is not None:
            return self
        try:
            self._remine(force=True)
        except ValueError:
            pass                              # no data yet: first write mines
        self._stop_evt.clear()
        self._thread = threading.Thread(target=self._loop,
                                        name="tricluster-remine",
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop_evt.set()
        self._wake.set()
        if self._thread is not None:
            self._thread.join(timeout=30)
            self._thread = None

    def __enter__(self) -> "TriclusterService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- reader path ---------------------------------------------------------

    def snapshot(self, at_least_version: Optional[int] = None,
                 timeout: Optional[float] = None) -> Snapshot:
        """The current snapshot — one reference read, never blocking on
        mining.  ``at_least_version`` switches freshness mode: wait (up
        to ``timeout`` seconds) until a snapshot with that version or
        newer is published, then return it."""
        snap = self._snap
        if at_least_version is None:
            if snap is None:
                raise RuntimeError("no snapshot published yet — ingest "
                                   "data and start()/refresh() first")
            return snap
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while self._snap is None or \
                    self._snap.version < at_least_version:
                remaining = (None if deadline is None
                             else deadline - time.monotonic())
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(
                        f"version {at_least_version} not published within "
                        f"{timeout}s (current: {self.version})")
                self._cv.wait(timeout=remaining)
            return self._snap

    def query(self, entity: Optional[int] = None,
              mode: Optional[int] = None,
              signature: Optional[Tuple[int, int]] = None,
              k: int = 10, at_least_version: Optional[int] = None,
              timeout: Optional[float] = None) -> QueryResult:
        """Ranked lookup against one consistent snapshot.

        ``signature=(lo, hi)``: exact resolution (≤ 1 hit, score
        attached).  ``entity=e [, mode=m]``: top-``k`` by the ranking
        policy.  Neither: the snapshot's global top-``k``."""
        snap = self.snapshot(at_least_version, timeout)
        if signature is not None:
            row = int(snap.querier.lookup_signatures([signature])[0])
            hits: List[Tuple[ClusterView, float]] = []
            if row >= 0:
                view = snap.index.clusters[row]
                if entity is None or view.contains(int(entity), mode):
                    hits = [(view, float(snap.querier.scores[row]))]
        elif entity is not None:
            hits = snap.querier.topk(int(entity), mode, k)
        else:
            hits = R.top_clusters(snap.index, k, self.policy, snap.ages)
        return QueryResult(snap.version, snap.stream_version, hits)

    def query_batch(self, entities, mode: Optional[int] = None,
                    k: int = 10, at_least_version: Optional[int] = None,
                    timeout: Optional[float] = None) -> QueryResult:
        """Vectorised multi-entity top-``k``: one stacked-window pass for
        the whole batch (``ranking.BatchQuerier.topk_batch``) against one
        consistent snapshot; ``hits[i]`` corresponds to ``entities[i]``
        and equals the scalar ``query(entity=entities[i])`` hits."""
        snap = self.snapshot(at_least_version, timeout)
        return QueryResult(snap.version, snap.stream_version,
                           snap.querier.topk_batch(entities, mode, k))
