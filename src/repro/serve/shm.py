"""Zero-copy snapshot bundles over POSIX shared memory (DESIGN.md §8).

One serving process mines and builds indexes; N *replica reader*
processes answer queries.  The bridge between them is this module: the
writer publishes every snapshot's stacked arrays — membership words,
component members/bounds, packed signatures, scores, per-row stats —
into a ``multiprocessing.shared_memory`` segment, and replicas map the
segment and serve straight out of it (``np.frombuffer`` views, no
copy, no deserialisation).

**Memory model.**  Two kinds of segments per ``prefix``:

* ``{prefix}.ctl`` — a fixed 4 KiB *control block*, created once by the
  writer.  It names the current data segment and carries the snapshot
  version, stream version, publish wall-time and cluster count behind a
  *seqlock*: the writer bumps a sequence word to odd, rewrites the
  payload, bumps it to even; a reader re-reads until it observes the
  same even sequence before and after — so a reader never acts on a
  torn control block.  A separate ``dirty`` slot (the write backlog)
  sits outside the seqlock payload and is updated on every write
  without bumping the sequence.
* ``{prefix}.v{version}`` — one immutable *data segment per snapshot*:
  an 8-byte header length, a JSON manifest (array names / dtypes /
  shapes / offsets / **per-array 64-bit checksums** + snapshot meta),
  then the arrays, 64-byte aligned.  Data segments are never mutated after the
  control block names them — single-reference swap semantics, exactly
  like the in-process ``TriclusterService`` snapshot swap.

**Integrity.**  The manifest checksums are the fail-silent defence
(DESIGN.md §9): :class:`SnapshotBundle` verifies every array against
its recorded :func:`checksum64` at attach time and refuses the segment
with :class:`ShmCorruptionError` on mismatch, and :class:`ReplicaService`
re-verifies the held bundle opportunistically (one rotating array per
scrub tick) — a word flipped *after* attach is caught between swaps,
not served.  Either detection escalates exactly like a dead writer:
keep serving the last good snapshot, signal the supervisor
(``on_writer_dead`` path) so the writer republishes under a new epoch.

**Reclamation.**  After publishing version ``v`` the writer *unlinks*
segment ``v-1``.  POSIX keeps the memory alive until the last process
unmaps it, so replicas still serving ``v-1`` are never torn; the
segment is physically reclaimed when the last reader drops its mapping
(replicas drop theirs when they attach ``v``; CPython refcounting frees
the old mapping as soon as no in-flight query holds a view).  A replica
that loses the attach race (control named ``v`` but the writer already
moved on and unlinked it) just re-reads the control block and retries.

Replicas must *not* let Python's ``resource_tracker`` adopt attached
segments — it would unlink live segments when the replica exits — so
:func:`attach_segment` detaches them from tracking (``track=False``
where available, else explicit unregister).
"""
from __future__ import annotations

import json
import os
import struct
import threading
import time
from multiprocessing import shared_memory
from typing import List, Optional

import numpy as np

CTL_SIZE = 4096
# seq, epoch, version, stream_version, wall, n, name_len — ``epoch``
# increments each time a (re)started writer adopts the prefix, so a
# restarted writer's first publish is unambiguous to readers even if
# its version numbering restarted (bundle identity is (epoch, version))
_CTL_FMT = "<QQQQdQQ"
_CTL_PAYLOAD = struct.calcsize(_CTL_FMT)
_NAME_OFF = _CTL_PAYLOAD
_NAME_MAX = 200
_DIRTY_OFF = 512         # outside the seqlock payload (see module doc)
_PID_OFF = 520           # writer pid — the reader-side liveness probe
_ALIGN = 64


class ShmCorruptionError(RuntimeError):
    """A mapped data segment failed its manifest checksums (or its
    arrays violate structural invariants): the bytes in shared memory
    are not the bytes the writer published.  Readers must not serve
    from the segment — they keep their held snapshot and escalate along
    the ``on_writer_dead`` path so the supervisor makes the writer
    republish (a restart bumps the epoch; the next clean attach clears
    the condition)."""


class WriterDeadError(RuntimeError):
    """The seqlock stayed odd past the spin bound and (re-attach
    confirmed) the writer cannot finish the swing: it crashed
    mid-publish, or is alive but wedged.  Readers keep serving their
    held snapshot; whoever supervises the writer should restart it."""

    def __init__(self, prefix: str, pid: int, alive: bool):
        state = ("alive but stuck" if alive else "dead")
        super().__init__(f"publisher of {prefix!r} is {state} "
                         f"(pid {pid}): seqlock stuck odd")
        self.prefix, self.pid, self.alive = prefix, pid, alive


def _pid_alive(pid: int) -> bool:
    if pid <= 0:
        return False
    try:
        os.kill(pid, 0)
        return True
    except ProcessLookupError:
        return False
    except PermissionError:
        return True


class _Segment(shared_memory.SharedMemory):
    """SharedMemory whose ``close`` tolerates live zero-copy views:
    ``mmap.close`` refuses while exported buffers exist (in-flight
    queries still reading the old snapshot), and that is fine — the
    mapping is freed when the last view dies."""

    def close(self):                         # also guards __del__
        try:
            super().close()
        except BufferError:
            pass


def _untrack(name: str) -> None:
    """Detach a segment from this process's ``resource_tracker`` (the
    tracker would unlink it when the process dies — wrong for segments
    whose lifetime must span a crash)."""
    try:
        from multiprocessing import resource_tracker
        resource_tracker.unregister(name, "shared_memory")
    except Exception:                        # noqa: BLE001 — advisory
        pass


def attach_segment(name: str) -> shared_memory.SharedMemory:
    """Attach an existing segment *without* resource-tracker ownership
    (the writer owns unlink; a tracked reader would destroy live
    segments on exit)."""
    try:
        return _Segment(name=name, track=False)
    except TypeError:                        # Python < 3.13: no track=
        seg = _Segment(name=name)
        _untrack(seg._name)
        return seg


def _unlink_segment(seg: shared_memory.SharedMemory) -> None:
    """Unlink with a balanced resource-tracker state: re-register first
    (a set add — idempotent), so unlink's unregister never targets an
    absent name (which the tracker process logs as a KeyError when a
    same-process reader already unregistered it)."""
    try:
        from multiprocessing import resource_tracker
        resource_tracker.register(seg._name, "shared_memory")
    except Exception:                        # noqa: BLE001 — advisory
        pass
    try:
        seg.unlink()
    except FileNotFoundError:
        pass


def _pad(n: int) -> int:
    return (n + _ALIGN - 1) // _ALIGN * _ALIGN


_M64 = (1 << 64) - 1


def checksum64(data) -> int:
    """64-bit content checksum of an array or buffer: one single-pass
    wrap-around ``uint64`` sum over the 8-byte words, tail bytes and
    length folded in, finished with a splitmix64-style mix.

    This is the **shared-memory manifest** checksum, chosen over
    ``zlib.crc32`` deliberately: crc32 streams bytes through zlib at
    ~1 GB/s, which on a small host is a visible fraction of every
    snapshot-swap; the NumPy reduction runs at memory bandwidth
    (>10 GB/s), keeping the clean-path verify cost inside the ≤5%
    overhead budget (DESIGN.md §9).  Detection guarantee: *any*
    corruption confined to a single 64-bit word — every bit-flip burst
    the fault injector or real bit rot produces in one word — always
    changes the sum (by ``w' - w ≠ 0 mod 2^64``); independent
    multi-word damage escapes with probability ~2^-64, better odds
    than crc32's 2^-32.  The mix step keeps single-word deltas from
    producing correlated checksum deltas.  The WAL and checkpoint
    frames keep CRC32: they are off the swap path, and byte-granular
    torn-tail detection matters more there."""
    if isinstance(data, np.ndarray):
        mv = memoryview(np.ascontiguousarray(data)).cast("B")
    else:
        mv = memoryview(data).cast("B")
    n = len(mv)
    k = n - n % 8
    s = 0
    if k:
        s = int(np.add.reduce(np.frombuffer(mv[:k], dtype="<u8"),
                              dtype=np.uint64))
    tail = int.from_bytes(mv[k:], "little") if k < n else 0
    h = ((n ^ s) * 0x9E3779B97F4A7C15) & _M64
    h ^= h >> 30
    h = ((h ^ tail) * 0xBF58476D1CE4E5B9) & _M64
    return (h ^ (h >> 31)) & _M64


class SnapshotBundle:
    """One mapped data segment: zero-copy array views + snapshot meta.
    Holds the segment mapping alive exactly as long as any of its
    arrays (or itself) is referenced.

    Attach is the integrity gate: every array is checksummed against
    the manifest's recorded :func:`checksum64` before the bundle is
    usable (``verify=False`` skips it — benchmark baseline only).
    Legacy manifests without checksums attach unverified."""

    def __init__(self, seg: shared_memory.SharedMemory,
                 verify: bool = True):
        self._seg = seg
        (hlen,) = struct.unpack_from("<Q", seg.buf, 0)
        head = json.loads(bytes(seg.buf[8:8 + hlen]))
        self.meta: dict = head["meta"]
        self.manifest: list = head["arrays"]
        self.version: int = int(self.meta["version"])
        self.epoch: int = int(self.meta.get("epoch", 1))
        self.stream_version: int = int(self.meta["stream_version"])
        self.published_wall: float = float(self.meta["published_wall"])
        self.arrays: dict = {}
        for a in self.manifest:
            arr = np.frombuffer(seg.buf, dtype=np.dtype(a["dtype"]),
                                count=int(np.prod(a["shape"], dtype=int)),
                                offset=a["offset"]).reshape(a["shape"])
            arr.flags.writeable = False
            self.arrays[a["name"]] = arr
        if verify:
            bad = self.verify()
            if bad:
                raise ShmCorruptionError(
                    f"segment {getattr(seg, 'name', '?')} v"
                    f"{self.version}: checksum mismatch in "
                    f"{', '.join(bad)}")

    def verify(self, names: Optional[List[str]] = None) -> List[str]:
        """Re-checksum mapped arrays against the manifest (all of them,
        or just ``names``) and return the mismatching array names.
        Runs over the raw segment bytes — no copies.  Entries without a
        recorded checksum (legacy manifests) pass vacuously."""
        bad: List[str] = []
        for a in self.manifest:
            if names is not None and a["name"] not in names:
                continue
            want = a.get("sum64")
            if want is None:
                continue
            o = int(a["offset"])
            nbytes = int(self.arrays[a["name"]].nbytes)
            if checksum64(self._seg.buf[o:o + nbytes]) != int(want):
                bad.append(a["name"])
        return bad


class ShmPublisher:
    """Writer side: owns the control block, publishes one data segment
    per snapshot, unlinks the previous one after each swap.

    Crash safety: adopting a dead predecessor's control block bumps the
    **epoch** (readers see an unambiguous new writer), records the last
    version the predecessor named (``resumed_version`` — the restart's
    version floor), garbage-collects every orphaned ``{prefix}.v*``
    data segment the crash leaked, and stamps this process's pid into
    the control block for the readers' stuck-odd liveness probe."""

    def __init__(self, prefix: str, fault=None, checksums: bool = True):
        if len(prefix) + 16 > _NAME_MAX:
            raise ValueError(f"prefix too long: {prefix!r}")
        self.prefix = prefix
        self.fault = fault
        #: record per-array :func:`checksum64` values in the manifest
        #: (the attach-time integrity gate); False is the
        #: overhead-benchmark baseline
        self.checksums = bool(checksums)
        self._seq = 0
        self.epoch = 1
        self.resumed_version = 0
        self._data: Optional[shared_memory.SharedMemory] = None
        try:
            self._ctl = _Segment(
                name=f"{prefix}.ctl", create=True, size=CTL_SIZE)
            # the control block is the crash-durable rendezvous — it
            # carries the epoch watermark a restarted writer must read,
            # so the resource tracker must not unlink it on crash
            _untrack(self._ctl._name)
        except FileExistsError:
            # a stale control block from a dead writer: adopt, recover
            # its (epoch, version) watermark — possibly written by a
            # crash mid-swing, hence read raw, no seqlock — and reset
            self._ctl = attach_segment(f"{prefix}.ctl")
            _, epoch, ver, *_ = struct.unpack_from(_CTL_FMT,
                                                   self._ctl.buf, 0)
            self.epoch = int(epoch) + 1
            self.resumed_version = int(ver)
        self._ctl.buf[:CTL_SIZE] = b"\0" * CTL_SIZE
        struct.pack_into("<Q", self._ctl.buf, _PID_OFF, os.getpid())
        self.reclaimed = self._gc_orphans()

    def _gc_orphans(self) -> int:
        """Unlink every leftover ``{prefix}.v*`` data segment of a dead
        predecessor (readers still mapping one keep it alive — unlink
        only removes the name).  Without this, a restart that republishes
        a version number its predecessor already used would collide with
        the orphan and crash-loop."""
        n = 0
        shm_dir = "/dev/shm"                 # POSIX shm namespace; the
        if not os.path.isdir(shm_dir):       # only portable way to list
            return 0
        for entry in os.listdir(shm_dir):
            if not entry.startswith(f"{self.prefix}.v"):
                continue
            try:
                seg = attach_segment(entry)
                seg.close()
                _unlink_segment(seg)
                n += 1
            except FileNotFoundError:
                pass
        return n

    def publish(self, version: int, stream_version: int,
                arrays: dict, meta: Optional[dict] = None,
                published_wall: Optional[float] = None) -> str:
        """Write ``arrays`` into a fresh ``{prefix}.v{version}`` segment
        and swing the control block to it; then unlink the previous
        segment (readers still mapping it keep it alive)."""
        if self.fault is not None:
            self.fault.fire("publish", int(version))
        wall = time.time() if published_wall is None else published_wall
        manifest, offset = [], 0
        items = [(k, np.ascontiguousarray(v)) for k, v in arrays.items()]
        # header size depends on offsets which depend on header size:
        # reserve generously once, then lay arrays after it
        probe = json.dumps({"meta": dict(meta or {}), "arrays": [
            {"name": k, "dtype": str(v.dtype), "shape": list(v.shape),
             "offset": 0, "sum64": _M64}
            for k, v in items]}).encode()
        data_off = _pad(8 + len(probe) + 4096)
        offset = data_off
        for k, v in items:
            ent = {"name": k, "dtype": str(v.dtype),
                   "shape": list(v.shape), "offset": offset}
            if self.checksums:
                # checksum the source array, not the segment copy: the
                # manifest records what the writer *meant* to publish,
                # so any later mutation of the shared bytes — torn
                # write, stray DMA, injected flip — fails attach verify
                ent["sum64"] = checksum64(v)
            manifest.append(ent)
            offset = _pad(offset + v.nbytes)
        m = dict(meta or {})
        m.update(version=int(version), stream_version=int(stream_version),
                 published_wall=wall, epoch=int(self.epoch))
        head = json.dumps({"meta": m, "arrays": manifest}).encode()
        if 8 + len(head) > data_off:
            raise ValueError("header overflow")          # 4 KiB slack
        name = f"{self.prefix}.v{int(version)}"
        seg = _Segment(name=name, create=True,
                       size=max(offset, data_off + 1))
        struct.pack_into("<Q", seg.buf, 0, len(head))
        seg.buf[8:8 + len(head)] = head
        for spec, (_, v) in zip(manifest, items):
            o = spec["offset"]
            seg.buf[o:o + v.nbytes] = v.tobytes()
        if self.fault is not None \
                and self.fault.corrupt("shm", int(version)) is not None:
            # injected bit rot: invert one aligned word of the first
            # sizeable array *after* its checksum was recorded — the
            # replicas' attach-time verify, not reader luck, is what
            # stands between this segment and wrong answers
            self._flip_word(seg, manifest)
        self._swing(version, stream_version, wall,
                    int(arrays.get("packed_sigs", np.zeros(0)).shape[0]),
                    name)
        prev, self._data = self._data, seg
        if prev is not None:
            prev.close()
            _unlink_segment(prev)
        return name

    def publish_snapshot(self, snap, sizes=None) -> str:
        """Publish a ``serve.service.Snapshot`` whose index carries the
        stacked arrays (``supports_delta``)."""
        idx = snap.index
        if not idx.supports_delta:
            raise ValueError("index lacks stacked arrays — build it "
                             "with from_result/delta_from_result")
        arrays = {
            "packed_sigs": idx.packed_sigs,
            "any_pairs": idx.any_pairs,
            "scores": snap.querier.scores,
            "ages": np.asarray(snap.ages, np.float64),
            # straight off the index's stats arrays — publishing must
            # not force the lazy view list
            "density": np.asarray(idx.density, np.float64),
            "gen_count": np.asarray(idx.gen_count, np.int64),
            "volume": np.asarray(idx.volume, np.float64),
        }
        for k in range(len(idx.mode_pairs)):
            arrays[f"mode_pairs_{k}"] = idx.mode_pairs[k]
            arrays[f"comp_ents_{k}"] = idx.comp_ents[k]
            arrays[f"comp_bounds_{k}"] = idx.comp_bounds[k]
        meta = {"n_modes": len(idx.mode_pairs),
                "sizes": [] if sizes is None else [int(s) for s in sizes]}
        return self.publish(snap.version, snap.stream_version, arrays,
                            meta=meta,
                            published_wall=getattr(snap, "published_wall",
                                                   None))

    @staticmethod
    def _flip_word(seg, manifest) -> None:
        for spec in manifest:
            n = (int(np.prod(spec["shape"], dtype=int))
                 * np.dtype(spec["dtype"]).itemsize)
            if n >= 8:
                o = int(spec["offset"]) + (n // 16) * 8
                w = bytes(seg.buf[o:o + 8])
                seg.buf[o:o + 8] = bytes(b ^ 0xFF for b in w)
                return

    def _swing(self, version, stream_version, wall, n, name) -> None:
        nb = name.encode()
        self._seq += 1                                   # odd: writing
        struct.pack_into("<Q", self._ctl.buf, 0, self._seq)
        struct.pack_into(_CTL_FMT, self._ctl.buf, 0, self._seq,
                         int(self.epoch), int(version),
                         int(stream_version), float(wall),
                         int(n), len(nb))
        self._ctl.buf[_NAME_OFF:_NAME_OFF + len(nb)] = nb
        if self.fault is not None:
            # the torn-publish site: a "kill" armed here dies with the
            # seqlock odd and the new segment orphaned
            self.fault.fire("torn", int(version))
        self._seq += 1                                   # even: stable
        struct.pack_into("<Q", self._ctl.buf, 0, self._seq)

    def update_dirty(self, dirty: int) -> None:
        """Advisory write-backlog slot; no seqlock bump (see module
        doc), so replicas surface it without re-attaching anything."""
        struct.pack_into("<Q", self._ctl.buf, _DIRTY_OFF, int(dirty))

    def close(self, unlink: bool = True) -> None:
        if self._data is not None:
            self._data.close()
            if unlink:
                _unlink_segment(self._data)
            self._data = None
        self._ctl.close()
        if unlink:
            _unlink_segment(self._ctl)


class ShmReplica:
    """Reader side: seqlock-consistent control reads + data-segment
    attach with swap-race retry.  Thread-safe; meant to back one
    replica process's query surface (``ReplicaService``)."""

    def __init__(self, prefix: str, connect_timeout: float = 60.0,
                 seqlock_spin_s: float = 1.0):
        self.prefix = prefix
        #: bounded-spin budget for an odd seqlock before the stuck-odd
        #: protocol (re-attach, probe the writer pid, declare it dead)
        self.seqlock_spin_s = float(seqlock_spin_s)
        self._lock = threading.Lock()
        self._bundle: Optional[SnapshotBundle] = None
        deadline = time.monotonic() + connect_timeout
        while True:
            try:
                self._ctl = attach_segment(f"{prefix}.ctl")
                break
            except FileNotFoundError:
                if time.monotonic() >= deadline:
                    raise TimeoutError(
                        f"no publisher control block {prefix!r}.ctl "
                        f"after {connect_timeout}s") from None
                time.sleep(0.05)

    def _reattach_ctl(self) -> None:
        """Drop and re-open the control mapping — a restarted writer
        may have replaced the segment behind the old name."""
        old = self._ctl
        self._ctl = attach_segment(f"{self.prefix}.ctl")
        old.close()

    def read_control(self) -> dict:
        """One seqlock-consistent control read (never torn).

        A writer normally holds the lock odd for microseconds; odd past
        ``seqlock_spin_s`` means the writer died (or wedged) mid-swing.
        The stuck-odd protocol then runs: re-attach the control block
        (it may have been recreated), give it one more spin budget, and
        if still odd raise :class:`WriterDeadError` carrying the
        writer-pid liveness probe — the caller keeps serving its held
        snapshot and signals the supervisor."""
        reattached = False
        deadline = time.monotonic() + self.seqlock_spin_s
        while True:
            buf = self._ctl.buf
            (s1,) = struct.unpack_from("<Q", buf, 0)
            if s1 % 2:                       # writer mid-swing
                if time.monotonic() >= deadline:
                    if not reattached:
                        reattached = True
                        self._reattach_ctl()
                        deadline = (time.monotonic()
                                    + self.seqlock_spin_s)
                        continue
                    (pid,) = struct.unpack_from("<Q", buf, _PID_OFF)
                    raise WriterDeadError(self.prefix, int(pid),
                                          _pid_alive(int(pid)))
                time.sleep(0.0002)
                continue
            seq, epoch, ver, sv, wall, n, nlen = struct.unpack_from(
                _CTL_FMT, buf, 0)
            name = bytes(buf[_NAME_OFF:_NAME_OFF + nlen]).decode()
            (dirty,) = struct.unpack_from("<Q", buf, _DIRTY_OFF)
            (pid,) = struct.unpack_from("<Q", buf, _PID_OFF)
            (s2,) = struct.unpack_from("<Q", buf, 0)
            if s1 == s2:
                return {"version": ver, "epoch": epoch,
                        "stream_version": sv,
                        "published_wall": wall, "clusters": n,
                        "segment": name, "dirty": dirty,
                        "writer_pid": pid}

    def current(self) -> Optional[SnapshotBundle]:
        """The bundle for the control block's current snapshot,
        (re-)attaching on (epoch, version) change; None until the
        writer has published anything.  Losing the attach race to a
        concurrent swap (segment already unlinked) retries off the
        fresh control block."""
        while True:
            ctl = self.read_control()
            if ctl["version"] == 0:
                return None
            ident = (ctl["epoch"], ctl["version"])
            b = self._bundle
            if b is not None and (b.epoch, b.version) == ident:
                return b
            with self._lock:
                b = self._bundle
                if b is not None and (b.epoch, b.version) == ident:
                    return b
                try:
                    seg = attach_segment(ctl["segment"])
                except FileNotFoundError:
                    continue                 # swapped under us: retry
                bundle = SnapshotBundle(seg)
                # dropping the previous bundle releases our mapping of
                # the old (already unlinked) segment once the last
                # in-flight query referencing its arrays completes
                self._bundle = bundle
                return bundle

    def wait_version(self, at_least: int,
                     timeout: Optional[float] = None) -> SnapshotBundle:
        deadline = (None if timeout is None
                    else time.monotonic() + timeout)
        while True:
            b = self.current()
            if b is not None and b.version >= at_least:
                return b
            if deadline is not None and time.monotonic() >= deadline:
                cur = 0 if b is None else b.version
                raise TimeoutError(
                    f"version {at_least} not published within {timeout}s "
                    f"(current: {cur})")
            time.sleep(0.002)

    def close(self) -> None:
        self._bundle = None
        self._ctl.close()


class ReplicaService:
    """Read-only query surface of one replica reader process.

    Maps the writer's shared-memory snapshots (:class:`ShmReplica`),
    reassembles the ``ClusterIndex`` + querier from the zero-copy array
    views on every version change, and answers ``query`` /
    ``query_batch`` / ``snapshot`` with exactly the in-process
    service's semantics (same shared ``snapshot_query`` logic, same
    freshness modes) — so ``serve.protocol.make_server`` serves a
    replica unchanged, minus the write routes (``read_only``)."""

    read_only = True

    def __init__(self, prefix: str, poll_interval: float = 0.005,
                 connect_timeout: float = 60.0,
                 seqlock_spin_s: float = 1.0, on_writer_dead=None,
                 dead_signal_cooldown: float = 5.0,
                 scrub_interval: float = 0.5):
        self.replica = ShmReplica(prefix, connect_timeout=connect_timeout,
                                  seqlock_spin_s=seqlock_spin_s)
        self.poll_interval = float(poll_interval)
        #: called (with the WriterDeadError / ShmCorruptionError) when
        #: the stuck-odd protocol declares the publisher dead or a
        #: segment fails verification — the supervisor signal
        #: (``launch/cluster_serve.py`` wires a restart-flag file here);
        #: rate-limited by ``dead_signal_cooldown``
        self.on_writer_dead = on_writer_dead
        self.dead_signal_cooldown = float(dead_signal_cooldown)
        self._last_dead_signal = -float("inf")
        #: opportunistic re-verify cadence (s): each tick checksums one
        #: rotating array of the *held* bundle, so corruption landing
        #: after a clean attach is caught between swaps; 0 disables
        self.scrub_interval = float(scrub_interval)
        self._last_scrub = 0.0
        self._scrub_cursor = 0
        self._corrupt = False
        self._ident = (0, 0)                  # (epoch, version) served
        self._cv = threading.Condition()
        self._snap = None
        self._stop_evt = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._started = False
        self._stats = {"attaches": 0, "attach_errors": 0,
                       "last_attach_ms": 0.0, "writer_dead_signals": 0,
                       "shm_corruptions": 0, "scrubs": 0,
                       "scrub_violations": []}

    # -- snapshot maintenance ------------------------------------------------

    def _build(self, bundle: SnapshotBundle):
        from . import ranking as R
        from .clusters import ClusterIndex
        from .service import Snapshot
        t0 = time.perf_counter()
        n_modes = int(bundle.meta.get("n_modes", 0))
        a = bundle.arrays
        # structural invariants on top of the checksum gate: they prove
        # the bytes are what the writer published, these prove what it
        # published is servable (a writer-side build gone wrong must
        # not propagate to readers as garbage answers)
        bad: list = []
        ps = a["packed_sigs"]
        if ps.size > 1 and not bool(np.all(ps[:-1] <= ps[1:])):
            bad.append("packed_sigs not sorted")
        if not bool(np.all(np.isfinite(a["scores"]))):
            bad.append("non-finite scores")
        for k in range(n_modes):
            cb = a[f"comp_bounds_{k}"]
            if cb.size > 1 and not bool(np.all(cb[:-1] <= cb[1:])):
                bad.append(f"comp_bounds_{k} not monotone")
        if bad:
            raise ShmCorruptionError(
                f"bundle v{bundle.version}: invariant violations: "
                f"{'; '.join(bad)}")
        idx = ClusterIndex.from_arrays(
            a["packed_sigs"],
            [a[f"mode_pairs_{k}"] for k in range(n_modes)],
            [a[f"comp_ents_{k}"] for k in range(n_modes)],
            [a[f"comp_bounds_{k}"] for k in range(n_modes)],
            a["any_pairs"], a["density"], a["gen_count"], a["volume"])
        querier = R.BatchQuerier(idx, scores=a["scores"])
        snap = Snapshot(version=bundle.version,
                        stream_version=bundle.stream_version,
                        result=None, index=idx, querier=querier,
                        ages=a["ages"], published_at=time.monotonic(),
                        published_wall=bundle.published_wall)
        self._stats["attaches"] += 1
        self._stats["last_attach_ms"] = (time.perf_counter() - t0) * 1e3
        return snap

    def _signal_supervisor(self, err) -> None:
        """Rate-limited escalation callback — one path for a dead
        writer and a corrupt segment (both mean: the writer must
        republish; we keep serving the held snapshot meanwhile)."""
        cb = self.on_writer_dead
        now = time.monotonic()
        if (cb is not None and now - self._last_dead_signal
                >= self.dead_signal_cooldown):
            self._last_dead_signal = now
            try:
                cb(err)
            except Exception:                # noqa: BLE001 — advisory
                pass

    def _writer_dead(self, err: WriterDeadError) -> None:
        self._stats["writer_dead_signals"] += 1
        self._stats["last_writer_dead"] = repr(err)
        self._signal_supervisor(err)

    def _corruption(self, err: ShmCorruptionError) -> None:
        self._stats["shm_corruptions"] += 1
        self._stats["last_shm_corruption"] = repr(err)
        self._signal_supervisor(err)

    def _maybe_attach(self) -> None:
        try:
            ctl = self.replica.read_control()
        except WriterDeadError as e:
            # keep serving the held snapshot; surface the death to the
            # supervisor and move on — recovery is the writer's problem
            self._writer_dead(e)
            return
        ident = (ctl["epoch"], ctl["version"])
        if ctl["version"] == 0 or ident == self._ident:
            return
        try:
            bundle = self.replica.current()
        except ShmCorruptionError as e:
            # refused segment: serve the held snapshot, escalate — the
            # exact opposite of silently serving the corrupt bytes
            self._corruption(e)
            return
        if bundle is None:
            return
        ident = (bundle.epoch, bundle.version)
        if ident == self._ident:
            return
        try:
            snap = self._build(bundle)
        except ShmCorruptionError as e:
            self._corruption(e)
            return
        self._ident = ident
        # a verified attach supersedes any corruption the scrubber
        # found in the previous bundle
        self._corrupt = False
        self._stats["scrub_violations"] = []
        with self._cv:
            self._snap = snap                # the replica's atomic swap
            self._cv.notify_all()

    def _maybe_scrub(self) -> None:
        """Opportunistic held-bundle re-verify: one rotating array's
        checksum per tick, so a full pass completes every
        ``n_arrays * scrub_interval`` seconds without ever stalling
        the attach loop."""
        if self.scrub_interval <= 0:
            return
        now = time.monotonic()
        if now - self._last_scrub < self.scrub_interval:
            return
        self._last_scrub = now
        b = self.replica._bundle
        if b is None or not b.manifest:
            return
        names = sorted(b.arrays)
        name = names[self._scrub_cursor % len(names)]
        self._scrub_cursor += 1
        bad = b.verify([name])
        self._stats["scrubs"] += 1
        if bad:
            self._corrupt = True
            self._stats["scrub_violations"] = [
                f"shm checksum mismatch in held bundle "
                f"v{b.version}: {bad[0]}"]
            self._corruption(ShmCorruptionError(
                f"scrub: array {bad[0]!r} of held segment v"
                f"{b.version} no longer matches its published checksum"))

    def _loop(self) -> None:
        while not self._stop_evt.is_set():
            try:
                self._maybe_attach()
                self._maybe_scrub()
            except Exception as e:           # noqa: BLE001 — keep
                # serving the previous snapshot on any attach failure
                self._stats["attach_errors"] += 1
                self._stats["last_attach_error"] = repr(e)
            self._stop_evt.wait(self.poll_interval)

    def start(self, first_snapshot_timeout: float = 60.0
              ) -> "ReplicaService":
        if self._thread is not None:
            return self
        deadline = time.monotonic() + first_snapshot_timeout
        while self._snap is None:
            self._maybe_attach()
            if self._snap is not None:
                break
            if time.monotonic() >= deadline:
                raise TimeoutError("writer published no snapshot within "
                                   f"{first_snapshot_timeout}s")
            time.sleep(0.02)
        self._stop_evt.clear()
        self._thread = threading.Thread(target=self._loop,
                                        name="replica-attach", daemon=True)
        self._thread.start()
        self._started = True
        return self

    def stop(self) -> None:
        self._stop_evt.set()
        if self._thread is not None:
            self._thread.join(timeout=30)
            self._thread = None
        self.replica.close()

    def __enter__(self) -> "ReplicaService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- service-compatible reader surface -----------------------------------

    @property
    def version(self) -> int:
        snap = self._snap
        return 0 if snap is None else snap.version

    @property
    def stream_version(self) -> int:
        snap = self._snap
        return 0 if snap is None else snap.stream_version

    @property
    def epoch(self) -> int:
        """Writer epoch of the served snapshot (bumps on writer
        restart)."""
        return int(self._ident[0])

    @property
    def thread_alive(self) -> bool:
        """False only when the attach thread was started and died — the
        /health 503 condition (a replica that cannot follow the writer
        any more must be ejected by the balancer)."""
        if not self._started or self._stop_evt.is_set():
            return True
        t = self._thread
        return t is not None and t.is_alive()

    @property
    def dirty(self) -> int:
        """The writer's advisory write-backlog slot."""
        try:
            return int(self.replica.read_control()["dirty"])
        except Exception:                    # noqa: BLE001
            return 0

    @property
    def sizes(self):
        return tuple(int(s) for s in self._meta_sizes())

    def _meta_sizes(self):
        b = self.replica._bundle
        return [] if b is None else b.meta.get("sizes", [])

    def staleness_s(self) -> float:
        """Cross-process staleness: wall-clock now − the writer's
        publish wall time."""
        snap = self._snap
        if snap is None:
            return float("inf")
        return max(0.0, time.time() - snap.published_wall)

    @property
    def scrub_clean(self) -> bool:
        """False while the held bundle is known corrupt (scrub found a
        checksum mismatch and no verified attach has superseded it) — the
        /health 503 condition for silent corruption."""
        return not (self._corrupt or self._stats["scrub_violations"])

    def resilience_stats(self) -> dict:
        """Integrity/escalation counters (mirrors the writer-side and
        router ``resilience_stats`` contract)."""
        s = self._stats
        return {k: s[k] for k in (
            "scrubs", "scrub_violations", "shm_corruptions",
            "writer_dead_signals", "attach_errors")}

    def stats(self) -> dict:
        out = dict(self._stats)
        snap = self._snap
        out.update(role="replica", version=self.version,
                   stream_version=self.stream_version, epoch=self.epoch,
                   clusters=0 if snap is None else len(snap.index),
                   dirty=self.dirty, staleness_s=self.staleness_s(),
                   thread_alive=self.thread_alive,
                   scrub_clean=self.scrub_clean,
                   sizes=list(self._meta_sizes()))
        return out

    def snapshot(self, at_least_version: Optional[int] = None,
                 timeout: Optional[float] = None):
        snap = self._snap
        if at_least_version is None:
            if snap is None:
                raise RuntimeError("no snapshot attached yet")
            return snap
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cv:
            while self._snap is None or \
                    self._snap.version < at_least_version:
                remaining = (None if deadline is None
                             else deadline - time.monotonic())
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(
                        f"version {at_least_version} not published "
                        f"within {timeout}s (current: {self.version})")
                self._cv.wait(timeout=remaining)
            return self._snap

    def query(self, entity=None, mode=None, signature=None, k: int = 10,
              at_least_version: Optional[int] = None,
              timeout: Optional[float] = None):
        from .service import QueryResult, snapshot_query
        snap = self.snapshot(at_least_version, timeout)
        hits = snapshot_query(snap, entity=entity, mode=mode,
                              signature=signature, k=k)
        return QueryResult(snap.version, snap.stream_version, hits)

    def query_batch(self, entities, mode=None, k: int = 10,
                    at_least_version: Optional[int] = None,
                    timeout: Optional[float] = None):
        from .service import QueryResult, snapshot_query_batch
        snap = self.snapshot(at_least_version, timeout)
        return QueryResult(snap.version, snap.stream_version,
                           snapshot_query_batch(snap, entities, mode, k))
