"""Cluster-query serving surface: mined clusters as a queryable index
(DESIGN.md §8; ROADMAP "serving surface for mined clusters").

``postprocess`` ranks and exports clusters; this module makes them
*servable*: a :class:`ClusterIndex` built once from any engine's
``PipelineResult`` answers point lookups —

* ``entity → clusters``: every kept cluster whose mode-``mode``
  component (any mode when unspecified) contains the entity,
* ``signature → cluster``: exact lookup by the 2×32-bit cluster
  signature, the stable cross-engine cluster identity (all engines with
  the same seed emit bit-identical signatures, so a signature handed
  out by a batch job resolves against a streaming snapshot's index).

Index construction is *vectorised* (the serving layer rebuilds it on
every snapshot swap, so it sits on the swap's critical path): the kept
tuples' component windows are stacked with one repeat/cumsum gather per
mode, deduplicated as packed ``(cluster << 32) | entity`` words with a
single ``np.unique``, and re-sorted once into per-mode
``(entity << 32) | cluster`` membership arrays (``mode_pairs``).
Entity queries are then two ``searchsorted`` probes; the ranking layer
(``serve.ranking``) reuses the same arrays for its batched path.
``cluster_query`` is the one-shot convenience wrapper; long-lived
serving should build the index once per snapshot
(``serve.service.TriclusterService`` does).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

_LOW32 = np.uint64(0xFFFFFFFF)
_HIGH32 = np.uint64(0xFFFFFFFF00000000)
_U32 = np.uint64(32)


class LazyComponents:
    """Tuple-like per-mode component sets of one cluster, materialised
    per mode on first access from the index's shared stacked membership
    arrays.  Serving-path queries (ranked hits: signature/score/stats)
    usually never touch the sets, and eagerly building them dominated
    snapshot-swap latency — tens of millions of set inserts per swap at
    benchmark scale."""
    __slots__ = ("_ents", "_bounds", "_row", "_sets")

    def __init__(self, ents, bounds, row: int):
        self._ents = ents        # per mode: int64 member array
        self._bounds = bounds    # per mode: (n_clusters+1,) offsets
        self._row = row
        self._sets = [None] * len(ents)

    def __len__(self) -> int:
        return len(self._sets)

    def __getitem__(self, k):
        if isinstance(k, slice):
            return tuple(self[i] for i in range(len(self._sets))[k])
        if k < 0:
            k += len(self._sets)
        s = self._sets[k]
        if s is None:
            b = self._bounds[k]
            s = frozenset(
                self._ents[k][b[self._row]:b[self._row + 1]].tolist())
            self._sets[k] = s
        return s

    def __iter__(self):
        return (self[k] for k in range(len(self._sets)))

    def __eq__(self, other):
        if not isinstance(other, (tuple, list, LazyComponents)):
            return NotImplemented
        return (len(self) == len(other)
                and all(a == b for a, b in zip(self, other)))

    def __hash__(self):
        return hash(tuple(self))

    def __repr__(self):
        return repr(tuple(self))


@dataclasses.dataclass(frozen=True)
class ClusterView:
    """One mined cluster, host-side: per-mode component sets + stats."""
    signature: Tuple[int, int]            # (sig_lo, sig_hi) cluster id
    components: Tuple[frozenset, ...]     # per-mode entity-id sets
                                          # (or an equivalent
                                          # LazyComponents)
    density: float
    gen_count: int
    volume: float

    @property
    def arity(self) -> int:
        return len(self.components)

    def contains(self, entity: int, mode: Optional[int] = None) -> bool:
        if mode is not None:
            return entity in self.components[mode]
        return any(entity in c for c in self.components)

    def format(self, names=None) -> str:
        # deferred: postprocess pulls the jit engines; replica reader
        # processes (serve.shm) never need them
        from ..core import postprocess as PP
        return PP.format_cluster(self.components, names=names,
                                 density=self.density)


def pack_sig_words(sig_lo, sig_hi) -> np.ndarray:
    """(lo, hi) signature pairs → one ``(hi << 32) | lo`` uint64 word —
    Stage 3's packed sort key, reused as the cluster identity that
    row-orders every index (``serve.ranking.pack_signatures`` is the
    same packing, re-exported there for the query side)."""
    lo = np.asarray(sig_lo).astype(np.uint64) & _LOW32
    hi = np.asarray(sig_hi).astype(np.uint64) & _LOW32
    return (hi << _U32) | lo


def _merge_sorted(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Merge two sorted uint64 arrays with disjoint values — one
    ``searchsorted`` + one ``np.insert`` memcpy, no re-sort."""
    if not b.size:
        return a
    if not a.size:
        return b
    return np.insert(a, np.searchsorted(a, b), b)


def _window_ce(rlo_k, rhi_k, sorted_e_k, sel, cl_rows) -> np.ndarray:
    """Stack the component windows of result rows ``sel`` per mode:
    repeat/cumsum flat gather, dedup as ``(cluster_row << 32) | entity``
    words in ONE ``np.unique`` (``cl_rows[i]`` is the index row embedded
    for ``sel[i]``) — the per-cluster python loop this replaces
    dominated snapshot-swap latency at serving scale."""
    counts = (rhi_k[sel] - rlo_k[sel]).astype(np.int64)
    total = int(counts.sum())
    starts = np.cumsum(counts) - counts
    flat = (np.arange(total, dtype=np.int64)
            - np.repeat(starts, counts)
            + np.repeat(rlo_k[sel].astype(np.int64), counts))
    ent = sorted_e_k[flat].astype(np.uint64)
    return np.unique(
        (np.repeat(cl_rows.astype(np.uint64), counts) << _U32) | ent)


class ClusterIndex:
    """Inverted index over kept clusters of one mining result.

    ``mode_pairs`` — one sorted uint64 array per mode of packed
    ``(entity << 32) | cluster_row`` membership words — is the single
    structure behind entity lookups here and the batched top-k path in
    ``serve.ranking``.  Indexes built by :meth:`from_result` /
    :meth:`delta_from_result` / :meth:`from_arrays` additionally carry
    ``packed_sigs`` (sorted — cluster rows are *signature-ordered*),
    ``comp_ents`` and ``comp_bounds``, which makes them delta-
    maintainable (``supports_delta``) and shared-memory-publishable
    (``serve.shm``); an index built from a plain cluster list
    reconstructs ``mode_pairs`` but supports neither."""

    def __init__(self, clusters: Optional[List[ClusterView]] = None,
                 mode_pairs: Optional[Sequence[np.ndarray]] = None, *,
                 any_pairs: Optional[np.ndarray] = None,
                 comp_ents: Optional[Sequence[np.ndarray]] = None,
                 comp_bounds: Optional[Sequence[np.ndarray]] = None,
                 packed_sigs: Optional[np.ndarray] = None,
                 stats: Optional[Tuple] = None):
        if clusters is None:
            # vectorised path: per-row stats arrays, NO view objects —
            # ``clusters`` materialises lazily; eager construction of
            # tens of thousands of views per swap was the dominant term
            # of the delta rebuild (it is O(clusters), the splice is
            # O(changed))
            if stats is None or comp_ents is None:
                raise ValueError("array-built index needs stats= and "
                                 "comp_ents=")
            (self.sig_lo, self.sig_hi, self.density, self.gen_count,
             self.volume) = (np.asarray(a) for a in stats)
            self._clusters: Optional[List[ClusterView]] = None
            self._view_cache: dict = {}
            self._n = int(self.sig_lo.size)
            arity = len(comp_ents)
        else:
            self._clusters = list(clusters)
            self._n = len(self._clusters)
            arity = self._clusters[0].arity if self._clusters else 0
            self.sig_lo = np.fromiter(
                (c.signature[0] for c in self._clusters), np.int64,
                self._n)
            self.sig_hi = np.fromiter(
                (c.signature[1] for c in self._clusters), np.int64,
                self._n)
            self.density = np.fromiter(
                (c.density for c in self._clusters), np.float64, self._n)
            self.gen_count = np.fromiter(
                (c.gen_count for c in self._clusters), np.int64, self._n)
            self.volume = np.fromiter(
                (c.volume for c in self._clusters), np.float64, self._n)
        self._by_sig: Optional[dict] = None
        if mode_pairs is None:
            mode_pairs = []
            for k in range(arity):
                pairs = [(int(e) << 32) | row
                         for row, c in enumerate(self.clusters)
                         for e in c.components[k]]
                mode_pairs.append(np.sort(np.asarray(pairs, np.uint64)))
        self._mode_pairs: Optional[List[np.ndarray]] = list(mode_pairs)
        self._any_pairs: Optional[np.ndarray] = (
            any_pairs if any_pairs is not None
            else np.unique(np.concatenate(self._mode_pairs))
            if self._mode_pairs else np.zeros(0, np.uint64))
        # row-major stacked members (``LazyComponents`` backing) and the
        # sorted packed signature words — present iff built vectorised
        self._comp_ents = None if comp_ents is None else list(comp_ents)
        self._comp_bounds = (None if comp_bounds is None
                             else list(comp_bounds))
        self.packed_sigs = packed_sigs
        self._arity = len(self._mode_pairs)
        self._init_overlay_none()

    def _init_overlay_none(self) -> None:
        # overlay state (see delta_from_result): None/0 on flat indexes
        self._base: Optional["ClusterIndex"] = None
        self._lut: Optional[np.ndarray] = None          # id -> row, -1 dead
        self._id_of_row: Optional[np.ndarray] = None    # row -> stable id
        self._ov_words: Optional[List[np.ndarray]] = None
        self._ov_ents: Optional[List[np.ndarray]] = None
        self._ov_bounds: Optional[List[np.ndarray]] = None
        self._ov_any: Optional[np.ndarray] = None
        self._n_ov = 0
        self._dead_words = 0

    # -- flat stacked arrays -------------------------------------------------
    # On a delta-built (overlay) index these materialise lazily — and
    # cache — so the swap-critical delta build never pays for them; the
    # zero-copy publisher, the batched stacker and identity checks do,
    # once, on first demand.

    @property
    def arity(self) -> int:
        """Number of modes, without materialising anything."""
        return self._arity

    @property
    def mode_pairs(self) -> List[np.ndarray]:
        if self._mode_pairs is None:
            self._ensure_flat()
        return self._mode_pairs

    @property
    def any_pairs(self) -> np.ndarray:
        if self._any_pairs is None:
            self._ensure_flat()
        return self._any_pairs

    @property
    def comp_ents(self) -> Optional[List[np.ndarray]]:
        if self._comp_ents is None and self._base is not None:
            self._ensure_flat()
        return self._comp_ents

    @property
    def comp_bounds(self) -> Optional[List[np.ndarray]]:
        if self._comp_bounds is None and self._base is not None:
            self._ensure_flat()
        return self._comp_bounds

    @property
    def clusters(self) -> List[ClusterView]:
        """Per-row host views, built on first access (bulk ``.tolist``
        — cheaper than per-row numpy scalar indexing)."""
        if self._clusters is None:
            slo_l, shi_l = self.sig_lo.tolist(), self.sig_hi.tolist()
            dens_l = self.density.tolist()
            gen_l, vol_l = self.gen_count.tolist(), self.volume.tolist()
            cache = self._view_cache
            # reuse any per-row views already handed out: callers may
            # hold them and rely on identity with later lookups
            self._clusters = [cache.get(i) or ClusterView(
                signature=(slo_l[i], shi_l[i]),
                components=LazyComponents(*self._comp_source(i)),
                density=dens_l[i], gen_count=gen_l[i], volume=vol_l[i])
                for i in range(self._n)]
        return self._clusters

    def _comp_source(self, row: int):
        """(ents, bounds, index) triple backing ``row``'s per-mode
        component slices — the base arrays for carried-over clusters,
        the overlay for clusters first seen after the base snapshot;
        never materialises the flat arrays."""
        if self._base is None or self._comp_ents is not None:
            return self._comp_ents, self._comp_bounds, row
        i = int(self._id_of_row[row])
        nb = len(self._base)
        if i < nb:
            return self._base._comp_ents, self._base._comp_bounds, i
        return self._ov_ents, self._ov_bounds, i - nb

    def view_at(self, row: int) -> ClusterView:
        """One row's view without materialising the whole list — the
        ranked-hit path touches k rows of tens of thousands.  Views are
        memoised per row, so repeated hits share one object."""
        if self._clusters is not None:
            return self._clusters[row]
        row = int(row)
        v = self._view_cache.get(row)
        if v is None:
            # setdefault: concurrent readers racing on the same row
            # still end up sharing one canonical view object
            v = self._view_cache.setdefault(row, ClusterView(
                signature=(int(self.sig_lo[row]), int(self.sig_hi[row])),
                components=LazyComponents(*self._comp_source(row)),
                density=float(self.density[row]),
                gen_count=int(self.gen_count[row]),
                volume=float(self.volume[row])))
        return v

    def signature_keys(self) -> List[Tuple[int, int]]:
        """Row-aligned ``(sig_lo, sig_hi)`` tuples without building
        views (the recency/first-seen bookkeeping key)."""
        return list(zip(self.sig_lo.tolist(), self.sig_hi.tolist()))

    @property
    def supports_delta(self) -> bool:
        """True when this index carries the signature-sorted stacked
        arrays (or an overlay over them) that
        :meth:`delta_from_result` extends; never materialises."""
        return (self.packed_sigs is not None
                and (self._comp_ents is not None
                     or self._base is not None))

    @staticmethod
    def _kept_rows(result, only_kept: bool, min_density: float):
        """Select kept result rows and order them by packed signature —
        the row order of every vectorised index.  Signature order (not
        keep order) is what makes delta maintenance O(changed): the
        survivor old→new row remap is then monotone, so masked old
        arrays stay sorted after remapping."""
        for field in ("range_lo", "range_hi", "sorted_e"):
            if not hasattr(result, field):
                raise ValueError(
                    f"result has no '{field}' — component windows are "
                    "needed to build a ClusterIndex (DistributedResult "
                    "does not carry them; build the index from a "
                    "batch/streaming PipelineResult of the same context "
                    "and resolve signatures against it)")
        flag = np.asarray(result.keep if only_kept else result.is_unique)
        dens = np.asarray(result.density)
        if min_density:
            flag = flag & (dens >= min_density)
        sel = np.nonzero(flag)[0]
        slo = np.asarray(result.sig_lo)
        shi = np.asarray(result.sig_hi)
        sw = pack_sig_words(slo[sel], shi[sel])
        order = np.argsort(sw, kind="stable")
        return sel[order], sw[order], slo, shi, dens

    @staticmethod
    def _stats_for(result, sel, slo, shi, dens) -> Tuple:
        """Row-aligned per-cluster stats arrays (no view objects — the
        views materialise lazily from exactly these arrays)."""
        return (slo[sel], shi[sel], dens[sel],
                np.asarray(result.gen_count)[sel],
                np.asarray(result.volume)[sel])

    @classmethod
    def from_result(cls, result, only_kept: bool = True,
                    min_density: float = 0.0) -> "ClusterIndex":
        """Build from a ``PipelineResult`` (batch / NOAC / streaming —
        any result carrying component windows).  ``DistributedResult``
        ships per-shard aggregates without the windows; serve those by
        mining the snapshot through the streaming/batch engine (or
        ``DistributedMiner.serving_snapshot``), or resolve its
        signatures against an index built from one (the signatures are
        bit-identical across engines).

        This is the full rebuild — the *oracle* the delta path
        (:meth:`delta_from_result`) must reproduce bit-identically."""
        sel, packed, slo, shi, dens = cls._kept_rows(
            result, only_kept, min_density)
        rlo, rhi = np.asarray(result.range_lo), np.asarray(result.range_hi)
        sorted_e = np.asarray(result.sorted_e)
        n_modes = sorted_e.shape[0]
        nk = int(sel.size)
        comp_ents, comp_bounds, mode_pairs = [], [], []
        cl_rows = np.arange(nk, dtype=np.uint64)
        for k in range(n_modes):
            ce = _window_ce(rlo[k], rhi[k], sorted_e[k], sel, cl_rows)
            comp_ents.append((ce & _LOW32).astype(np.int64))
            comp_bounds.append(np.searchsorted(ce >> _U32,
                                               np.arange(nk + 1)))
            mode_pairs.append(np.sort((ce << _U32) | (ce >> _U32)))
        return cls(mode_pairs=mode_pairs, comp_ents=comp_ents,
                   comp_bounds=comp_bounds, packed_sigs=packed,
                   stats=cls._stats_for(result, sel, slo, shi, dens))

    @classmethod
    def delta_from_result(cls, prev: "ClusterIndex", result,
                          only_kept: bool = True,
                          min_density: float = 0.0) -> "ClusterIndex":
        """Build the index for ``result`` in O(changed clusters) by
        layering an *overlay* over ``prev``'s stacked arrays instead of
        restacking every membership word.

        Clusters are diffed by packed Stage-3 signature — the invariant
        this relies on is exactly the cross-engine identity contract:
        *signature-equal ⇒ membership-equal* (the signature is an
        order-independent hash of the component sets).  Survivors keep
        their *stable id* (their row in the base snapshot); the base
        membership arrays are never rewritten.  The delta build only

        * restacks the *dirty* clusters' windows into a small sorted
          overlay of ``(entity << 32) | id`` words,
        * rebuilds the O(n_clusters) id→row lut (``-1`` tombstones
          deleted clusters) and the per-row stats arrays.

        Queries answer from base + overlay directly (two probes, lut
        remap on the hit slice only), so the swap-critical path never
        touches the O(M) word arrays.  The canonical flat arrays — what
        ``from_result`` builds, and what the zero-copy publisher and
        the batched stacker consume — materialise lazily on first
        demand and are then cached, which also promotes this index to a
        base for the next delta.  Per-cluster stats (density /
        gen_count / volume *can* change for an unchanged signature) are
        re-read from ``result`` for every row, so the materialised
        output is bit-identical to ``from_result(result)``.

        Falls back to a full build when ``prev`` lacks the stacked
        arrays, or when the overlay / tombstoned portion outgrows the
        base (self-compaction keeps query probes cheap).
        """
        if not prev.supports_delta:
            return cls.from_result(result, only_kept=only_kept,
                                   min_density=min_density)
        sel, packed, slo, shi, dens = cls._kept_rows(
            result, only_kept, min_density)
        nk = int(sel.size)
        old = prev.packed_sigs
        n_old = int(old.size)
        # survivor matching: both signature lists sorted, one pass
        if n_old:
            pos = np.searchsorted(old, packed)
            posc = np.minimum(pos, n_old - 1)
            sur = old[posc] == packed
        else:
            pos = np.zeros(nk, np.int64)
            sur = np.zeros(nk, bool)
        new_sur = np.nonzero(sur)[0]
        old_sur = pos[sur]
        sur_mask_old = np.zeros(n_old, bool)
        sur_mask_old[old_sur] = True
        deleted_old = np.nonzero(~sur_mask_old)[0]
        dirty_rows = np.nonzero(~sur)[0]
        sel_dirty = sel[~sur]
        # a prev with materialised flat arrays is itself the next base
        # (chain depth stays 1); an un-materialised overlay prev shares
        # its base and extends its overlay
        if prev._comp_ents is not None:
            base, prev_ids, n_ov0, dead = prev, None, 0, 0
            ov_w0 = [np.zeros(0, np.uint64)] * prev._arity
            ov_e0 = [np.zeros(0, np.int64)] * prev._arity
            ov_b0 = [np.zeros(1, np.int64)] * prev._arity
            ov_a0 = np.zeros(0, np.uint64)
        else:
            base, prev_ids = prev._base, prev._id_of_row
            n_ov0, dead = prev._n_ov, prev._dead_words
            ov_w0, ov_e0 = prev._ov_words, prev._ov_ents
            ov_b0, ov_a0 = prev._ov_bounds, prev._ov_any
        nb = len(base)
        arity = base._arity
        rlo, rhi = np.asarray(result.range_lo), np.asarray(result.range_hi)
        # self-compaction: once the overlay plus the dead (tombstoned)
        # words outgrow the base, rebuild flat — the full build is the
        # oracle, so compaction is just from_result
        del_ids = (deleted_old if prev_ids is None
                   else prev_ids[deleted_old])
        dirty_est = 0
        for k in range(arity):
            bb, ob = base._comp_bounds[k], ov_b0[k]
            bi = del_ids[del_ids < nb]
            oi = del_ids[del_ids >= nb] - nb
            dead += int((bb[bi + 1] - bb[bi]).sum())
            dead += int((ob[oi + 1] - ob[oi]).sum())
            dirty_est += int((rhi[k][sel_dirty] - rlo[k][sel_dirty]).sum())
        base_words = (sum(int(mp.size) for mp in base._mode_pairs)
                      + int(base._any_pairs.size))
        ov_words_est = sum(int(w.size) for w in ov_w0) + 2 * dirty_est
        if nb == 0 or 2 * (ov_words_est + dead) > base_words:
            return cls.from_result(result, only_kept=only_kept,
                                   min_density=min_density)
        # stable ids: survivors inherit, dirty clusters get fresh ids
        # appended after the base + existing overlay
        n_dirty = int(dirty_rows.size)
        id_of_row = np.empty(nk, np.int64)
        id_of_row[new_sur] = (old_sur if prev_ids is None
                              else prev_ids[old_sur])
        new_ids = nb + n_ov0 + np.arange(n_dirty, dtype=np.int64)
        id_of_row[dirty_rows] = new_ids
        lut = np.full(nb + n_ov0 + n_dirty, -1, np.int64)
        lut[id_of_row] = np.arange(nk, dtype=np.int64)
        # restack ONLY the dirty clusters' windows, keyed by stable id
        sorted_e = np.asarray(result.sorted_e)
        gid_bounds = np.arange(nb + n_ov0, nb + n_ov0 + n_dirty + 1)
        ov_words, ov_ents, ov_bounds, dirty_any = [], [], [], []
        for k in range(arity):
            ce_d = _window_ce(rlo[k], rhi[k], sorted_e[k], sel_dirty,
                              new_ids.astype(np.uint64))
            w_d = np.sort((ce_d << _U32) | (ce_d >> _U32))
            dirty_any.append(w_d)
            ov_words.append(_merge_sorted(ov_w0[k], w_d))
            ov_ents.append(np.concatenate(
                (ov_e0[k], (ce_d & _LOW32).astype(np.int64))))
            db = np.searchsorted(ce_d >> _U32, gid_bounds)
            ov_bounds.append(np.concatenate(
                (ov_b0[k], ov_b0[k][-1] + db[1:])))
        a_d = (np.unique(np.concatenate(dirty_any)) if dirty_any
               else np.zeros(0, np.uint64))
        ov_any = _merge_sorted(ov_a0, a_d)
        return cls._make_overlay(
            base=base, lut=lut, id_of_row=id_of_row, ov_words=ov_words,
            ov_ents=ov_ents, ov_bounds=ov_bounds, ov_any=ov_any,
            n_ov=n_ov0 + n_dirty, dead_words=dead, packed_sigs=packed,
            stats=cls._stats_for(result, sel, slo, shi, dens))

    @classmethod
    def _make_overlay(cls, *, base, lut, id_of_row, ov_words, ov_ents,
                      ov_bounds, ov_any, n_ov, dead_words, packed_sigs,
                      stats) -> "ClusterIndex":
        self = object.__new__(cls)
        (self.sig_lo, self.sig_hi, self.density, self.gen_count,
         self.volume) = (np.asarray(a) for a in stats)
        self._clusters = None
        self._view_cache = {}
        self._n = int(self.sig_lo.size)
        self._by_sig = None
        self._mode_pairs = None
        self._any_pairs = None
        self._comp_ents = None
        self._comp_bounds = None
        self.packed_sigs = packed_sigs
        self._arity = base._arity
        self._base = base
        self._lut = lut
        self._id_of_row = id_of_row
        self._ov_words = ov_words
        self._ov_ents = ov_ents
        self._ov_bounds = ov_bounds
        self._ov_any = ov_any
        self._n_ov = int(n_ov)
        self._dead_words = int(dead_words)
        return self

    def _ensure_flat(self) -> None:
        """Materialise (and cache) the canonical flat arrays of an
        overlay-backed index — bit-identical to what ``from_result``
        builds for the same snapshot.  Off the swap-critical path: runs
        on first demand from the zero-copy publisher, the batched
        stacker, or an identity check; afterwards this index serves as
        a base for subsequent deltas."""
        if self._mode_pairs is not None:
            return
        base, nk = self._base, self._n
        nb = len(base)
        lut_b = self._lut[:nb]
        alive_b = lut_b >= 0
        have_dead = not bool(alive_b.all())
        # sentinel splice: an O(n) L2-resident table re-stamps the low
        # 32-bit id field to the current row with one gather + add (the
        # shift never borrows into the entity field; uint64 wraparound
        # realises negative shifts).  Tombstoned ids carry bit 63 — a
        # live word never does while entity ids stay below 2^31 — so
        # one compare + compress drops deleted clusters' words.
        _SENT = np.uint64(1) << np.uint64(63)
        tab = (lut_b - np.arange(nb, dtype=np.int64)).astype(np.uint64)
        tab[~alive_b] = _SENT
        plain = bool(
            all(not mp.size or mp[-1] < _SENT
                for mp in base._mode_pairs)
            and (not base._any_pairs.size
                 or base._any_pairs[-1] < _SENT))
        lut = self._lut

        def splice(words: np.ndarray, ov: np.ndarray) -> np.ndarray:
            if words.size:
                if plain:
                    v = words + tab[words & _LOW32]
                    v = v[v < _SENT] if have_dead else v
                else:
                    # entity ids >= 2^31 collide with the sentinel:
                    # fall back to an explicit keep gather
                    ids = (words & _LOW32).astype(np.int64)
                    v = words + (tab[ids] & ~_SENT)
                    v = v[alive_b[ids]] if have_dead else v
            else:
                v = words
            if ov.size:
                rows_o = lut[(ov & _LOW32).astype(np.int64)]
                ok = rows_o >= 0
                w_o = np.sort((ov[ok] & _HIGH32)
                              | rows_o[ok].astype(np.uint64))
                v = _merge_sorted(v, w_o)
            return v

        mode_pairs = [splice(base._mode_pairs[k], self._ov_words[k])
                      for k in range(self._arity)]
        any_pairs = splice(base._any_pairs, self._ov_any)
        # row-major members: base survivors keep contiguous slices in
        # row order (the id→row remap is monotone on the base — both
        # orders are signature order); alive overlay clusters' slices
        # are inserted at their row's offset
        rows_ov = lut[nb:]
        alive_o = np.nonzero(rows_ov >= 0)[0]
        ord_o = alive_o[np.argsort(rows_ov[alive_o], kind="stable")]
        comp_ents, comp_bounds = [], []
        for k in range(self._arity):
            pe, pb = base._comp_ents[k], base._comp_bounds[k]
            oc = np.diff(pb)
            pe_sur = pe[np.repeat(alive_b, oc)] if have_dead else pe
            ob = self._ov_bounds[k]
            ocnt = ob[1:] - ob[:-1]
            counts = np.zeros(nk, np.int64)
            counts[lut_b[alive_b]] = oc[alive_b]
            counts[rows_ov[alive_o]] = ocnt[alive_o]
            comp_bounds.append(np.concatenate(
                (np.zeros(1, np.int64),
                 np.cumsum(counts, dtype=np.int64))))
            if ord_o.size:
                oe = self._ov_ents[k]
                ents_o = np.concatenate(
                    [oe[ob[i]:ob[i + 1]] for i in ord_o.tolist()])
                counts_sur = counts.copy()
                counts_sur[rows_ov[alive_o]] = 0
                sur_prefix = np.concatenate(
                    (np.zeros(1, np.int64),
                     np.cumsum(counts_sur, dtype=np.int64)))
                obj = np.repeat(sur_prefix[rows_ov[ord_o]],
                                ocnt[ord_o])
                comp_ents.append(np.insert(pe_sur, obj, ents_o))
            else:
                comp_ents.append(pe_sur)
        self._mode_pairs = mode_pairs
        self._any_pairs = any_pairs
        self._comp_ents = comp_ents
        self._comp_bounds = comp_bounds

    @classmethod
    def from_arrays(cls, packed_sigs, mode_pairs, comp_ents, comp_bounds,
                    any_pairs, density, gen_count,
                    volume) -> "ClusterIndex":
        """Reassemble an index from its published stacked arrays — the
        replica-reader path (``serve.shm``): the arrays arrive as
        zero-copy shared-memory views and are *not* copied here; only
        the per-row host views are rebuilt."""
        sigs_lo = (np.asarray(packed_sigs) & _LOW32).astype(np.int64)
        sigs_hi = (np.asarray(packed_sigs) >> _U32).astype(np.int64)
        return cls(mode_pairs=list(mode_pairs),
                   any_pairs=any_pairs, comp_ents=list(comp_ents),
                   comp_bounds=list(comp_bounds), packed_sigs=packed_sigs,
                   stats=(sigs_lo, sigs_hi, np.asarray(density),
                          np.asarray(gen_count), np.asarray(volume)))

    def __len__(self) -> int:
        return self._n

    def __iter__(self) -> Iterator[ClusterView]:
        return iter(self.clusters)

    def _lookup_sig(self, sig: Tuple[int, int]) -> Optional[ClusterView]:
        """Exact-signature row: one searchsorted probe into the sorted
        packed words when available, else a lazily-built dict."""
        if self.packed_sigs is not None:
            w = pack_sig_words(sig[0], sig[1])
            i = int(np.searchsorted(self.packed_sigs, w))
            if i < self._n and self.packed_sigs[i] == w:
                return self.view_at(i)
            return None
        if self._by_sig is None:
            self._by_sig = {c.signature: c for c in self.clusters}
        return self._by_sig.get((int(sig[0]), int(sig[1])))

    def entity_rows(self, entity: int,
                    mode: Optional[int] = None) -> np.ndarray:
        """Cluster rows whose mode-``mode`` (any-mode when None)
        component contains ``entity``, ascending — two ``searchsorted``
        probes into the packed membership words."""
        e = int(entity)
        if e < 0 or e >= 1 << 32:
            return np.zeros(0, np.int64)

        def window(pairs: np.ndarray) -> np.ndarray:
            lo = np.searchsorted(pairs, np.uint64(e << 32))
            hi = (pairs.size if e + 1 >= 1 << 32  # avoid uint64 overflow
                  else np.searchsorted(pairs, np.uint64((e + 1) << 32)))
            return pairs[lo:hi]

        if self._base is None or self._mode_pairs is not None:
            pairs = (self.any_pairs if mode is None
                     else self.mode_pairs[mode])
            return (window(pairs) & _LOW32).astype(np.int64)
        # overlay path: probe base + overlay words (both keyed by stable
        # id), remap the hit slices through the lut, drop tombstones
        base = self._base
        b = (base._any_pairs if mode is None else base._mode_pairs[mode])
        o = self._ov_any if mode is None else self._ov_words[mode]
        ids = np.concatenate(((window(b) & _LOW32).astype(np.int64),
                              (window(o) & _LOW32).astype(np.int64)))
        rows = self._lut[ids]
        return np.sort(rows[rows >= 0])

    def query(self, entity: Optional[int] = None,
              mode: Optional[int] = None,
              signature: Optional[Tuple[int, int]] = None,
              min_density: float = 0.0) -> List[ClusterView]:
        """Kept clusters matching the given constraints.

        ``signature=(lo, hi)``: exact cluster lookup (≤ 1 hit).
        ``entity=e [, mode=k]``: membership in mode ``k``'s component
        (any mode when ``mode`` is None; ``mode`` without ``entity`` is
        rejected).  Constraints combine with AND.
        """
        if mode is not None:
            if entity is None:
                raise ValueError("mode=... requires entity=...")
            if self._n and not 0 <= mode < self._arity:
                raise ValueError(f"mode {mode} out of range")
            if not self._n:                 # empty index: no hits
                return []
        if signature is not None:
            hit = self._lookup_sig((int(signature[0]),
                                    int(signature[1])))
            out = [] if hit is None else [hit]
            if entity is not None:
                out = [c for c in out if c.contains(int(entity), mode)]
        elif entity is not None:
            out = [self.view_at(r)
                   for r in self.entity_rows(entity, mode)]
        else:
            out = list(self.clusters)
        if min_density:
            out = [c for c in out if c.density >= min_density]
        return out


def cluster_query(result, entity: Optional[int] = None,
                  mode: Optional[int] = None,
                  signature: Optional[Tuple[int, int]] = None,
                  min_density: float = 0.0,
                  only_kept: bool = True) -> List[ClusterView]:
    """One-shot query over a mining result: build the index and look up
    (``ClusterIndex.from_result(...).query(...)``).

    Hits come back *ranked* — best density first (ties keep index
    order), matching the serving layer's default policy — not in
    whatever order the index happened to store them."""
    hits = ClusterIndex.from_result(result, only_kept=only_kept).query(
        entity=entity, mode=mode, signature=signature,
        min_density=min_density)
    from .ranking import rank_views       # deferred: ranking imports us
    return [v for v, _ in rank_views([(c, c.density) for c in hits])]
