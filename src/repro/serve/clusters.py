"""Cluster-query serving surface: mined clusters as a queryable index
(DESIGN.md §8; ROADMAP "serving surface for mined clusters").

``postprocess`` ranks and exports clusters; this module makes them
*servable*: a :class:`ClusterIndex` built once from any engine's
``PipelineResult`` answers point lookups —

* ``entity → clusters``: every kept cluster whose mode-``mode``
  component (any mode when unspecified) contains the entity,
* ``signature → cluster``: exact lookup by the 2×32-bit cluster
  signature, the stable cross-engine cluster identity (all engines with
  the same seed emit bit-identical signatures, so a signature handed
  out by a batch job resolves against a streaming snapshot's index).

Index construction is one host pass over the kept tuples' component
windows (the O(|I|) post-processing cost the paper's §2 budgets);
queries are dictionary lookups.  ``cluster_query`` is the one-shot
convenience wrapper; long-lived serving should build the index once per
snapshot.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional, Tuple

import numpy as np

from ..core import postprocess as PP


@dataclasses.dataclass(frozen=True)
class ClusterView:
    """One mined cluster, host-side: per-mode component sets + stats."""
    signature: Tuple[int, int]            # (sig_lo, sig_hi) cluster id
    components: Tuple[frozenset, ...]     # per-mode entity-id sets
    density: float
    gen_count: int
    volume: float

    @property
    def arity(self) -> int:
        return len(self.components)

    def contains(self, entity: int, mode: Optional[int] = None) -> bool:
        if mode is not None:
            return entity in self.components[mode]
        return any(entity in c for c in self.components)

    def format(self, names=None) -> str:
        return PP.format_cluster(self.components, names=names,
                                 density=self.density)


class ClusterIndex:
    """Inverted index over kept clusters of one mining result."""

    def __init__(self, clusters: List[ClusterView]):
        self.clusters = list(clusters)
        self._by_sig = {c.signature: c for c in self.clusters}
        arity = self.clusters[0].arity if self.clusters else 0
        self._by_entity: list[dict] = [{} for _ in range(arity)]
        for c in self.clusters:
            for k, comp in enumerate(c.components):
                for e in comp:
                    self._by_entity[k].setdefault(int(e), []).append(c)

    @classmethod
    def from_result(cls, result, only_kept: bool = True,
                    min_density: float = 0.0) -> "ClusterIndex":
        """Build from a ``PipelineResult`` (batch / NOAC / streaming —
        any result carrying component windows).  ``DistributedResult``
        ships per-shard aggregates without the windows; serve those by
        mining the snapshot through the streaming/batch engine, or
        resolve its signatures against an index built from one (the
        signatures are bit-identical across engines)."""
        for field in ("range_lo", "range_hi", "sorted_e"):
            if not hasattr(result, field):
                raise ValueError(
                    f"result has no '{field}' — component windows are "
                    "needed to build a ClusterIndex (DistributedResult "
                    "does not carry them; build the index from a "
                    "batch/streaming PipelineResult of the same context "
                    "and resolve signatures against it)")
        flag = np.asarray(result.keep if only_kept else result.is_unique)
        dens = np.asarray(result.density)
        if min_density:
            flag = flag & (dens >= min_density)
        rlo, rhi = np.asarray(result.range_lo), np.asarray(result.range_hi)
        sorted_e = np.asarray(result.sorted_e)
        slo = np.asarray(result.sig_lo)
        shi = np.asarray(result.sig_hi)
        gen = np.asarray(result.gen_count)
        vol = np.asarray(result.volume)
        n = sorted_e.shape[0]
        views = []
        for i in np.nonzero(flag)[0]:
            comps = tuple(
                frozenset(np.unique(sorted_e[k][rlo[k, i]:rhi[k, i]])
                          .tolist())
                for k in range(n))
            views.append(ClusterView(
                signature=(int(slo[i]), int(shi[i])), components=comps,
                density=float(dens[i]), gen_count=int(gen[i]),
                volume=float(vol[i])))
        return cls(views)

    def __len__(self) -> int:
        return len(self.clusters)

    def __iter__(self) -> Iterator[ClusterView]:
        return iter(self.clusters)

    def query(self, entity: Optional[int] = None,
              mode: Optional[int] = None,
              signature: Optional[Tuple[int, int]] = None,
              min_density: float = 0.0) -> List[ClusterView]:
        """Kept clusters matching the given constraints.

        ``signature=(lo, hi)``: exact cluster lookup (≤ 1 hit).
        ``entity=e [, mode=k]``: membership in mode ``k``'s component
        (any mode when ``mode`` is None; ``mode`` without ``entity`` is
        rejected).  Constraints combine with AND.
        """
        if mode is not None:
            if entity is None:
                raise ValueError("mode=... requires entity=...")
            if self._by_entity and not 0 <= mode < len(self._by_entity):
                raise ValueError(f"mode {mode} out of range")
            if not self._by_entity:         # empty index: no hits
                return []
        if signature is not None:
            hit = self._by_sig.get((int(signature[0]), int(signature[1])))
            out = [] if hit is None else [hit]
            if entity is not None:
                out = [c for c in out if c.contains(int(entity), mode)]
        elif entity is not None:
            if mode is not None:
                out = list(self._by_entity[mode].get(int(entity), []))
            else:       # any-mode: union of the per-mode inverted maps
                seen, out = set(), []
                for by in self._by_entity:
                    for c in by.get(int(entity), []):
                        if id(c) not in seen:
                            seen.add(id(c))
                            out.append(c)
        else:
            out = list(self.clusters)
        if min_density:
            out = [c for c in out if c.density >= min_density]
        return out


def cluster_query(result, entity: Optional[int] = None,
                  mode: Optional[int] = None,
                  signature: Optional[Tuple[int, int]] = None,
                  min_density: float = 0.0,
                  only_kept: bool = True) -> List[ClusterView]:
    """One-shot query over a mining result: build the index and look up
    (``ClusterIndex.from_result(...).query(...)``)."""
    return ClusterIndex.from_result(result, only_kept=only_kept).query(
        entity=entity, mode=mode, signature=signature,
        min_density=min_density)
