"""Cluster-query serving surface: mined clusters as a queryable index
(DESIGN.md §8; ROADMAP "serving surface for mined clusters").

``postprocess`` ranks and exports clusters; this module makes them
*servable*: a :class:`ClusterIndex` built once from any engine's
``PipelineResult`` answers point lookups —

* ``entity → clusters``: every kept cluster whose mode-``mode``
  component (any mode when unspecified) contains the entity,
* ``signature → cluster``: exact lookup by the 2×32-bit cluster
  signature, the stable cross-engine cluster identity (all engines with
  the same seed emit bit-identical signatures, so a signature handed
  out by a batch job resolves against a streaming snapshot's index).

Index construction is *vectorised* (the serving layer rebuilds it on
every snapshot swap, so it sits on the swap's critical path): the kept
tuples' component windows are stacked with one repeat/cumsum gather per
mode, deduplicated as packed ``(cluster << 32) | entity`` words with a
single ``np.unique``, and re-sorted once into per-mode
``(entity << 32) | cluster`` membership arrays (``mode_pairs``).
Entity queries are then two ``searchsorted`` probes; the ranking layer
(``serve.ranking``) reuses the same arrays for its batched path.
``cluster_query`` is the one-shot convenience wrapper; long-lived
serving should build the index once per snapshot
(``serve.service.TriclusterService`` does).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

from ..core import postprocess as PP

_LOW32 = np.uint64(0xFFFFFFFF)


class LazyComponents:
    """Tuple-like per-mode component sets of one cluster, materialised
    per mode on first access from the index's shared stacked membership
    arrays.  Serving-path queries (ranked hits: signature/score/stats)
    usually never touch the sets, and eagerly building them dominated
    snapshot-swap latency — tens of millions of set inserts per swap at
    benchmark scale."""
    __slots__ = ("_ents", "_bounds", "_row", "_sets")

    def __init__(self, ents, bounds, row: int):
        self._ents = ents        # per mode: int64 member array
        self._bounds = bounds    # per mode: (n_clusters+1,) offsets
        self._row = row
        self._sets = [None] * len(ents)

    def __len__(self) -> int:
        return len(self._sets)

    def __getitem__(self, k):
        if isinstance(k, slice):
            return tuple(self[i] for i in range(len(self._sets))[k])
        if k < 0:
            k += len(self._sets)
        s = self._sets[k]
        if s is None:
            b = self._bounds[k]
            s = frozenset(
                self._ents[k][b[self._row]:b[self._row + 1]].tolist())
            self._sets[k] = s
        return s

    def __iter__(self):
        return (self[k] for k in range(len(self._sets)))

    def __eq__(self, other):
        if not isinstance(other, (tuple, list, LazyComponents)):
            return NotImplemented
        return (len(self) == len(other)
                and all(a == b for a, b in zip(self, other)))

    def __hash__(self):
        return hash(tuple(self))

    def __repr__(self):
        return repr(tuple(self))


@dataclasses.dataclass(frozen=True)
class ClusterView:
    """One mined cluster, host-side: per-mode component sets + stats."""
    signature: Tuple[int, int]            # (sig_lo, sig_hi) cluster id
    components: Tuple[frozenset, ...]     # per-mode entity-id sets
                                          # (or an equivalent
                                          # LazyComponents)
    density: float
    gen_count: int
    volume: float

    @property
    def arity(self) -> int:
        return len(self.components)

    def contains(self, entity: int, mode: Optional[int] = None) -> bool:
        if mode is not None:
            return entity in self.components[mode]
        return any(entity in c for c in self.components)

    def format(self, names=None) -> str:
        return PP.format_cluster(self.components, names=names,
                                 density=self.density)


class ClusterIndex:
    """Inverted index over kept clusters of one mining result.

    ``mode_pairs`` — one sorted uint64 array per mode of packed
    ``(entity << 32) | cluster_row`` membership words — is the single
    structure behind entity lookups here and the batched top-k path in
    ``serve.ranking``; it is computed vectorised by
    :meth:`from_result` and reconstructed from the views when an index
    is built from a plain cluster list."""

    def __init__(self, clusters: List[ClusterView],
                 mode_pairs: Optional[Sequence[np.ndarray]] = None):
        self.clusters = list(clusters)
        self._by_sig = {c.signature: c for c in self.clusters}
        arity = self.clusters[0].arity if self.clusters else 0
        if mode_pairs is None:
            mode_pairs = []
            for k in range(arity):
                pairs = [(int(e) << 32) | row
                         for row, c in enumerate(self.clusters)
                         for e in c.components[k]]
                mode_pairs.append(np.sort(np.asarray(pairs, np.uint64)))
        self.mode_pairs: List[np.ndarray] = list(mode_pairs)
        self.any_pairs: np.ndarray = (
            np.unique(np.concatenate(self.mode_pairs))
            if self.mode_pairs else np.zeros(0, np.uint64))

    @classmethod
    def from_result(cls, result, only_kept: bool = True,
                    min_density: float = 0.0) -> "ClusterIndex":
        """Build from a ``PipelineResult`` (batch / NOAC / streaming —
        any result carrying component windows).  ``DistributedResult``
        ships per-shard aggregates without the windows; serve those by
        mining the snapshot through the streaming/batch engine (or
        ``DistributedMiner.serving_snapshot``), or resolve its
        signatures against an index built from one (the signatures are
        bit-identical across engines)."""
        for field in ("range_lo", "range_hi", "sorted_e"):
            if not hasattr(result, field):
                raise ValueError(
                    f"result has no '{field}' — component windows are "
                    "needed to build a ClusterIndex (DistributedResult "
                    "does not carry them; build the index from a "
                    "batch/streaming PipelineResult of the same context "
                    "and resolve signatures against it)")
        flag = np.asarray(result.keep if only_kept else result.is_unique)
        dens = np.asarray(result.density)
        if min_density:
            flag = flag & (dens >= min_density)
        rlo, rhi = np.asarray(result.range_lo), np.asarray(result.range_hi)
        sorted_e = np.asarray(result.sorted_e)
        slo = np.asarray(result.sig_lo)
        shi = np.asarray(result.sig_hi)
        gen = np.asarray(result.gen_count)
        vol = np.asarray(result.volume)
        n_modes = sorted_e.shape[0]
        sel = np.nonzero(flag)[0]
        nk = int(sel.size)
        # stack all kept windows per mode: repeat/cumsum flat gather,
        # dedup as (cluster << 32) | entity words in ONE np.unique —
        # the per-cluster np.unique python loop this replaces dominated
        # snapshot-swap latency at serving scale
        comp_ents, comp_bounds, mode_pairs = [], [], []
        cl_rows = np.arange(nk, dtype=np.uint64)
        for k in range(n_modes):
            counts = (rhi[k, sel] - rlo[k, sel]).astype(np.int64)
            total = int(counts.sum())
            starts = np.cumsum(counts) - counts
            flat = (np.arange(total, dtype=np.int64)
                    - np.repeat(starts, counts)
                    + np.repeat(rlo[k, sel].astype(np.int64), counts))
            ent = sorted_e[k][flat].astype(np.uint64)
            ce = np.unique((np.repeat(cl_rows, counts) << np.uint64(32))
                           | ent)
            ents_k = (ce & _LOW32).astype(np.int64)
            comp_ents.append(ents_k)
            comp_bounds.append(np.searchsorted(ce >> np.uint64(32),
                                               np.arange(nk + 1)))
            mode_pairs.append(np.sort((ce << np.uint64(32))
                                      | (ce >> np.uint64(32))))
        # views share the stacked arrays; component sets materialise
        # lazily (LazyComponents) — plain-python scalar lists here keep
        # numpy scalar indexing out of the construction loop
        slo_l, shi_l = slo[sel].tolist(), shi[sel].tolist()
        dens_l, gen_l = dens[sel].tolist(), gen[sel].tolist()
        vol_l = vol[sel].tolist()
        views = [ClusterView(
            signature=(slo_l[i], shi_l[i]),
            components=LazyComponents(comp_ents, comp_bounds, i),
            density=dens_l[i], gen_count=gen_l[i], volume=vol_l[i])
            for i in range(nk)]
        return cls(views, mode_pairs=mode_pairs)

    def __len__(self) -> int:
        return len(self.clusters)

    def __iter__(self) -> Iterator[ClusterView]:
        return iter(self.clusters)

    def entity_rows(self, entity: int,
                    mode: Optional[int] = None) -> np.ndarray:
        """Cluster rows whose mode-``mode`` (any-mode when None)
        component contains ``entity``, ascending — two ``searchsorted``
        probes into the packed membership words."""
        e = int(entity)
        if e < 0 or e >= 1 << 32:
            return np.zeros(0, np.int64)
        pairs = self.any_pairs if mode is None else self.mode_pairs[mode]
        lo = np.searchsorted(pairs, np.uint64(e << 32))
        hi = (pairs.size if e + 1 >= 1 << 32      # avoid uint64 overflow
              else np.searchsorted(pairs, np.uint64((e + 1) << 32)))
        return (pairs[lo:hi] & _LOW32).astype(np.int64)

    def query(self, entity: Optional[int] = None,
              mode: Optional[int] = None,
              signature: Optional[Tuple[int, int]] = None,
              min_density: float = 0.0) -> List[ClusterView]:
        """Kept clusters matching the given constraints.

        ``signature=(lo, hi)``: exact cluster lookup (≤ 1 hit).
        ``entity=e [, mode=k]``: membership in mode ``k``'s component
        (any mode when ``mode`` is None; ``mode`` without ``entity`` is
        rejected).  Constraints combine with AND.
        """
        if mode is not None:
            if entity is None:
                raise ValueError("mode=... requires entity=...")
            if self.clusters and not 0 <= mode < len(self.mode_pairs):
                raise ValueError(f"mode {mode} out of range")
            if not self.clusters:           # empty index: no hits
                return []
        if signature is not None:
            hit = self._by_sig.get((int(signature[0]), int(signature[1])))
            out = [] if hit is None else [hit]
            if entity is not None:
                out = [c for c in out if c.contains(int(entity), mode)]
        elif entity is not None:
            out = [self.clusters[r]
                   for r in self.entity_rows(entity, mode)]
        else:
            out = list(self.clusters)
        if min_density:
            out = [c for c in out if c.density >= min_density]
        return out


def cluster_query(result, entity: Optional[int] = None,
                  mode: Optional[int] = None,
                  signature: Optional[Tuple[int, int]] = None,
                  min_density: float = 0.0,
                  only_kept: bool = True) -> List[ClusterView]:
    """One-shot query over a mining result: build the index and look up
    (``ClusterIndex.from_result(...).query(...)``).

    Hits come back *ranked* — best density first (ties keep index
    order), matching the serving layer's default policy — not in
    whatever order the index happened to store them."""
    hits = ClusterIndex.from_result(result, only_kept=only_kept).query(
        entity=entity, mode=mode, signature=signature,
        min_density=min_density)
    from .ranking import rank_views       # deferred: ranking imports us
    return [v for v, _ in rank_views([(c, c.density) for c in hits])]
