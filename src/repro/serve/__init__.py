"""Serving substrate: batched prefill+decode engine over the model API,
plus the cluster-query surface over mined results (``serve.clusters``)."""
from .clusters import ClusterIndex, ClusterView, cluster_query
from .engine import GenerationResult, ServeEngine

__all__ = ["ServeEngine", "GenerationResult", "ClusterIndex",
           "ClusterView", "cluster_query"]
