"""Serving: the online cluster-serving subsystem over mined results —
snapshot-swapped :class:`TriclusterService` (``serve.service``), ranked
and batched lookups (``serve.ranking``), the cluster-query index
(``serve.clusters``) and the stdlib HTTP endpoint/client
(``serve.protocol``) — plus the LM-side batched prefill+decode engine
(``serve.engine``)."""
from .clusters import ClusterIndex, ClusterView, cluster_query
from .engine import GenerationResult, ServeEngine
from .protocol import ClusterClient, ClusterServeServer, make_server
from .ranking import (BatchQuerier, RankingPolicy, cluster_scores,
                      pack_signatures, rank_views, top_clusters)
from .service import QueryResult, Snapshot, TriclusterService

__all__ = [
    # cluster-query surface
    "ClusterIndex", "ClusterView", "cluster_query",
    # ranking layer
    "BatchQuerier", "RankingPolicy", "cluster_scores", "pack_signatures",
    "rank_views", "top_clusters",
    # snapshot-swapped service + protocol
    "TriclusterService", "Snapshot", "QueryResult",
    "ClusterClient", "ClusterServeServer", "make_server",
    # LM serving engine
    "ServeEngine", "GenerationResult",
]
