"""Serving: the online cluster-serving subsystem over mined results —
snapshot-swapped :class:`TriclusterService` (``serve.service``), ranked
and batched lookups (``serve.ranking``), the cluster-query index with
delta maintenance (``serve.clusters``), the stdlib HTTP
endpoint/client (``serve.protocol``), zero-copy shared-memory snapshot
replicas (``serve.shm``), the sharded query router (``serve.router``),
the fault-tolerance layer — deterministic fault injection
(``serve.faults``) and process supervision (``serve.supervise``) —
plus the LM-side batched prefill+decode engine (``serve.engine``).

``serve.engine`` is the only jax-dependent module here, so it is
imported lazily: replica readers and routers import ``repro.serve``
without paying (or needing) the accelerator stack.
"""
from .clusters import (ClusterIndex, ClusterView, cluster_query,
                       pack_sig_words)
from .faults import (KILL_EXIT_CODE, DropRequest, Fault, FaultInjector,
                     FaultPlan)
from .protocol import (ClusterClient, ClusterServeServer, health_doc,
                       make_server)
from .ranking import (BatchQuerier, RankingPolicy, cluster_scores,
                      pack_signatures, rank_views, top_clusters,
                      top_from_scores)
from .router import (CircuitBreaker, GatewayTimeout, PooledClient,
                     RouterServer, RouterService, Shard,
                     make_router_server)
from .service import (QueryResult, Snapshot, TriclusterService,
                      snapshot_query, snapshot_query_batch)
from .shm import (ReplicaService, ShmPublisher, ShmReplica,
                  SnapshotBundle, WriterDeadError)
from .supervise import Supervisor, write_restart_flag

__all__ = [
    # cluster-query surface
    "ClusterIndex", "ClusterView", "cluster_query", "pack_sig_words",
    # ranking layer
    "BatchQuerier", "RankingPolicy", "cluster_scores", "pack_signatures",
    "rank_views", "top_clusters", "top_from_scores",
    # snapshot-swapped service + protocol
    "TriclusterService", "Snapshot", "QueryResult",
    "snapshot_query", "snapshot_query_batch",
    "ClusterClient", "ClusterServeServer", "make_server", "health_doc",
    # zero-copy shared-memory replicas
    "ShmPublisher", "ShmReplica", "ReplicaService", "SnapshotBundle",
    "WriterDeadError",
    # sharded query router
    "RouterService", "RouterServer", "Shard", "PooledClient",
    "make_router_server", "CircuitBreaker", "GatewayTimeout",
    # fault tolerance: injection + supervision
    "FaultPlan", "FaultInjector", "Fault", "DropRequest",
    "KILL_EXIT_CODE", "Supervisor", "write_restart_flag",
    # LM serving engine (lazy: jax)
    "ServeEngine", "GenerationResult",
]

_LAZY = {"ServeEngine": "engine", "GenerationResult": "engine"}


def __getattr__(name: str):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute "
                             f"{name!r}")
    from importlib import import_module
    return getattr(import_module(f".{mod}", __name__), name)
