"""Serving substrate: batched prefill+decode engine over the model API."""
from .engine import GenerationResult, ServeEngine

__all__ = ["ServeEngine", "GenerationResult"]
