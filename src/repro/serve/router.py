"""Radix-range query router: one serving plane over N shards
(DESIGN.md §8).

Scaling writes past one miner reuses the partitioner the mining side
already trusts: ``core.runs.shard_of_rows`` — the top radix digit of
the mode-0 identity key, the same key-range ownership scheme
``DistributedMiner.ingest`` and the shuffle use (and the MapReduce FCA
/ distributed-triangle-counting partitioning of the related work).
Each shard is an independent writer (``TriclusterService`` + HTTP
endpoint) with optional shared-memory replica readers
(``serve.shm.ReplicaService``); this module is the thin tier in front:

* **writes** (``upsert`` / ``delete``) are partitioned by
  ``shard_of_rows`` and forwarded to the owning shards' writers;
* **queries** fan out to every shard (a cluster lives in the shard
  that owns its *generating tuples*, but its components may contain
  any entity, so entity lookups cannot be routed by entity id), each
  shard answers its local ranked top-k, and the router k-way-merges
  the per-shard lists by ``(-score, shard, rank)`` with a heap —
  top-k of the union equals the merge of per-shard top-ks;
* **freshness** is a per-shard vector: ``/refresh`` returns
  ``shard_versions`` (one snapshot version per shard) as the
  *write token*; passing that list back as ``at_least_version``
  makes every shard wait for its own component — cross-shard
  read-your-writes.  A scalar ``at_least_version`` is broadcast.

Mining stays *shard-local*: a cluster's components are computed from
the tuples its shard owns, so a logical cluster whose generating
tuples straddle a range boundary appears as per-shard parts (exactly
the per-partition aggregation trade-off of the MapReduce scheme).
Merged hits are deduplicated by signature (best score wins) so the
plane still answers with one hit per cluster identity.

The router speaks the same HTTP/JSON dialect as ``serve.protocol`` —
``ClusterClient`` works unchanged against a router endpoint — and
keeps per-worker-thread persistent connections to every backend, so
its fan-out adds no per-query TCP setup.

**Failure handling** (DESIGN.md §9).  Every backend endpoint carries a
:class:`CircuitBreaker`; per-shard calls retry with capped exponential
backoff under one per-request deadline budget, migrating off ejected
replicas, while a background loop re-probes open circuits with
/health.  When a shard stays unreachable past its budget, queries
*degrade*: the router merges the live shards and stamps the response
``degraded: true`` with a ``coverage`` list of answering shards —
never a 502 for a partial outage (pass ``require_all`` to restore
all-or-nothing).  Writes are never degraded: a partially-applied
scatter would silently lose ranges, so write failures still propagate
after their retry budget.
"""
from __future__ import annotations

import heapq
import itertools
import json
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import List, Optional, Sequence

import http.client

import numpy as np

from ..obs import NULL_OBS, TRACE_HEADER, parse_trace_header
from ..obs.metrics import Histogram
from .protocol import handle_obs_get


class GatewayTimeout(TimeoutError):
    """HTTP 504 from a backend: the backend is *alive* — it answered —
    but could not satisfy the freshness token in time.  Distinct from a
    transport ``TimeoutError`` so the retry/circuit-breaker layer does
    not punish a live backend for a client-requested wait."""


class CircuitBreaker:
    """Per-endpoint ejection: ``threshold`` consecutive transport
    failures open the circuit for ``cooldown`` seconds (doubling per
    re-trip, capped), after which exactly one caller at a time gets a
    half-open probe slot; one success closes it.  Thread-safe — one
    breaker per endpoint, shared by all router worker threads."""

    def __init__(self, threshold: int = 3, cooldown: float = 0.5,
                 cooldown_max: float = 8.0):
        self.threshold = int(threshold)
        self.cooldown = float(cooldown)
        self.cooldown_max = float(cooldown_max)
        self._lock = threading.Lock()
        self._fails = 0
        self._cd = self.cooldown
        self._open_until = 0.0
        self.trips = 0

    def allow(self) -> bool:
        """May a request be sent now?  True while closed; when open,
        True only for the first caller past the cooldown (the half-open
        probe — the slot is pushed forward so concurrent callers do not
        stampede a struggling backend)."""
        with self._lock:
            if self._fails < self.threshold:
                return True
            now = time.monotonic()
            if now >= self._open_until:
                self._open_until = now + self._cd
                return True
            return False

    def probe_due(self) -> bool:
        """Like :meth:`allow` but only for *open* circuits — the
        background re-probe loop's gate (never touches healthy
        endpoints)."""
        with self._lock:
            if self._fails < self.threshold:
                return False
            now = time.monotonic()
            if now < self._open_until:
                return False
            self._open_until = now + self._cd
            return True

    def ok(self) -> None:
        with self._lock:
            self._fails = 0
            self._cd = self.cooldown

    def fail(self) -> None:
        with self._lock:
            self._fails += 1
            if self._fails >= self.threshold:
                if self._fails == self.threshold:
                    self.trips += 1
                self._open_until = time.monotonic() + self._cd
                self._cd = min(self._cd * 2, self.cooldown_max)

    @property
    def is_open(self) -> bool:
        with self._lock:
            return self._fails >= self.threshold

    def state(self) -> str:
        with self._lock:
            if self._fails < self.threshold:
                return "closed"
            return ("half-open"
                    if time.monotonic() >= self._open_until else "open")


class PooledClient:
    """Minimal JSON-over-HTTP client with one persistent connection per
    calling thread (stdlib ``http.client``).  A request failing on a
    *reused* keep-alive socket (backend restarted between requests:
    ``ConnectionResetError`` / ``BadStatusLine`` / a torn empty
    response) is retried exactly once on a fresh connection before the
    backend is declared down; transport timeouts are deadlines and are
    never retried here.  Carries the endpoint's :class:`CircuitBreaker`
    (state shared across all threads)."""

    def __init__(self, base_url: str, timeout: float = 30.0,
                 breaker: Optional[CircuitBreaker] = None):
        base = base_url.rstrip("/")
        if base.startswith("http://"):
            base = base[len("http://"):]
        self.base_url = "http://" + base
        host, _, port = base.partition(":")
        self.host, self.port = host, int(port or 80)
        self.timeout = timeout
        self.breaker = breaker if breaker is not None else CircuitBreaker()
        self._local = threading.local()

    def _conn(self) -> http.client.HTTPConnection:
        c = getattr(self._local, "conn", None)
        if c is None:
            c = http.client.HTTPConnection(self.host, self.port,
                                           timeout=self.timeout)
            self._local.conn = c
        return c

    def _drop(self) -> None:
        c = getattr(self._local, "conn", None)
        if c is not None:
            c.close()
        self._local.conn = None

    def call(self, path: str, doc: Optional[dict] = None,
             timeout: Optional[float] = None,
             headers: Optional[dict] = None) -> dict:
        body = None if doc is None else json.dumps(doc).encode()
        method = "GET" if doc is None else "POST"
        hdrs = {"Content-Type": "application/json"}
        if headers:
            hdrs.update(headers)
        t = self.timeout if timeout is None else max(0.01, float(timeout))
        for attempt in (0, 1):
            try:
                c = self._conn()
                if c.timeout != t:
                    c.timeout = t
                    if c.sock is not None:
                        c.sock.settimeout(t)
                c.request(method, path, body=body, headers=hdrs)
                r = c.getresponse()
                data = r.read()
                break
            except TimeoutError:
                # a deadline, not a stale socket: retrying would double
                # the caller's wait — surface it
                self._drop()
                raise
            except (http.client.HTTPException, ConnectionError,
                    OSError):
                self._drop()
                if attempt:
                    raise
        out = json.loads(data) if data else {}
        if r.status == 504:
            raise GatewayTimeout(out.get("error", "gateway timeout"))
        if r.status >= 400:
            raise RuntimeError(f"{path}: "
                               f"{out.get('error', f'HTTP {r.status}')}")
        return out


class Shard:
    """One radix range: a writer endpoint plus its replica readers.
    Queries round-robin over the replicas whose circuit breakers admit
    traffic (falling back to the writer when none do, and to the
    round-robin pick as a last resort — a fully-ejected shard still
    gets its half-open probes through); writes always go to the
    writer."""

    def __init__(self, writer: str, replicas: Sequence[str] = (),
                 timeout: float = 30.0):
        self.writer = PooledClient(writer, timeout)
        self.replicas = [PooledClient(u, timeout) for u in replicas]
        self._rr = itertools.count()

    def reader(self) -> PooledClient:
        cands = self.replicas if self.replicas else [self.writer]
        start = next(self._rr)
        for j in range(len(cands)):
            c = cands[(start + j) % len(cands)]
            if c.breaker.allow():
                return c
        if self.replicas and self.writer.breaker.allow():
            return self.writer
        return cands[start % len(cands)]

    def endpoints(self) -> List[PooledClient]:
        return [self.writer, *self.replicas]


def _merge_hits(per_shard: List[list], k: int) -> list:
    """K-way merge of per-shard ranked hit lists; global best-first by
    ``(-score, shard, rank)``, deduplicated by signature (first — i.e.
    best — occurrence wins), truncated to ``k``."""
    streams = [((-h["score"], s, i), h)
               for s, hits in enumerate(per_shard)
               for i, h in enumerate(hits)]
    out, seen = [], set()
    for _, h in heapq.nsmallest(len(streams), streams, key=lambda t: t[0]):
        sig = tuple(h["signature"])
        if sig in seen:
            continue
        seen.add(sig)
        out.append(h)
        if len(out) >= k:
            break
    return out


class RouterService:
    """Fan-out / merge logic over a list of :class:`Shard`; the HTTP
    front-end (:func:`make_router_server`) is a thin JSON shim over
    these methods, and they are equally usable in-process."""

    def __init__(self, shards: Sequence[Shard], sizes=None,
                 timeout: float = 30.0, retry_base: float = 0.05,
                 retry_cap: float = 0.5, probe_interval: float = 0.25,
                 probe_timeout: float = 1.0, obs=None):
        if not shards:
            raise ValueError("router needs at least one shard")
        self.shards = list(shards)
        self.timeout = timeout
        self.obs = obs if obs is not None else NULL_OBS
        # per-endpoint handler latency lives in plain always-on
        # histograms (not the registry): resilience_stats() must stay
        # auditable even when the plane runs without --metrics
        self._endpoint_hist: dict = {}
        self._ep_lock = threading.Lock()
        #: hot-path registry-instrument handles keyed ``(endpoint,
        #: status)`` — the per-request label lookup is too slow to
        #: re-enter in the handler (benign race: the registry memoises,
        #: so duplicate builders converge on the same instruments)
        self._req_instruments: dict = {}
        if self.obs.enabled:
            self.obs.metrics.register_collector(self._collect_metrics)
        #: capped exponential backoff between per-shard retries, all
        #: under one per-request deadline budget (``timeout``)
        self.retry_base = float(retry_base)
        self.retry_cap = float(retry_cap)
        self.probe_timeout = float(probe_timeout)
        self._pool = ThreadPoolExecutor(
            max_workers=max(2, len(self.shards) * 2),
            thread_name_prefix="router-fan")
        self._sizes = None if sizes is None else tuple(int(s)
                                                       for s in sizes)
        self._id_plan = None
        self._lock = threading.Lock()
        self._stats = {"retries": 0, "degraded_responses": 0,
                       "shard_failures": 0, "probes": 0,
                       "probe_recoveries": 0}
        # background re-probe: open circuits get /health probes so an
        # ejected backend rejoins without waiting for query traffic to
        # half-open it
        self.probe_interval = float(probe_interval)
        self._stop_probe = threading.Event()
        self._probe_thread = threading.Thread(
            target=self._probe_loop, name="router-probe", daemon=True)
        if self.probe_interval > 0:
            self._probe_thread.start()

    def _probe_loop(self) -> None:
        while not self._stop_probe.wait(max(self.probe_interval, 0.01)):
            for sh in self.shards:
                for c in sh.endpoints():
                    if not c.breaker.probe_due():
                        continue
                    self._stats["probes"] += 1
                    try:
                        c.call("/health", timeout=self.probe_timeout)
                        c.breaker.ok()
                        self._stats["probe_recoveries"] += 1
                    except Exception:        # noqa: BLE001 — stays open
                        c.breaker.fail()

    # -- observability -------------------------------------------------------

    def observe_endpoint(self, endpoint: str, ms: float) -> None:
        """Record one handler latency for ``endpoint`` — always on, so
        the per-endpoint latency the handler measures actually reaches
        :meth:`resilience_stats` (it used to be computed and thrown
        away)."""
        h = self._endpoint_hist.get(endpoint)
        if h is None:
            with self._ep_lock:
                h = self._endpoint_hist.setdefault(endpoint, Histogram())
        h.observe(ms)

    def _collect_metrics(self):
        """Scrape-time fold of the router's stats dict and breaker
        states into the registry (one source of truth: `/stats`,
        `/metrics` render the same counters)."""
        for k, v in self._stats.items():
            yield f"router_{k}", {}, v
        for s, sh in enumerate(self.shards):
            for c in sh.endpoints():
                lbl = {"shard": s, "endpoint": c.base_url}
                yield "router_breaker_open", lbl, int(c.breaker.is_open)
                yield "router_breaker_trips", lbl, c.breaker.trips
        with self._ep_lock:
            hists = dict(self._endpoint_hist)
        for ep, h in hists.items():
            lbl = {"endpoint": ep}
            yield "router_endpoint_latency_ms_count", lbl, h.count
            yield "router_endpoint_latency_ms_p50", lbl, h.quantile(0.5)
            yield "router_endpoint_latency_ms_p99", lbl, h.quantile(0.99)

    # -- partitioning --------------------------------------------------------

    @property
    def sizes(self):
        if self._sizes is None:
            st = self.shards[0].writer.call("/stats")
            self._sizes = tuple(int(s) for s in st["sizes"])
        return self._sizes

    def shard_of(self, rows) -> np.ndarray:
        """Owning shard per tuple row — ``core.runs.shard_of_rows`` on
        the mode-0 identity key's top radix digit (the partitioner the
        shuffle and ``DistributedMiner`` already use)."""
        if self._id_plan is None:
            with self._lock:
                if self._id_plan is None:
                    from ..core import keys as K
                    self._id_plan = K.plan_mode_key(self.sizes, 0,
                                                    with_values=False)
        from ..core import runs as RS
        return RS.shard_of_rows(np.asarray(rows, np.int64), self._id_plan,
                                len(self.shards))

    # -- fan-out helpers -----------------------------------------------------

    def _fan(self, calls) -> list:
        """Run ``(client, path, doc)`` triples concurrently; returns the
        responses in order.  Any backend failure propagates (the plane
        answers fully or not at all — partial answers would silently
        drop ranges)."""
        futs = [self._pool.submit(c.call, path, doc)
                for c, path, doc in calls]
        return [f.result(timeout=self.timeout + 5) for f in futs]

    def _retrying(self, pick, path: str, doc, budget: float,
                  trace=(None, None), shard=None) -> dict:
        """One logical backend call under a deadline budget: transport
        failures retry with capped exponential backoff against whatever
        endpoint ``pick()`` currently favours (breaker-aware, so
        retries migrate off an ejected replica).  A :class:`GatewayTimeout`
        (HTTP 504 — live backend, unmet freshness token) and HTTP-level
        errors propagate immediately: the backend answered.

        When tracing is on, every *attempt* gets its own span (child of
        ``trace``) whose id rides the :data:`TRACE_HEADER` to the
        backend — the failed attempts are part of the story."""
        deadline = time.monotonic() + budget
        delay = self.retry_base
        last: Optional[BaseException] = None
        tracer = self.obs.tracer if self.obs.enabled else None
        attempt = 0
        while True:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise (last if last is not None
                       else TimeoutError(f"{path}: retry budget "
                                         f"({budget:.1f}s) exhausted"))
            c = pick()
            sp = headers = None
            if tracer is not None:
                sp = tracer.start("router.attempt", trace_id=trace[0],
                                  parent_id=trace[1], path=path,
                                  attempt=attempt, endpoint=c.base_url)
                if shard is not None:
                    sp.set("shard", shard)
                headers = {TRACE_HEADER: sp.header()}
            attempt += 1
            try:
                # per-attempt timeout: the endpoint's own bound, capped
                # by the remaining budget — one hung backend must not
                # swallow the whole deadline in a single attempt
                out = c.call(path, doc, timeout=min(remaining, c.timeout),
                             headers=headers)
                c.breaker.ok()
                if sp is not None:
                    sp.set("outcome", "ok").finish()
                return out
            except GatewayTimeout as e:
                c.breaker.ok()               # it answered — alive
                if sp is not None:
                    sp.set("outcome", "gateway_timeout")
                    sp.error(str(e)).finish()
                raise
            except RuntimeError as e:
                c.breaker.ok()               # HTTP error from a live
                if sp is not None:           # backend, not a transport
                    sp.set("outcome", "http_error")
                    sp.error(str(e)).finish()
                raise
            except (TimeoutError, ConnectionError,
                    http.client.HTTPException, OSError) as e:
                c.breaker.fail()
                last = e
                if sp is not None:
                    sp.set("outcome", "retry").error(repr(e)).finish()
            if time.monotonic() + delay >= deadline:
                raise last
            self._stats["retries"] += 1
            time.sleep(delay)
            delay = min(delay * 2, self.retry_cap)

    def _tokens(self, at_least_version) -> List[Optional[int]]:
        n = len(self.shards)
        if at_least_version is None:
            return [None] * n
        if isinstance(at_least_version, (list, tuple)):
            if len(at_least_version) != n:
                raise ValueError(
                    f"at_least_version list must have one entry per "
                    f"shard ({n}), got {len(at_least_version)}")
            return [int(v) for v in at_least_version]
        return [int(at_least_version)] * n

    # -- reads ---------------------------------------------------------------

    def query(self, entity=None, mode=None, signature=None, k: int = 10,
              at_least_version=None, timeout=None,
              include_components: bool = False,
              require_all: bool = False, trace=(None, None)) -> dict:
        doc = {"k": int(k), "include_components": bool(include_components)}
        if entity is not None:
            doc["entity"] = int(entity)
        if mode is not None:
            doc["mode"] = int(mode)
        if signature is not None:
            doc["signature"] = [int(signature[0]), int(signature[1])]
        res = self._fan_query(doc, at_least_version, timeout, require_all,
                              trace)
        hits = _merge_hits([r["hits"] for r in res if r is not None],
                           int(k))
        return self._doc(res, hits)

    def query_batch(self, entities, mode=None, k: int = 10,
                    at_least_version=None, timeout=None,
                    include_components: bool = False,
                    require_all: bool = False,
                    trace=(None, None)) -> dict:
        doc = {"entities": [int(e) for e in entities], "k": int(k),
               "include_components": bool(include_components)}
        if mode is not None:
            doc["mode"] = int(mode)
        res = self._fan_query(doc, at_least_version, timeout, require_all,
                              trace)
        hits = [_merge_hits([r["hits"][i] for r in res if r is not None],
                            int(k))
                for i in range(len(doc["entities"]))]
        return self._doc(res, hits)

    def _shard_query(self, s: int, sh: Shard, doc: dict, budget: float,
                     trace=(None, None)) -> dict:
        """One shard's slice of a fan-out, wrapped in a ``router.shard``
        span that records which endpoints the circuit breakers were
        holding ejected when the shard was dispatched."""
        if not self.obs.enabled:
            return self._retrying(sh.reader, "/query", doc, budget)
        # is_open (not allow()) — allow() consumes the half-open probe
        # slot, and observability must never perturb breaker behaviour
        skipped = [c.base_url for c in sh.endpoints() if c.breaker.is_open]
        sp = self.obs.tracer.start("router.shard", trace_id=trace[0],
                                   parent_id=trace[1], shard=s)
        if skipped:
            sp.set("breakers_open", skipped)
            self.obs.metrics.counter("router_breaker_skips",
                                     shard=s).inc(len(skipped))
        try:
            out = self._retrying(sh.reader, "/query", doc, budget,
                                 trace=(sp.trace_id, sp.span_id), shard=s)
            sp.set("version", out.get("version"))
            return out
        except BaseException as e:
            sp.error(repr(e))
            raise
        finally:
            sp.finish()

    def _fan_query(self, doc: dict, at_least_version, timeout,
                   require_all: bool = False,
                   trace=(None, None)) -> list:
        """Fan a /query to every shard with per-shard retry under the
        deadline budget.  Returns one response per shard, ``None`` for
        a shard whose retry budget was exhausted — **degraded partial
        results**, unless ``require_all`` (then the first shard failure
        propagates, restoring all-or-nothing).  Every shard down is
        always an error; a live shard's 504 (unmet freshness token)
        always propagates — the token was a promise."""
        tokens = self._tokens(at_least_version)
        budget = float(timeout) if timeout is not None else self.timeout
        futs = []
        for s, (sh, tok) in enumerate(zip(self.shards, tokens)):
            d = dict(doc)
            if tok is not None:
                d["at_least_version"] = tok
                d["timeout"] = timeout
            futs.append(self._pool.submit(
                self._shard_query, s, sh, d, budget, trace))
        res: List[Optional[dict]] = []
        first_err: Optional[BaseException] = None
        for s, f in enumerate(futs):
            try:
                res.append(f.result(timeout=budget + 5))
            except GatewayTimeout:
                raise
            except Exception as e:           # noqa: BLE001 — transport
                self._stats["shard_failures"] += 1
                if self.obs.enabled:
                    # the drop leaves a mark in the trace: a degraded
                    # answer is reconstructable after the fact
                    drop = self.obs.tracer.start(
                        "router.degraded_drop", trace_id=trace[0],
                        parent_id=trace[1], shard=s)
                    drop.error(repr(e)).finish()
                if first_err is None:
                    first_err = e
                res.append(None)
        if all(r is None for r in res):
            raise RuntimeError(f"all {len(self.shards)} shards "
                               f"unreachable: {first_err!r}")
        if require_all and first_err is not None:
            raise first_err
        return res

    def _doc(self, res: list, hits) -> dict:
        """Merge per-shard responses (``None`` = shard down) into the
        router doc.  ``coverage`` lists the shards that answered;
        ``degraded`` flags a partial answer; a down shard reports
        version 0 in ``shard_versions`` (no read-your-writes guarantee
        for its range)."""
        coverage = [s for s, r in enumerate(res) if r is not None]
        live = [r for r in res if r is not None]
        degraded = len(coverage) < len(res)
        if degraded:
            self._stats["degraded_responses"] += 1
        vers = [0 if r is None else int(r["version"]) for r in res]
        return {"version": min(int(r["version"]) for r in live),
                "shard_versions": vers,
                "stream_version": min(int(r["stream_version"])
                                      for r in live),
                "hits": hits, "degraded": degraded, "coverage": coverage}

    # -- writes --------------------------------------------------------------

    def _scatter(self, op: str, rows, values=None,
                 trace=(None, None)) -> dict:
        rows = [list(map(int, r)) for r in rows]
        if not rows:
            raise ValueError(f"/{op} needs non-empty 'rows'")
        owner = self.shard_of(rows)
        calls, touched = [], []
        for s, sh in enumerate(self.shards):
            idx = np.nonzero(owner == s)[0]
            if not idx.size:
                continue
            doc = {"rows": [rows[int(i)] for i in idx]}
            if values is not None:
                doc["values"] = [float(values[int(i)]) for i in idx]
            calls.append((sh.writer, f"/{op}", doc))
            touched.append(s)
        # writes stay all-or-nothing — a partially-applied scatter would
        # silently lose ranges — but each shard's call retries under the
        # deadline budget, so a writer mid-restart absorbs the write
        # once its supervisor brings it back
        futs = [self._pool.submit(self._retrying,
                                  (lambda c=c: c), path, doc, self.timeout,
                                  trace, s)
                for (c, path, doc), s in zip(calls, touched)]
        res = [f.result(timeout=self.timeout + 5) for f in futs]
        svs = [0] * len(self.shards)
        dirty = [0] * len(self.shards)
        for s, r in zip(touched, res):
            svs[s] = int(r["stream_version"])
            dirty[s] = int(r.get("dirty", 0))
        return {"shards": touched, "stream_versions": svs,
                "dirty": sum(dirty)}

    def upsert(self, rows, values=None, trace=(None, None)) -> dict:
        return self._scatter("upsert", rows, values, trace)

    def delete(self, rows, trace=(None, None)) -> dict:
        return self._scatter("delete", rows, trace=trace)

    def refresh(self) -> dict:
        """Synchronous re-mine + swap on every shard; the returned
        ``shard_versions`` list is the cross-shard write token."""
        res = self._fan([(sh.writer, "/refresh", {})
                         for sh in self.shards])
        vers = [int(r["version"]) for r in res]
        return {"version": min(vers), "shard_versions": vers,
                "clusters": sum(int(r["clusters"]) for r in res)}

    # -- health / lifecycle --------------------------------------------------

    def health(self) -> dict:
        """Plane health, tolerant of down backends: an unreachable
        endpoint becomes a ``down`` entry instead of failing the whole
        doc (a router that 502s its own /health while a shard restarts
        would get *itself* ejected).  Raises only when every endpoint
        of every shard is unreachable."""
        clients = [(s, c) for s, sh in enumerate(self.shards)
                   for c in sh.endpoints()]
        futs = [self._pool.submit(c.call, "/health", None,
                                  min(self.probe_timeout * 2,
                                      self.timeout))
                for _, c in clients]
        docs: List[Optional[dict]] = []
        down: List[str] = []
        for (s, c), f in zip(clients, futs):
            try:
                docs.append(f.result(timeout=self.timeout + 5))
            except Exception:                # noqa: BLE001 — down
                docs.append(None)
                down.append(c.base_url)
        per_shard, i = [], 0
        for sh in self.shards:
            n = 1 + len(sh.replicas)
            per_shard.append([d for d in docs[i:i + n] if d is not None])
            i += n
        live = [ends for ends in per_shard if ends]
        if not live:
            raise RuntimeError("all backends unreachable")
        vers = [min(int(e["version"]) for e in ends) if ends else 0
                for ends in per_shard]
        stale = [e.get("staleness_s") for ends in per_shard for e in ends]
        stale = [s for s in stale if s is not None]
        return {"role": "router",
                "version": min(v for v, ends in zip(vers, per_shard)
                               if ends),
                "shard_versions": vers,
                "stream_version": min(int(ends[0]["stream_version"])
                                      for ends in live),
                "clusters": sum(int(ends[0]["clusters"])
                                for ends in live),
                "dirty": sum(int(ends[0]["dirty"]) for ends in live),
                "dirty_clusters": sum(int(ends[0].get("dirty_clusters", 0))
                                      for ends in live),
                "staleness_s": max(stale) if stale else None,
                "shards": len(self.shards),
                "replicas": [len(sh.replicas) for sh in self.shards],
                "down": down,
                "coverage": [s for s, ends in enumerate(per_shard)
                             if ends],
                "degraded": bool(down)}

    def resilience_stats(self) -> dict:
        """Router-local failure-handling counters + per-endpoint
        breaker states (no backend round-trips), plus the per-endpoint
        handler-latency digests that make breaker decisions auditable
        after the fact."""
        out = dict(self._stats)
        out["breakers"] = [
            {"shard": s, "endpoint": c.base_url,
             "state": c.breaker.state(), "trips": c.breaker.trips}
            for s, sh in enumerate(self.shards)
            for c in sh.endpoints()]
        with self._ep_lock:
            hists = dict(self._endpoint_hist)
        out["endpoint_latency_ms"] = {
            ep: {"count": h.count, "p50": h.quantile(0.5),
                 "p99": h.quantile(0.99)}
            for ep, h in sorted(hists.items())}
        return out

    def stats(self) -> dict:
        res = self._fan([(sh.writer, "/stats", None)
                         for sh in self.shards])
        out = self.health()
        out["sizes"] = res[0].get("sizes")
        out["shard_stats"] = res
        out["resilience"] = self.resilience_stats()
        return out

    def shutdown_backends(self) -> None:
        """Best-effort fan-out /shutdown to every backend (replicas
        first, then writers)."""
        for sh in self.shards:
            for c in [*sh.replicas, sh.writer]:
                try:
                    c.call("/shutdown", {})
                except Exception:            # noqa: BLE001 — teardown
                    pass

    def close(self) -> None:
        self._stop_probe.set()
        if self._probe_thread.is_alive():
            self._probe_thread.join(timeout=5)
        self._pool.shutdown(wait=False)


class _RouterHandler(BaseHTTPRequestHandler):
    def log_message(self, fmt, *args):
        if getattr(self.server, "verbose", False):
            super().log_message(fmt, *args)

    def _reply(self, doc: dict, status: int = 200) -> None:
        self._status = status            # for the request instruments
        body = json.dumps(doc).encode()
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def do_GET(self):
        router: RouterService = self.server.router
        try:
            if self.path == "/health":
                self._reply(router.health())
            elif self.path == "/stats":
                self._reply(router.stats())
            elif handle_obs_get(self, router.obs):
                pass
            else:
                self._reply({"error": f"unknown path {self.path}"}, 404)
        except TimeoutError as e:
            self._reply({"error": str(e)}, 504)
        except (RuntimeError, OSError) as e:
            self._reply({"error": f"backend failure: {e}"}, 502)

    def do_POST(self):
        t_recv = time.perf_counter()
        router: RouterService = self.server.router
        obs = router.obs
        try:
            n = int(self.headers.get("Content-Length") or 0)
            doc = json.loads(self.rfile.read(n) or b"{}")
        except (ValueError, json.JSONDecodeError) as e:
            return self._reply({"error": f"bad JSON body: {e}"}, 400)
        ep = (self.path if self.path in
              ("/query", "/upsert", "/delete", "/refresh", "/shutdown")
              else "other")
        sp = None
        trace = (None, None)
        if obs.enabled:
            tid, pid = parse_trace_header(self.headers.get(TRACE_HEADER))
            sp = obs.tracer.start(f"router{self.path}", trace_id=tid,
                                  parent_id=pid, role="router")
            trace = (sp.trace_id, sp.span_id)
        self._status = 200
        coverage = None
        t0 = time.perf_counter()
        try:
            if self.path == "/query":
                if "entities" in doc:
                    out = router.query_batch(
                        doc["entities"], mode=doc.get("mode"),
                        k=int(doc.get("k", 10)),
                        at_least_version=doc.get("at_least_version"),
                        timeout=doc.get("timeout"),
                        include_components=bool(
                            doc.get("include_components", False)),
                        require_all=bool(doc.get("require_all", False)),
                        trace=trace)
                else:
                    sig = doc.get("signature")
                    out = router.query(
                        entity=doc.get("entity"), mode=doc.get("mode"),
                        signature=(None if sig is None
                                   else (int(sig[0]), int(sig[1]))),
                        k=int(doc.get("k", 10)),
                        at_least_version=doc.get("at_least_version"),
                        timeout=doc.get("timeout"),
                        include_components=bool(
                            doc.get("include_components", False)),
                        require_all=bool(doc.get("require_all", False)),
                        trace=trace)
                out["server_ms"] = (time.perf_counter() - t0) * 1e3
                coverage = out.get("coverage")
                if sp is not None and sp.trace_id:
                    out["trace_id"] = sp.trace_id
                self._reply(out)
            elif self.path == "/upsert":
                self._reply(router.upsert(doc.get("rows") or [],
                                          doc.get("values"), trace=trace))
            elif self.path == "/delete":
                self._reply(router.delete(doc.get("rows") or [],
                                          trace=trace))
            elif self.path == "/refresh":
                self._reply(router.refresh())
            elif self.path == "/shutdown":
                if not getattr(self.server, "allow_shutdown", True):
                    return self._reply({"error": "shutdown disabled"}, 403)
                if getattr(self.server, "cascade_shutdown", False) or \
                        doc.get("cascade"):
                    router.shutdown_backends()
                self._reply({"ok": True})
                threading.Thread(target=self.server.shutdown,
                                 daemon=True).start()
            else:
                self._reply({"error": f"unknown path {self.path}"}, 404)
        except TimeoutError as e:
            self._reply({"error": str(e)}, 504)
        except (ValueError, KeyError, IndexError, OverflowError,
                TypeError) as e:
            self._reply({"error": str(e)}, 400)
        except (RuntimeError, OSError) as e:
            self._reply({"error": f"backend failure: {e}"}, 502)
        finally:
            now = time.perf_counter()
            handler_ms = (now - t0) * 1e3
            total_ms = (now - t_recv) * 1e3
            status = getattr(self, "_status", 200)
            # the fix for the dropped server_ms: handler latency now
            # reaches resilience_stats() through the always-on digests
            router.observe_endpoint(ep, handler_ms)
            if sp is not None:
                sp.set("status", status)
                if coverage is not None:
                    sp.set("coverage", coverage)
                if status >= 500:
                    sp.error(f"HTTP {status}")
                sp.finish()
            if obs.enabled:
                pair = router._req_instruments.get((ep, status))
                if pair is None:
                    pair = (obs.metrics.histogram("router_request_ms",
                                                  endpoint=ep),
                            obs.metrics.counter("router_requests_total",
                                                endpoint=ep,
                                                code=str(status)))
                    router._req_instruments[(ep, status)] = pair
                pair[0].observe(handler_ms)
                pair[1].inc()
                if ep == "/query":
                    obs.slow.record(
                        ep, total_ms, handler_ms=handler_ms,
                        wait_ms=total_ms - handler_ms,
                        trace_id=sp.trace_id if sp is not None else "",
                        coverage=coverage)


class RouterServer(ThreadingHTTPServer):
    """HTTP front-end bound to one :class:`RouterService`."""
    daemon_threads = True

    def __init__(self, router: RouterService, addr=("127.0.0.1", 0),
                 allow_shutdown: bool = True,
                 cascade_shutdown: bool = False, verbose: bool = False):
        super().__init__(addr, _RouterHandler)
        self.router = router
        self.allow_shutdown = allow_shutdown
        self.cascade_shutdown = cascade_shutdown
        self.verbose = verbose

    @property
    def port(self) -> int:
        return self.server_address[1]


def make_router_server(router: RouterService, host: str = "127.0.0.1",
                       port: int = 0, allow_shutdown: bool = True,
                       cascade_shutdown: bool = False,
                       verbose: bool = False) -> RouterServer:
    """Bind (port 0 = ephemeral; read ``server.port``) without serving;
    call ``serve_forever()`` — typically on a thread — to go live."""
    return RouterServer(router, (host, port),
                        allow_shutdown=allow_shutdown,
                        cascade_shutdown=cascade_shutdown,
                        verbose=verbose)
