"""Radix-range query router: one serving plane over N shards
(DESIGN.md §8).

Scaling writes past one miner reuses the partitioner the mining side
already trusts: ``core.runs.shard_of_rows`` — the top radix digit of
the mode-0 identity key, the same key-range ownership scheme
``DistributedMiner.ingest`` and the shuffle use (and the MapReduce FCA
/ distributed-triangle-counting partitioning of the related work).
Each shard is an independent writer (``TriclusterService`` + HTTP
endpoint) with optional shared-memory replica readers
(``serve.shm.ReplicaService``); this module is the thin tier in front:

* **writes** (``upsert`` / ``delete``) are partitioned by
  ``shard_of_rows`` and forwarded to the owning shards' writers;
* **queries** fan out to every shard (a cluster lives in the shard
  that owns its *generating tuples*, but its components may contain
  any entity, so entity lookups cannot be routed by entity id), each
  shard answers its local ranked top-k, and the router k-way-merges
  the per-shard lists by ``(-score, shard, rank)`` with a heap —
  top-k of the union equals the merge of per-shard top-ks;
* **freshness** is a per-shard vector: ``/refresh`` returns
  ``shard_versions`` (one snapshot version per shard) as the
  *write token*; passing that list back as ``at_least_version``
  makes every shard wait for its own component — cross-shard
  read-your-writes.  A scalar ``at_least_version`` is broadcast.

Mining stays *shard-local*: a cluster's components are computed from
the tuples its shard owns, so a logical cluster whose generating
tuples straddle a range boundary appears as per-shard parts (exactly
the per-partition aggregation trade-off of the MapReduce scheme).
Merged hits are deduplicated by signature (best score wins) so the
plane still answers with one hit per cluster identity.

The router speaks the same HTTP/JSON dialect as ``serve.protocol`` —
``ClusterClient`` works unchanged against a router endpoint — and
keeps per-worker-thread persistent connections to every backend, so
its fan-out adds no per-query TCP setup.
"""
from __future__ import annotations

import heapq
import itertools
import json
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import List, Optional, Sequence

import http.client

import numpy as np


class PooledClient:
    """Minimal JSON-over-HTTP client with one persistent connection per
    calling thread (stdlib ``http.client``; reconnects once on a stale
    keep-alive socket)."""

    def __init__(self, base_url: str, timeout: float = 30.0):
        base = base_url.rstrip("/")
        if base.startswith("http://"):
            base = base[len("http://"):]
        self.base_url = "http://" + base
        host, _, port = base.partition(":")
        self.host, self.port = host, int(port or 80)
        self.timeout = timeout
        self._local = threading.local()

    def _conn(self) -> http.client.HTTPConnection:
        c = getattr(self._local, "conn", None)
        if c is None:
            c = http.client.HTTPConnection(self.host, self.port,
                                           timeout=self.timeout)
            self._local.conn = c
        return c

    def _drop(self) -> None:
        c = getattr(self._local, "conn", None)
        if c is not None:
            c.close()
        self._local.conn = None

    def call(self, path: str, doc: Optional[dict] = None) -> dict:
        body = None if doc is None else json.dumps(doc).encode()
        method = "GET" if doc is None else "POST"
        for attempt in (0, 1):
            try:
                c = self._conn()
                c.request(method, path, body=body,
                          headers={"Content-Type": "application/json"})
                r = c.getresponse()
                data = r.read()
                break
            except (http.client.HTTPException, ConnectionError,
                    OSError):
                self._drop()
                if attempt:
                    raise
        out = json.loads(data) if data else {}
        if r.status == 504:
            raise TimeoutError(out.get("error", "gateway timeout"))
        if r.status >= 400:
            raise RuntimeError(f"{path}: "
                               f"{out.get('error', f'HTTP {r.status}')}")
        return out


class Shard:
    """One radix range: a writer endpoint plus its replica readers.
    Queries round-robin over the replicas (falling back to the writer
    when there are none); writes always go to the writer."""

    def __init__(self, writer: str, replicas: Sequence[str] = (),
                 timeout: float = 30.0):
        self.writer = PooledClient(writer, timeout)
        self.replicas = [PooledClient(u, timeout) for u in replicas]
        self._rr = itertools.count()

    def reader(self) -> PooledClient:
        if not self.replicas:
            return self.writer
        return self.replicas[next(self._rr) % len(self.replicas)]

    def endpoints(self) -> List[PooledClient]:
        return [self.writer, *self.replicas]


def _merge_hits(per_shard: List[list], k: int) -> list:
    """K-way merge of per-shard ranked hit lists; global best-first by
    ``(-score, shard, rank)``, deduplicated by signature (first — i.e.
    best — occurrence wins), truncated to ``k``."""
    streams = [((-h["score"], s, i), h)
               for s, hits in enumerate(per_shard)
               for i, h in enumerate(hits)]
    out, seen = [], set()
    for _, h in heapq.nsmallest(len(streams), streams, key=lambda t: t[0]):
        sig = tuple(h["signature"])
        if sig in seen:
            continue
        seen.add(sig)
        out.append(h)
        if len(out) >= k:
            break
    return out


class RouterService:
    """Fan-out / merge logic over a list of :class:`Shard`; the HTTP
    front-end (:func:`make_router_server`) is a thin JSON shim over
    these methods, and they are equally usable in-process."""

    def __init__(self, shards: Sequence[Shard], sizes=None,
                 timeout: float = 30.0):
        if not shards:
            raise ValueError("router needs at least one shard")
        self.shards = list(shards)
        self.timeout = timeout
        self._pool = ThreadPoolExecutor(
            max_workers=max(2, len(self.shards) * 2),
            thread_name_prefix="router-fan")
        self._sizes = None if sizes is None else tuple(int(s)
                                                       for s in sizes)
        self._id_plan = None
        self._lock = threading.Lock()

    # -- partitioning --------------------------------------------------------

    @property
    def sizes(self):
        if self._sizes is None:
            st = self.shards[0].writer.call("/stats")
            self._sizes = tuple(int(s) for s in st["sizes"])
        return self._sizes

    def shard_of(self, rows) -> np.ndarray:
        """Owning shard per tuple row — ``core.runs.shard_of_rows`` on
        the mode-0 identity key's top radix digit (the partitioner the
        shuffle and ``DistributedMiner`` already use)."""
        if self._id_plan is None:
            with self._lock:
                if self._id_plan is None:
                    from ..core import keys as K
                    self._id_plan = K.plan_mode_key(self.sizes, 0,
                                                    with_values=False)
        from ..core import runs as RS
        return RS.shard_of_rows(np.asarray(rows, np.int64), self._id_plan,
                                len(self.shards))

    # -- fan-out helpers -----------------------------------------------------

    def _fan(self, calls) -> list:
        """Run ``(client, path, doc)`` triples concurrently; returns the
        responses in order.  Any backend failure propagates (the plane
        answers fully or not at all — partial answers would silently
        drop ranges)."""
        futs = [self._pool.submit(c.call, path, doc)
                for c, path, doc in calls]
        return [f.result(timeout=self.timeout + 5) for f in futs]

    def _tokens(self, at_least_version) -> List[Optional[int]]:
        n = len(self.shards)
        if at_least_version is None:
            return [None] * n
        if isinstance(at_least_version, (list, tuple)):
            if len(at_least_version) != n:
                raise ValueError(
                    f"at_least_version list must have one entry per "
                    f"shard ({n}), got {len(at_least_version)}")
            return [int(v) for v in at_least_version]
        return [int(at_least_version)] * n

    # -- reads ---------------------------------------------------------------

    def query(self, entity=None, mode=None, signature=None, k: int = 10,
              at_least_version=None, timeout=None,
              include_components: bool = False) -> dict:
        doc = {"k": int(k), "include_components": bool(include_components)}
        if entity is not None:
            doc["entity"] = int(entity)
        if mode is not None:
            doc["mode"] = int(mode)
        if signature is not None:
            doc["signature"] = [int(signature[0]), int(signature[1])]
        res = self._fan_query(doc, at_least_version, timeout)
        hits = _merge_hits([r["hits"] for r in res], int(k))
        return self._doc(res, hits)

    def query_batch(self, entities, mode=None, k: int = 10,
                    at_least_version=None, timeout=None,
                    include_components: bool = False) -> dict:
        doc = {"entities": [int(e) for e in entities], "k": int(k),
               "include_components": bool(include_components)}
        if mode is not None:
            doc["mode"] = int(mode)
        res = self._fan_query(doc, at_least_version, timeout)
        hits = [_merge_hits([r["hits"][i] for r in res], int(k))
                for i in range(len(doc["entities"]))]
        return self._doc(res, hits)

    def _fan_query(self, doc: dict, at_least_version, timeout) -> list:
        tokens = self._tokens(at_least_version)
        calls = []
        for sh, tok in zip(self.shards, tokens):
            d = dict(doc)
            if tok is not None:
                d["at_least_version"] = tok
                d["timeout"] = timeout
            calls.append((sh.reader(), "/query", d))
        return self._fan(calls)

    def _doc(self, res: list, hits) -> dict:
        vers = [int(r["version"]) for r in res]
        return {"version": min(vers), "shard_versions": vers,
                "stream_version": min(int(r["stream_version"])
                                      for r in res),
                "hits": hits}

    # -- writes --------------------------------------------------------------

    def _scatter(self, op: str, rows, values=None) -> dict:
        rows = [list(map(int, r)) for r in rows]
        if not rows:
            raise ValueError(f"/{op} needs non-empty 'rows'")
        owner = self.shard_of(rows)
        calls, touched = [], []
        for s, sh in enumerate(self.shards):
            idx = np.nonzero(owner == s)[0]
            if not idx.size:
                continue
            doc = {"rows": [rows[int(i)] for i in idx]}
            if values is not None:
                doc["values"] = [float(values[int(i)]) for i in idx]
            calls.append((sh.writer, f"/{op}", doc))
            touched.append(s)
        res = self._fan(calls)
        svs = [0] * len(self.shards)
        dirty = [0] * len(self.shards)
        for s, r in zip(touched, res):
            svs[s] = int(r["stream_version"])
            dirty[s] = int(r.get("dirty", 0))
        return {"shards": touched, "stream_versions": svs,
                "dirty": sum(dirty)}

    def upsert(self, rows, values=None) -> dict:
        return self._scatter("upsert", rows, values)

    def delete(self, rows) -> dict:
        return self._scatter("delete", rows)

    def refresh(self) -> dict:
        """Synchronous re-mine + swap on every shard; the returned
        ``shard_versions`` list is the cross-shard write token."""
        res = self._fan([(sh.writer, "/refresh", {})
                         for sh in self.shards])
        vers = [int(r["version"]) for r in res]
        return {"version": min(vers), "shard_versions": vers,
                "clusters": sum(int(r["clusters"]) for r in res)}

    # -- health / lifecycle --------------------------------------------------

    def health(self) -> dict:
        res = self._fan([(c, "/health", None)
                         for sh in self.shards for c in sh.endpoints()])
        per_shard, i = [], 0
        for sh in self.shards:
            ends = res[i:i + 1 + len(sh.replicas)]
            i += len(ends)
            per_shard.append(ends)
        vers = [min(int(e["version"]) for e in ends)
                for ends in per_shard]
        stale = [e.get("staleness_s") for ends in per_shard for e in ends]
        stale = [s for s in stale if s is not None]
        return {"role": "router", "version": min(vers),
                "shard_versions": vers,
                "stream_version": min(int(ends[0]["stream_version"])
                                      for ends in per_shard),
                "clusters": sum(int(ends[0]["clusters"])
                                for ends in per_shard),
                "dirty": sum(int(ends[0]["dirty"]) for ends in per_shard),
                "dirty_clusters": sum(int(ends[0].get("dirty_clusters", 0))
                                      for ends in per_shard),
                "staleness_s": max(stale) if stale else None,
                "shards": len(self.shards),
                "replicas": [len(sh.replicas) for sh in self.shards]}

    def stats(self) -> dict:
        res = self._fan([(sh.writer, "/stats", None)
                         for sh in self.shards])
        out = self.health()
        out["sizes"] = res[0].get("sizes")
        out["shard_stats"] = res
        return out

    def shutdown_backends(self) -> None:
        """Best-effort fan-out /shutdown to every backend (replicas
        first, then writers)."""
        for sh in self.shards:
            for c in [*sh.replicas, sh.writer]:
                try:
                    c.call("/shutdown", {})
                except Exception:            # noqa: BLE001 — teardown
                    pass

    def close(self) -> None:
        self._pool.shutdown(wait=False)


class _RouterHandler(BaseHTTPRequestHandler):
    def log_message(self, fmt, *args):
        if getattr(self.server, "verbose", False):
            super().log_message(fmt, *args)

    def _reply(self, doc: dict, status: int = 200) -> None:
        body = json.dumps(doc).encode()
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def do_GET(self):
        router: RouterService = self.server.router
        try:
            if self.path == "/health":
                self._reply(router.health())
            elif self.path == "/stats":
                self._reply(router.stats())
            else:
                self._reply({"error": f"unknown path {self.path}"}, 404)
        except TimeoutError as e:
            self._reply({"error": str(e)}, 504)
        except (RuntimeError, OSError) as e:
            self._reply({"error": f"backend failure: {e}"}, 502)

    def do_POST(self):
        router: RouterService = self.server.router
        try:
            n = int(self.headers.get("Content-Length") or 0)
            doc = json.loads(self.rfile.read(n) or b"{}")
        except (ValueError, json.JSONDecodeError) as e:
            return self._reply({"error": f"bad JSON body: {e}"}, 400)
        try:
            t0 = time.perf_counter()
            if self.path == "/query":
                if "entities" in doc:
                    out = router.query_batch(
                        doc["entities"], mode=doc.get("mode"),
                        k=int(doc.get("k", 10)),
                        at_least_version=doc.get("at_least_version"),
                        timeout=doc.get("timeout"),
                        include_components=bool(
                            doc.get("include_components", False)))
                else:
                    sig = doc.get("signature")
                    out = router.query(
                        entity=doc.get("entity"), mode=doc.get("mode"),
                        signature=(None if sig is None
                                   else (int(sig[0]), int(sig[1]))),
                        k=int(doc.get("k", 10)),
                        at_least_version=doc.get("at_least_version"),
                        timeout=doc.get("timeout"),
                        include_components=bool(
                            doc.get("include_components", False)))
                out["server_ms"] = (time.perf_counter() - t0) * 1e3
                self._reply(out)
            elif self.path == "/upsert":
                self._reply(router.upsert(doc.get("rows") or [],
                                          doc.get("values")))
            elif self.path == "/delete":
                self._reply(router.delete(doc.get("rows") or []))
            elif self.path == "/refresh":
                self._reply(router.refresh())
            elif self.path == "/shutdown":
                if not getattr(self.server, "allow_shutdown", True):
                    return self._reply({"error": "shutdown disabled"}, 403)
                if getattr(self.server, "cascade_shutdown", False) or \
                        doc.get("cascade"):
                    router.shutdown_backends()
                self._reply({"ok": True})
                threading.Thread(target=self.server.shutdown,
                                 daemon=True).start()
            else:
                self._reply({"error": f"unknown path {self.path}"}, 404)
        except TimeoutError as e:
            self._reply({"error": str(e)}, 504)
        except (ValueError, KeyError, IndexError, OverflowError,
                TypeError) as e:
            self._reply({"error": str(e)}, 400)
        except (RuntimeError, OSError) as e:
            self._reply({"error": f"backend failure: {e}"}, 502)


class RouterServer(ThreadingHTTPServer):
    """HTTP front-end bound to one :class:`RouterService`."""
    daemon_threads = True

    def __init__(self, router: RouterService, addr=("127.0.0.1", 0),
                 allow_shutdown: bool = True,
                 cascade_shutdown: bool = False, verbose: bool = False):
        super().__init__(addr, _RouterHandler)
        self.router = router
        self.allow_shutdown = allow_shutdown
        self.cascade_shutdown = cascade_shutdown
        self.verbose = verbose

    @property
    def port(self) -> int:
        return self.server_address[1]


def make_router_server(router: RouterService, host: str = "127.0.0.1",
                       port: int = 0, allow_shutdown: bool = True,
                       cascade_shutdown: bool = False,
                       verbose: bool = False) -> RouterServer:
    """Bind (port 0 = ephemeral; read ``server.port``) without serving;
    call ``serve_forever()`` — typically on a thread — to go live."""
    return RouterServer(router, (host, port),
                        allow_shutdown=allow_shutdown,
                        cascade_shutdown=cascade_shutdown,
                        verbose=verbose)
