"""Thin stdlib HTTP/JSON endpoint + client for the cluster service.

The service itself (``serve.service``) is in-process; this module makes
it drivable from outside: a ``ThreadingHTTPServer`` front-end (one OS
thread per connection — every request is a lock-free snapshot read, so
plain threads are plenty) and a ``urllib``-based :class:`ClusterClient`.
No third-party dependencies; wire format is JSON.

Routes (all bodies/responses JSON):

* ``GET /health`` — ``{version, stream_version, clusters, dirty,
  dirty_clusters, staleness_s, role}``: ``dirty`` is the write backlog
  (writes not yet covered by the published snapshot),
  ``dirty_clusters`` how many cluster signatures changed at the last
  swap, ``staleness_s`` the seconds since the served snapshot was
  published (wall-clock on replicas — comparable across processes)
* ``GET /stats`` — full service stats (includes ``sizes`` so clients
  can build valid rows/entities without out-of-band knowledge)
* ``POST /query`` — ``{entity | entities | signature, mode?, k?,
  at_least_version?, timeout?, include_components?}``; with
  ``entities`` the batched path answers the whole list in one
  stacked-window pass and ``hits`` is one list per entity.  Responses
  carry ``server_ms`` — handler wall time, so clients can attribute
  tail latency to queue wait vs handler work
* ``POST /upsert`` / ``POST /delete`` — ``{rows, values?}``; returns
  ``{stream_version, dirty}`` (the background thread picks the write up
  on its cadence/threshold; follow with ``/refresh`` to force).
  **501** on a read-only replica (``serve.shm.ReplicaService``) —
  writes go to the shard's writer endpoint.  **429** + ``Retry-After``
  when the server was built with ``max_write_backlog`` and the
  pending-write backlog (writes since the last published snapshot) has
  reached it — write backpressure: the miner is behind, keep accepting
  and it degrades unboundedly.  :class:`ClusterClient` honours
  ``Retry-After`` once before surfacing the error
* ``POST /refresh`` — synchronous re-mine + swap; returns the new
  ``{version, stream_version, clusters}`` (**501** on a replica)
* ``POST /shutdown`` — stop serving (enabled by default; pass
  ``allow_shutdown=False`` to :func:`make_server` to disable)

Signatures travel as ``[lo, hi]`` pairs — the cross-engine cluster
identity, so a signature minted by a batch job yesterday resolves over
HTTP against today's streaming snapshot.

**Load-balancer contract.**  A fleet of replicas behind one writer (or
a ``serve.router`` fan-out over several shards) is balanced on two
/health signals, both cheap lock-free reads:

* *readiness* — route queries to a backend once ``version >= 1``;
  ``ClusterClient.wait_ready`` polls exactly this.
* *freshness* — ``staleness_s`` + ``dirty``: a backend whose
  ``staleness_s`` grows while ``dirty > 0`` has a stuck writer (or a
  replica whose publisher died) and should be drained;
  ``ClusterClient.wait_until_fresh`` blocks on the complementary
  condition (backlog drained and snapshot younger than a bound).
  Replicas of the same shard report the same ``version`` stream, so a
  balancer may also pin ``at_least_version`` tokens (read-your-writes)
  to any replica of the shard that served the write.
"""
from __future__ import annotations

import contextlib
import json
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional
from urllib import error as _uerror
from urllib import request as _urequest
from urllib.parse import parse_qs

from ..obs import NULL_OBS, TRACE_HEADER, parse_trace_header
from .faults import DropRequest
from .service import QueryResult, TriclusterService


def health_doc(svc, max_staleness_s: Optional[float] = None) -> dict:
    """The /health body for any service-shaped object (in-process
    writer or shared-memory replica).  ``healthy`` goes False — and the
    HTTP route answers **503** — when the background thread (miner on a
    writer, attach loop on a replica) has died, when the integrity
    scrubber found corruption in the served snapshot (``scrub_clean``
    False — serving known-bad structures would be silently wrong
    answers), or when ``max_staleness_s`` is set and the served
    snapshot is older than that with writes outstanding: all mean a
    balancer must eject the backend, and a 200 would keep it in
    rotation."""
    snap = getattr(svc, "_snap", None)
    stale = svc.staleness_s() if hasattr(svc, "staleness_s") else None
    if stale is not None and stale == float("inf"):
        stale = None
    alive = bool(getattr(svc, "thread_alive", True))
    scrub_ok = bool(getattr(svc, "scrub_clean", True))
    doc = {"version": svc.version,
           "stream_version": svc.stream_version,
           "clusters": 0 if snap is None else len(snap.index),
           "dirty": svc.dirty,
           "dirty_clusters": int(getattr(svc, "dirty_clusters", 0)),
           "staleness_s": stale,
           "thread_alive": alive,
           "scrub_clean": scrub_ok,
           "role": ("replica" if getattr(svc, "read_only", False)
                    else "writer")}
    healthy, why = True, None
    if not alive:
        healthy, why = False, "background thread died"
    elif not scrub_ok:
        # the integrity scrubber found corruption in the served
        # structures: wrong answers are worse than no answers — eject
        healthy, why = False, "integrity scrub failed: corruption " \
            "detected in served snapshot"
    elif (max_staleness_s is not None and stale is not None
            and stale > max_staleness_s and doc["dirty"] > 0):
        healthy, why = False, (f"stale snapshot: {stale:.1f}s > "
                               f"{max_staleness_s:.1f}s with "
                               f"dirty={doc['dirty']}")
    doc["healthy"] = healthy
    if why is not None:
        doc["error"] = why
    return doc


def hit_doc(view, score: float, include_components: bool = False) -> dict:
    """JSON form of one ranked hit."""
    d = {"signature": [int(view.signature[0]), int(view.signature[1])],
         "score": float(score), "density": float(view.density),
         "volume": float(view.volume), "gen_count": int(view.gen_count)}
    if include_components:
        d["components"] = [sorted(int(e) for e in c)
                           for c in view.components]
    return d


def _query_doc(res: QueryResult, batched: bool,
               include_components: bool) -> dict:
    if batched:
        hits = [[hit_doc(v, s, include_components) for v, s in per]
                for per in res.hits]
    else:
        hits = [hit_doc(v, s, include_components) for v, s in res.hits]
    return {"version": res.version, "stream_version": res.stream_version,
            "hits": hits}


#: GET routes served by the observability plane (DESIGN.md §11) — the
#: same three on the service endpoint and the router
OBS_PATHS = ("/metrics", "/debug/trace", "/debug/slow")


def handle_obs_get(handler, obs) -> bool:
    """Serve the observability GET routes on any JSON handler that has
    a ``_reply(doc, status)`` method.  Returns True when ``handler.path``
    was one of :data:`OBS_PATHS` (whether it answered data or the
    disabled-404); False means "not mine, keep dispatching".

    * ``/metrics`` — Prometheus text exposition of the process registry
      (native instruments + collector-folded stats dicts).
    * ``/debug/trace[?trace_id=..&limit=N]`` — the span ring as JSON.
    * ``/debug/slow`` — the slow-query ring, slowest first.
    """
    path, _, qs = handler.path.partition("?")
    if path not in OBS_PATHS:
        return False
    if obs is None or not obs.enabled:
        handler._reply({"error": "observability disabled — launch "
                        "with --metrics"}, 404)
        return True
    if path == "/metrics":
        body = obs.metrics.expose().encode()
        handler.send_response(200)
        handler.send_header("Content-Type",
                            "text/plain; version=0.0.4; charset=utf-8")
        handler.send_header("Content-Length", str(len(body)))
        handler.end_headers()
        handler.wfile.write(body)
    elif path == "/debug/trace":
        params = parse_qs(qs)
        tid = (params.get("trace_id") or [None])[0]
        try:
            limit = int((params.get("limit") or [0])[0])
        except ValueError:
            limit = 0
        handler._reply({"service": obs.service,
                        "dropped": obs.tracer.dropped,
                        "spans": obs.tracer.spans(tid, limit)})
    else:
        handler._reply({"service": obs.service,
                        "stats": obs.slow.stats(),
                        "slowest": obs.slow.entries()})
    return True


class _Handler(BaseHTTPRequestHandler):
    # quiet by default: the load generator would otherwise spam stderr
    def log_message(self, fmt, *args):
        if getattr(self.server, "verbose", False):
            super().log_message(fmt, *args)

    def _reply(self, doc: dict, status: int = 200,
               headers: Optional[dict] = None) -> None:
        self._status = status            # for the request instruments
        body = json.dumps(doc).encode()
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(body)))
        for k, v in (headers or {}).items():
            self.send_header(k, str(v))
        self.end_headers()
        self.wfile.write(body)

    def _service(self) -> TriclusterService:
        return self.server.service

    def _enter(self) -> bool:
        """Per-request entry: fire the ``request`` fault site (the
        chaos plane's drop/slow/hang injection point) and register the
        request for drain accounting.  Returns False when the request
        must be severed with no response bytes (an injected torn
        backend) — the caller just returns."""
        inj = getattr(self.server, "fault", None)
        if inj is not None:
            try:
                inj.fire("request")
            except DropRequest:
                self.close_connection = True
                return False
        return True

    def do_GET(self):
        if not self._enter():
            return
        with self.server.track_request():
            svc = self._service()
            if self.path == "/health":
                doc = health_doc(
                    svc, getattr(self.server, "health_max_staleness", None))
                self._reply(doc, 200 if doc["healthy"] else 503)
            elif self.path == "/stats":
                self._reply(svc.stats())
            elif handle_obs_get(self, self.server.obs):
                pass
            else:
                self._reply({"error": f"unknown path {self.path}"}, 404)

    def do_POST(self):
        t_recv = time.perf_counter()
        if not self._enter():
            return
        with self.server.track_request():
            obs = self.server.obs
            if not obs.enabled:
                return self._post()
            # adopt the caller's trace (router fan-out) or mint a fresh
            # one — this span is the backend's "handled it" record
            tid, pid = parse_trace_header(self.headers.get(TRACE_HEADER))
            role = ("replica" if getattr(self._service(), "read_only",
                                         False) else "writer")
            sp = obs.tracer.start(f"serve{self.path}", trace_id=tid,
                                  parent_id=pid, role=role)
            self._cur_span = sp
            self._status = 200
            t0 = time.perf_counter()
            try:
                self._post()
            finally:
                now = time.perf_counter()
                handler_ms = (now - t0) * 1e3
                total_ms = (now - t_recv) * 1e3
                status = self._status
                sp.set("status", status)
                if status >= 500:
                    sp.error(f"HTTP {status}")
                sp.finish()
                ep = (self.path if self.path in
                      ("/query", "/upsert", "/delete", "/refresh",
                       "/shutdown") else "other")
                pair = self.server._req_instruments.get((ep, status))
                if pair is None:
                    pair = (obs.metrics.histogram("server_request_ms",
                                                  endpoint=ep, role=role),
                            obs.metrics.counter("server_requests_total",
                                                endpoint=ep,
                                                code=str(status),
                                                role=role))
                    self.server._req_instruments[(ep, status)] = pair
                pair[0].observe(handler_ms)
                pair[1].inc()
                if ep == "/query":
                    # wait = receive-to-handler (fault delays, body
                    # read); handler = the dispatch itself
                    obs.slow.record(ep, total_ms, handler_ms=handler_ms,
                                    wait_ms=total_ms - handler_ms,
                                    trace_id=sp.trace_id)

    def _post(self):
        svc = self._service()
        try:
            n = int(self.headers.get("Content-Length") or 0)
            doc = json.loads(self.rfile.read(n) or b"{}")
        except (ValueError, json.JSONDecodeError) as e:
            return self._reply({"error": f"bad JSON body: {e}"}, 400)
        try:
            if self.path == "/query":
                self._reply(self._query(svc, doc))
            elif self.path in ("/upsert", "/delete", "/refresh") and \
                    getattr(svc, "read_only", False):
                self._reply({"error": f"{self.path} on a read-only "
                             "replica — send writes to the shard's "
                             "writer endpoint"}, 501)
            elif self.path in ("/upsert", "/delete"):
                self._mutate(svc, doc, self.path[1:])
            elif self.path == "/refresh":
                snap = svc.refresh()
                self._reply({"version": snap.version,
                             "stream_version": snap.stream_version,
                             "clusters": len(snap.index)})
            elif self.path == "/shutdown":
                if not getattr(self.server, "allow_shutdown", True):
                    return self._reply({"error": "shutdown disabled"}, 403)
                self._reply({"ok": True})
                threading.Thread(target=self.server.shutdown,
                                 daemon=True).start()
            else:
                self._reply({"error": f"unknown path {self.path}"}, 404)
        except TimeoutError as e:
            self._reply({"error": str(e)}, 504)
        except (ValueError, KeyError, IndexError, OverflowError,
                TypeError, RuntimeError) as e:
            # malformed-but-parseable input must get the JSON error
            # contract, not a torn connection
            self._reply({"error": str(e)}, 400)

    def _query(self, svc: TriclusterService, doc: dict) -> dict:
        t0 = time.perf_counter()
        alv = doc.get("at_least_version")
        common = dict(k=int(doc.get("k", 10)),
                      at_least_version=(None if alv is None else int(alv)),
                      timeout=doc.get("timeout"))
        mode = doc.get("mode")
        mode = None if mode is None else int(mode)
        inc = bool(doc.get("include_components", False))
        if "entities" in doc:
            res = svc.query_batch([int(e) for e in doc["entities"]],
                                  mode=mode, **common)
            out = _query_doc(res, True, inc)
        else:
            sig = doc.get("signature")
            res = svc.query(
                entity=(None if doc.get("entity") is None
                        else int(doc["entity"])),
                mode=mode,
                signature=(None if sig is None
                           else (int(sig[0]), int(sig[1]))),
                **common)
            out = _query_doc(res, False, inc)
        # handler wall time: the client subtracts this from its own
        # round-trip to attribute tail latency (queue vs handler)
        out["server_ms"] = (time.perf_counter() - t0) * 1e3
        sp = getattr(self, "_cur_span", None)
        if sp is not None and sp.trace_id:
            out["trace_id"] = sp.trace_id
        return out

    def _mutate(self, svc: TriclusterService, doc: dict,
                op: str) -> None:
        rows = doc.get("rows")
        if not rows:
            raise ValueError(f"/{op} needs non-empty 'rows'")
        limit = int(getattr(self.server, "max_write_backlog", 0) or 0)
        if limit and svc.dirty >= limit:
            # write backpressure: the miner is `limit` writes behind
            # the published snapshot — admitting more just grows the
            # backlog unboundedly.  429 + Retry-After sized to the
            # re-mine cadence tells well-behaved clients when the
            # backlog plausibly drained
            retry_s = max(2 * float(getattr(svc, "refresh_interval",
                                            0.25)), 0.05)
            self.server.throttled_writes += 1
            return self._reply(
                {"error": f"write backlog {svc.dirty} >= "
                          f"max_write_backlog {limit} — retry after "
                          f"the next snapshot swap",
                 "retry_after_s": retry_s, "dirty": svc.dirty},
                429, headers={"Retry-After": f"{retry_s:.3f}"})
        if op == "delete":
            sv = svc.delete(rows)
        else:
            sv = svc.upsert(rows, doc.get("values"))
        self._reply({"stream_version": sv, "dirty": svc.dirty})


class ClusterServeServer(ThreadingHTTPServer):
    """HTTP front-end bound to one :class:`TriclusterService`.

    Tracks in-flight requests so a graceful shutdown can *drain*: stop
    accepting (``shutdown()``), then :meth:`drain_inflight` with a
    deadline, then checkpoint/close — the SIGTERM sequence in
    ``launch/cluster_serve.py``."""
    daemon_threads = True

    def __init__(self, service: TriclusterService, addr=("127.0.0.1", 0),
                 allow_shutdown: bool = True, verbose: bool = False,
                 health_max_staleness: Optional[float] = None,
                 fault=None, max_write_backlog: int = 0, obs=None):
        super().__init__(addr, _Handler)
        self.service = service
        self.allow_shutdown = allow_shutdown
        self.verbose = verbose
        self.health_max_staleness = health_max_staleness
        self.fault = fault
        #: write backpressure bound: /upsert//delete answer 429 once
        #: ``service.dirty`` reaches this (0 = unbounded)
        self.max_write_backlog = int(max_write_backlog)
        self.throttled_writes = 0
        self._inflight = 0
        self._idle = threading.Condition()
        #: observability hub (DESIGN.md §11) — request histograms,
        #: trace spans and the slow-query ring; NULL_OBS when absent
        self.obs = obs if obs is not None else NULL_OBS
        #: hot-path instrument handles keyed ``(endpoint, status)`` —
        #: the registry's label-key lookup is too slow to re-enter per
        #: request (benign race: the registry memoises, so duplicate
        #: builders converge on the same instruments)
        self._req_instruments: dict = {}
        if self.obs.enabled:
            self.obs.metrics.register_collector(self._collect_metrics)

    def _collect_metrics(self):
        """Scrape-time rows: server-local counters, plus the service's
        stats dict when the service does not carry its own obs hub
        (shared-memory replicas) — so /stats and /metrics stay two
        views of the same numbers."""
        yield "server_throttled_writes", {}, self.throttled_writes
        yield "server_inflight", {}, self.inflight
        svc = self.service
        if getattr(svc, "obs", None) is not self.obs:
            role = ("replica" if getattr(svc, "read_only", False)
                    else "writer")
            try:
                for k, val in svc.stats().items():
                    yield f"service_{k}", {"role": role}, val
            except Exception:    # noqa: BLE001 — scrape must survive
                return           # a service mid-teardown

    @property
    def port(self) -> int:
        return self.server_address[1]

    @contextlib.contextmanager
    def track_request(self):
        with self._idle:
            self._inflight += 1
        try:
            yield
        finally:
            with self._idle:
                self._inflight -= 1
                if self._inflight == 0:
                    self._idle.notify_all()

    @property
    def inflight(self) -> int:
        with self._idle:
            return self._inflight

    def drain_inflight(self, timeout: float = 10.0) -> bool:
        """Wait (bounded) for in-flight requests to complete.  Call
        after ``shutdown()`` so no new requests are being accepted;
        returns False if stragglers remain at the deadline (the caller
        proceeds with teardown anyway — a bounded drain, not a hang)."""
        with self._idle:
            return self._idle.wait_for(lambda: self._inflight == 0,
                                       timeout=timeout)


def make_server(service: TriclusterService, host: str = "127.0.0.1",
                port: int = 0, allow_shutdown: bool = True,
                verbose: bool = False,
                health_max_staleness: Optional[float] = None,
                fault=None,
                max_write_backlog: int = 0,
                obs=None) -> ClusterServeServer:
    """Bind (port 0 = ephemeral; read ``server.port``) without serving;
    call ``serve_forever()`` — typically on a thread — to go live."""
    return ClusterServeServer(service, (host, port),
                              allow_shutdown=allow_shutdown, verbose=verbose,
                              health_max_staleness=health_max_staleness,
                              fault=fault,
                              max_write_backlog=max_write_backlog,
                              obs=obs)


def _version_token(v):
    """Freshness token: a scalar against one service, or a per-shard
    list against a ``serve.router`` endpoint (cross-shard
    read-your-writes)."""
    if isinstance(v, (list, tuple)):
        return [int(x) for x in v]
    return int(v)


class ClusterClient:
    """urllib client for the endpoint above (stdlib only)."""

    def __init__(self, base_url: str, timeout: float = 30.0):
        self.base_url = base_url.rstrip("/")
        self.timeout = timeout

    def _call(self, path: str, doc: Optional[dict] = None,
              accept_statuses: tuple = ()) -> dict:
        for attempt in (0, 1):
            req = _urequest.Request(
                self.base_url + path,
                data=None if doc is None else json.dumps(doc).encode(),
                headers={"Content-Type": "application/json"},
                method="GET" if doc is None else "POST")
            try:
                with _urequest.urlopen(req, timeout=self.timeout) as r:
                    return json.loads(r.read())
            except _uerror.HTTPError as e:
                try:
                    body = json.loads(e.read())
                except Exception:
                    body = None
                if (e.code == 429 and attempt == 0
                        and 429 not in accept_statuses):
                    # write backpressure: honour Retry-After exactly
                    # once, then surface the error to the caller
                    ra = e.headers.get("Retry-After") if e.headers \
                        else None
                    if ra is None and isinstance(body, dict):
                        ra = body.get("retry_after_s")
                    try:
                        delay = min(max(float(ra), 0.0), 30.0)
                    except (TypeError, ValueError):
                        delay = 0.5
                    time.sleep(delay)
                    continue
                if e.code in accept_statuses and isinstance(body, dict):
                    body["http_status"] = e.code
                    return body
                msg = (body.get("error", str(e))
                       if isinstance(body, dict) else str(e))
                raise RuntimeError(f"{path}: {msg}") from None

    def health(self) -> dict:
        """The /health doc.  A sick backend (HTTP 503) still returns
        its body — with ``healthy: false``, the ``error`` reason and
        ``http_status: 503`` — instead of raising, so balancers and
        tests can inspect *why* a backend is being ejected."""
        return self._call("/health", accept_statuses=(503,))

    def stats(self) -> dict:
        return self._call("/stats")

    def wait_ready(self, timeout: float = 60.0, min_version: int = 1
                   ) -> dict:
        """Poll ``/health`` until the server answers with a published
        snapshot (connection errors are retried until ``timeout``)."""
        import time
        deadline = time.monotonic() + timeout
        last = None
        while time.monotonic() < deadline:
            try:
                h = self.health()
                if h.get("version", 0) >= min_version and \
                        h.get("healthy", True):
                    return h
                last = h
            except (OSError, RuntimeError) as e:
                last = e
            time.sleep(0.1)
        raise TimeoutError(f"server not ready after {timeout}s ({last!r})")

    def wait_until_fresh(self, max_staleness_s: float = 5.0,
                         timeout: float = 60.0) -> dict:
        """Block until the server's write backlog is drained
        (``dirty == 0``) and its snapshot is younger than
        ``max_staleness_s`` — the load-balancer freshness condition
        (module docstring).  Returns the satisfying /health doc."""
        import time
        deadline = time.monotonic() + timeout
        last = None
        while time.monotonic() < deadline:
            try:
                h = self.health()
                stale = h.get("staleness_s")
                if (h.get("version", 0) >= 1 and h.get("dirty", 0) == 0
                        and h.get("healthy", True)
                        and stale is not None
                        and stale <= max_staleness_s):
                    return h
                last = h
            except (OSError, RuntimeError) as e:
                last = e
            time.sleep(0.05)
        raise TimeoutError(
            f"server not fresh (≤{max_staleness_s}s, drained) after "
            f"{timeout}s ({last!r})")

    def query(self, entity: Optional[int] = None,
              mode: Optional[int] = None, signature=None, k: int = 10,
              at_least_version: Optional[int] = None,
              timeout: Optional[float] = None,
              include_components: bool = False,
              require_all: bool = False) -> dict:
        doc = {"k": k, "include_components": include_components}
        if require_all:
            # router endpoints only: refuse degraded partial coverage
            doc["require_all"] = True
        if entity is not None:
            doc["entity"] = int(entity)
        if mode is not None:
            doc["mode"] = int(mode)
        if signature is not None:
            doc["signature"] = [int(signature[0]), int(signature[1])]
        if at_least_version is not None:
            doc["at_least_version"] = _version_token(at_least_version)
            doc["timeout"] = timeout
        return self._call("/query", doc)

    def query_batch(self, entities, mode: Optional[int] = None,
                    k: int = 10,
                    at_least_version: Optional[int] = None,
                    timeout: Optional[float] = None,
                    include_components: bool = False,
                    require_all: bool = False) -> dict:
        doc = {"entities": [int(e) for e in entities], "k": k,
               "include_components": include_components}
        if require_all:
            doc["require_all"] = True
        if mode is not None:
            doc["mode"] = int(mode)
        if at_least_version is not None:
            doc["at_least_version"] = _version_token(at_least_version)
            doc["timeout"] = timeout
        return self._call("/query", doc)

    def upsert(self, rows, values=None) -> dict:
        doc = {"rows": [list(map(int, r)) for r in rows]}
        if values is not None:
            doc["values"] = [float(v) for v in values]
        return self._call("/upsert", doc)

    def delete(self, rows) -> dict:
        return self._call("/delete",
                          {"rows": [list(map(int, r)) for r in rows]})

    def refresh(self) -> dict:
        return self._call("/refresh", {})

    def shutdown(self) -> dict:
        return self._call("/shutdown", {})
