"""Process supervision for the serving plane (DESIGN.md §9).

The sharded plane (``launch/cluster_serve.py``) is a tree of OS
processes — shard writers and shm replica readers — and the paper's
distributed setting makes worker death *normal*, not exceptional.  This
module is the part of the Hadoop-era framework contract the hand-rolled
plane was missing: a :class:`Supervisor` owns a set of named children,
restarts one when it dies (capped exponential backoff between
attempts), and gives up on a crash-looping child after ``max_restarts``
exits inside ``restart_window`` seconds (state ``failed`` — restarting
a deterministically-crashing writer forever would just burn CPU while
the router's degraded path already covers the range).

Children are described by a *factory*: a callable returning a
**started** ``multiprocessing.Process``.  The factory re-runs on every
restart, so a writer factory that points at a ``recover_dir`` gets the
checkpoint+WAL recovery path (``serve.service.TriclusterService``) for
free — restart *is* recovery.

Two restart triggers:

* **exit** — the child process died.  Exit codes in ``clean_exits``
  (default: 0) mark a deliberate stop and are not restarted.
* **restart flag** — a file named ``{name}.restart`` appearing in
  ``flag_dir``.  This is the cross-process escalation path for *hung*
  children: a replica whose stuck-odd protocol declares its writer dead
  (``serve.shm.WriterDeadError``) cannot kill the writer itself — it
  drops a flag file and the supervisor terminates + relaunches the
  writer.  Flag files are consumed (unlinked) exactly once.

Everything is driven by one monitor thread polling at
``poll_interval``; all state transitions are recorded in an ``events``
list (name, event, detail tuples) so fault-injection tests can assert
exact restart sequences instead of sleeping and hoping.  The log is a
*bounded tail*: past ``max_events`` entries it rotates atomically (a
fresh list is bound in one assignment, led by a ``rotated`` marker
carrying the cumulative drop count), so a long-running plane cannot
leak memory through its own audit trail while readers holding the old
reference still see a consistent list.

Children can also *push* events into the log across the process
boundary: :func:`write_event` appends JSON lines to
``{flag_dir}/{name}.events``, which the monitor ingests (atomic
rename + read) on every tick — the path the data-integrity plane uses
to surface WAL/checkpoint quarantines (``serve.service``) in the same
timeline as the restarts they explain.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple


class _Child:
    __slots__ = ("name", "factory", "proc", "state", "restarts",
                 "exit_times", "backoff", "next_restart_at",
                 "started_at", "last_exit", "clean_exits")

    def __init__(self, name: str, factory, clean_exits: Sequence[int]):
        self.name = name
        self.factory = factory
        self.proc = None
        self.state = "new"        # new|running|backoff|failed|stopped
        self.restarts = 0
        self.exit_times: List[float] = []
        self.backoff = 0.0
        self.next_restart_at = 0.0
        self.started_at = 0.0
        self.last_exit: Optional[int] = None
        self.clean_exits = tuple(int(c) for c in clean_exits)


class Supervisor:
    """Restart-with-backoff supervision over named child processes."""

    def __init__(self, restart_backoff: float = 0.2,
                 backoff_max: float = 5.0, max_restarts: int = 5,
                 restart_window: float = 60.0,
                 flag_dir: Optional[str] = None,
                 poll_interval: float = 0.05,
                 max_events: int = 2048):
        self.restart_backoff = float(restart_backoff)
        self.backoff_max = float(backoff_max)
        self.max_restarts = int(max_restarts)
        self.restart_window = float(restart_window)
        self.flag_dir = flag_dir
        self.poll_interval = float(poll_interval)
        self.max_events = max(8, int(max_events))
        self._children: Dict[str, _Child] = {}
        self._lock = threading.RLock()
        self._stop_evt = threading.Event()
        self._thread: Optional[threading.Thread] = None
        #: (name, event, detail) transition log — the deterministic
        #: assertion surface for chaos tests.  Bounded: rotates to the
        #: newest half past ``max_events`` (see :meth:`_event`)
        self.events: List[Tuple[str, str, str]] = []
        #: total entries dropped by rotation so far
        self.events_dropped = 0

    # -- registration / lifecycle --------------------------------------------

    def add(self, name: str, factory: Callable,
            clean_exits: Sequence[int] = (0,)) -> "Supervisor":
        """Register (and immediately launch) child ``name``.
        ``factory()`` must return a *started* ``multiprocessing``
        process; it re-runs on every restart."""
        with self._lock:
            if name in self._children:
                raise ValueError(f"duplicate child {name!r}")
            ch = _Child(name, factory, clean_exits)
            self._children[name] = ch
            self._launch(ch)
        return self

    def _event(self, name: str, event: str, detail: str = "") -> None:
        with self._lock:
            ev = self.events
            ev.append((name, event, detail))
            if len(ev) > self.max_events:
                keep = self.max_events // 2
                self.events_dropped += len(ev) - keep
                # atomic rotation: bind a *new* list in one assignment —
                # readers holding the old reference keep a consistent
                # (if stale) view, and the tail they care about survives
                self.events = [("<supervisor>", "rotated",
                                f"dropped {self.events_dropped} older "
                                f"events")] + ev[-keep:]

    def _launch(self, ch: _Child) -> None:
        ch.proc = ch.factory()
        ch.state = "running"
        ch.started_at = time.monotonic()
        self._event(ch.name, "started", f"pid={ch.proc.pid}")

    def start(self) -> "Supervisor":
        if self._thread is not None:
            return self
        self._stop_evt.clear()
        self._thread = threading.Thread(target=self._monitor,
                                        name="supervisor", daemon=True)
        self._thread.start()
        return self

    def stop(self, terminate: bool = True, join_timeout: float = 10.0
             ) -> None:
        self._stop_evt.set()
        if self._thread is not None:
            self._thread.join(timeout=join_timeout)
            self._thread = None
        if not terminate:
            return
        with self._lock:
            for ch in self._children.values():
                p = ch.proc
                if p is not None and p.is_alive():
                    p.terminate()
                ch.state = "stopped"
        with self._lock:
            procs = [ch.proc for ch in self._children.values()
                     if ch.proc is not None]
        for p in procs:
            p.join(timeout=join_timeout)

    def __enter__(self) -> "Supervisor":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- monitoring ----------------------------------------------------------

    def _flag_path(self, name: str) -> Optional[str]:
        if self.flag_dir is None:
            return None
        return os.path.join(self.flag_dir, f"{name}.restart")

    def _consume_flag(self, name: str) -> bool:
        path = self._flag_path(name)
        if path is None:
            return False
        try:
            os.unlink(path)                  # consume exactly once
            return True
        except FileNotFoundError:
            return False

    def _schedule_restart(self, ch: _Child, reason: str) -> None:
        now = time.monotonic()
        ch.exit_times.append(now)
        cutoff = now - self.restart_window
        ch.exit_times = [t for t in ch.exit_times if t >= cutoff]
        if len(ch.exit_times) > self.max_restarts:
            ch.state = "failed"
            self._event(ch.name, "failed",
                        f"{len(ch.exit_times)} exits in "
                        f"{self.restart_window:.0f}s ({reason})")
            return
        # a child that ran for a while before dying earns a fresh
        # backoff; a quick death doubles the previous one
        if ch.started_at and now - ch.started_at > 2 * self.backoff_max:
            ch.backoff = 0.0
        ch.backoff = (self.restart_backoff if ch.backoff == 0.0
                      else min(ch.backoff * 2, self.backoff_max))
        ch.next_restart_at = now + ch.backoff
        ch.state = "backoff"
        self._event(ch.name, "backoff",
                    f"{reason}; retry in {ch.backoff:.2f}s")

    def _tick(self) -> None:
        with self._lock:
            for ch in self._children.values():
                if ch.state == "running":
                    if self._consume_flag(ch.name):
                        # hung-child escalation: terminate + relaunch
                        self._event(ch.name, "flagged", "restart flag")
                        p = ch.proc
                        if p is not None and p.is_alive():
                            p.terminate()
                            p.join(timeout=10)
                        ch.restarts += 1
                        ch.last_exit = (None if p is None
                                        else p.exitcode)
                        self._schedule_restart(ch, "restart flag")
                    elif not ch.proc.is_alive():
                        ch.proc.join()
                        ch.last_exit = ch.proc.exitcode
                        if ch.last_exit in ch.clean_exits:
                            ch.state = "stopped"
                            self._event(ch.name, "stopped",
                                        f"exit={ch.last_exit}")
                        else:
                            ch.restarts += 1
                            self._schedule_restart(
                                ch, f"exit={ch.last_exit}")
                elif ch.state == "backoff" and \
                        time.monotonic() >= ch.next_restart_at:
                    self._event(ch.name, "restarting",
                                f"attempt {ch.restarts}")
                    self._launch(ch)
        self._ingest_child_events()

    def _ingest_child_events(self) -> None:
        """Adopt events pushed by children via :func:`write_event` into
        the supervisor's log.  The file is claimed by atomic rename
        first, so a child appending concurrently either lands in this
        batch or in a fresh file for the next tick — never lost."""
        if self.flag_dir is None:
            return
        with self._lock:
            names = list(self._children)
        for name in names:
            path = os.path.join(self.flag_dir, f"{name}.events")
            claimed = f"{path}.ingest"
            try:
                os.replace(path, claimed)
            except OSError:
                continue
            try:
                with open(claimed, encoding="utf-8") as fh:
                    data = fh.read()
            finally:
                try:
                    os.unlink(claimed)
                except OSError:
                    pass
            for line in data.splitlines():
                line = line.strip()
                if not line:
                    continue
                try:
                    doc = json.loads(line)
                except json.JSONDecodeError:
                    doc = {"event": "child_event", "detail": line}
                self._event(name, str(doc.get("event", "child_event")),
                            str(doc.get("detail", "")))

    def _monitor(self) -> None:
        while not self._stop_evt.wait(self.poll_interval):
            try:
                self._tick()
            except Exception as e:           # noqa: BLE001 — the
                # supervisor itself must not die of a child race
                self._event("<supervisor>", "tick_error", repr(e))

    # -- introspection -------------------------------------------------------

    def restart(self, name: str) -> None:
        """Manual restart request — same path as a flag file."""
        with self._lock:
            ch = self._children[name]
            p = ch.proc
            if p is not None and p.is_alive():
                p.terminate()
                p.join(timeout=10)
            ch.restarts += 1
            self._schedule_restart(ch, "manual restart")

    def child_state(self, name: str) -> str:
        with self._lock:
            return self._children[name].state

    def wait_state(self, name: str, states: Sequence[str],
                   timeout: float = 30.0) -> str:
        """Block until child ``name`` reaches one of ``states`` —
        event-driven test synchronisation (no sleeps-as-sync)."""
        deadline = time.monotonic() + timeout
        while True:
            st = self.child_state(name)
            if st in states:
                return st
            if time.monotonic() >= deadline:
                raise TimeoutError(
                    f"{name}: state {st!r} after {timeout}s "
                    f"(waiting for {states})")
            time.sleep(0.01)

    def stats(self) -> dict:
        with self._lock:
            return {"children": {
                ch.name: {"state": ch.state, "restarts": ch.restarts,
                          "last_exit": ch.last_exit,
                          "pid": (None if ch.proc is None
                                  else ch.proc.pid),
                          "alive": (ch.proc is not None
                                    and ch.proc.is_alive())}
                for ch in self._children.values()}}


def write_restart_flag(flag_dir: str, name: str) -> str:
    """Drop the restart flag the supervisor watches for — the signal a
    replica's ``on_writer_dead`` callback sends (atomic create; racing
    writers are harmless, the flag is level-triggered)."""
    path = os.path.join(flag_dir, f"{name}.restart")
    with open(path, "w") as fh:
        fh.write(str(time.time()))
    return path


def write_event(flag_dir: str, name: str, event: str,
                detail: str = "") -> str:
    """Push one event from child ``name`` into the supervisor's log
    (appends a JSON line to ``{name}.events``; the monitor thread
    ingests the file on its next tick).  The cross-process half of the
    integrity plane's reporting: quarantines and scrub violations land
    in the same ordered timeline as the restarts they explain."""
    path = os.path.join(flag_dir, f"{name}.events")
    with open(path, "a", encoding="utf-8") as fh:
        fh.write(json.dumps({"event": str(event),
                             "detail": str(detail)}) + "\n")
    return path
