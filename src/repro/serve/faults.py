"""Deterministic fault injection for the serving plane (DESIGN.md §9).

The chaos tests and the ``benchmarks/chaos.py`` kill-and-restart cycles
need *reproducible* failures: a fault must fire at the same logical
point of the run every time, independent of thread scheduling or wall
clock.  So every fault here triggers on a **counter the instrumented
site passes in** — a stream version, a snapshot publish version, a
request ordinal — never on elapsed time.  A :class:`FaultPlan` is a
JSON-serialisable list of :class:`Fault` records plus a seed; the seed
feeds :meth:`FaultPlan.scattered`, which derives drop/slow request
ordinals from a splitmix64 stream so a whole chaos run is replayable
from one integer.

Fault sites (the strings instrumented code fires):

* ``write``   — fired by ``TriclusterService._write`` with the miner's
  new ``stream_version``: ``kill`` here is *kill-shard-at-version-N*.
* ``publish`` — fired by ``ShmPublisher.publish`` with the snapshot
  version, before any segment bytes are written.
* ``torn``    — fired by ``ShmPublisher._swing`` **while the seqlock is
  odd**: ``kill`` here dies mid-publish, leaving a stuck-odd control
  block and an orphaned data segment (the crash-safe-shm test fixture).
* ``request`` — fired by the HTTP front-ends per request (ordinal
  counter): ``drop`` severs the connection with no response, ``slow``
  and ``hang`` delay it (``hang`` defaults to effectively forever —
  the circuit-breaker fixture).

**Corruption sites** (the fail-silent half — DESIGN.md §9): three
sites are *polled* via :meth:`FaultInjector.corrupt` instead of fired,
because the corruption itself must be enacted by the code that owns
the bytes, **after** the protecting checksum was computed — so the
integrity plane's detection, not luck, is what the chaos run gates:

* ``wal``        — polled by ``TriclusterService._wal_append`` with
  the record's stream version: ``flip`` rots one byte of the framed
  payload on disk (the in-memory apply is untouched — silent at-rest
  corruption).
* ``checkpoint`` — polled after ``RunStore`` checkpoint persistence
  with the publish version: ``truncate`` cuts the blob in half.
* ``shm``        — polled by ``ShmPublisher.publish`` after the
  arrays are written: ``flip`` inverts one aligned word of the first
  sizeable array in the segment.

Plans are scoped per component: ``plan.for_component(role, shard,
replica)`` returns the :class:`FaultInjector` holding exactly the
faults aimed at that component (``-1`` fields are wildcards), so one
plan string can be handed to every process of a plane
(``launch/cluster_serve.py --fault-plan``).
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
import time
from typing import List, Optional, Sequence, Tuple

#: exit status of an injected ``kill`` — distinctive, so supervisors and
#: tests can tell an injected crash from a genuine one.
KILL_EXIT_CODE = 23

KINDS = ("kill", "hang", "drop", "slow", "flip", "truncate")
SITES = ("write", "publish", "torn", "request", "wal", "checkpoint", "shm")
ROLES = ("writer", "replica", "router", "*")

#: kinds enacted by the *call site* via :meth:`FaultInjector.corrupt`
#: rather than by :meth:`FaultInjector.fire`
CORRUPT_KINDS = ("flip", "truncate")


class DropRequest(Exception):
    """Raised by a ``drop`` fault: the HTTP handler must sever the
    connection without writing any response (a torn backend)."""


@dataclasses.dataclass(frozen=True)
class Fault:
    """One armed fault.  ``at`` is compared against the counter the
    site fires with; ``every`` re-arms periodically past ``at``;
    ``count`` caps total firings (0 = unlimited)."""
    kind: str                 # kill | hang | drop | slow | flip | truncate
    site: str                 # write | publish | torn | request | wal | checkpoint | shm
    role: str = "*"           # writer | replica | router | *
    shard: int = -1           # -1 = any
    replica: int = -1         # -1 = any
    at: int = 0
    every: int = 0
    count: int = 1
    param: float = 0.0        # seconds (hang/slow); unused otherwise

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r}")
        if self.role not in ROLES:
            raise ValueError(f"unknown fault role {self.role!r}")

    def matches(self, role: str, shard: int, replica: int) -> bool:
        return ((self.role in ("*", role))
                and (self.shard < 0 or self.shard == int(shard))
                and (self.replica < 0 or self.replica == int(replica)))

    def due(self, value: int, fired: int) -> bool:
        # count=0 means unlimited, but a cleared fault (fired forced
        # huge by FaultInjector.clear) must stay disarmed
        if fired >= (self.count or (1 << 30)):
            return False
        if value < self.at:
            return False
        if self.every > 0:
            return (value - self.at) % self.every == 0
        return fired == 0


def _splitmix64(x: int) -> int:
    """One step of splitmix64 — the deterministic ordinal stream behind
    :meth:`FaultPlan.scattered` (no numpy: replicas stay jax/np-light)."""
    x = (x + 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
    z = x
    z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & 0xFFFFFFFFFFFFFFFF
    z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & 0xFFFFFFFFFFFFFFFF
    return z ^ (z >> 31)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded, serialisable set of faults for one chaos run."""
    faults: Tuple[Fault, ...] = ()
    seed: int = 0

    # -- construction helpers ------------------------------------------------

    @staticmethod
    def build(*faults: Fault, seed: int = 0) -> "FaultPlan":
        return FaultPlan(tuple(faults), int(seed))

    @staticmethod
    def kill_writer(shard: int, at_stream_version: int) -> Fault:
        """Kill shard ``shard``'s writer when its stream version
        reaches N (hard ``os._exit`` — no cleanup runs)."""
        return Fault("kill", "write", role="writer", shard=shard,
                     at=int(at_stream_version))

    @staticmethod
    def kill_writer_at_publish(shard: int, at_version: int) -> Fault:
        """Kill the writer when it is about to publish snapshot
        version N (before any shm bytes are written)."""
        return Fault("kill", "publish", role="writer", shard=shard,
                     at=int(at_version))

    @staticmethod
    def torn_publish(shard: int, at_version: int) -> Fault:
        """Kill the writer mid-seqlock-swing of snapshot version N:
        the control block is left odd, the data segment orphaned."""
        return Fault("kill", "torn", role="writer", shard=shard,
                     at=int(at_version))

    @staticmethod
    def hang_replica(shard: int, replica: int, at_request: int,
                     for_s: float = 3600.0, count: int = 1) -> Fault:
        """Replica ``(shard, replica)`` blocks its ``at_request``-th
        request for ``for_s`` seconds (default: effectively forever)."""
        return Fault("hang", "request", role="replica", shard=shard,
                     replica=replica, at=int(at_request),
                     count=int(count), param=float(for_s))

    @staticmethod
    def flip_wal_byte(shard: int, at_stream_version: int,
                      count: int = 1) -> Fault:
        """Rot one byte of the WAL record framed at stream version N —
        after its CRC was computed, so only replay-time verification
        can catch it (the victim's in-memory state is untouched)."""
        return Fault("flip", "wal", role="writer", shard=shard,
                     at=int(at_stream_version), count=int(count))

    @staticmethod
    def truncate_checkpoint(shard: int, at_version: int,
                            count: int = 1) -> Fault:
        """Cut the checkpoint blob persisted at publish version N in
        half on disk — the framed length/CRC header must reject it and
        recovery must fall back to the previous generation."""
        return Fault("truncate", "checkpoint", role="writer",
                     shard=shard, at=int(at_version), count=int(count))

    @staticmethod
    def flip_shm_word(shard: int, at_version: int,
                      count: int = 1) -> Fault:
        """Invert one aligned 8-byte word inside the data segment of
        snapshot version N, after the manifest checksums were taken —
        replicas must refuse the segment at attach-time verify."""
        return Fault("flip", "shm", role="writer", shard=shard,
                     at=int(at_version), count=int(count))

    @staticmethod
    def drop_requests(role: str, shard: int, at: int, every: int = 0,
                      count: int = 1, replica: int = -1) -> Fault:
        """Sever matching requests without any response bytes."""
        return Fault("drop", "request", role=role, shard=shard,
                     replica=replica, at=int(at), every=int(every),
                     count=int(count))

    @staticmethod
    def slow_requests(role: str, shard: int, at: int, delay_s: float,
                      every: int = 0, count: int = 1,
                      replica: int = -1) -> Fault:
        return Fault("slow", "request", role=role, shard=shard,
                     replica=replica, at=int(at), every=int(every),
                     count=int(count), param=float(delay_s))

    @staticmethod
    def scattered(seed: int, role: str, shard: int, window: int,
                  n_drop: int = 0, n_slow: int = 0,
                  slow_s: float = 0.05, replica: int = -1,
                  offset: int = 1) -> "FaultPlan":
        """Seed-derived dropped/slow responses: ``n_drop + n_slow``
        distinct request ordinals drawn deterministically from
        ``[offset, offset + window)`` via splitmix64 — the replayable
        "flaky backend" of the chaos benchmark."""
        picks: List[int] = []
        x = (int(seed) << 1) | 1
        while len(picks) < n_drop + n_slow:
            x = _splitmix64(x)
            o = offset + (x % max(1, int(window)))
            if o not in picks:
                picks.append(o)
        faults = [FaultPlan.drop_requests(role, shard, at=o,
                                          replica=replica)
                  for o in picks[:n_drop]]
        faults += [FaultPlan.slow_requests(role, shard, at=o,
                                           delay_s=slow_s,
                                           replica=replica)
                   for o in picks[n_drop:]]
        return FaultPlan(tuple(faults), int(seed))

    # -- serialisation -------------------------------------------------------

    def to_json(self) -> str:
        return json.dumps({"seed": self.seed,
                           "faults": [dataclasses.asdict(f)
                                      for f in self.faults]})

    @staticmethod
    def from_json(s: str) -> "FaultPlan":
        doc = json.loads(s)
        return FaultPlan(tuple(Fault(**f) for f in doc.get("faults", ())),
                         int(doc.get("seed", 0)))

    def merge(self, other: "FaultPlan") -> "FaultPlan":
        return FaultPlan(self.faults + other.faults, self.seed)

    # -- scoping -------------------------------------------------------------

    def for_component(self, role: str, shard: int = 0,
                      replica: int = -1) -> "FaultInjector":
        sel = tuple(f for f in self.faults
                    if f.matches(role, shard, replica))
        return FaultInjector(sel)


class FaultInjector:
    """The per-component runtime: instrumented sites call
    :meth:`fire` with their counter; armed faults act.  Thread-safe;
    cheap when empty (components hold ``None`` instead when no plan is
    threaded through, so the truly-disabled path is one ``is None``)."""

    def __init__(self, faults: Sequence[Fault] = ()):
        self.faults = tuple(faults)
        self._fired = [0] * len(self.faults)
        self._counters: dict = {}
        self._lock = threading.Lock()

    def __bool__(self) -> bool:
        return bool(self.faults)

    def fired(self, site: Optional[str] = None) -> int:
        with self._lock:
            return sum(n for f, n in zip(self.faults, self._fired)
                       if site is None or f.site == site)

    def clear(self, site: Optional[str] = None) -> None:
        """Disarm matching faults (future fires become no-ops)."""
        with self._lock:
            for i, f in enumerate(self.faults):
                if site is None or f.site == site:
                    self._fired[i] = max(self._fired[i],
                                         f.count if f.count else 1 << 30)

    def fire(self, site: str, value: Optional[int] = None) -> None:
        """Report that ``site`` reached ``value`` (or its next internal
        ordinal).  May sleep (hang/slow), raise :class:`DropRequest`,
        or terminate the process (kill) — in that priority order a
        given call resolves at most one *kill*, after honouring any
        matching delays."""
        if not self.faults:
            return
        actions: List[Fault] = []
        with self._lock:
            if value is None:
                value = self._counters.get(site, 0) + 1
                self._counters[site] = value
            for i, f in enumerate(self.faults):
                if f.site != site or f.kind in CORRUPT_KINDS:
                    continue
                if f.due(int(value), self._fired[i]):
                    self._fired[i] += 1
                    actions.append(f)
        drop = False
        for f in actions:
            if f.kind in ("hang", "slow"):
                time.sleep(f.param if f.param > 0 else 3600.0)
            elif f.kind == "drop":
                drop = True
        for f in actions:
            if f.kind == "kill":
                # a *crash*, not an exit: no atexit, no finally blocks,
                # no publisher cleanup — exactly what recovery must
                # survive
                os._exit(KILL_EXIT_CODE)
        if drop:
            raise DropRequest(f"injected drop at {site}#{value}")

    def corrupt(self, site: str, value: int) -> Optional[Fault]:
        """Poll the corruption sites: return the armed ``flip`` /
        ``truncate`` fault due at ``value`` (marking it fired), else
        ``None``.  Unlike :meth:`fire`, the *caller* enacts the damage
        — it owns the bytes being rotted and must do so after the
        protecting checksum was computed."""
        if not self.faults:
            return None
        with self._lock:
            for i, f in enumerate(self.faults):
                if f.site != site or f.kind not in CORRUPT_KINDS:
                    continue
                if f.due(int(value), self._fired[i]):
                    self._fired[i] += 1
                    return f
        return None


#: shared no-op injector for call sites that want an always-valid object
NO_FAULTS = FaultInjector(())
