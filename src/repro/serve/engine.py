"""Batched serving engine (prefill + ragged decode).

Ragged prompt batching without masks or cache surgery: prefill runs on the
*common prefix* (min prompt length), then the decode loop *replays* each
sequence's remaining prompt tokens by teacher forcing — ``decode_step``
takes a (B,) token vector, so every step each slot independently feeds
either its next prompt token (still inside its prompt) or its previously
sampled token (generating). Correct for causal LMs with per-sequence
positions identical, which holds because every slot advances one position
per step.

The same engine object serves both `serve.py` (throughput runs) and the
examples; on TPU the jit'd prefill/decode are the production steps the
dry-run lowers for the decode/prefill cells.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..models.api import get_model
from ..sharding.rules import MeshRules


@dataclasses.dataclass
class GenerationResult:
    tokens: list                  # list[list[int]] generated per request
    prefill_s: float
    decode_s: float
    steps: int

    @property
    def tokens_per_s(self) -> float:
        n = sum(len(t) for t in self.tokens)
        return n / self.decode_s if self.decode_s else float("inf")


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_len: int = 2048,
                 rules: Optional[MeshRules] = None,
                 temperature: float = 0.0, eos_id: Optional[int] = None,
                 seed: int = 0):
        self.cfg, self.params, self.rules = cfg, params, rules
        self.max_len = max_len
        self.temperature = temperature
        self.eos_id = eos_id
        self.model = get_model(cfg)
        self._key = jax.random.PRNGKey(seed)

        def prefill(p, tokens):
            return self.model.prefill(cfg, p, {"tokens": tokens}, max_len,
                                      rules)

        def decode(p, cache, tok, key, temp):
            cache, logits = self.model.decode_step(cfg, p, cache, tok, rules)
            greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            sampled = jax.random.categorical(key, logits / jnp.maximum(
                temp, 1e-6), axis=-1).astype(jnp.int32)
            nxt = jnp.where(temp > 0, sampled, greedy)
            return cache, nxt

        self._prefill = jax.jit(prefill)
        self._decode = jax.jit(decode)

    # -- batched generation ---------------------------------------------------

    def generate(self, prompts: Sequence[Sequence[int]],
                 max_new_tokens: int = 32) -> GenerationResult:
        b = len(prompts)
        lens = np.array([len(p) for p in prompts])
        if (lens <= 0).any():
            raise ValueError("empty prompt")
        s_min = int(lens.min())
        s_max = int(lens.max())
        total = s_max + max_new_tokens
        if total > self.max_len and self.cfg.window is None:
            raise ValueError(f"total {total} exceeds engine max_len "
                             f"{self.max_len}")
        # right-pad prompts; padding is only read by the replay logic below
        pad = np.zeros((b, s_max), np.int32)
        for i, p in enumerate(prompts):
            pad[i, :len(p)] = p

        t0 = time.time()
        cache, logits = jax.block_until_ready(
            self._prefill(self.params, jnp.asarray(pad[:, :s_min])))
        prefill_s = time.time() - t0

        # per-slot cursor: absolute position of the next token to *feed*
        cursor = np.full((b,), s_min)
        last = np.asarray(jnp.argmax(logits, axis=-1))     # next-token guess
        done = np.zeros((b,), bool)
        out: list[list[int]] = [[] for _ in range(b)]

        t0 = time.time()
        steps = 0
        while True:
            replaying = cursor < lens
            full = np.array([len(o) >= max_new_tokens for o in out])
            if (~replaying & (done | full)).all():
                break
            feed = np.where(replaying, pad[np.arange(b),
                                           np.minimum(cursor, s_max - 1)],
                            last)
            self._key, sub = jax.random.split(self._key)
            cache, nxt = self._decode(self.params, cache,
                                      jnp.asarray(feed, jnp.int32), sub,
                                      jnp.float32(self.temperature))
            nxt = np.asarray(jax.block_until_ready(nxt))
            steps += 1
            for i in range(b):
                if replaying[i]:
                    pass                       # still consuming the prompt
                elif not done[i] and len(out[i]) < max_new_tokens:
                    out[i].append(int(last[i]))
                    if self.eos_id is not None and last[i] == self.eos_id:
                        done[i] = True
            last = nxt
            cursor += 1
            if steps > self.max_len + max_new_tokens:
                raise RuntimeError("decode loop failed to terminate")
        decode_s = time.time() - t0
        return GenerationResult(out, prefill_s, decode_s, steps)
