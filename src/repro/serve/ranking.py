"""Ranking layer over the cluster-query surface (DESIGN.md §8).

``serve.clusters.ClusterIndex`` answers *membership*; this module
answers *which hits matter*: every cluster of a snapshot gets one scalar
score — a weighted sum of density, log-scaled volume and recency
(``RankingPolicy``) — and queries return hits best-first.

Two query paths share the same scores and the same ordering:

* scalar (``BatchQuerier.topk``): one per-entity probe through the
  index plus a per-query python sort — the serving baseline;
* batched (``BatchQuerier.topk_batch``): the per-mode component windows
  of the snapshot are *stacked* once at build time into a single sorted
  array of packed ``(entity << 32) | cluster_row`` words (the
  ``core.keys`` trick — one word comparison instead of a tuple compare),
  so a multi-entity query is two vectorised ``searchsorted`` passes plus
  one ``lexsort`` over the combined hit set, instead of N python probes
  and N python sorts.  Both paths return bit-identical hit lists
  (tested), so callers can batch opportunistically.

Cluster *signatures* rank the same way: ``pack_signatures`` folds the
2×32-bit cross-engine signature into one uint64 word (exactly Stage 3's
packed sort key), and ``BatchQuerier.lookup_signatures`` resolves a
batch of signatures — issued by *any* engine with the same seed —
against the snapshot in one ``searchsorted`` pass.

Recency is a property of the *stream*, not of one mining result: the
serving layer (``serve.service``) tracks, per signature, the snapshot
version that first published it, and passes per-cluster ages here.
Without ages every cluster counts as fresh.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .clusters import ClusterIndex, ClusterView


@dataclasses.dataclass(frozen=True)
class RankingPolicy:
    """Score = ``w_density * density + w_volume * vol + w_recency * rec``
    with ``vol = log1p(volume) / log1p(max volume in snapshot)`` (so one
    huge cluster cannot drown the density term) and
    ``rec = 1 / (1 + age_in_versions)`` (1.0 for clusters first seen in
    the current snapshot).  All three terms live in [0, 1]."""
    w_density: float = 1.0
    w_volume: float = 0.0
    w_recency: float = 0.0


DEFAULT_POLICY = RankingPolicy()


def cluster_scores(index: ClusterIndex,
                   policy: RankingPolicy = DEFAULT_POLICY,
                   ages: Optional[np.ndarray] = None) -> np.ndarray:
    """One float64 score per ``index.clusters`` row (ties are broken by
    row order everywhere downstream, so equal-score rankings are still
    deterministic)."""
    n = len(index)
    dens = index.density.astype(np.float64)
    score = policy.w_density * dens
    if policy.w_volume:
        vol = np.log1p(index.volume.astype(np.float64))
        score = score + policy.w_volume * (vol / max(vol.max(initial=0.0),
                                                     1e-12))
    if policy.w_recency:
        age = (np.zeros(n, np.float64) if ages is None
               else np.asarray(ages, np.float64))
        score = score + policy.w_recency / (1.0 + age)
    return score


def rank_views(hits: Sequence[Tuple[ClusterView, float]],
               k: Optional[int] = None) -> List[Tuple[ClusterView, float]]:
    """Best-first ordering of (view, score) pairs, stable in input order
    on ties; ``k`` truncates."""
    out = sorted(enumerate(hits), key=lambda t: (-t[1][1], t[0]))
    return [h for _, h in (out if k is None else out[:k])]


def top_clusters(index: ClusterIndex, k: int = 10,
                 policy: RankingPolicy = DEFAULT_POLICY,
                 ages: Optional[np.ndarray] = None
                 ) -> List[Tuple[ClusterView, float]]:
    """Global top-k of a snapshot (no entity constraint)."""
    scores = cluster_scores(index, policy, ages)
    order = np.lexsort((np.arange(len(scores)), -scores))[:k]
    return [(index.view_at(int(i)), float(scores[i])) for i in order]


def pack_signatures(sig_lo, sig_hi) -> np.ndarray:
    """(lo, hi) uint32 pairs → one uint64 word, ``(hi << 32) | lo`` —
    the same single-word form Stage 3 sorts (``core.keys``), reused here
    as the O(log n)-resolvable serving identity."""
    lo = np.asarray(sig_lo, np.uint64) & np.uint64(0xFFFFFFFF)
    hi = np.asarray(sig_hi, np.uint64) & np.uint64(0xFFFFFFFF)
    return (hi << np.uint64(32)) | lo


def top_from_scores(index: ClusterIndex, scores: np.ndarray, k: int = 10
                    ) -> List[Tuple[ClusterView, float]]:
    """Global top-k from an already-computed score vector (the replica
    path reuses the scores the writer published; identical ordering to
    :func:`top_clusters` given the same scores)."""
    order = np.lexsort((np.arange(len(scores)), -scores))[:k]
    return [(index.view_at(int(i)), float(scores[i])) for i in order]


class BatchQuerier:
    """Ranked lookups over one snapshot's :class:`ClusterIndex`.

    Built once per snapshot (O(total component membership) stacking +
    sorts); immutable afterwards, so it is shared freely across reader
    threads like the index itself."""

    def __init__(self, index: ClusterIndex,
                 policy: RankingPolicy = DEFAULT_POLICY,
                 ages: Optional[np.ndarray] = None,
                 scores: Optional[np.ndarray] = None):
        self.index = index
        self.policy = policy
        #: ``scores`` short-circuits the recompute — replica readers
        #: (serve.shm) rank with the exact score vector the writer
        #: published, so writer and replicas answer bit-identically
        self.scores = (np.asarray(scores, np.float64) if scores is not None
                       else cluster_scores(index, policy, ages))
        #: bits of the packed word holding the cluster row (low field) —
        #: the index's membership words are always (entity << 32) | row
        self.cluster_bits = 32
        self._row_mask = np.uint64(0xFFFFFFFF)
        # the stacked component windows are shared with the index, but
        # pulled lazily: a delta-built index answers scalar probes from
        # its overlay without ever materialising the flat arrays, so
        # constructing a querier stays off the swap-critical path
        self._keys_cache: Optional[Tuple[List[np.ndarray], np.ndarray]] \
            = None
        # signature resolution: sorted packed words + their rows; a
        # vectorised index is already row-ordered by packed signature,
        # so its sig array is reused as-is (argsort is the identity) —
        # and no view objects are touched anywhere in construction
        if index.packed_sigs is not None:
            self._sig_sorted = index.packed_sigs
            self._sig_order = np.arange(len(index), dtype=np.int64)
        else:
            sigs = pack_signatures(index.sig_lo, index.sig_hi)
            self._sig_order = np.argsort(sigs).astype(np.int64)
            self._sig_sorted = sigs[self._sig_order]

    # -- scalar path (the baseline) -----------------------------------------

    def topk(self, entity: int, mode: Optional[int] = None, k: int = 10
             ) -> List[Tuple[ClusterView, float]]:
        """Per-entity probe + per-query sort: best-``k`` clusters whose
        mode-``mode`` (any-mode when None) component holds ``entity``.
        Ordering: score desc, cluster row asc — identical to
        :meth:`topk_batch`."""
        if mode is not None:
            if not len(self.index):
                return []
            if not 0 <= mode < self.index.arity:
                raise ValueError(f"mode {mode} out of range")
        rows = self.index.entity_rows(int(entity), mode).tolist()
        order = sorted(rows, key=lambda r: (-self.scores[r], r))[:k]
        return [(self.index.view_at(r), float(self.scores[r]))
                for r in order]

    # -- batched path --------------------------------------------------------

    def _stacked(self, mode: Optional[int]) -> np.ndarray:
        if self._keys_cache is None:
            # first batched query materialises (and caches) the flat
            # stacked arrays — a no-op on a full-built index
            self._keys_cache = (self.index.mode_pairs,
                                self.index.any_pairs)
        mode_keys, any_keys = self._keys_cache
        if mode is None:
            return any_keys
        if not mode_keys:
            return np.zeros(0, np.uint64)
        if not 0 <= mode < len(mode_keys):
            raise ValueError(f"mode {mode} out of range")
        return mode_keys[mode]

    def topk_batch_raw(self, entities, mode: Optional[int] = None,
                       k: int = 10):
        """The vectorised core: (qid, cluster_row, score) int64/float64
        arrays, grouped by query, best-first within each query.  Two
        ``searchsorted`` passes bound every entity's slice of the stacked
        member array, one ``lexsort`` ranks the combined hit set, and the
        top-``k`` mask needs no per-query python at all."""
        keys = self._stacked(mode)
        qi = np.asarray(entities, np.int64)
        # out-of-range ids get zero hits, exactly like the scalar path
        # (entity_rows guards the same way) — no uint64 casts blow up
        ok = (qi >= 0) & (qi < 1 << 32)
        q = np.where(ok, qi, 0).astype(np.uint64)
        cb = np.uint64(self.cluster_bits)
        lo = np.searchsorted(keys, q << cb, side="left")
        # inclusive upper key (entity, max row): no uint64 overflow at
        # the top of the entity range
        hi = np.searchsorted(keys, (q << cb) | self._row_mask,
                             side="right")
        counts = np.where(ok, hi - lo, 0).astype(np.int64)
        lo = np.where(ok, lo, 0)
        total = int(counts.sum())
        starts = np.cumsum(counts) - counts
        within = np.arange(total, dtype=np.int64) - np.repeat(starts, counts)
        flat = within + np.repeat(lo.astype(np.int64), counts)
        rows = (keys[flat] & self._row_mask).astype(np.int64)
        qid = np.repeat(np.arange(len(q), dtype=np.int64), counts)
        sc = self.scores[rows]
        order = np.lexsort((rows, -sc, qid))
        keep = within < k          # within-group ranks survive the lexsort:
        # ``order`` permutes only inside each qid group (qid is the
        # primary key and groups were already contiguous), so group
        # sizes/offsets — and hence ``within`` — are unchanged.
        sel = order[keep]
        return qid[sel], rows[sel], sc[sel]

    def topk_batch(self, entities, mode: Optional[int] = None, k: int = 10
                   ) -> List[List[Tuple[ClusterView, float]]]:
        """Ranked hits for many entities in one pass; result ``i`` is
        bit-identical to ``topk(entities[i], mode, k)``."""
        qid, rows, sc = self.topk_batch_raw(entities, mode, k)
        out: List[List[Tuple[ClusterView, float]]] = [[] for _ in entities]
        view_at = self.index.view_at
        for i, r, s in zip(qid.tolist(), rows.tolist(), sc.tolist()):
            out[i].append((view_at(r), s))
        return out

    # -- signatures ----------------------------------------------------------

    def lookup_signatures(self, signatures) -> np.ndarray:
        """Cluster rows for a batch of (lo, hi) signature pairs in one
        ``searchsorted`` pass over the packed signature words; -1 where
        the signature is not in this snapshot."""
        sigs = np.atleast_2d(np.asarray(signatures, np.uint64))
        q = pack_signatures(sigs[:, 0], sigs[:, 1])
        if not self._sig_sorted.size:
            return np.full(q.shape, -1, np.int64)
        pos = np.searchsorted(self._sig_sorted, q)
        pos_c = np.minimum(pos, len(self._sig_sorted) - 1)
        ok = self._sig_sorted[pos_c] == q
        return np.where(ok, self._sig_order[pos_c], -1).astype(np.int64)
