"""Pallas TPU kernel: fused masked-weight prefix sums for Stage-2 segment
reductions.

Every component operator of the mining pipeline (prime cumulus and
δ-range alike) reduces the same three per-position streams over sorted
order: two uint32 hash-weight lanes and the first-occurrence counter,
all masked by the first-occurrence flag.  The jnp path spends three
separate ``segment_sum``/``cumsum`` sweeps on them; this kernel computes
the three *inclusive prefix sums* in one pass —

    out_lo[i]  = Σ_{j<=i} first[j] ? w_lo[j] : 0      (mod 2³²)
    out_hi[i]  = Σ_{j<=i} first[j] ? w_hi[j] : 0      (mod 2³²)
    out_cnt[i] = Σ_{j<=i} first[j]

— after which any segment or δ-window reduction is two boundary gathers
(``pref[b] - pref[a]``; modular uint32 arithmetic makes the differences
exact).  Within a block the scan is a log2(bt)-step Hillis–Steele ladder
on the VPU; the sequential TPU grid carries the running block totals in
scratch, so arbitrarily long tuple tables stream through VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan(x: jnp.ndarray, bt: int) -> jnp.ndarray:
    """Inclusive prefix sum of a (bt,) block: Hillis–Steele, static steps."""
    s = 1
    while s < bt:
        x = x + jnp.concatenate([jnp.zeros((s,), x.dtype), x[:-s]])
        s *= 2
    return x


def _kernel(wlo_ref, whi_ref, f_ref, olo_ref, ohi_ref, ocnt_ref,
            clo_ref, chi_ref, ccnt_ref, *, bt: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        clo_ref[0] = jnp.uint32(0)
        chi_ref[0] = jnp.uint32(0)
        ccnt_ref[0] = jnp.int32(0)

    f = f_ref[...] != 0
    lo = _scan(jnp.where(f, wlo_ref[...], jnp.uint32(0)), bt) + clo_ref[0]
    hi = _scan(jnp.where(f, whi_ref[...], jnp.uint32(0)), bt) + chi_ref[0]
    cnt = _scan(f.astype(jnp.int32), bt) + ccnt_ref[0]
    olo_ref[...] = lo
    ohi_ref[...] = hi
    ocnt_ref[...] = cnt
    clo_ref[0] = lo[bt - 1]
    chi_ref[0] = hi[bt - 1]
    ccnt_ref[0] = cnt[bt - 1]


def segment_reduce(w_lo: jnp.ndarray, w_hi: jnp.ndarray, first: jnp.ndarray,
                   *, bt: int = 1024, interpret: bool = False):
    """w_lo/w_hi (T,) uint32, first (T,) int32 0/1 -> three (T,) inclusive
    masked prefix sums (uint32, uint32, int32).  T must divide by bt."""
    t = w_lo.shape[0]
    assert t % bt == 0, (t, bt)
    spec = pl.BlockSpec((bt,), lambda i: (i,))
    return pl.pallas_call(
        functools.partial(_kernel, bt=bt),
        grid=(t // bt,),
        in_specs=[spec, spec, spec],
        out_specs=[spec, spec, spec],
        out_shape=[jax.ShapeDtypeStruct((t,), jnp.uint32),
                   jax.ShapeDtypeStruct((t,), jnp.uint32),
                   jax.ShapeDtypeStruct((t,), jnp.int32)],
        scratch_shapes=[pltpu.SMEM((1,), jnp.uint32),
                        pltpu.SMEM((1,), jnp.uint32),
                        pltpu.SMEM((1,), jnp.int32)],
        interpret=interpret,
    )(w_lo, w_hi, first)
