"""jit'd dispatch layer over the Pallas kernels.

Every public op here has the same calling convention as a plain jnp
function, chooses interpret-mode automatically off-TPU (so tests and the
CPU container execute the *kernel body*), pads ragged inputs up to the
kernel's block grid, and exposes ``use_pallas=False`` fall-through to the
pure-jnp oracle in ref.py. The model layers call these ops; with
``use_pallas=False`` (default in configs) the dry-run sees real XLA FLOPs
(custom-call kernels are opaque to ``cost_analysis`` — DESIGN.md §7).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .decode_attention import decode_attention as _decode_kernel
from .flash_attention import flash_attention as _flash_kernel
from .radix_sort import radix_histogram as _radix_histogram_kernel
from .radix_sort import radix_rank as _radix_rank_kernel
from .rmsnorm import rmsnorm as _rmsnorm_kernel
from .segment_reduce import segment_reduce as _segment_reduce_kernel
from .signature import signature as _signature_kernel
from .tricluster_density import tricluster_density as _density_kernel


@functools.lru_cache(None)
def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret(flag: Optional[bool]) -> bool:
    return not on_tpu() if flag is None else flag


def _pad_to(x: jnp.ndarray, axis: int, multiple: int) -> jnp.ndarray:
    pad = (-x.shape[axis]) % multiple
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: Optional[int] = None,
                    q_offset: Optional[int] = None,
                    scale: Optional[float] = None,
                    bq: int = 128, bk: int = 128,
                    use_pallas: bool = True,
                    interpret: Optional[bool] = None) -> jnp.ndarray:
    """Batched GQA attention. q (B, Hq, Sq, D); k, v (B, Hkv, Skv, D)."""
    if not use_pallas:
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                       q_offset=q_offset, scale=scale)
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    kv_len = skv
    if q_offset is None:
        q_offset = skv - sq
    bq_ = min(bq, max(8, sq))
    qp = _pad_to(q.reshape(b * hq, sq, d), 1, bq_)
    kp = _pad_to(k.reshape(b * hkv, skv, d), 1, bk)
    vp = _pad_to(v.reshape(b * hkv, skv, d), 1, bk)
    out = _flash_kernel(qp, kp, vp, group=group, causal=causal,
                        window=window, q_offset=q_offset, kv_len=kv_len,
                        scale=scale, bq=bq_, bk=bk,
                        interpret=_interpret(interpret))
    return out[:, :sq].reshape(b, hq, sq, d)


def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                     window: Optional[int] = None,
                     kv_len: Optional[int] = None,
                     scale: Optional[float] = None, bk: int = 512,
                     use_pallas: bool = True,
                     interpret: Optional[bool] = None) -> jnp.ndarray:
    """Single-token decode. q (B, Hq, D); k, v (B, Hkv, S, D)."""
    if not use_pallas:
        return ref.decode_attention_ref(q, k, v, window=window,
                                        kv_len=kv_len, scale=scale)
    b, hq, d = q.shape
    _, hkv, s, _ = k.shape
    group = hq // hkv
    if kv_len is None:
        kv_len = s
    bk_ = min(bk, s)
    kp = _pad_to(k.reshape(b * hkv, s, d), 1, bk_)
    vp = _pad_to(v.reshape(b * hkv, s, d), 1, bk_)
    out = _decode_kernel(q.reshape(b * hq, 1, d), kp, vp, group=group,
                         window=window, kv_len=kv_len, scale=scale, bk=bk_,
                         interpret=_interpret(interpret))
    return out.reshape(b, hq, d)


# ---------------------------------------------------------------------------
# Norm
# ---------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6, *,
            use_pallas: bool = True,
            interpret: Optional[bool] = None) -> jnp.ndarray:
    """RMSNorm over the last axis; any leading shape."""
    if not use_pallas:
        return ref.rmsnorm_ref(x, w, eps)
    lead = x.shape[:-1]
    d = x.shape[-1]
    rows = int(np.prod(lead)) if lead else 1
    x2 = x.reshape(rows, d)
    br = min(256, rows) if rows % min(256, rows) == 0 else 1
    out = _rmsnorm_kernel(_pad_to(x2, 0, br), w, eps=eps, br=br,
                          interpret=_interpret(interpret))
    return out[:rows].reshape(*lead, d)


# ---------------------------------------------------------------------------
# Triclustering kernels (Stages 2/3 of the paper's pipeline)
# ---------------------------------------------------------------------------

def segment_reduce(w_lo: jnp.ndarray, w_hi: jnp.ndarray, first: jnp.ndarray,
                   *, bt: int = 1024, use_pallas: bool = True,
                   interpret: Optional[bool] = None):
    """Fused masked prefix sums for Stage-2 segment reductions.

    w_lo/w_hi (T,) uint32 hash weights, first (T,) bool/0-1 mask ->
    three (T,) inclusive prefix sums (uint32, uint32, int32) of the
    masked weights and of the mask — one pass instead of three
    ``segment_sum``/``cumsum`` sweeps; per-segment (or δ-window) sums
    are then boundary differences of the prefixes."""
    if not use_pallas:
        return ref.segment_reduce_ref(w_lo, w_hi, first)
    t = w_lo.shape[0]
    bt_ = min(bt, max(8, 1 << int(np.ceil(np.log2(max(t, 2))))))
    f = first.astype(jnp.int32)
    lo, hi, cnt = _segment_reduce_kernel(
        _pad_to(w_lo, 0, bt_), _pad_to(w_hi, 0, bt_), _pad_to(f, 0, bt_),
        bt=bt_, interpret=_interpret(interpret))
    return lo[:t], hi[:t], cnt[:t]

def radix_histogram(words, shifts, widths, *, bt: int = 512,
                    use_pallas: bool = True,
                    interpret: Optional[bool] = None):
    """One-sweep histograms of every pruned radix digit position.

    words: 1-2 msb-first (T,) uint32 packed key arrays; shifts/widths:
    static per-pass digit bit ranges -> (npass, 256) int32. The pad
    rows appended to reach the block grid all carry digit 0, so their
    count is subtracted from bucket 0 of every pass."""
    if not use_pallas:
        return ref.radix_histogram_ref(words, shifts, widths)
    t = words[0].shape[0]
    bt_ = min(bt, max(8, 1 << int(np.ceil(np.log2(max(t, 2))))))
    pad = (-t) % bt_
    hist = _radix_histogram_kernel(
        [_pad_to(w, 0, bt_) for w in words], shifts=tuple(shifts),
        widths=tuple(widths), bt=bt_, interpret=_interpret(interpret))
    if pad:
        hist = hist.at[:, 0].add(-pad)
    return hist


def radix_rank(digits: jnp.ndarray, starts: jnp.ndarray, *, bt: int = 512,
               use_pallas: bool = True,
               interpret: Optional[bool] = None) -> jnp.ndarray:
    """Stable radix-pass ranks ``starts[d_i] + occurrence_i``.

    digits (T,) uint32 in [0, 256), starts (256,) int32 exclusive
    bucket starts -> (T,) int32. End-padding is safe: pad positions
    only consume ranks *after* every real element's."""
    if not use_pallas:
        return ref.radix_rank_ref(digits, starts)
    t = digits.shape[0]
    bt_ = min(bt, max(8, 1 << int(np.ceil(np.log2(max(t, 2))))))
    out = _radix_rank_kernel(_pad_to(digits, 0, bt_), starts, bt=bt_,
                             interpret=_interpret(interpret))
    return out[:t]


def set_signature(mask: jnp.ndarray, r: jnp.ndarray, *,
                  use_pallas: bool = True,
                  interpret: Optional[bool] = None) -> jnp.ndarray:
    """Order-independent set signatures: (T, E) 0/1 × (E,) u32 -> (T,) u32."""
    if not use_pallas:
        return ref.signature_ref(mask, r)
    t, e = mask.shape
    bt = 256 if t % 256 == 0 else (8 if t % 8 == 0 else 1)
    be = 512 if e % 512 == 0 else (128 if e % 128 == 0 else e)
    mp = _pad_to(_pad_to(mask, 0, bt), 1, be)
    rp = _pad_to(r, 0, be)
    out = _signature_kernel(mp, rp, bt=bt, be=be,
                            interpret=_interpret(interpret))
    return out[:t]


def tricluster_density(tensor: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray,
                       z: jnp.ndarray, *, use_pallas: bool = True,
                       interpret: Optional[bool] = None) -> jnp.ndarray:
    """Exact box-count numerators |X×Y×Z ∩ I| for T triclusters.

    tensor (G, M, B) 0/1; x (T, G); y (T, M); z (T, B) -> (T,) f32.
    The exact-density estimator of DESIGN.md §3 (beyond-paper: the paper's
    Alg. 7 uses the generating-tuple count approximation).
    """
    if not use_pallas:
        return ref.tricluster_density_ref(tensor, x, y, z)
    t, g = x.shape
    bt = 128 if t % 128 == 0 else (8 if t % 8 == 0 else 1)
    bg = 8 if g >= 8 else 1
    tp = _pad_to(tensor, 0, bg)
    xp = _pad_to(_pad_to(x, 0, bt), 1, bg)
    yp = _pad_to(y, 0, bt)
    zp = _pad_to(z, 0, bt)
    return _density_kernel(tp, xp, yp, zp, bt=bt, bg=bg,
                           interpret=_interpret(interpret))[:t]


def exact_density(tensor: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray,
                  z: jnp.ndarray, **kw) -> jnp.ndarray:
    """Exact densities: numerator / volume (0 if any component empty)."""
    num = tricluster_density(tensor, x, y, z, **kw)
    vol = (x.sum(-1).astype(jnp.float32) * y.sum(-1).astype(jnp.float32)
           * z.sum(-1).astype(jnp.float32))
    return num / jnp.maximum(vol, 1.0)
