"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth the kernel tests
``assert_allclose`` against (interpret=True on CPU, real TPU otherwise).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def tricluster_density_ref(tensor: jnp.ndarray, x: jnp.ndarray,
                           y: jnp.ndarray, z: jnp.ndarray) -> jnp.ndarray:
    """Exact tricluster box-count numerators.

    tensor: (G, M, B) 0/1; x: (T, G); y: (T, M); z: (T, B).
    Returns (T,) float32: |X_t × Y_t × Z_t ∩ I|.
    """
    t32 = tensor.astype(jnp.float32)
    num = jnp.einsum("tg,tm,tb,gmb->t", x.astype(jnp.float32),
                     y.astype(jnp.float32), z.astype(jnp.float32), t32)
    return num


def signature_ref(mask: jnp.ndarray, r: jnp.ndarray) -> jnp.ndarray:
    """Order-independent set signatures: sig[t] = Σ_e mask[t,e]·r[e] mod 2³².

    mask: (T, E) bool/0-1; r: (E,) uint32. Returns (T,) uint32.
    """
    m = mask.astype(jnp.uint32)
    return (m * r[None, :]).sum(axis=1, dtype=jnp.uint32)


def segment_reduce_ref(w_lo: jnp.ndarray, w_hi: jnp.ndarray,
                       first: jnp.ndarray):
    """Fused masked prefix sums: inclusive cumsums of first-occurrence-
    masked uint32 hash weights and of the mask itself.

    w_lo, w_hi: (T,) uint32; first: (T,) bool/0-1.
    Returns ((T,) uint32, (T,) uint32, (T,) int32).
    """
    f = first.astype(bool)
    lo = jnp.cumsum(jnp.where(f, w_lo, jnp.uint32(0)), dtype=jnp.uint32)
    hi = jnp.cumsum(jnp.where(f, w_hi, jnp.uint32(0)), dtype=jnp.uint32)
    cnt = jnp.cumsum(f.astype(jnp.int32), dtype=jnp.int32)
    return lo, hi, cnt


def radix_histogram_ref(words, shifts, widths):
    """All pruned digit histograms of the packed key words.

    words: 1-2 msb-first (T,) uint32 arrays; shifts/widths: the radix
    plan's per-pass digit bit ranges. Returns (npass, 256) int32.
    """
    from ..core.radix import HIST_BUCKETS, extract_digit
    rows = []
    for shift, width in zip(shifts, widths):
        d = extract_digit(words, shift, width).astype(jnp.int32)
        rows.append(jnp.zeros((HIST_BUCKETS,), jnp.int32).at[d].add(1))
    return jnp.stack(rows)


def radix_rank_ref(digits: jnp.ndarray, starts: jnp.ndarray) -> jnp.ndarray:
    """Stable LSD-pass ranks: rank[i] = starts[d_i] + #{j<i : d_j==d_i}.

    digits: (T,) uint32 in [0, 256); starts: (256,) int32 exclusive
    bucket starts. Returns (T,) int32 destination positions.
    """
    from ..core.radix import HIST_BUCKETS
    oh = (digits[:, None] ==
          jnp.arange(HIST_BUCKETS, dtype=jnp.uint32)[None, :])
    oh = oh.astype(jnp.int32)
    occ = jnp.cumsum(oh, axis=0, dtype=jnp.int32) - oh
    return (oh * (occ + starts[None, :])).sum(axis=1)


def _attn_mask(sq: int, skv: int, q_offset: int, causal: bool,
               window: Optional[int]) -> jnp.ndarray:
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    return mask


def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, causal: bool = True,
                        window: Optional[int] = None,
                        q_offset: Optional[int] = None,
                        scale: Optional[float] = None) -> jnp.ndarray:
    """Reference attention. q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D);
    GQA via head-group broadcast. fp32 softmax accumulation."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    if q_offset is None:
        q_offset = skv - sq
    if scale is None:
        scale = d ** -0.5
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qf = qf.reshape(b, hkv, group, sq, d)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qf, kf)
    mask = _attn_mask(sq, skv, q_offset, causal, window)
    logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
    return out.reshape(b, hq, sq, d).astype(q.dtype)


def decode_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         *, window: Optional[int] = None,
                         kv_len: Optional[int] = None,
                         scale: Optional[float] = None) -> jnp.ndarray:
    """Single-token decode. q: (B, Hq, D); k, v: (B, Hkv, S, D). The query
    position is kv_len-1 (attends to keys [max(0, kv_len-window), kv_len))."""
    b, hq, d = q.shape
    _, hkv, s, _ = k.shape
    if kv_len is None:
        kv_len = s
    out = flash_attention_ref(q[:, :, None, :], k, v, causal=True,
                              window=window, q_offset=kv_len - 1,
                              scale=scale)
    return out[:, :, 0, :]


def rmsnorm_ref(x: jnp.ndarray, w: jnp.ndarray,
                eps: float = 1e-6) -> jnp.ndarray:
    """RMSNorm rows of x (..., D) with fp32 statistics."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(
        x.dtype)
