"""Pallas TPU kernels: one-sweep primitives of the 8-bit-digit radix
sort backend (``core/radix.py``, DESIGN.md §3b).

Two kernels, both streaming the tuple table through VMEM with a
sequential grid and scratch carries (the ``segment_reduce`` pattern):

* ``radix_histogram`` — ONE sweep over the packed key words builds the
  256-bucket histogram of *every* pruned digit position at once (the
  bit-plan tells us statically which bit ranges are live, so dead
  digits never cost a pass).  Histograms are permutation-invariant, so
  this runs once per sort on the original word order.  (The
  distributed shuffle's range partitioner is the same top-digit
  histogram primitive applied to the *pre-shuffle* keys — conceptually
  shared, but a separate computation on different data.)

* ``radix_rank`` — one LSD pass's stable ranks:
  ``rank[i] = bucket_start[digit_i] + #{j < i : digit_j == digit_i}``.
  Within a block the running occurrence is an exclusive one-hot prefix
  sum (Hillis–Steele ladder on the VPU); the sequential grid carries
  per-digit block totals in scratch, so the occurrence is global.
  Bucket gathers are expressed as one-hot reductions (VPU-friendly —
  no dynamic gather inside the kernel).
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.radix import HIST_BUCKETS, extract_digit


def _digit(word_refs, shift: int, width: int):
    """``core.radix.extract_digit`` on materialised refs — one bit-field
    reader for every formulation, so the Pallas path can never extract a
    different digit than the composite/reference paths."""
    return extract_digit(tuple(r[...] for r in word_refs), shift, width)


def _one_hot(dig: jnp.ndarray, bt: int) -> jnp.ndarray:
    """(bt,) uint32 digits -> (bt, 256) int32 one-hot."""
    cols = jax.lax.broadcasted_iota(jnp.uint32, (bt, HIST_BUCKETS), 1)
    return (dig[:, None] == cols).astype(jnp.int32)


def _scan_rows(x: jnp.ndarray, bt: int) -> jnp.ndarray:
    """Inclusive prefix sum along axis 0 of a (bt, 256) block."""
    s = 1
    while s < bt:
        pad = jnp.zeros((s, x.shape[1]), x.dtype)
        x = x + jnp.concatenate([pad, x[:-s]], axis=0)
        s *= 2
    return x


# ---------------------------------------------------------------------------
# Histogram sweep
# ---------------------------------------------------------------------------

def _hist_kernel(*refs, bt: int, nw: int,
                 shifts: Tuple[int, ...], widths: Tuple[int, ...]):
    word_refs, out_ref, acc_ref = refs[:nw], refs[nw], refs[nw + 1]
    i = pl.program_id(0)
    n = pl.num_programs(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    for p, (shift, width) in enumerate(zip(shifts, widths)):
        oh = _one_hot(_digit(word_refs, shift, width), bt)
        acc_ref[p, :] = acc_ref[p, :] + oh.sum(axis=0)

    @pl.when(i == n - 1)
    def _flush():
        out_ref[...] = acc_ref[...]


def radix_histogram(words: Sequence[jnp.ndarray],
                    shifts: Sequence[int], widths: Sequence[int],
                    *, bt: int = 512, interpret: bool = False):
    """All pruned digit histograms in one sweep.  words: 1-2 msb-first
    (T,) uint32 arrays, T divisible by bt -> (npass, 256) int32."""
    t = words[0].shape[0]
    assert t % bt == 0, (t, bt)
    npass = len(shifts)
    spec = pl.BlockSpec((bt,), lambda i: (i,))
    out_spec = pl.BlockSpec((npass, HIST_BUCKETS), lambda i: (0, 0))
    return pl.pallas_call(
        functools.partial(_hist_kernel, bt=bt, nw=len(words),
                          shifts=tuple(shifts), widths=tuple(widths)),
        grid=(t // bt,),
        in_specs=[spec] * len(words),
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((npass, HIST_BUCKETS), jnp.int32),
        scratch_shapes=[pltpu.VMEM((npass, HIST_BUCKETS), jnp.int32)],
        interpret=interpret,
    )(*words)


# ---------------------------------------------------------------------------
# Per-pass stable ranks
# ---------------------------------------------------------------------------

def _rank_kernel(dig_ref, starts_ref, out_ref, carry_ref, *, bt: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    oh = _one_hot(dig_ref[...], bt)
    inc = _scan_rows(oh, bt)
    # exclusive global occurrence + bucket start, gathered one-hot-wise
    base = carry_ref[0, :] + starts_ref[...]
    rank = (oh * (inc - oh + base[None, :])).sum(axis=1)
    out_ref[...] = rank
    carry_ref[0, :] = carry_ref[0, :] + inc[bt - 1, :]


def radix_rank(digits: jnp.ndarray, starts: jnp.ndarray,
               *, bt: int = 512, interpret: bool = False):
    """Stable LSD-pass ranks.  digits (T,) uint32 in [0, 256), starts
    (256,) int32 exclusive bucket starts, T divisible by bt ->
    (T,) int32 destination positions."""
    t = digits.shape[0]
    assert t % bt == 0, (t, bt)
    spec = pl.BlockSpec((bt,), lambda i: (i,))
    return pl.pallas_call(
        functools.partial(_rank_kernel, bt=bt),
        grid=(t // bt,),
        in_specs=[spec, pl.BlockSpec((HIST_BUCKETS,), lambda i: (0,))],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((t,), jnp.int32),
        scratch_shapes=[pltpu.VMEM((1, HIST_BUCKETS), jnp.int32)],
        interpret=interpret,
    )(digits, starts)
