"""Pallas TPU kernel: single-token (q_len = 1) GQA decode attention.

The decode hot path: one query row against a long KV cache. The grid
streams KV blocks (split-KV) with online-softmax partial statistics in
VMEM; sliding windows and padded caches are handled by position masks.
The ops.py wrapper folds (batch, heads).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, window: Optional[int], kv_len: int,
            bk: int, nk: int):
    ik = pl.program_id(1)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    k_start = ik * bk
    qpos = kv_len - 1
    relevant = k_start <= qpos
    if window is not None:
        relevant &= k_start + bk - 1 > qpos - window

    @pl.when(relevant)
    def _step():
        q = q_ref[0].astype(jnp.float32) * scale          # (1, d)
        k = k_ref[0].astype(jnp.float32)                  # (bk, d)
        v = v_ref[0].astype(jnp.float32)                  # (bk, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (1, bk)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        mask = kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, _NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                     group: int, window: Optional[int] = None,
                     kv_len: Optional[int] = None,
                     scale: Optional[float] = None, bk: int = 512,
                     interpret: bool = False) -> jnp.ndarray:
    """q (BHq, 1, D); k, v (BHkv, Skv, D) -> (BHq, 1, D)."""
    bhq, one, d = q.shape
    bhkv, skv, _ = k.shape
    assert one == 1 and bhq == bhkv * group
    if kv_len is None:
        kv_len = skv
    if scale is None:
        scale = d ** -0.5
    assert skv % bk == 0, (skv, bk)
    nk = skv // bk
    kernel = functools.partial(_kernel, scale=scale, window=window,
                               kv_len=kv_len, bk=bk, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(bhq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, d), lambda h, ik: (h, 0, 0)),
            pl.BlockSpec((1, bk, d), lambda h, ik, g=group: (h // g, ik, 0)),
            pl.BlockSpec((1, bk, d), lambda h, ik, g=group: (h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, d), lambda h, ik: (h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bhq, 1, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((1,), jnp.float32),
                        pltpu.VMEM((1,), jnp.float32),
                        pltpu.VMEM((1, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v)
