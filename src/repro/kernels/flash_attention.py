"""Pallas TPU kernel: fused causal/sliding-window GQA flash attention (fwd).

IO-aware attention for the LM substrate's train/prefill hot path: online
softmax over KV blocks with fp32 running (m, l, acc) statistics in VMEM,
one (bq × d) output tile per query block. GQA is handled by the k/v block
index map (query head h reads kv head h // group). Sliding windows skip
KV blocks wholly outside the band.

Layout: q (BH, Sq, D), k/v (BHkv, Skv, D) — the ops.py wrapper folds
(batch, heads) and restores them.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: Optional[int],
            q_offset: int, kv_len: int, bq: int, bk: int, nk: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    iq = pl.program_id(1)
    q_start = iq * bq + q_offset          # global position of first q row
    k_start = ik * bk

    # block-level relevance: any (qpos, kpos) pair inside the mask?
    relevant = k_start < kv_len
    if causal:
        relevant &= k_start <= q_start + bq - 1
    if window is not None:
        relevant &= k_start + bk - 1 > q_start - window

    @pl.when(relevant)
    def _step():
        q = q_ref[0].astype(jnp.float32) * scale         # (bq, d)
        k = k_ref[0].astype(jnp.float32)                 # (bk, d)
        v = v_ref[0].astype(jnp.float32)                 # (bk, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < kv_len
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, _NEG)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    group: int, causal: bool = True,
                    window: Optional[int] = None,
                    q_offset: Optional[int] = None,
                    kv_len: Optional[int] = None,
                    scale: Optional[float] = None,
                    bq: int = 128, bk: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """q (BHq, Sq, D); k, v (BHkv, Skv, D); BHq == BHkv * group.

    ``kv_len`` masks out padded keys beyond the true length; ``q_offset``
    is the global position of q row 0 (defaults to kv_len - Sq)."""
    bhq, sq, d = q.shape
    bhkv, skv, _ = k.shape
    assert bhq == bhkv * group
    if kv_len is None:
        kv_len = skv
    if q_offset is None:
        q_offset = kv_len - sq
    if scale is None:
        scale = d ** -0.5
    assert sq % bq == 0 and skv % bk == 0, (sq, bq, skv, bk)
    nk = skv // bk
    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, kv_len=kv_len, bq=bq, bk=bk, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(bhq, sq // bq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, iq, ik: (h, iq, 0)),
            pl.BlockSpec((1, bk, d),
                         lambda h, iq, ik, g=group: (h // g, ik, 0)),
            pl.BlockSpec((1, bk, d),
                         lambda h, iq, ik, g=group: (h // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, iq, ik: (h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((bhq, sq, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq,), jnp.float32),
                        pltpu.VMEM((bq,), jnp.float32),
                        pltpu.VMEM((bq, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v)
