"""Pallas TPU kernel: order-independent set signatures of bitmask rows.

sig[t] = Σ_e mask[t,e] · r[e]  (mod 2³², uint32 wraparound)

This is the Stage-3 dedup hash of the M/R pipeline (paper Alg. 6/7 keys):
equal entity sets hash equal regardless of order and multiplicity of the
set's construction. Integer multiply-accumulate runs on the VPU; the grid
tiles (T, E) so arbitrarily wide entity spaces stream through VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(mask_ref, r_ref, o_ref, acc_ref, *, ne: int):
    ie = pl.program_id(1)

    @pl.when(ie == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    m = mask_ref[...].astype(jnp.uint32)            # (bt, be)
    r = r_ref[...].astype(jnp.uint32)               # (be,)
    acc_ref[...] += jnp.sum(m * r[None, :], axis=1, dtype=jnp.uint32)

    @pl.when(ie == ne - 1)
    def _done():
        o_ref[...] = acc_ref[...]


def signature(mask: jnp.ndarray, r: jnp.ndarray, *, bt: int = 256,
              be: int = 512, interpret: bool = False) -> jnp.ndarray:
    """mask (T, E) 0/1, r (E,) uint32 -> (T,) uint32 signatures."""
    t, e = mask.shape
    assert t % bt == 0 and e % be == 0, (t, bt, e, be)
    ne = e // be
    return pl.pallas_call(
        functools.partial(_kernel, ne=ne),
        grid=(t // bt, ne),
        in_specs=[
            pl.BlockSpec((bt, be), lambda it, ie: (it, ie)),
            pl.BlockSpec((be,), lambda it, ie: (ie,)),
        ],
        out_specs=pl.BlockSpec((bt,), lambda it, ie: (it,)),
        out_shape=jax.ShapeDtypeStruct((t,), jnp.uint32),
        scratch_shapes=[pltpu.VMEM((bt,), jnp.uint32)],
        interpret=interpret,
    )(mask, r)
