"""Pallas TPU kernel: exact tricluster density numerators (beyond-paper).

For T candidate triclusters with membership masks X (T,G), Y (T,M), Z (T,B)
against the dense Boolean tensor I (G,M,B), computes

    num[t] = Σ_{g,m,b} X[t,g]·Y[t,m]·Z[t,b]·I[g,m,b]

The contraction is factored into two MXU matmuls per (t, g) tile
(DESIGN.md §7):

    C[t, g·B+b] = Y[t] @ I[g]           (bt×M by M×(bg·B) matmul)
    s[t, g]     = Σ_b C[t,g,b]·Z[t,b]   (VPU multiply-reduce)
    num[t]     += Σ_g X[t,g]·s[t,g]

Grid: (T/bt, G/bg), accumulating over the g axis in a VMEM scratch.
The MB working set per step is bg·M·B·4 bytes — pick bg so it fits VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(i_ref, x_ref, y_ref, z_ref, o_ref, acc_ref, *, ng: int):
    ig = pl.program_id(1)

    @pl.when(ig == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    i_blk = i_ref[...].astype(jnp.float32)           # (bg, M, B)
    bg, m, b = i_blk.shape
    y = y_ref[...].astype(jnp.float32)               # (bt, M)
    z = z_ref[...].astype(jnp.float32)               # (bt, B)
    x = x_ref[...].astype(jnp.float32)               # (bt, bg)
    # C[t, g*B+b] = Σ_m y[t,m] I[g,m,b]  — MXU matmul
    c = jnp.dot(y, i_blk.transpose(1, 0, 2).reshape(m, bg * b),
                preferred_element_type=jnp.float32)  # (bt, bg*B)
    c = c.reshape(-1, bg, b)
    s = jnp.einsum("tgb,tb->tg", c, z)               # (bt, bg)
    acc_ref[...] += jnp.sum(s * x, axis=1)

    @pl.when(ig == ng - 1)
    def _done():
        o_ref[...] = acc_ref[...]


def tricluster_density(tensor: jnp.ndarray, x: jnp.ndarray, y: jnp.ndarray,
                       z: jnp.ndarray, *, bt: int = 128, bg: int = 8,
                       interpret: bool = False) -> jnp.ndarray:
    """(G,M,B) 0/1 tensor + (T,G)/(T,M)/(T,B) masks -> (T,) f32 numerators.

    T must be a multiple of bt and G of bg (ops.py pads)."""
    g, m, b = tensor.shape
    t = x.shape[0]
    assert t % bt == 0 and g % bg == 0, (t, bt, g, bg)
    ng = g // bg
    return pl.pallas_call(
        functools.partial(_kernel, ng=ng),
        grid=(t // bt, ng),
        in_specs=[
            pl.BlockSpec((bg, m, b), lambda it, ig: (ig, 0, 0)),
            pl.BlockSpec((bt, bg), lambda it, ig: (it, ig)),
            pl.BlockSpec((bt, m), lambda it, ig: (it, 0)),
            pl.BlockSpec((bt, b), lambda it, ig: (it, 0)),
        ],
        out_specs=pl.BlockSpec((bt,), lambda it, ig: (it,)),
        out_shape=jax.ShapeDtypeStruct((t,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bt,), jnp.float32)],
        interpret=interpret,
    )(tensor, x, y, z)
