"""Pallas TPU kernel: fused RMSNorm (row statistics + scale in one pass)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)            # (br, D)
    w = w_ref[...].astype(jnp.float32)            # (D,)
    var = jnp.mean(x * x, axis=1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * w[None, :]).astype(
        o_ref.dtype)


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, *, eps: float = 1e-6,
            br: int = 256, interpret: bool = False) -> jnp.ndarray:
    """x (R, D), w (D,) -> (R, D); rows must be a multiple of br."""
    r, d = x.shape
    assert r % br == 0, (r, br)
    return pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(r // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), x.dtype),
        interpret=interpret,
    )(x, w)
