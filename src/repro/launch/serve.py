"""Serving driver: ``python -m repro.launch.serve --arch <id> [--smoke]``.

Batched prefill + ragged decode over the ServeEngine; prints prefill
latency, decode throughput, and a sample of generated ids.
"""
from __future__ import annotations

import argparse
import sys

import jax


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--model-shards", type=int, default=1)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from ..configs import get_config, get_smoke_config
    from ..data.tokens import TokenPipeline
    from ..models.api import get_model
    from ..serve.engine import ServeEngine
    from ..sharding.rules import MeshRules
    from .mesh import make_local_mesh

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family == "encdec":
        print("[serve] enc-dec serving demo uses the audio example; "
              "use examples/translate_stream.py")
        return 0
    mesh = make_local_mesh(model=args.model_shards)
    rules = MeshRules(mesh, fsdp=cfg.fsdp)

    with mesh:
        model = get_model(cfg)
        params = model.init(cfg, jax.random.PRNGKey(args.seed))
        engine = ServeEngine(cfg, params, max_len=args.max_len, rules=rules,
                             temperature=args.temperature, seed=args.seed)
        pipeline = TokenPipeline(cfg, args.batch, args.prompt_len,
                                 seed=args.seed)
        prompts = pipeline.prompts(args.batch, args.prompt_len)
        res = engine.generate(prompts, max_new_tokens=args.new_tokens)
    print(f"[serve] arch={cfg.name} batch={args.batch} "
          f"prompt_len≈{args.prompt_len} new={args.new_tokens}")
    print(f"[serve] prefill {res.prefill_s * 1e3:.1f} ms, decode "
          f"{res.decode_s * 1e3:.1f} ms over {res.steps} steps "
          f"({res.tokens_per_s:.1f} tok/s)")
    for i, toks in enumerate(res.tokens[:2]):
        print(f"[serve] sample[{i}]: {toks[:16]}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
