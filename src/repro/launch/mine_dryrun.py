import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ same contract as dryrun.py: must precede any jax import.
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

import argparse
import json
import sys
import time

import jax
import numpy as np

from ..analysis.roofline import V5E
from ..analysis.hlo import profile_module
from ..core import DistributedMiner, pad_tuples
from ..data import synthetic as S
from .mesh import make_production_mesh

"""Dry-run of the paper's own pipeline on the production mesh: lower +
compile the DistributedMiner (both merge strategies) for a MovieLens-1M
scale tuple table on the (16,16) and (2,16,16) meshes, and report the
same roofline terms as the LM cells — this is the §Perf cell most
representative of the paper's technique.
"""


def run_cell(mesh, mesh_label, strategy: str, n_tuples: int, arity: int,
             sizes, axes) -> dict:
    miner = DistributedMiner(sizes, mesh, axes=axes, strategy=strategy)
    tuples = np.zeros((pad_len(n_tuples, miner.n_shards), arity), np.int32)
    t0 = time.time()
    lowered = miner.lowered(tuples)
    compiled = lowered.compile()
    dt = time.time() - t0
    prof = profile_module(compiled.as_text(), int(mesh.devices.size))
    ma = compiled.memory_analysis()
    from ..analysis.roofline import cost_analysis_dict
    ca = cost_analysis_dict(compiled)
    out = {
        "cell": f"tricluster/{strategy}", "mesh": mesh_label,
        "axes": list(axes), "n_shards": miner.n_shards,
        "tuples": int(tuples.shape[0]), "arity": arity,
        "compile_s": round(dt, 2),
        "flops_per_device": prof.flops,
        "mxu_flops_per_device": prof.mxu_flops,
        "bytes_per_device": prof.traffic_bytes,
        "coll_operand_bytes": prof.operand_bytes,
        "coll_wire_bytes": prof.wire_bytes,
        "by_kind": {k: list(v) for k, v in prof.by_kind.items()},
        "argument_bytes": int(ma.argument_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "flops_xla_raw": float(ca.get("flops", 0.0)),
    }
    out["compute_s"] = prof.flops / V5E.peak_flops
    out["memory_s"] = prof.traffic_bytes / V5E.hbm_bw
    out["collective_s"] = prof.operand_bytes / V5E.link_bw
    terms = {"compute": out["compute_s"], "memory": out["memory_s"],
             "collective": out["collective_s"]}
    out["bound"] = max(terms, key=terms.get)
    return out


def pad_len(n: int, shards: int) -> int:
    return -(-n // shards) * shards


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-tuples", type=int, default=1_000_000)
    ap.add_argument("--arity", type=int, default=4)
    ap.add_argument("--out", default="results/mine_dryrun.jsonl")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    args = ap.parse_args(argv)
    sizes = (6040, 3952, 5, 2048)[: args.arity]   # MovieLens-1M modes

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("1pod", make_production_mesh(multi_pod=False),
                       ("data",)))
        meshes.append(("1pod-full", make_production_mesh(multi_pod=False),
                       ("data", "model")))
    if args.mesh in ("multi", "both"):
        meshes.append(("2pod-full", make_production_mesh(multi_pod=True),
                       ("pod", "data", "model")))
    with open(args.out, "a") as f:
        for label, mesh, axes in meshes:
            for strategy in ("replicate", "shuffle"):
                print(f"[mine-dryrun] {strategy} × {label} "
                      f"(axes={axes})", flush=True)
                try:
                    row = run_cell(mesh, label, strategy, args.n_tuples,
                                   args.arity, sizes, axes)
                    print(f"  c={row['compute_s']:.4f}s "
                          f"m={row['memory_s']:.4f}s "
                          f"x={row['collective_s']:.4f}s "
                          f"-> {row['bound']}", flush=True)
                except Exception as e:
                    row = {"cell": f"tricluster/{strategy}", "mesh": label,
                           "status": "error", "error": str(e)[:500]}
                    print(f"  ERROR {e}", flush=True)
                f.write(json.dumps(row) + "\n")
                f.flush()
    return 0


if __name__ == "__main__":
    sys.exit(main())
