"""Production mesh construction (brief §MULTI-POD DRY-RUN).

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state; the dry-run process forces 512
host devices *before* any jax import and then calls it.
"""
from __future__ import annotations

import jax

from .._compat import make_mesh  # noqa: F401  (re-export; single shim home)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(model: int = 1, pod: int = 0):
    """Mesh over whatever devices exist (tests, examples, local runs):
    (data=n/model, model) or (pod, data, model) when pod>0."""
    n = len(jax.devices())
    if pod:
        shape = (pod, n // (pod * model), model)
        axes = ("pod", "data", "model")
    else:
        shape = (n // model, model)
        axes = ("data", "model")
    return make_mesh(shape, axes)


def mesh_name(mesh) -> str:
    return "x".join(str(s) for s in mesh.devices.shape)
