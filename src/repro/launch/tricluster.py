"""The paper's application driver (its Java `App` analogue):
``python -m repro.launch.tricluster --dataset imdb --backend batch``.

Mines multimodal clusters from any of the paper's datasets with any
engine from the registry (``repro.core.mine``): batch (single shard),
distributed (shard_map mesh, replicate or shuffle merge), streaming
(incremental sorted-run snapshots), reference (pure python oracle) —
each in the prime or NOAC (δ/ρ_min/minsup many-valued) variant. Prints
timings, cluster counts, and §5.2-formatted top patterns.
"""
from __future__ import annotations

import argparse
import sys


def load_dataset(name: str, n_tuples: int, seed: int):
    from ..data import synthetic as S
    if name == "k1":
        return S.k1_dense_cube()
    if name == "k2":
        return S.k2_three_cuboids()
    if name == "k3":
        return S.k3_dense_4d()
    if name == "imdb":
        return S.imdb_like(seed=seed)
    if name == "movielens":
        return S.movielens_like(n_tuples=n_tuples or 100_000, seed=seed)
    if name == "bibsonomy":
        return S.bibsonomy_like(n_tuples=n_tuples or 816_197, seed=seed)
    if name == "frames":
        return S.semantic_frames_like(n_tuples=n_tuples or 100_000,
                                      seed=seed)
    if name == "random":
        return S.random_context((64, 48, 32), n_tuples or 4096, seed=seed)
    raise ValueError(f"unknown dataset {name!r}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="imdb",
                    choices=["k1", "k2", "k3", "imdb", "movielens",
                             "bibsonomy", "frames", "random"])
    ap.add_argument("--n-tuples", type=int, default=0)
    ap.add_argument("--backend", default="batch",
                    help="engine backend (see repro.core.available_engines)")
    ap.add_argument("--variant", default=None,
                    help="'prime' | 'noac'; default: noac iff --delta given")
    ap.add_argument("--strategy", default="replicate",
                    choices=["replicate", "shuffle"])
    ap.add_argument("--theta", type=float, default=0.0,
                    help="min density (Alg. 7 estimate)")
    ap.add_argument("--delta", type=float, default=None,
                    help="NOAC δ for many-valued contexts")
    ap.add_argument("--rho-min", type=float, default=0.0)
    ap.add_argument("--minsup", type=int, default=0)
    ap.add_argument("--chunks", type=int, default=8,
                    help="streaming / incremental-distributed: number of "
                         "ingestion chunks")
    ap.add_argument("--chunk-budget", type=int, default=0,
                    help="batch: out-of-core chunked Stage 1 — sort at "
                         "most this many rows per host chunk "
                         "(core.runs store; 0 = in-core)")
    ap.add_argument("--window-budget", type=int, default=0,
                    help="windowed device pipeline (DESIGN.md §3c): "
                         "stream Stage 1-3 through sorted-order windows "
                         "of at most this many rows — peak incremental "
                         "device memory O(window), bit-identical to the "
                         "monolithic path (0 = off)")
    ap.add_argument("--incremental", action="store_true",
                    help="distributed: chunked ingestion into per-shard "
                         "run stores + merged-run snapshots instead of "
                         "one-shot mining")
    ap.add_argument("--no-incremental", action="store_true",
                    help="streaming: full device re-sort per snapshot "
                         "(disable the sorted-run merge path)")
    ap.add_argument("--sort-path", default="auto",
                    choices=["auto", "packed", "lexsort"],
                    help="Stage-1/3 sort: packed single-word keys "
                         "(core.keys), the lexsort baseline, or auto "
                         "(packed whenever the key fits 64 bits)")
    ap.add_argument("--sort-backend", default="auto",
                    choices=["auto", "radix", "lax", "lexsort"],
                    help="packed word-sort algorithm: the bit-plan-"
                         "pruned LSD radix (core.radix; the auto "
                         "default for fitting keys), the lax.sort "
                         "comparison baseline, or lexsort to force "
                         "the column path")
    ap.add_argument("--no-prune-values", action="store_true",
                    help="disable value-lane cardinality pruning (keep "
                         "the 32-bit float lane in many-valued keys)")
    ap.add_argument("--print-top", type=int, default=3)
    ap.add_argument("--top-k", type=int, default=0,
                    help="route the mined result through the serving "
                         "ranking layer (serve.ranking) and print the "
                         "global top-k ranked clusters")
    ap.add_argument("--query-entity", type=int, default=None,
                    help="ranked clusters containing this entity "
                         "(serve-path query; combine with --query-mode "
                         "and --top-k)")
    ap.add_argument("--query-mode", type=int, default=None,
                    help="restrict --query-entity to one mode's "
                         "component")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--repeat", type=int, default=1,
                    help="timing repeats (paper used 5)")
    args = ap.parse_args(argv)

    from ..core import available_engines, mine
    from ..core import postprocess as PP

    variant = args.variant or ("noac" if args.delta is not None else "prime")
    ctx = load_dataset(args.dataset, args.n_tuples, args.seed)
    print(f"[tricluster] dataset={args.dataset} sizes={ctx.sizes} "
          f"|I|={ctx.tuples.shape[0]}")

    try:
        packed = {"auto": None, "packed": True, "lexsort": False}
        incremental = (False if args.no_incremental
                       else True if args.incremental
                       else None)
        run = mine(ctx, backend=args.backend, variant=variant,
                   theta=args.theta, delta=args.delta,
                   rho_min=args.rho_min, minsup=args.minsup,
                   strategy=args.strategy, chunks=args.chunks,
                   chunk_budget=args.chunk_budget or None,
                   window_budget=args.window_budget or None,
                   **({} if incremental is None
                      else {"incremental": incremental}),
                   packed=packed[args.sort_path],
                   sort_backend=(None if args.sort_backend == "auto"
                                 else args.sort_backend),
                   prune_values=not args.no_prune_values,
                   seed=args.seed or 0x5EED)
        # warm repeats reuse the compiled engine (paper best-of-N protocol)
        best = run.elapsed_s
        for _ in range(max(1, args.repeat) - 1):
            run.rerun()
            best = min(best, run.rerun.last_s)
        run.elapsed_s = best
    except ValueError as e:
        valid = ", ".join(f"{b}/{v}" for b, v in available_engines())
        print(f"[tricluster] error: {e}", file=sys.stderr)
        print(f"[tricluster] valid backend/variant choices: {valid}",
              file=sys.stderr)
        return 2

    label = args.backend + (f"/{args.strategy}"
                            if args.backend == "distributed" else "")
    if variant == "noac":
        print(f"[tricluster] NOAC(δ={args.delta}, ρ={args.rho_min}, "
              f"minsup={args.minsup}) backend={label}: "
              f"{run.n_clusters} triclusters; "
              f"best {run.elapsed_s * 1e3:.1f} ms over {args.repeat} run(s)")
    else:
        print(f"[tricluster] backend={label} θ={args.theta}: "
              f"{run.n_clusters} unique clusters; "
              f"best {run.elapsed_s * 1e3:.1f} ms over {args.repeat} run(s)")
    overflow = getattr(run.result, "overflow", None)
    if overflow is not None:
        print(f"[tricluster] shuffle overflow flag: {int(overflow)}")

    if args.print_top and run.clusters:
        mats = sorted(run.clusters, key=lambda cd: -(cd[1]
                                                     if cd[1] == cd[1] else 0))
        names = ctx.names if getattr(ctx, "names", None) else None
        for comps, dens in mats[:args.print_top]:
            print(PP.format_cluster(comps, names=names,
                                    density=None if dens != dens else dens))

    if args.top_k or args.query_entity is not None:
        # the CLI exercises the same ranked query path the service
        # serves (serve.clusters index + serve.ranking scores)
        return _serve_query(run, ctx, args)
    return 0


def _serve_query(run, ctx, args) -> int:
    from ..serve import BatchQuerier, ClusterIndex, top_clusters
    from ..core import postprocess as PP

    res = run.result
    if res is None or not hasattr(res, "range_lo"):
        print("[tricluster] --top-k/--query-entity need component "
              "windows; the distributed backend's result does not carry "
              "them (serve via backend=streaming/batch, or "
              "TriclusterService(backend='distributed') which re-mines "
              "the serving snapshot)", file=sys.stderr)
        return 2
    k = args.top_k or 3
    idx = ClusterIndex.from_result(res)
    names = ctx.names if getattr(ctx, "names", None) else None
    if args.query_entity is not None:
        bq = BatchQuerier(idx)
        hits = bq.topk(args.query_entity, mode=args.query_mode, k=k)
        where = ("any mode" if args.query_mode is None
                 else f"mode {args.query_mode}")
        print(f"[tricluster] top-{k} clusters containing entity "
              f"{args.query_entity} ({where}): {len(hits)} hit(s)")
    else:
        hits = top_clusters(idx, k=k)
        print(f"[tricluster] global top-{k} of {len(idx)} clusters")
    for view, score in hits:
        print(f"  score={score:.3f} "
              + PP.format_cluster(view.components, names=names,
                                  density=view.density))
    return 0


if __name__ == "__main__":
    sys.exit(main())
