"""The paper's application driver (its Java `App` analogue):
``python -m repro.launch.tricluster --dataset imdb --backend batch``.

Mines multimodal clusters from any of the paper's datasets with any
backend/variant: batch (single shard), distributed (shard_map mesh,
replicate or shuffle merge), streaming (online chunks), reference (pure
python oracle), NOAC (δ/ρ_min/minsup many-valued). Prints timings,
cluster counts, and §5.2-formatted top patterns.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np


def load_dataset(name: str, n_tuples: int, seed: int):
    from ..data import synthetic as S
    if name == "k1":
        return S.k1_dense_cube()
    if name == "k2":
        return S.k2_three_cuboids()
    if name == "k3":
        return S.k3_dense_4d()
    if name == "imdb":
        return S.imdb_like(seed=seed)
    if name == "movielens":
        return S.movielens_like(n_tuples=n_tuples or 100_000, seed=seed)
    if name == "bibsonomy":
        return S.bibsonomy_like(n_tuples=n_tuples or 816_197, seed=seed)
    if name == "frames":
        return S.semantic_frames_like(n_tuples=n_tuples or 100_000,
                                      seed=seed)
    if name == "random":
        return S.random_context((64, 48, 32), n_tuples or 4096, seed=seed)
    raise ValueError(f"unknown dataset {name!r}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="imdb",
                    choices=["k1", "k2", "k3", "imdb", "movielens",
                             "bibsonomy", "frames", "random"])
    ap.add_argument("--n-tuples", type=int, default=0)
    ap.add_argument("--backend", default="batch",
                    choices=["batch", "distributed", "streaming",
                             "reference"])
    ap.add_argument("--strategy", default="replicate",
                    choices=["replicate", "shuffle"])
    ap.add_argument("--theta", type=float, default=0.0,
                    help="min density (Alg. 7 estimate)")
    ap.add_argument("--delta", type=float, default=None,
                    help="NOAC δ for many-valued contexts")
    ap.add_argument("--rho-min", type=float, default=0.0)
    ap.add_argument("--minsup", type=int, default=0)
    ap.add_argument("--chunks", type=int, default=8,
                    help="streaming: number of ingestion chunks")
    ap.add_argument("--print-top", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--repeat", type=int, default=1,
                    help="timing repeats (paper used 5)")
    args = ap.parse_args(argv)

    from ..core import (BatchMiner, DistributedMiner, NOACMiner,
                        StreamingMiner, pad_tuples)
    from ..core import postprocess as PP
    from ..core import reference as R
    from .mesh import make_local_mesh

    ctx = load_dataset(args.dataset, args.n_tuples, args.seed)
    print(f"[tricluster] dataset={args.dataset} sizes={ctx.sizes} "
          f"|I|={ctx.tuples.shape[0]}")

    if args.backend == "reference":
        t0 = time.time()
        if args.delta is not None:
            clusters = R.noac(ctx, args.delta, args.rho_min, args.minsup)
        else:
            clusters = R.multimodal_clusters(ctx, theta=args.theta)
        dt = time.time() - t0
        print(f"[tricluster] reference: {len(clusters)} clusters "
              f"in {dt * 1e3:.1f} ms")
        return 0

    if args.delta is not None:
        miner = NOACMiner(ctx.sizes, delta=args.delta, rho_min=args.rho_min,
                          minsup=args.minsup)
        vals = ctx.values if ctx.values is not None else np.ones(
            ctx.tuples.shape[0], np.float32)
        times = []
        for _ in range(args.repeat):
            t0 = time.time()
            res = miner(ctx.tuples, vals)
            np.asarray(res.keep)
            times.append(time.time() - t0)
        n = int(np.asarray(res.keep).sum())
        print(f"[tricluster] NOAC(δ={args.delta}, ρ={args.rho_min}, "
              f"minsup={args.minsup}): {n} triclusters; "
              f"best {min(times) * 1e3:.1f} ms")
        return 0

    if args.backend == "distributed":
        mesh = make_local_mesh()
        miner = DistributedMiner(ctx.sizes, mesh, axes="data",
                                 theta=args.theta, strategy=args.strategy)
        tuples = pad_tuples(ctx.tuples, int(mesh.devices.size))
    elif args.backend == "streaming":
        miner = StreamingMiner(ctx.sizes, theta=args.theta)
        tuples = ctx.tuples
    else:
        miner = BatchMiner(ctx.sizes, theta=args.theta)
        tuples = ctx.tuples

    times, res = [], None
    for _ in range(args.repeat):
        t0 = time.time()
        if args.backend == "streaming":
            miner.state = None
            for chunk in np.array_split(tuples, args.chunks):
                miner.add(chunk)
            res = miner.snapshot()
        else:
            res = miner(tuples)
        np.asarray(res.keep)
        times.append(time.time() - t0)

    keep = np.asarray(res.keep)
    n_clusters = int(keep.sum())
    print(f"[tricluster] backend={args.backend}"
          + (f"/{args.strategy}" if args.backend == "distributed" else "")
          + f" θ={args.theta}: {n_clusters} unique clusters; "
          f"best {min(times) * 1e3:.1f} ms over {args.repeat} run(s)")
    if getattr(res, "overflow", None) is not None:
        print(f"[tricluster] shuffle overflow flag: {int(res.overflow)}")

    if args.print_top and args.backend == "batch":
        mats = miner.materialise(res, tuples)
        mats.sort(key=lambda cd: -cd[1])
        names = ctx.names if getattr(ctx, "names", None) else None
        for comps, dens in mats[:args.print_top]:
            print(PP.format_cluster(comps, names=names, density=dens))
    return 0


if __name__ == "__main__":
    sys.exit(main())
