import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.
# The dry-run (and only the dry-run) needs 512 placeholder host devices so
# jax.make_mesh can build the production meshes (16,16) and (2,16,16).
# Tests run this file as a subprocess with REPRO_DRYRUN_DEVICES to shrink it.
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from ..analysis.roofline import V5E, roofline_from_compiled
from ..configs import SHAPES, get_config, shape_applicable, ARCHS
from ..models.api import get_model, input_specs
from ..sharding.rules import MeshRules
from ..train.step import (TrainConfig, make_train_step, state_shardings,
                          state_structs)
from .mesh import make_production_mesh, mesh_name

"""Multi-pod dry-run driver (brief §MULTI-POD DRY-RUN).

For every (architecture × input shape × mesh) cell: build the production
mesh, lower the real jit'd step (train_step / prefill / decode_step — the
same function objects the drivers run), ``.compile()`` it, and record

  * ``compiled.memory_analysis()``  — proves the cell fits in HBM,
  * ``compiled.cost_analysis()``    — FLOPs / bytes for §Roofline,
  * the parsed collective schedule  — collective_bytes for §Roofline.

Failures (sharding mismatch, OOM at compile, unsupported collective) are
bugs in the framework, not in the cell. Results append to a JSONL so the
run is resumable per cell.
"""


def apply_overrides(cfg, overrides: dict):
    return dataclasses.replace(cfg, **overrides) if overrides else cfg


def serve_param_structs(cfg, model, rules):
    """bf16 weight structs for serve cells. Under ``cfg.fsdp`` every
    parameter's spec is ZeRO-extended over the data axes (``zero1_spec``)
    — GSPMD then all-gathers each layer's weights inside the scan on use
    (ZeRO-inference). Plain TP layout otherwise."""
    if not cfg.fsdp:
        return model.structs(cfg, rules, dtype=jnp.bfloat16)
    from jax.sharding import NamedSharding
    from ..models.params import map_defs
    from ..train.optim import zero1_spec

    def one(d):
        spec = zero1_spec(rules.spec(d.axes, d.shape), d.shape, rules)
        return jax.ShapeDtypeStruct(
            d.shape, jnp.bfloat16,
            sharding=NamedSharding(rules.mesh, spec))

    return map_defs(one, model.param_defs(cfg))


def lower_cell(cfg, shape, mesh, *, tc: TrainConfig = TrainConfig()):
    """Lower one cell; returns (lowered, aux_info)."""
    rules = MeshRules(mesh, fsdp=cfg.fsdp)
    model = get_model(cfg)
    if shape.kind == "train":
        step = make_train_step(cfg, rules, tc)
        sstructs = state_structs(cfg, rules, tc)
        batch = input_specs(cfg, shape, rules)
        shard = state_shardings(cfg, rules, tc)
        lowered = jax.jit(step, out_shardings=(shard, None),
                          donate_argnums=(0,)).lower(sstructs, batch)
        return lowered, {"inputs": "state+batch"}
    pstructs = serve_param_structs(cfg, model, rules)
    if shape.kind == "prefill":
        inputs = input_specs(cfg, shape, rules)

        def fn(p, i):
            return model.prefill(cfg, p, i, shape.seq_len, rules)

        lowered = jax.jit(fn).lower(pstructs, inputs)
        return lowered, {"inputs": "params+tokens"}
    # decode: one new token against a cache of seq_len
    cache = model.cache_structs(cfg, shape.global_batch, shape.seq_len,
                                rules, dtype=jnp.bfloat16)
    toks = input_specs(cfg, shape, rules)["tokens"]

    def fn(p, c, t):
        return model.decode_step(cfg, p, c, t, rules)

    lowered = jax.jit(fn, donate_argnums=(1,)).lower(pstructs, cache, toks)
    return lowered, {"inputs": "params+cache+token"}


def shape_defaults(cfg, shape) -> dict:
    """Per-shape-kind config defaults (fit-tuning; overridable via --set).

    * train: microbatch the global batch so per-device activations (the
      logits/loss region above all) stay inside HBM;
    * serve (prefill/decode) on >=8B-param archs: fsdp=True — bf16 weights
      additionally sharded over the data axes and gathered per layer
      inside the scan (ZeRO-inference); a 76B model is 9.5 GB/chip under
      16-way TP alone, which starves a 16 GB v5e once the KV cache lands.
    """
    out = {}
    if (shape.kind == "train" and cfg.microbatch == 1
            and shape.global_batch % 8 == 0):
        out["microbatch"] = 8
    if shape.kind in ("prefill", "decode") and cfg.n_params() >= 8e9:
        out["fsdp"] = True
    return out


def run_cell(arch: str, shape_name: str, mesh, mesh_label: str,
             overrides: dict = None, verbose: bool = True) -> dict:
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    base = shape_defaults(cfg, shape)
    base.update(overrides or {})
    cfg = apply_overrides(cfg, base)
    row = {"arch": arch, "shape": shape_name, "mesh": mesh_label,
           "n_devices": int(mesh.devices.size)}
    runs, why = shape_applicable(cfg, shape)
    if not runs:
        row.update(status="skip", reason=why)
        return row
    t0 = time.time()
    try:
        lowered, aux = lower_cell(cfg, shape, mesh)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        report = roofline_from_compiled(
            compiled, arch=arch, shape=shape, mesh_name=mesh_label,
            n_devices=int(mesh.devices.size), cfg=cfg)
        ma = compiled.memory_analysis()
        if verbose:
            print(f"  memory_analysis: arg={ma.argument_size_in_bytes / 1e9:.3f}GB "
                  f"out={ma.output_size_in_bytes / 1e9:.3f}GB "
                  f"temp={ma.temp_size_in_bytes / 1e9:.3f}GB "
                  f"(fits={report.fits})")
            from ..analysis.roofline import cost_analysis_dict
            ca = cost_analysis_dict(compiled)
            print(f"  cost_analysis: flops={ca.get('flops', 0):.3e} "
                  f"bytes={ca.get('bytes accessed', 0):.3e}")
            print(f"  {report.row()}")
        row.update(status="ok", lower_s=round(t_lower, 2),
                   compile_s=round(t_compile, 2), **report.to_dict())
    except Exception as e:  # a failure here is a framework bug
        row.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:])
    return row


def iter_cells(archs, shapes):
    for arch in archs:
        for shape in shapes:
            yield arch, shape


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    help="arch id or comma list or 'all'")
    ap.add_argument("--shape", default="all",
                    help="shape name or comma list or 'all'")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells already in --out")
    ap.add_argument("--set", action="append", default=[],
                    metavar="K=V", help="ModelConfig overrides (perf knobs)")
    args = ap.parse_args(argv)

    archs = list(ARCHS) if args.arch == "all" else args.arch.split(",")
    shapes = (list(SHAPES) if args.shape == "all"
              else args.shape.split(","))
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        try:
            v = json.loads(v)
        except json.JSONDecodeError:
            pass
        overrides[k] = v

    done = set()
    if args.resume and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                if line.strip():
                    r = json.loads(line)
                    done.add((r["arch"], r["shape"], r["mesh"]))

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("1pod", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("2pod", make_production_mesh(multi_pod=True)))

    n_ok = n_skip = n_err = 0
    with open(args.out, "a") as f:
        for label, mesh in meshes:
            for arch, shape in iter_cells(archs, shapes):
                if (arch, shape, label) in done:
                    continue
                print(f"[dryrun] {arch} × {shape} × {label} "
                      f"({mesh.devices.size} devices)", flush=True)
                row = run_cell(arch, shape, mesh, label, overrides)
                if overrides:
                    row["overrides"] = overrides
                f.write(json.dumps(row) + "\n")
                f.flush()
                st = row["status"]
                n_ok += st == "ok"
                n_skip += st == "skip"
                n_err += st == "error"
                if st == "error":
                    print(f"  ERROR {row['error']}", flush=True)
                elif st == "skip":
                    print(f"  {row['reason']}", flush=True)
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skip, {n_err} error")
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())
