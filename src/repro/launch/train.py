"""Training driver: ``python -m repro.launch.train --arch <id> [--smoke]``.

The production loop with everything the brief's fault-tolerance story
needs: jit'd train step with pinned shardings, deterministic resumable
data pipeline, atomic async checkpoints, heartbeat for the supervisor,
``--resume auto``, and ``--crash-at`` fault injection (used by the FT
tests to prove restart-correctness).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config of the same family (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--model-shards", type=int, default=1)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default="",
                    help="'auto' or a step number")
    ap.add_argument("--heartbeat", default="")
    ap.add_argument("--crash-at", type=int, default=-1,
                    help="fault injection: hard-exit at this step")
    ap.add_argument("--hang-at", type=int, default=-1,
                    help="fault injection: stop heartbeating at this step")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-out", default="")
    args = ap.parse_args(argv)

    from ..configs import get_config, get_smoke_config
    from ..data.tokens import TokenPipeline
    from ..sharding.rules import MeshRules
    from ..train.checkpoints import CheckpointManager
    from ..train.fault_tolerance import beat
    from ..train.step import (TrainConfig, init_train_state, jit_train_step,
                              state_shardings)
    from .mesh import make_local_mesh

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    cfg = dataclasses.replace(cfg, microbatch=args.microbatch)
    mesh = make_local_mesh(model=args.model_shards)
    rules = MeshRules(mesh, fsdp=cfg.fsdp)
    tc = TrainConfig(peak_lr=args.lr, warmup_steps=args.warmup,
                     total_steps=args.steps)

    pipeline = TokenPipeline(cfg, args.global_batch, args.seq,
                             seed=args.seed)
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    with mesh:
        state = init_train_state(cfg, jax.random.PRNGKey(args.seed))
        start = 0
        if mgr is not None and args.resume:
            want = None if args.resume == "auto" else int(args.resume)
            if mgr.latest_step() is not None or want is not None:
                shard = state_shardings(cfg, rules, tc)
                start, state = mgr.restore(want, template=state,
                                           shardings=shard)
                print(f"[train] resumed from step {start}", flush=True)
        step_fn = jit_train_step(cfg, rules, tc)

        t0 = time.time()
        metrics_log = []
        for step in range(start, args.steps):
            if step == args.crash_at:
                print(f"[train] injected crash at step {step}", flush=True)
                os._exit(42)
            batch = {k: jnp.asarray(v)
                     for k, v in pipeline.batch_at(step).items()}
            state, metrics = step_fn(state, batch)
            if args.heartbeat and step != args.hang_at:
                beat(args.heartbeat, step)
            if args.hang_at >= 0 and step >= args.hang_at:
                time.sleep(3600)             # simulated straggler
            if (step + 1) % args.log_every == 0 or step + 1 == args.steps:
                m = jax.device_get(metrics)
                dt = time.time() - t0
                row = {"step": step + 1, "loss": float(m["loss"]),
                       "grad_norm": float(m["grad_norm"]),
                       "lr": float(m["lr"]),
                       "tok_per_s": args.global_batch * args.seq
                       * args.log_every / max(dt, 1e-9)}
                metrics_log.append(row)
                print(f"[train] step {row['step']:5d} "
                      f"loss {row['loss']:.4f} gnorm {row['grad_norm']:.3f} "
                      f"lr {row['lr']:.2e} {row['tok_per_s']:.0f} tok/s",
                      flush=True)
                t0 = time.time()
            if (mgr is not None and args.ckpt_every
                    and (step + 1) % args.ckpt_every == 0):
                mgr.save(step + 1, state, block=False,
                         metadata={"arch": args.arch, "seq": args.seq,
                                   "global_batch": args.global_batch})
        if mgr is not None:
            mgr.wait()
            mgr.save(args.steps, state,
                     metadata={"arch": args.arch, "final": True})
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(metrics_log, f)
    print("[train] done", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
