"""Cluster-serving driver: a long-lived HTTP query service over a live
mining stream (DESIGN.md §8).

Server:  ``python -m repro.launch.cluster_serve --dataset imdb
--port 8787`` — preloads the dataset into a :class:`TriclusterService`
(streaming by default, ``--backend distributed`` for per-shard run
stores), publishes the first snapshot, and serves queries while the
background thread re-mines on writes.

Smoke client:  ``python -m repro.launch.cluster_serve --smoke-client
--port-file /tmp/p`` — drives a running server through the whole
surface (scalar, batch, top-k and signature queries; an upsert; a
forced refresh asserting the version advanced; clean shutdown).  Exits
non-zero on any violation — this is the CI serve-smoke step.
"""
from __future__ import annotations

import argparse
import sys
import time


def _serve(args) -> int:
    from ..serve.protocol import make_server
    from ..serve.ranking import RankingPolicy
    from ..serve.service import TriclusterService
    from .tricluster import load_dataset

    ctx = load_dataset(args.dataset, args.n_tuples, args.seed)
    policy = RankingPolicy(w_density=args.w_density,
                           w_volume=args.w_volume,
                           w_recency=args.w_recency)
    svc = TriclusterService(
        ctx.sizes, backend=args.backend, theta=args.theta,
        delta=args.delta, rho_min=args.rho_min, minsup=args.minsup,
        refresh_interval=args.refresh_interval,
        dirty_threshold=args.dirty_threshold, policy=policy,
        seed=args.seed or 0x5EED)
    n = ctx.tuples.shape[0]
    step = -(-n // max(1, args.preload_chunks))
    for lo in range(0, n, step):
        svc.add(ctx.tuples[lo:lo + step],
                None if ctx.values is None or args.delta is None
                else ctx.values[lo:lo + step])
    svc.start()
    server = make_server(svc, host=args.host, port=args.port,
                         allow_shutdown=not args.no_shutdown,
                         verbose=args.verbose)
    if args.port_file:
        with open(args.port_file, "w") as f:
            f.write(str(server.port))
    print(f"[cluster-serve] dataset={args.dataset} sizes={ctx.sizes} "
          f"|I|={n} backend={args.backend} version={svc.version} "
          f"clusters={svc.stats()['clusters']}", flush=True)
    print(f"[cluster-serve] listening on http://{args.host}:{server.port}",
          flush=True)
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        server.server_close()
        svc.stop()
        print("[cluster-serve] stopped", flush=True)
    return 0


def _smoke_client(args) -> int:
    from ..serve.protocol import ClusterClient

    port = args.port
    if args.port_file:
        deadline = time.monotonic() + args.timeout
        while time.monotonic() < deadline:
            try:
                with open(args.port_file) as f:
                    port = int(f.read().strip())
                break
            except (OSError, ValueError):
                time.sleep(0.1)
        else:
            print(f"[serve-smoke] no port in {args.port_file}")
            return 1
    cl = ClusterClient(f"http://{args.host}:{port}")
    h = cl.wait_ready(timeout=args.timeout)
    print(f"[serve-smoke] ready: {h}")
    sizes = cl.stats()["sizes"]

    scalar = cl.query(entity=0, mode=0, k=3)
    assert "hits" in scalar and isinstance(scalar["hits"], list), scalar
    print(f"[serve-smoke] scalar query: {len(scalar['hits'])} hit(s)")

    ents = list(range(min(64, sizes[0])))
    batch = cl.query_batch(ents, mode=0, k=3)
    assert len(batch["hits"]) == len(ents), "batch arity mismatch"
    # batch row 0 must equal the scalar query on the same snapshot
    if batch["version"] == scalar["version"]:
        assert batch["hits"][0] == scalar["hits"], \
            "batch/scalar hit mismatch"
    print(f"[serve-smoke] batch query over {len(ents)} entities OK")

    top = cl.query(k=3, include_components=True)
    assert top["hits"], "empty top-k on a preloaded dataset"
    scores = [hit["score"] for hit in top["hits"]]
    assert scores == sorted(scores, reverse=True), "top-k not ranked"
    sig = top["hits"][0]["signature"]
    by_sig = cl.query(signature=sig, include_components=True)
    assert by_sig["hits"] and by_sig["hits"][0]["components"] \
        == top["hits"][0]["components"], "signature round-trip mismatch"
    print(f"[serve-smoke] top-k + signature round-trip OK "
          f"(top score {scores[0]:.3f})")

    v0 = cl.health()["version"]
    up = cl.upsert([[0] * len(sizes)])
    assert up["stream_version"] > 0
    ref = cl.refresh()
    assert ref["version"] > v0, \
        f"version did not advance over upsert+refresh ({v0} -> {ref})"
    fresh = cl.query(entity=0, at_least_version=ref["version"], timeout=30)
    assert fresh["version"] >= ref["version"]
    print(f"[serve-smoke] upsert advanced version {v0} -> "
          f"{ref['version']}; at_least_version read OK")

    cl.shutdown()
    print("[serve-smoke] PASS")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="imdb",
                    choices=["k1", "k2", "k3", "imdb", "movielens",
                             "bibsonomy", "frames", "random"])
    ap.add_argument("--n-tuples", type=int, default=0)
    ap.add_argument("--backend", default="streaming",
                    choices=["streaming", "distributed"])
    ap.add_argument("--theta", type=float, default=0.0)
    ap.add_argument("--delta", type=float, default=None,
                    help="NOAC δ — serve the many-valued variant")
    ap.add_argument("--rho-min", type=float, default=0.0)
    ap.add_argument("--minsup", type=int, default=0)
    ap.add_argument("--refresh-interval", type=float, default=0.25,
                    help="re-mine cadence (s) once a write is pending")
    ap.add_argument("--dirty-threshold", type=int, default=64,
                    help="re-mine as soon as this many writes accumulate")
    ap.add_argument("--w-density", type=float, default=1.0)
    ap.add_argument("--w-volume", type=float, default=0.0)
    ap.add_argument("--w-recency", type=float, default=0.0)
    ap.add_argument("--preload-chunks", type=int, default=4)
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8787,
                    help="0 = ephemeral (use --port-file to discover)")
    ap.add_argument("--port-file", default="",
                    help="write the bound port here once listening")
    ap.add_argument("--no-shutdown", action="store_true",
                    help="disable the POST /shutdown endpoint")
    ap.add_argument("--verbose", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke-client", action="store_true",
                    help="run the CI smoke sequence against a running "
                         "server and exit (needs --port or --port-file)")
    ap.add_argument("--timeout", type=float, default=120.0,
                    help="smoke client readiness timeout (s)")
    args = ap.parse_args(argv)
    if args.smoke_client:
        return _smoke_client(args)
    return _serve(args)


if __name__ == "__main__":
    sys.exit(main())
