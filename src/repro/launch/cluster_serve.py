"""Cluster-serving driver: a long-lived HTTP query service over a live
mining stream (DESIGN.md §8).

Server:  ``python -m repro.launch.cluster_serve --dataset imdb
--port 8787`` — preloads the dataset into a :class:`TriclusterService`
(streaming by default, ``--backend distributed`` for per-shard run
stores), publishes the first snapshot, and serves queries while the
background thread re-mines on writes.

Sharded plane:  ``--shards 2 --replicas 2`` spawns (per shard) one
writer process — which preloads only the radix range it owns
(``core.runs.shard_of_rows`` on the mode-0 identity key) and mirrors
every snapshot into shared memory — plus N zero-copy replica reader
processes (``serve.shm.ReplicaService``; jax-free), then fronts the
whole topology with a ``serve.router`` endpoint on ``--port``.  The
router speaks the same protocol, so clients are unchanged.

Smoke client:  ``python -m repro.launch.cluster_serve --smoke-client
--port-file /tmp/p`` — drives a running server through the whole
surface (scalar, batch, top-k and signature queries; an upsert; a
forced refresh asserting the version advanced; clean shutdown).
Against a router it additionally verifies cross-shard
read-your-writes: an upsert spanning every shard, then a query pinned
to the per-shard ``shard_versions`` write token.  Exits non-zero on
any violation — this is the CI serve-smoke step.
"""
from __future__ import annotations

import argparse
import os
import sys
import tempfile
import time


def _serve(args) -> int:
    from ..serve.protocol import make_server
    from ..serve.ranking import RankingPolicy
    from ..serve.service import TriclusterService
    from .tricluster import load_dataset

    ctx = load_dataset(args.dataset, args.n_tuples, args.seed)
    policy = RankingPolicy(w_density=args.w_density,
                           w_volume=args.w_volume,
                           w_recency=args.w_recency)
    svc = TriclusterService(
        ctx.sizes, backend=args.backend, theta=args.theta,
        delta=args.delta, rho_min=args.rho_min, minsup=args.minsup,
        refresh_interval=args.refresh_interval,
        dirty_threshold=args.dirty_threshold, policy=policy,
        delta_index=not args.no_delta_index, seed=args.seed or 0x5EED)
    n = ctx.tuples.shape[0]
    step = -(-n // max(1, args.preload_chunks))
    for lo in range(0, n, step):
        svc.add(ctx.tuples[lo:lo + step],
                None if ctx.values is None or args.delta is None
                else ctx.values[lo:lo + step])
    svc.start()
    server = make_server(svc, host=args.host, port=args.port,
                         allow_shutdown=not args.no_shutdown,
                         verbose=args.verbose)
    if args.port_file:
        with open(args.port_file, "w") as f:
            f.write(str(server.port))
    print(f"[cluster-serve] dataset={args.dataset} sizes={ctx.sizes} "
          f"|I|={n} backend={args.backend} version={svc.version} "
          f"clusters={svc.stats()['clusters']}", flush=True)
    print(f"[cluster-serve] listening on http://{args.host}:{server.port}",
          flush=True)
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        server.server_close()
        svc.stop()
        print("[cluster-serve] stopped", flush=True)
    return 0


def _wait_port_file(path: str, timeout: float) -> int:
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        try:
            with open(path) as f:
                return int(f.read().strip())
        except (OSError, ValueError):
            time.sleep(0.1)
    raise TimeoutError(f"no port in {path} after {timeout}s")


def _child_writer(cfg: dict) -> None:
    """Spawn target: one shard's writer — loads the dataset, keeps only
    the radix range this shard owns, publishes snapshots to shared
    memory (when replicas attach) and serves the write/query HTTP
    surface on an ephemeral port."""
    from ..serve.protocol import make_server
    from ..serve.ranking import RankingPolicy
    from ..serve.service import TriclusterService
    from .tricluster import load_dataset

    ctx = load_dataset(cfg["dataset"], cfg["n_tuples"], cfg["seed"])
    publisher = None
    if cfg["shm_prefix"]:
        from ..serve.shm import ShmPublisher
        publisher = ShmPublisher(cfg["shm_prefix"])
    svc = TriclusterService(
        ctx.sizes, backend=cfg["backend"], theta=cfg["theta"],
        delta=cfg["delta"], rho_min=cfg["rho_min"], minsup=cfg["minsup"],
        refresh_interval=cfg["refresh_interval"],
        dirty_threshold=cfg["dirty_threshold"],
        policy=RankingPolicy(*cfg["policy"]),
        delta_index=cfg["delta_index"], publisher=publisher,
        seed=cfg["seed"] or 0x5EED)
    tuples, values = ctx.tuples, ctx.values
    if cfg["n_shards"] > 1:
        # deterministic load (same dataset+seed in every writer), so
        # each writer can compute ownership locally — no coordinator
        from ..core import keys as K
        from ..core import runs as RS
        plan = K.plan_mode_key(ctx.sizes, 0, with_values=False)
        own = RS.shard_of_rows(tuples, plan,
                               cfg["n_shards"]) == cfg["shard"]
        tuples = tuples[own]
        values = None if values is None else values[own]
    n = tuples.shape[0]
    step = -(-max(n, 1) // max(1, cfg["preload_chunks"]))
    for lo in range(0, n, step):
        svc.add(tuples[lo:lo + step],
                None if values is None or cfg["delta"] is None
                else values[lo:lo + step])
    svc.start()
    server = make_server(svc, host=cfg["host"], port=0,
                         verbose=cfg["verbose"])
    with open(cfg["port_file"], "w") as f:
        f.write(str(server.port))
    print(f"[shard-{cfg['shard']}] |I|={n} version={svc.version} "
          f"clusters={svc.stats()['clusters']} port={server.port}",
          flush=True)
    try:
        server.serve_forever()
    finally:
        server.server_close()
        svc.stop()
        if publisher is not None:
            publisher.close()


def _child_replica(cfg: dict) -> None:
    """Spawn target: one zero-copy replica reader — attaches the
    shard's shared-memory snapshot bundles (never imports jax, never
    mines) and serves the read-only HTTP surface."""
    from ..serve.protocol import make_server
    from ..serve.shm import ReplicaService

    svc = ReplicaService(cfg["shm_prefix"],
                         connect_timeout=cfg["timeout"])
    svc.start(first_snapshot_timeout=cfg["timeout"])
    server = make_server(svc, host=cfg["host"], port=0,
                         verbose=cfg["verbose"])
    with open(cfg["port_file"], "w") as f:
        f.write(str(server.port))
    print(f"[replica-{cfg['shard']}.{cfg['replica']}] attached "
          f"version={svc.version} port={server.port}", flush=True)
    try:
        server.serve_forever()
    finally:
        server.server_close()
        svc.stop()


def _serve_topology(args) -> int:
    """Boot ``--shards`` writer processes (+ ``--replicas`` zero-copy
    readers each) and front them with a router endpoint."""
    import multiprocessing as mp

    from ..serve.router import RouterService, Shard, make_router_server

    mp_ctx = mp.get_context("spawn")          # fork is unsafe under jax
    tmp = tempfile.mkdtemp(prefix="cluster-serve-")
    base_cfg = {
        "dataset": args.dataset, "n_tuples": args.n_tuples,
        "seed": args.seed, "backend": args.backend, "theta": args.theta,
        "delta": args.delta, "rho_min": args.rho_min,
        "minsup": args.minsup,
        "refresh_interval": args.refresh_interval,
        "dirty_threshold": args.dirty_threshold,
        "policy": (args.w_density, args.w_volume, args.w_recency),
        "delta_index": not args.no_delta_index,
        "preload_chunks": args.preload_chunks, "host": args.host,
        "verbose": args.verbose, "n_shards": args.shards,
        "timeout": args.timeout,
    }
    procs, shard_specs = [], []
    try:
        for s in range(args.shards):
            prefix = (f"cs{os.getpid()}s{s}" if args.replicas else "")
            wcfg = dict(base_cfg, shard=s, shm_prefix=prefix,
                        port_file=os.path.join(tmp, f"w{s}.port"))
            p = mp_ctx.Process(target=_child_writer, args=(wcfg,),
                               daemon=True, name=f"shard-{s}")
            p.start()
            procs.append(p)
            rfiles = []
            for r in range(args.replicas):
                rcfg = dict(base_cfg, shard=s, replica=r,
                            shm_prefix=prefix,
                            port_file=os.path.join(tmp,
                                                   f"r{s}.{r}.port"))
                p = mp_ctx.Process(target=_child_replica, args=(rcfg,),
                                   daemon=True, name=f"replica-{s}.{r}")
                p.start()
                procs.append(p)
                rfiles.append(rcfg["port_file"])
            shard_specs.append((wcfg["port_file"], rfiles))

        shards = []
        for wf, rfiles in shard_specs:
            wp = _wait_port_file(wf, args.timeout)
            rps = [_wait_port_file(rf, args.timeout) for rf in rfiles]
            shards.append(Shard(
                f"http://{args.host}:{wp}",
                [f"http://{args.host}:{rp}" for rp in rps]))
        router = RouterService(shards)
        server = make_router_server(
            router, host=args.host, port=args.port,
            allow_shutdown=not args.no_shutdown,
            cascade_shutdown=True, verbose=args.verbose)
        if args.port_file:
            with open(args.port_file, "w") as f:
                f.write(str(server.port))
        h = router.health()
        print(f"[cluster-serve] router over {args.shards} shard(s) x "
              f"{args.replicas} replica(s): clusters={h['clusters']} "
              f"shard_versions={h['shard_versions']}", flush=True)
        print(f"[cluster-serve] listening on "
              f"http://{args.host}:{server.port}", flush=True)
        try:
            server.serve_forever()
        except KeyboardInterrupt:
            pass
        finally:
            server.server_close()
            router.shutdown_backends()
            router.close()
    finally:
        deadline = time.monotonic() + 10
        for p in procs:
            p.join(timeout=max(0.1, deadline - time.monotonic()))
        for p in procs:
            if p.is_alive():
                p.terminate()
        print("[cluster-serve] stopped", flush=True)
    return 0


def _smoke_client(args) -> int:
    from ..serve.protocol import ClusterClient

    port = args.port
    if args.port_file:
        deadline = time.monotonic() + args.timeout
        while time.monotonic() < deadline:
            try:
                with open(args.port_file) as f:
                    port = int(f.read().strip())
                break
            except (OSError, ValueError):
                time.sleep(0.1)
        else:
            print(f"[serve-smoke] no port in {args.port_file}")
            return 1
    cl = ClusterClient(f"http://{args.host}:{port}")
    h = cl.wait_ready(timeout=args.timeout)
    print(f"[serve-smoke] ready: {h}")
    sizes = cl.stats()["sizes"]

    scalar = cl.query(entity=0, mode=0, k=3)
    assert "hits" in scalar and isinstance(scalar["hits"], list), scalar
    print(f"[serve-smoke] scalar query: {len(scalar['hits'])} hit(s)")

    ents = list(range(min(64, sizes[0])))
    batch = cl.query_batch(ents, mode=0, k=3)
    assert len(batch["hits"]) == len(ents), "batch arity mismatch"
    # batch row 0 must equal the scalar query on the same snapshot
    # (per-shard versions, when the backend is a router)
    if batch.get("shard_versions", batch["version"]) \
            == scalar.get("shard_versions", scalar["version"]):
        assert batch["hits"][0] == scalar["hits"], \
            "batch/scalar hit mismatch"
    print(f"[serve-smoke] batch query over {len(ents)} entities OK")

    top = cl.query(k=3, include_components=True)
    assert top["hits"], "empty top-k on a preloaded dataset"
    scores = [hit["score"] for hit in top["hits"]]
    assert scores == sorted(scores, reverse=True), "top-k not ranked"
    sig = top["hits"][0]["signature"]
    by_sig = cl.query(signature=sig, include_components=True)
    assert by_sig["hits"] and by_sig["hits"][0]["components"] \
        == top["hits"][0]["components"], "signature round-trip mismatch"
    print(f"[serve-smoke] top-k + signature round-trip OK "
          f"(top score {scores[0]:.3f})")

    health = cl.health()
    v0 = health["version"]
    if health.get("role") == "router":
        # one write per shard (spread across the key range), then a
        # read pinned to the per-shard write token: cross-shard
        # read-your-writes through the router
        n_shards = health["shards"]
        rows = [[int(sizes[0] * (2 * s + 1) // (2 * n_shards))]
                + [0] * (len(sizes) - 1) for s in range(n_shards)]
        up = cl.upsert(rows)
        assert sum(up["stream_versions"]) > 0, up
        ref = cl.refresh()
        tok = ref["shard_versions"]
        assert len(tok) == n_shards and ref["version"] > v0, (v0, ref)
        fresh = cl.query(entity=0, at_least_version=tok, timeout=30)
        assert all(v >= t for v, t in
                   zip(fresh["shard_versions"], tok)), (fresh, tok)
        h = cl.health()
        assert h["dirty"] == 0 and h["staleness_s"] is not None, h
        print(f"[serve-smoke] router: {n_shards} shard(s), replicas="
              f"{h['replicas']}; cross-shard read-your-writes OK "
              f"(token {tok} -> {fresh['shard_versions']})")
    else:
        up = cl.upsert([[0] * len(sizes)])
        assert up["stream_version"] > 0
        ref = cl.refresh()
        assert ref["version"] > v0, \
            f"version did not advance over upsert+refresh ({v0} -> {ref})"
        fresh = cl.query(entity=0, at_least_version=ref["version"],
                         timeout=30)
        assert fresh["version"] >= ref["version"]
    print(f"[serve-smoke] upsert advanced version {v0} -> "
          f"{ref['version']}; at_least_version read OK")

    cl.shutdown()
    print("[serve-smoke] PASS")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="imdb",
                    choices=["k1", "k2", "k3", "imdb", "movielens",
                             "bibsonomy", "frames", "random"])
    ap.add_argument("--n-tuples", type=int, default=0)
    ap.add_argument("--backend", default="streaming",
                    choices=["streaming", "distributed"])
    ap.add_argument("--theta", type=float, default=0.0)
    ap.add_argument("--delta", type=float, default=None,
                    help="NOAC δ — serve the many-valued variant")
    ap.add_argument("--rho-min", type=float, default=0.0)
    ap.add_argument("--minsup", type=int, default=0)
    ap.add_argument("--refresh-interval", type=float, default=0.25,
                    help="re-mine cadence (s) once a write is pending")
    ap.add_argument("--dirty-threshold", type=int, default=64,
                    help="re-mine as soon as this many writes accumulate")
    ap.add_argument("--w-density", type=float, default=1.0)
    ap.add_argument("--w-volume", type=float, default=0.0)
    ap.add_argument("--w-recency", type=float, default=0.0)
    ap.add_argument("--preload-chunks", type=int, default=4)
    ap.add_argument("--shards", type=int, default=1,
                    help=">1: spawn per-shard writer processes behind "
                         "a serve.router endpoint")
    ap.add_argument("--replicas", type=int, default=0,
                    help="zero-copy shared-memory replica readers per "
                         "shard (implies a router topology)")
    ap.add_argument("--no-delta-index", action="store_true",
                    help="full ClusterIndex rebuild every swap "
                         "(baseline; default is delta maintenance)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8787,
                    help="0 = ephemeral (use --port-file to discover)")
    ap.add_argument("--port-file", default="",
                    help="write the bound port here once listening")
    ap.add_argument("--no-shutdown", action="store_true",
                    help="disable the POST /shutdown endpoint")
    ap.add_argument("--verbose", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke-client", action="store_true",
                    help="run the CI smoke sequence against a running "
                         "server and exit (needs --port or --port-file)")
    ap.add_argument("--timeout", type=float, default=120.0,
                    help="smoke client readiness timeout (s)")
    args = ap.parse_args(argv)
    if args.smoke_client:
        return _smoke_client(args)
    if args.shards > 1 or args.replicas > 0:
        return _serve_topology(args)
    return _serve(args)


if __name__ == "__main__":
    sys.exit(main())
