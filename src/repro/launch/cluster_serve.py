"""Cluster-serving driver: a long-lived HTTP query service over a live
mining stream (DESIGN.md §8).

Server:  ``python -m repro.launch.cluster_serve --dataset imdb
--port 8787`` — preloads the dataset into a :class:`TriclusterService`
(streaming by default, ``--backend distributed`` for per-shard run
stores), publishes the first snapshot, and serves queries while the
background thread re-mines on writes.

Sharded plane:  ``--shards 2 --replicas 2`` spawns (per shard) one
writer process — which preloads only the radix range it owns
(``core.runs.shard_of_rows`` on the mode-0 identity key) and mirrors
every snapshot into shared memory — plus N zero-copy replica reader
processes (``serve.shm.ReplicaService``; jax-free), then fronts the
whole topology with a ``serve.router`` endpoint on ``--port``.  The
router speaks the same protocol, so clients are unchanged.

Smoke client:  ``python -m repro.launch.cluster_serve --smoke-client
--port-file /tmp/p`` — drives a running server through the whole
surface (scalar, batch, top-k and signature queries; an upsert; a
forced refresh asserting the version advanced; clean shutdown).
Against a router it additionally verifies cross-shard
read-your-writes: an upsert spanning every shard, then a query pinned
to the per-shard ``shard_versions`` write token.  Exits non-zero on
any violation — this is the CI serve-smoke step.
"""
from __future__ import annotations

import argparse
import os
import signal
import sys
import tempfile
import threading
import time


def _load_fault_plan(spec: str):
    """``--fault-plan`` value: inline JSON, or a path (optionally
    ``@``-prefixed) to a JSON file.  Returns a FaultPlan or None."""
    if not spec:
        return None
    if spec.startswith("@"):
        spec = spec[1:]
    if os.path.exists(spec):
        with open(spec) as f:
            spec = f.read()
    from ..serve.faults import FaultPlan
    return FaultPlan.from_json(spec)


def _make_obs(enabled: bool, slow_query_ms: float, service: str):
    """One per-process observability hub (``repro.obs.Obs``) or None.
    Each process of a topology builds its own — metrics and spans are
    process-local; the trace id stitches them back together."""
    if not enabled:
        return None
    from ..obs import Obs
    return Obs.create(service=service, slow_query_ms=slow_query_ms)


def _install_sigterm(server, flag: dict) -> None:
    """Graceful SIGTERM: mark the shutdown as supervisor-driven (shm
    segments are *kept* so a successor can adopt the epoch watermark)
    and unblock ``serve_forever`` — the caller's ``finally`` then
    drains, checkpoints and closes."""
    def _handler(signum, frame):
        flag["unlink"] = False
        threading.Thread(target=server.shutdown, daemon=True).start()
    try:
        signal.signal(signal.SIGTERM, _handler)
    except ValueError:                       # not the main thread
        pass


def _serve(args) -> int:
    from ..serve.protocol import make_server
    from ..serve.ranking import RankingPolicy
    from ..serve.service import TriclusterService
    from .tricluster import load_dataset

    ctx = load_dataset(args.dataset, args.n_tuples, args.seed)
    policy = RankingPolicy(w_density=args.w_density,
                           w_volume=args.w_volume,
                           w_recency=args.w_recency)
    plan = _load_fault_plan(args.fault_plan)
    inj = None if plan is None else plan.for_component("writer", 0)
    obs = _make_obs(args.metrics, args.slow_query_ms, "writer")
    svc = TriclusterService(
        ctx.sizes, backend=args.backend, theta=args.theta,
        delta=args.delta, rho_min=args.rho_min, minsup=args.minsup,
        refresh_interval=args.refresh_interval,
        dirty_threshold=args.dirty_threshold, policy=policy,
        delta_index=not args.no_delta_index, seed=args.seed or 0x5EED,
        recover_dir=args.recover_dir or None,
        checkpoint_every=args.checkpoint_every,
        scrub_interval=args.scrub_interval, fault=inj, obs=obs)
    n = ctx.tuples.shape[0]
    if not svc.recovered:                    # a recovered store already
        step = -(-n // max(1, args.preload_chunks))  # holds the data
        for lo in range(0, n, step):
            svc.add(ctx.tuples[lo:lo + step],
                    None if ctx.values is None or args.delta is None
                    else ctx.values[lo:lo + step])
    svc.start()
    server = make_server(svc, host=args.host, port=args.port,
                         allow_shutdown=not args.no_shutdown,
                         verbose=args.verbose,
                         health_max_staleness=(args.health_max_staleness
                                               or None),
                         max_write_backlog=args.max_write_backlog,
                         fault=inj, obs=obs)
    flag = {"unlink": True}
    _install_sigterm(server, flag)
    if args.port_file:
        with open(args.port_file, "w") as f:
            f.write(str(server.port))
    print(f"[cluster-serve] dataset={args.dataset} sizes={ctx.sizes} "
          f"|I|={n} backend={args.backend} version={svc.version} "
          f"clusters={svc.stats()['clusters']}", flush=True)
    print(f"[cluster-serve] listening on http://{args.host}:{server.port}",
          flush=True)
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        server.drain_inflight(timeout=args.drain_timeout)
        server.server_close()
        try:
            svc.final_checkpoint()
        except Exception:                    # noqa: BLE001 — teardown
            pass
        svc.stop()
        print("[cluster-serve] stopped", flush=True)
    return 0


def _wait_port_file(path: str, timeout: float) -> int:
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        try:
            with open(path) as f:
                return int(f.read().strip())
        except (OSError, ValueError):
            time.sleep(0.1)
    raise TimeoutError(f"no port in {path} after {timeout}s")


def _stable_port(cfg: dict) -> int:
    """A restarted child must come back on the port the router already
    holds a client for — reuse the port recorded by the previous
    incarnation (0 = first boot, ephemeral)."""
    try:
        with open(cfg["port_file"]) as f:
            return int(f.read().strip())
    except (OSError, ValueError):
        return 0


def _bind_server(make, port: int, retries: int = 40,
                 delay: float = 0.25):
    """Bind, retrying EADDRINUSE when rebinding a predecessor's port —
    its socket may linger for a moment after the crash."""
    while True:
        try:
            return make(port)
        except OSError:
            if port == 0 or retries <= 0:
                raise
            retries -= 1
            time.sleep(delay)


def _child_injector(cfg: dict, role: str):
    if not cfg.get("fault_plan"):
        return None
    from ..serve.faults import FaultPlan
    return FaultPlan.from_json(cfg["fault_plan"]).for_component(
        role, cfg.get("shard", 0), cfg.get("replica", -1))


def _child_writer(cfg: dict) -> None:
    """Spawn target: one shard's writer — loads the dataset, keeps only
    the radix range this shard owns, publishes snapshots to shared
    memory (when replicas attach) and serves the write/query HTTP
    surface.  With a ``recover_dir`` a restart restores the checkpoint,
    replays the WAL tail and skips the preload — restart *is*
    recovery."""
    from ..serve.protocol import make_server
    from ..serve.ranking import RankingPolicy
    from ..serve.service import TriclusterService
    from .tricluster import load_dataset

    inj = _child_injector(cfg, "writer")
    obs = _make_obs(cfg.get("metrics", False),
                    cfg.get("slow_query_ms", 100.0),
                    f"shard-{cfg['shard']}")
    ctx = load_dataset(cfg["dataset"], cfg["n_tuples"], cfg["seed"])
    publisher = None
    if cfg["shm_prefix"]:
        from ..serve.shm import ShmPublisher
        publisher = ShmPublisher(cfg["shm_prefix"], fault=inj)
    svc = TriclusterService(
        ctx.sizes, backend=cfg["backend"], theta=cfg["theta"],
        delta=cfg["delta"], rho_min=cfg["rho_min"], minsup=cfg["minsup"],
        refresh_interval=cfg["refresh_interval"],
        dirty_threshold=cfg["dirty_threshold"],
        policy=RankingPolicy(*cfg["policy"]),
        delta_index=cfg["delta_index"], publisher=publisher,
        seed=cfg["seed"] or 0x5EED,
        recover_dir=cfg.get("recover_dir") or None,
        checkpoint_every=cfg.get("checkpoint_every", 64),
        scrub_interval=cfg.get("scrub_interval", 0.5),
        event_dir=cfg.get("flag_dir") or None,
        event_name=f"shard-{cfg['shard']}",
        version_base=(0 if publisher is None
                      else publisher.resumed_version),
        fault=inj, obs=obs)
    if svc.recovered:
        print(f"[shard-{cfg['shard']}] recovered {svc.recovered}",
              flush=True)
    else:
        tuples, values = ctx.tuples, ctx.values
        if cfg["n_shards"] > 1:
            # deterministic load (same dataset+seed in every writer), so
            # each writer can compute ownership locally — no coordinator
            from ..core import keys as K
            from ..core import runs as RS
            plan = K.plan_mode_key(ctx.sizes, 0, with_values=False)
            own = RS.shard_of_rows(tuples, plan,
                                   cfg["n_shards"]) == cfg["shard"]
            tuples = tuples[own]
            values = None if values is None else values[own]
        n = tuples.shape[0]
        step = -(-max(n, 1) // max(1, cfg["preload_chunks"]))
        for lo in range(0, n, step):
            svc.add(tuples[lo:lo + step],
                    None if values is None or cfg["delta"] is None
                    else values[lo:lo + step])
    svc.start()
    server = _bind_server(
        lambda p: make_server(
            svc, host=cfg["host"], port=p, verbose=cfg["verbose"],
            health_max_staleness=cfg.get("health_max_staleness"),
            max_write_backlog=cfg.get("max_write_backlog", 0),
            fault=inj, obs=obs),
        _stable_port(cfg))
    flag = {"unlink": True}
    _install_sigterm(server, flag)
    with open(cfg["port_file"], "w") as f:
        f.write(str(server.port))
    print(f"[shard-{cfg['shard']}] version={svc.version} "
          f"clusters={svc.stats()['clusters']} port={server.port}",
          flush=True)
    try:
        server.serve_forever()
    finally:
        server.drain_inflight(timeout=cfg.get("drain_timeout", 5.0))
        server.server_close()
        try:
            svc.final_checkpoint()
        except Exception:                    # noqa: BLE001 — teardown
            pass
        svc.stop()
        if publisher is not None:
            # SIGTERM (supervisor restart): keep segments so the
            # successor adopts the epoch; /shutdown: full unlink
            publisher.close(unlink=flag["unlink"])


def _child_replica(cfg: dict) -> None:
    """Spawn target: one zero-copy replica reader — attaches the
    shard's shared-memory snapshot bundles (never imports jax, never
    mines) and serves the read-only HTTP surface.  When the stuck-odd
    seqlock protocol declares the shard's writer dead, drops a restart
    flag for the supervisor."""
    from ..serve.protocol import make_server
    from ..serve.shm import ReplicaService

    inj = _child_injector(cfg, "replica")
    obs = _make_obs(cfg.get("metrics", False),
                    cfg.get("slow_query_ms", 100.0),
                    f"replica-{cfg['shard']}.{cfg['replica']}")
    on_dead = None
    if cfg.get("flag_dir"):
        from ..serve.supervise import write_restart_flag

        def on_dead(err, _cfg=cfg):
            write_restart_flag(_cfg["flag_dir"],
                               f"shard-{_cfg['shard']}")
    svc = ReplicaService(cfg["shm_prefix"],
                         connect_timeout=cfg["timeout"],
                         seqlock_spin_s=cfg.get("seqlock_spin_s", 1.0),
                         scrub_interval=cfg.get("scrub_interval", 0.5),
                         on_writer_dead=on_dead)
    svc.start(first_snapshot_timeout=cfg["timeout"])
    server = _bind_server(
        lambda p: make_server(
            svc, host=cfg["host"], port=p, verbose=cfg["verbose"],
            health_max_staleness=cfg.get("health_max_staleness"),
            fault=inj, obs=obs),
        _stable_port(cfg))
    flag = {"unlink": True}
    _install_sigterm(server, flag)
    with open(cfg["port_file"], "w") as f:
        f.write(str(server.port))
    print(f"[replica-{cfg['shard']}.{cfg['replica']}] attached "
          f"version={svc.version} port={server.port}", flush=True)
    try:
        server.serve_forever()
    finally:
        server.drain_inflight(timeout=cfg.get("drain_timeout", 5.0))
        server.server_close()
        svc.stop()


def _serve_topology(args) -> int:
    """Boot ``--shards`` writer processes (+ ``--replicas`` zero-copy
    readers each) under a :class:`serve.supervise.Supervisor` and front
    them with a router endpoint.  A crashed child is restarted with
    backoff; writers recover their stream from checkpoint+WAL; replicas
    that detect a dead writer (stuck-odd seqlock) flag it for restart."""
    import multiprocessing as mp

    from ..serve.router import RouterService, Shard, make_router_server
    from ..serve.supervise import Supervisor

    mp_ctx = mp.get_context("spawn")          # fork is unsafe under jax
    tmp = tempfile.mkdtemp(prefix="cluster-serve-")
    recover_base = args.recover_dir or os.path.join(tmp, "recover")
    plan_json = ""
    if args.fault_plan:
        plan_json = _load_fault_plan(args.fault_plan).to_json()
    base_cfg = {
        "dataset": args.dataset, "n_tuples": args.n_tuples,
        "seed": args.seed, "backend": args.backend, "theta": args.theta,
        "delta": args.delta, "rho_min": args.rho_min,
        "minsup": args.minsup,
        "refresh_interval": args.refresh_interval,
        "dirty_threshold": args.dirty_threshold,
        "policy": (args.w_density, args.w_volume, args.w_recency),
        "delta_index": not args.no_delta_index,
        "preload_chunks": args.preload_chunks, "host": args.host,
        "verbose": args.verbose, "n_shards": args.shards,
        "timeout": args.timeout, "fault_plan": plan_json,
        "checkpoint_every": args.checkpoint_every,
        "health_max_staleness": args.health_max_staleness or None,
        "drain_timeout": args.drain_timeout,
        "max_write_backlog": args.max_write_backlog,
        "scrub_interval": args.scrub_interval,
        "flag_dir": "" if args.no_supervise else tmp,
        "metrics": args.metrics, "slow_query_ms": args.slow_query_ms,
    }
    sup = Supervisor(flag_dir=tmp,
                     restart_backoff=args.restart_backoff,
                     max_restarts=args.max_restarts)
    shard_specs = []
    try:
        for s in range(args.shards):
            prefix = (f"cs{os.getpid()}s{s}" if args.replicas else "")
            wcfg = dict(base_cfg, shard=s, shm_prefix=prefix,
                        recover_dir=os.path.join(recover_base, f"s{s}"),
                        port_file=os.path.join(tmp, f"w{s}.port"))
            os.makedirs(wcfg["recover_dir"], exist_ok=True)
            sup.add(f"shard-{s}",
                    lambda cfg=wcfg, s=s: _start_proc(
                        mp_ctx, _child_writer, cfg, f"shard-{s}"))
            rfiles = []
            for r in range(args.replicas):
                rcfg = dict(base_cfg, shard=s, replica=r,
                            shm_prefix=prefix,
                            port_file=os.path.join(tmp,
                                                   f"r{s}.{r}.port"))
                sup.add(f"replica-{s}.{r}",
                        lambda cfg=rcfg, s=s, r=r: _start_proc(
                            mp_ctx, _child_replica, cfg,
                            f"replica-{s}.{r}"))
                rfiles.append(rcfg["port_file"])
            shard_specs.append((wcfg["port_file"], rfiles))
        if not args.no_supervise:
            sup.start()

        shards = []
        for wf, rfiles in shard_specs:
            wp = _wait_port_file(wf, args.timeout)
            rps = [_wait_port_file(rf, args.timeout) for rf in rfiles]
            shards.append(Shard(
                f"http://{args.host}:{wp}",
                [f"http://{args.host}:{rp}" for rp in rps]))
        router = RouterService(
            shards, timeout=args.router_timeout,
            obs=_make_obs(args.metrics, args.slow_query_ms, "router"))
        if router.obs.enabled:
            # supervisor counters fold into the same registry the
            # router scrapes — restarts and crash-loop state are part
            # of the plane's one /metrics source of truth (DESIGN.md
            # §11); scrape-time collector, so /stats keeps its shape
            def _sup_collect():
                yield ("supervisor_events_dropped", {},
                       sup.events_dropped)
                for name, ch in sup.stats()["children"].items():
                    lbl = {"child": name}
                    yield "supervisor_child_restarts", lbl, \
                        ch["restarts"]
                    yield "supervisor_child_alive", lbl, ch["alive"]
                    yield ("supervisor_child_failed", lbl,
                           ch["state"] == "failed")
            router.obs.metrics.register_collector(_sup_collect)
        server = make_router_server(
            router, host=args.host, port=args.port,
            allow_shutdown=not args.no_shutdown,
            cascade_shutdown=True, verbose=args.verbose)
        if args.port_file:
            with open(args.port_file, "w") as f:
                f.write(str(server.port))
        h = router.health()
        print(f"[cluster-serve] router over {args.shards} shard(s) x "
              f"{args.replicas} replica(s): clusters={h['clusters']} "
              f"shard_versions={h['shard_versions']} "
              f"supervised={not args.no_supervise}", flush=True)
        print(f"[cluster-serve] listening on "
              f"http://{args.host}:{server.port}", flush=True)
        _install_sigterm(server, {"unlink": False})
        try:
            server.serve_forever()
        except KeyboardInterrupt:
            pass
        finally:
            server.server_close()
            router.shutdown_backends()
            # let the children drain to clean exits before the
            # supervisor terminates anything: SIGTERM mid-drain flips a
            # writer to keep-segments mode (supervisor-restart
            # semantics) and would leak its shm namespace on what is a
            # full plane shutdown
            deadline = time.monotonic() + 15
            while time.monotonic() < deadline and any(
                    c["alive"] for c in
                    sup.stats()["children"].values()):
                time.sleep(0.1)
            router.close()
    finally:
        sup.stop(terminate=True)
        print("[cluster-serve] stopped "
              f"(supervisor: {sup.stats()['children']})", flush=True)
    return 0


def _start_proc(mp_ctx, target, cfg: dict, name: str):
    p = mp_ctx.Process(target=target, args=(cfg,), daemon=True,
                       name=name)
    p.start()
    return p


def _smoke_client(args) -> int:
    from ..serve.protocol import ClusterClient

    port = args.port
    if args.port_file:
        deadline = time.monotonic() + args.timeout
        while time.monotonic() < deadline:
            try:
                with open(args.port_file) as f:
                    port = int(f.read().strip())
                break
            except (OSError, ValueError):
                time.sleep(0.1)
        else:
            print(f"[serve-smoke] no port in {args.port_file}")
            return 1
    cl = ClusterClient(f"http://{args.host}:{port}")
    h = cl.wait_ready(timeout=args.timeout)
    print(f"[serve-smoke] ready: {h}")
    sizes = cl.stats()["sizes"]

    scalar = cl.query(entity=0, mode=0, k=3)
    assert "hits" in scalar and isinstance(scalar["hits"], list), scalar
    print(f"[serve-smoke] scalar query: {len(scalar['hits'])} hit(s)")

    ents = list(range(min(64, sizes[0])))
    batch = cl.query_batch(ents, mode=0, k=3)
    assert len(batch["hits"]) == len(ents), "batch arity mismatch"
    # batch row 0 must equal the scalar query on the same snapshot
    # (per-shard versions, when the backend is a router)
    if batch.get("shard_versions", batch["version"]) \
            == scalar.get("shard_versions", scalar["version"]):
        assert batch["hits"][0] == scalar["hits"], \
            "batch/scalar hit mismatch"
    print(f"[serve-smoke] batch query over {len(ents)} entities OK")

    top = cl.query(k=3, include_components=True)
    assert top["hits"], "empty top-k on a preloaded dataset"
    scores = [hit["score"] for hit in top["hits"]]
    assert scores == sorted(scores, reverse=True), "top-k not ranked"
    sig = top["hits"][0]["signature"]
    by_sig = cl.query(signature=sig, include_components=True)
    assert by_sig["hits"] and by_sig["hits"][0]["components"] \
        == top["hits"][0]["components"], "signature round-trip mismatch"
    print(f"[serve-smoke] top-k + signature round-trip OK "
          f"(top score {scores[0]:.3f})")

    health = cl.health()
    v0 = health["version"]
    if health.get("role") == "router":
        # one write per shard (spread across the key range), then a
        # read pinned to the per-shard write token: cross-shard
        # read-your-writes through the router
        n_shards = health["shards"]
        rows = [[int(sizes[0] * (2 * s + 1) // (2 * n_shards))]
                + [0] * (len(sizes) - 1) for s in range(n_shards)]
        up = cl.upsert(rows)
        assert sum(up["stream_versions"]) > 0, up
        ref = cl.refresh()
        tok = ref["shard_versions"]
        assert len(tok) == n_shards and ref["version"] > v0, (v0, ref)
        fresh = cl.query(entity=0, at_least_version=tok, timeout=30)
        assert all(v >= t for v, t in
                   zip(fresh["shard_versions"], tok)), (fresh, tok)
        h = cl.health()
        assert h["dirty"] == 0 and h["staleness_s"] is not None, h
        print(f"[serve-smoke] router: {n_shards} shard(s), replicas="
              f"{h['replicas']}; cross-shard read-your-writes OK "
              f"(token {tok} -> {fresh['shard_versions']})")
    else:
        up = cl.upsert([[0] * len(sizes)])
        assert up["stream_version"] > 0
        ref = cl.refresh()
        assert ref["version"] > v0, \
            f"version did not advance over upsert+refresh ({v0} -> {ref})"
        fresh = cl.query(entity=0, at_least_version=ref["version"],
                         timeout=30)
        assert fresh["version"] >= ref["version"]
    print(f"[serve-smoke] upsert advanced version {v0} -> "
          f"{ref['version']}; at_least_version read OK")

    cl.shutdown()
    print("[serve-smoke] PASS")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="imdb",
                    choices=["k1", "k2", "k3", "imdb", "movielens",
                             "bibsonomy", "frames", "random"])
    ap.add_argument("--n-tuples", type=int, default=0)
    ap.add_argument("--backend", default="streaming",
                    choices=["streaming", "distributed"])
    ap.add_argument("--theta", type=float, default=0.0)
    ap.add_argument("--delta", type=float, default=None,
                    help="NOAC δ — serve the many-valued variant")
    ap.add_argument("--rho-min", type=float, default=0.0)
    ap.add_argument("--minsup", type=int, default=0)
    ap.add_argument("--refresh-interval", type=float, default=0.25,
                    help="re-mine cadence (s) once a write is pending")
    ap.add_argument("--dirty-threshold", type=int, default=64,
                    help="re-mine as soon as this many writes accumulate")
    ap.add_argument("--w-density", type=float, default=1.0)
    ap.add_argument("--w-volume", type=float, default=0.0)
    ap.add_argument("--w-recency", type=float, default=0.0)
    ap.add_argument("--preload-chunks", type=int, default=4)
    ap.add_argument("--shards", type=int, default=1,
                    help=">1: spawn per-shard writer processes behind "
                         "a serve.router endpoint")
    ap.add_argument("--replicas", type=int, default=0,
                    help="zero-copy shared-memory replica readers per "
                         "shard (implies a router topology)")
    ap.add_argument("--no-delta-index", action="store_true",
                    help="full ClusterIndex rebuild every swap "
                         "(baseline; default is delta maintenance)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8787,
                    help="0 = ephemeral (use --port-file to discover)")
    ap.add_argument("--port-file", default="",
                    help="write the bound port here once listening")
    ap.add_argument("--no-shutdown", action="store_true",
                    help="disable the POST /shutdown endpoint")
    ap.add_argument("--verbose", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fault-plan", default="",
                    help="serve.faults.FaultPlan JSON (inline, or a "
                         "path / @path) injected into the plane's "
                         "components — the chaos harness")
    ap.add_argument("--recover-dir", default="",
                    help="checkpoint+WAL directory (topology mode: one "
                         "subdir per shard; default: a run-scoped tmp "
                         "dir, so supervisor restarts recover)")
    ap.add_argument("--checkpoint-every", type=int, default=64,
                    help="persist a RunStore checkpoint each N writes")
    ap.add_argument("--max-write-backlog", type=int, default=0,
                    help=">0: answer 429 + Retry-After on writes once "
                         "this many are pending a re-mine (0 = off)")
    ap.add_argument("--scrub-interval", type=float, default=0.5,
                    help="background integrity-scrub cadence (s); "
                         "0 disables the scrubber thread")
    ap.add_argument("--health-max-staleness", type=float, default=0.0,
                    help=">0: /health answers 503 once the snapshot is "
                         "older than this with writes outstanding")
    ap.add_argument("--drain-timeout", type=float, default=5.0,
                    help="graceful-shutdown in-flight drain bound (s)")
    ap.add_argument("--no-supervise", action="store_true",
                    help="topology mode: no supervisor restarts")
    ap.add_argument("--restart-backoff", type=float, default=0.2,
                    help="supervisor restart backoff base (s)")
    ap.add_argument("--max-restarts", type=int, default=5,
                    help="crash-loop bound per restart window")
    ap.add_argument("--router-timeout", type=float, default=15.0,
                    help="router per-request deadline budget (s) — "
                         "shard retries + degradation live under this")
    ap.add_argument("--metrics", action="store_true",
                    help="enable the observability plane: /metrics "
                         "(Prometheus text), /debug/trace (cross-"
                         "process spans) and /debug/slow on every "
                         "endpoint of the plane")
    ap.add_argument("--slow-query-ms", type=float, default=100.0,
                    help="slow-query log threshold (ms); requests at "
                         "or above it are kept in /debug/slow "
                         "(needs --metrics; negative disables the log)")
    ap.add_argument("--smoke-client", action="store_true",
                    help="run the CI smoke sequence against a running "
                         "server and exit (needs --port or --port-file)")
    ap.add_argument("--timeout", type=float, default=120.0,
                    help="smoke client readiness timeout (s)")
    args = ap.parse_args(argv)
    if args.smoke_client:
        return _smoke_client(args)
    if args.shards > 1 or args.replicas > 0:
        return _serve_topology(args)
    return _serve(args)


if __name__ == "__main__":
    sys.exit(main())
