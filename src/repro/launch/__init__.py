"""Launchers: production mesh, multi-pod dry-run, train/serve/tricluster
drivers. ``dryrun.py`` must be started as a fresh process (it forces 512
host devices before importing jax); the other drivers run on whatever
devices exist.
"""
