"""Logical-axis → mesh-axis sharding rules (DESIGN.md §6).

Every parameter and activation in the model layer declares *logical* axis
names; ``MeshRules`` resolves them against a concrete mesh with
divisibility fallback (a dimension that does not divide by its mesh axes
is left unsharded and recorded in ``fallbacks`` — e.g. GQA kv_heads=8 on a
16-way model axis → KV replication, the standard tp>kv regime).

Default table:
  batch            -> ("pod", "data")     data parallel
  heads/vocab/ff/
  moe_ff/ssm_heads -> "model"             tensor parallel (Megatron splits)
  kv_seq           -> "model"             sequence-parallel decode caches
  embed            -> "data" iff fsdp     ZeRO-3-style parameter sharding
  everything else  -> replicated
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# logical axis -> tuple of candidate mesh axes (joined)
DEFAULT_TABLE: dict[str, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "heads": ("model",),
    "kv_heads": ("model",),
    "vocab": ("model",),
    "ff": ("model",),
    "moe_ff": ("model",),
    "ssm_heads": ("model",),
    "ssm_inner": ("model",),
    "kv_seq": ("model",),
    "long_seq": ("data", "model"),   # batch=1 long-context states
    "experts": (),                   # EP disabled by default (see DESIGN §6)
    "embed": (),                     # becomes ("data",) under fsdp
    "seq": (),
    "layers": (),
}


@dataclasses.dataclass
class MeshRules:
    mesh: Mesh
    table: dict = None
    fsdp: bool = False
    fallbacks: list = dataclasses.field(default_factory=list)

    def __post_init__(self):
        if self.table is None:
            self.table = dict(DEFAULT_TABLE)
        if self.fsdp:
            self.table = {**self.table, "embed": ("data",)}
        self._axis_sizes = dict(zip(self.mesh.axis_names,
                                    self.mesh.devices.shape))

    def _mesh_axes_for(self, logical: Optional[str]) -> tuple[str, ...]:
        if logical is None:
            return ()
        axes = self.table.get(logical, ())
        return tuple(a for a in axes if a in self._axis_sizes)

    def spec(self, logical_axes: Sequence[Optional[str]],
             shape: Optional[Sequence[int]] = None) -> P:
        """PartitionSpec for dims named by logical axes; if ``shape`` is
        given, non-dividing assignments fall back to replication."""
        entries, used = [], set()
        for i, name in enumerate(logical_axes):
            axes = self._mesh_axes_for(name)
            axes = tuple(a for a in axes if a not in used)
            if shape is not None and axes:
                div = int(np.prod([self._axis_sizes[a] for a in axes]))
                if shape[i] % div != 0:
                    # try progressively shorter prefixes of the axis tuple
                    while axes:
                        axes = axes[:-1]
                        div = int(np.prod([self._axis_sizes[a]
                                           for a in axes])) if axes else 1
                        if axes and shape[i] % div == 0:
                            break
                    if not axes:
                        self.fallbacks.append((tuple(logical_axes), i, name))
            used.update(axes)
            if not axes:
                entries.append(None)
            elif len(axes) == 1:
                entries.append(axes[0])
            else:
                entries.append(tuple(axes))
        while entries and entries[-1] is None:
            entries.pop()
        return P(*entries)

    def sharding(self, logical_axes: Sequence[Optional[str]],
                 shape: Optional[Sequence[int]] = None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(logical_axes, shape))

    def constrain(self, x, *logical_axes):
        """with_sharding_constraint by logical axes (activation hints)."""
        import jax
        return jax.lax.with_sharding_constraint(
            x, self.sharding(logical_axes, x.shape))

    @property
    def data_size(self) -> int:
        return int(np.prod([self._axis_sizes.get(a, 1)
                            for a in ("pod", "data")]))

    @property
    def model_size(self) -> int:
        return self._axis_sizes.get("model", 1)


def logical_spec(*names: Optional[str]) -> tuple:
    """Convenience: declare logical axes of a tensor."""
    return tuple(names)
