from .rules import MeshRules, logical_spec

__all__ = ["MeshRules", "logical_spec"]
