"""Generators for the paper's experimental datasets (§5.1, §6).

K1, K2, K3 are defined in closed form in the paper and reproduced exactly.
The real-world datasets (IMDB top-250, MovieLens, BibSonomy, FrameNet
tri-frames) are not shipped offline; ``*_like`` generators emulate their
published shape statistics (sizes, #tuples, density from the paper's
Table 2 and §5.1) so that the benchmark harness exercises the same regime.
All generators are deterministic given the seed.
"""
from __future__ import annotations

import numpy as np

from ..core.context import PolyadicContext


def k1_dense_cube(n: int = 60) -> PolyadicContext:
    """K1 = (G,M,B, G×M×B \\ {(g,m,b) | g=m=b}),  |I| = n^3 - n (§5.1)."""
    g, m, b = np.meshgrid(np.arange(n), np.arange(n), np.arange(n),
                          indexing="ij")
    triples = np.stack([g.ravel(), m.ravel(), b.ravel()], 1).astype(np.int32)
    keep = ~((triples[:, 0] == triples[:, 1]) &
             (triples[:, 1] == triples[:, 2]))
    return PolyadicContext((n, n, n), triples[keep])


def k2_three_cuboids(n: int = 50) -> PolyadicContext:
    """K2 = three disjoint n^3 cuboids,  |I| = 3·n^3 (§5.1)."""
    blocks = []
    for i in range(3):
        g, m, b = np.meshgrid(np.arange(n), np.arange(n), np.arange(n),
                              indexing="ij")
        t = np.stack([g.ravel() + i * n, m.ravel() + i * n,
                      b.ravel() + i * n], 1)
        blocks.append(t)
    triples = np.concatenate(blocks).astype(np.int32)
    return PolyadicContext((3 * n, 3 * n, 3 * n), triples)


def k3_dense_4d(n: int = 30) -> PolyadicContext:
    """K3 = dense 4-ary cuboid (A1..A4, A1×A2×A3×A4), |I| = n^4 (§5.1).

    The paper's worst case for the reducers: maximal input size and number
    of duplicates; the correct output is the single cluster (A1,A2,A3,A4).
    """
    idx = np.indices((n, n, n, n)).reshape(4, -1).T.astype(np.int32)
    return PolyadicContext((n, n, n, n), idx)


def random_context(sizes, n_tuples: int, seed: int = 0,
                   values: bool = False) -> PolyadicContext:
    """Uniform random context (with optional many-valued float values)."""
    rng = np.random.default_rng(seed)
    cols = [rng.integers(0, s, size=n_tuples, dtype=np.int32) for s in sizes]
    vals = rng.uniform(0, 1000, n_tuples).astype(np.float32) if values else None
    ctx = PolyadicContext(tuple(sizes), np.stack(cols, 1), vals)
    return ctx


def _power_law_ids(rng, n: int, count: int, alpha: float = 1.3):
    p = 1.0 / np.arange(1, n + 1) ** alpha
    p /= p.sum()
    return rng.choice(n, size=count, p=p).astype(np.int32)


def imdb_like(seed: int = 0) -> PolyadicContext:
    """IMDB top-250 regime: 250 movies × ~3k tags × ~20 genres, 3,818
    triples, density ≈ 8.7e-4 (paper Table 2). Tags/genres power-law."""
    rng = np.random.default_rng(seed)
    n_obj, n_tag, n_genre, t = 250, 700, 22, 3818
    movies = rng.integers(0, n_obj, t).astype(np.int32)
    tags = _power_law_ids(rng, n_tag, t)
    genres = _power_law_ids(rng, n_genre, t, alpha=1.0)
    return PolyadicContext((n_obj, n_tag, n_genre),
                           np.stack([movies, tags, genres], 1))


def movielens_like(n_tuples: int = 100_000, seed: int = 0,
                   values: bool = True) -> PolyadicContext:
    """MovieLens regime: users × movies × ratings(1-5 stars) [12]. The
    third mode is the rating bucket as in the paper's tricontext usage;
    ``values`` carries the raw star value for δ-mining."""
    rng = np.random.default_rng(seed)
    n_users, n_movies = 6040, 3952
    users = _power_law_ids(rng, n_users, n_tuples, alpha=1.1)
    movies = _power_law_ids(rng, n_movies, n_tuples, alpha=1.2)
    stars = rng.integers(1, 6, n_tuples).astype(np.int32)
    vals = stars.astype(np.float32) if values else None
    return PolyadicContext((n_users, n_movies, 5),
                           np.stack([users, movies, stars - 1], 1), vals)


def bibsonomy_like(n_tuples: int = 816_197, seed: int = 0,
                   scale: float = 1.0) -> PolyadicContext:
    """BibSonomy regime (paper Table 2): 2,337 users × 67,464 tags ×
    28,920 bookmarks, 816,197 triples, density 1.8e-7. ``scale`` shrinks
    all modes and the tuple count proportionally for CI-sized runs."""
    rng = np.random.default_rng(seed)
    nu = max(2, int(2337 * scale))
    nt = max(2, int(67464 * scale))
    nb = max(2, int(28920 * scale))
    t = max(1, int(n_tuples * scale))
    users = _power_law_ids(rng, nu, t, alpha=1.2)
    tags = _power_law_ids(rng, nt, t, alpha=1.4)
    bookmarks = _power_law_ids(rng, nb, t, alpha=1.1)
    return PolyadicContext((nu, nt, nb),
                           np.stack([users, tags, bookmarks], 1))


def semantic_frames_like(n_tuples: int = 100_000, seed: int = 0
                         ) -> PolyadicContext:
    """FrameNet tri-frame regime of the paper's §6 (subject-verb-object
    triples with DepCC frequencies) — used by the NOAC benchmarks."""
    rng = np.random.default_rng(seed)
    ns, nv, no = 5000, 1200, 5000
    subj = _power_law_ids(rng, ns, n_tuples, alpha=1.3)
    verb = _power_law_ids(rng, nv, n_tuples, alpha=1.5)
    obj = _power_law_ids(rng, no, n_tuples, alpha=1.3)
    freq = np.round(rng.pareto(1.5, n_tuples) * 10 + 1).astype(np.float32)
    return PolyadicContext((ns, nv, no), np.stack([subj, verb, obj], 1), freq)
