"""Data substrate: paper dataset generators + LM token pipeline."""
from .synthetic import (k1_dense_cube, k2_three_cuboids, k3_dense_4d,
                        imdb_like, movielens_like, bibsonomy_like,
                        random_context, semantic_frames_like)
