"""Deterministic, resumable LM token pipeline.

Every batch is a pure function of ``(seed, step)`` — the training state
only needs to carry ``data_step`` (an int) to resume bit-identically after
a crash/restart on any worker count. The synthetic "language" is Zipf
unigrams with injected repeated motifs so a real model's loss actually
decreases (quickstart/train examples assert this).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from ..configs.base import ModelConfig, ShapeConfig


def _rng(seed: int, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence(entropy=seed, spawn_key=(step,)))


@dataclasses.dataclass
class TokenPipeline:
    cfg: ModelConfig
    global_batch: int
    seq_len: int
    seed: int = 0
    motif_len: int = 8
    motif_count: int = 64
    motif_rate: float = 0.5      # fraction of positions covered by motifs

    def __post_init__(self):
        rng = _rng(self.seed, 0)
        v = max(self.cfg.vocab_size - 1, 2)
        self.motifs = rng.integers(
            1, v, size=(self.motif_count, self.motif_len)).astype(np.int32)

    def _tokens(self, step: int) -> np.ndarray:
        rng = _rng(self.seed, step + 1)
        b, s = self.global_batch, self.seq_len
        v = max(self.cfg.vocab_size - 1, 2)
        # Zipf-ish unigram background
        u = rng.random((b, s))
        toks = np.minimum((u ** 3 * v).astype(np.int32) + 1, v)
        # paste motifs over ~motif_rate of the stream
        n_paste = int(b * s * self.motif_rate / self.motif_len)
        rows = rng.integers(0, b, n_paste)
        cols = rng.integers(0, max(s - self.motif_len, 1), n_paste)
        ids = rng.integers(0, self.motif_count, n_paste)
        for r, c, i in zip(rows, cols, ids):
            toks[r, c:c + self.motif_len] = self.motifs[i]
        return toks

    def batch_at(self, step: int) -> dict:
        """Training batch for one step: tokens + next-token labels."""
        toks = self._tokens(step)
        labels = np.concatenate(
            [toks[:, 1:], np.full((self.global_batch, 1), -100, np.int32)],
            axis=1)
        out = {"tokens": toks, "labels": labels}
        if self.cfg.frontend == "patch":
            rng = _rng(self.seed ^ 0xBEEF, step + 1)
            out["patches"] = rng.standard_normal(
                (self.global_batch, self.cfg.frontend_len,
                 self.cfg.frontend_dim)).astype(np.float32)
        if self.cfg.family == "encdec":
            rng = _rng(self.seed ^ 0xF00D, step + 1)
            out["frames"] = rng.standard_normal(
                (self.global_batch, self.seq_len,
                 self.cfg.frontend_dim)).astype(np.float32)
        return out

    def prompts(self, n: int, length: int, step: int = 0) -> list:
        """Synthetic prompts for serving runs (ragged lengths)."""
        rng = _rng(self.seed ^ 0xCAFE, step + 1)
        v = max(self.cfg.vocab_size - 1, 2)
        return [rng.integers(1, v, size=max(1, length - (i % 3))).tolist()
                for i in range(n)]
