"""Packed-key sorting: the one Stage-1/Stage-3 sort path of every engine.

The paper's Hadoop shuffle *is* a sort, and sorting dominates every
engine's runtime.  ``jnp.lexsort`` already lowers to a single
``lax.sort``, but its comparator touches N+1 columns per comparison and
every payload column rides an index-gather round-trip afterwards.  This
module makes the sort hardware-shaped:

* **Bit-width planning** (``plan_mode_key`` / ``plan_context_keys``):
  each mode's lexicographic key — (other columns..., [value-lane,]
  e_k), exactly the order ``pipeline.sort_mode`` sorts by — is laid out
  as bit-fields of one conceptual uint64, entity widths sized
  ``ceil(log2(|A_j|))`` from the context's mode cardinalities.  Every
  mode's key covers all N columns (plus the value lane for many-valued
  contexts), so ``total_bits`` — and therefore ``fits`` — is a property
  of the *context*, not of the mode.

* **Value-lane cardinality pruning** (``value_slots``): by default the
  value lane is the 32-bit order-preserving float encoding, but when
  the caller knows the context's distinct-value *domain* up front
  (batch/many-valued/distributed engines — anything that sees the whole
  value column before packing), the lane stores the value's **rank** in
  the sorted domain instead: ``ceil(log2 n_distinct)`` bits, an
  order-isomorphic code, so every sort order, segment boundary and
  δ-window is unchanged while the radix backend prunes its pass
  schedule to the bits that actually vary (a movielens-like 5-star
  domain is a 3-bit lane — the NOAC key drops from two words to one).
  The streaming engine keeps the float lane: its incremental runs must
  stay mergeable when later chunks introduce unseen values.

* **One packer, two homes**: ``pack_host`` produces the np.uint64 the
  streaming engine merges sorted runs over; ``pack_device`` produces the
  same word as one uint32 (``total_bits`` ≤ 32) or an msb-first
  (hi, lo) uint32 pair — jax runs in 32-bit mode, so the device never
  materialises a real uint64, but ``(hi << 32) | lo == pack_host(...)``
  bit-for-bit.  Host-merged streaming permutations and device sorts
  therefore order identically by construction.

* **Single sort, payloads carried** (``sort_with_payload``): one stable
  sort whose key is the 1–2 packed words — by default the bit-plan-
  pruned LSD radix backend of ``core.radix`` (DESIGN.md §3b), with
  ``backend='lax'`` keeping the one-``lax.sort`` comparison path whose
  payload columns ride as sort operands.  Segment starts and first-
  occurrence flags downstream become 1–2 word comparisons
  (``drop_low_bits`` strips the [value,] e_k suffix to recover the
  subrelation key).

* **Fallback**: a context whose key exceeds 64 bits simply reports
  ``fits=False`` and the pipeline keeps the N+1-column lexsort path
  behind the same API — no engine has a packed-only code path.

Caveat shared with the streaming engine's original host codec: the
order-preserving float32 encoding (``float_sort_bits``) distinguishes
-0.0 from +0.0 and has no defined order for NaNs; value columns are
expected to be finite and normalised (DESIGN.md §3a).  The rank-coded
lane compares -0.0 == +0.0 (like the column lexsort fallback) but still
requires finite values.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

#: ``Field.src`` sentinel for the float-value lane of many-valued keys.
VALUE = -1

_SIGN = 0x80000000
_FULL = 0xFFFFFFFF


# ---------------------------------------------------------------------------
# Order-preserving float32 encoding (host + device, bit-identical)
# ---------------------------------------------------------------------------

def float_sort_bits_host(v: np.ndarray) -> np.ndarray:
    """Order-preserving uint32 encoding of finite float32 values."""
    u = np.ascontiguousarray(v, np.float32).view(np.uint32)
    return u ^ np.where(u & _SIGN, np.uint32(_FULL), np.uint32(_SIGN))


def float_sort_bits(v: jnp.ndarray) -> jnp.ndarray:
    """Device twin of :func:`float_sort_bits_host`."""
    u = jax.lax.bitcast_convert_type(v.astype(jnp.float32), jnp.uint32)
    return u ^ jnp.where((u & jnp.uint32(_SIGN)) != 0,
                         jnp.uint32(_FULL), jnp.uint32(_SIGN))


def float_from_sort_bits(u: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`float_sort_bits` (the encoding is a bijection),
    letting shuffle owners recover value columns from shipped keys."""
    orig = u ^ jnp.where((u & jnp.uint32(_SIGN)) != 0,
                         jnp.uint32(_SIGN), jnp.uint32(_FULL))
    return jax.lax.bitcast_convert_type(orig, jnp.float32)


# ---------------------------------------------------------------------------
# Bit-width planning
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Field:
    """One bit-field of a packed key: tuple column ``src`` (or ``VALUE``)
    at ``offset`` bits from the LSB, ``width`` bits wide."""
    src: int
    offset: int
    width: int


def entity_bits(size: int) -> int:
    """Bits needed for ids 0..size-1 (≥ 1, matching the streaming codec)."""
    return max(1, int(np.ceil(np.log2(max(int(size), 2)))))


def value_lane_bits(value_slots: Optional[int]) -> int:
    """Width of the value lane: rank bits for a known ``value_slots``-sized
    domain, the full float32 sort-bit encoding otherwise."""
    return 32 if value_slots is None else entity_bits(value_slots)


def value_domain_host(values) -> np.ndarray:
    """Sorted distinct float32 values — THE lane-pruning domain (one
    definition, so host packers, engines and benchmarks can never
    disagree on dedup/ordering semantics, e.g. -0.0 == +0.0)."""
    return np.unique(np.asarray(values, np.float32))


@dataclasses.dataclass(frozen=True)
class ModeKeyPlan:
    """Bit layout of mode ``k``'s sort key (msb-first ``fields``)."""
    k: int
    sizes: Tuple[int, ...]
    with_values: bool
    fields: Tuple[Field, ...]
    total_bits: int
    e_bits: int          # width of the trailing e_k field
    seg_shift: int       # bits to drop to recover the subrelation key
    fits: bool           # total_bits <= 64: packed path available
    value_bits: int = 32  # value-lane width (< 32: rank-coded, needs domain)

    @property
    def words(self) -> int:
        """Device words (uint32) holding the key: 1 or 2."""
        return 1 if self.total_bits <= 32 else 2

    @property
    def e_mask(self) -> int:
        return (1 << self.e_bits) - 1

    # -- value-lane encoding ------------------------------------------------

    def value_lane_host(self, values: np.ndarray,
                        domain: Optional[np.ndarray] = None) -> np.ndarray:
        """uint32 lane codes for float32 ``values``: sort bits, or ranks
        in the sorted distinct-value ``domain`` (pruned plans)."""
        if self.value_bits == 32:
            return float_sort_bits_host(values)
        if domain is None:
            raise ValueError("rank-coded value lane needs the domain")
        return np.searchsorted(np.asarray(domain, np.float32),
                               np.asarray(values, np.float32),
                               side="left").astype(np.uint32)

    def value_lane(self, values: jnp.ndarray,
                   domain: Optional[jnp.ndarray] = None) -> jnp.ndarray:
        """Device twin of :meth:`value_lane_host` (bit-identical)."""
        if self.value_bits == 32:
            return float_sort_bits(values)
        if domain is None:
            raise ValueError("rank-coded value lane needs the domain")
        return jnp.searchsorted(domain.astype(jnp.float32),
                                values.astype(jnp.float32),
                                side="left").astype(jnp.uint32)

    # -- packing ------------------------------------------------------------

    def pack_host(self, rows: np.ndarray,
                  values: Optional[np.ndarray] = None,
                  domain: Optional[np.ndarray] = None) -> np.ndarray:
        """(L, N) int32 rows [+ (L,) float32 values] -> (L,) uint64 keys."""
        key = np.zeros(rows.shape[0], np.uint64)
        lane = (self.value_lane_host(values, domain)
                if self.with_values else None)
        for f in self.fields:
            v = lane if f.src == VALUE else rows[:, f.src].astype(np.uint32)
            key = (key << np.uint64(f.width)) | v.astype(np.uint64)
        return key

    def pack_device(self, tuples: jnp.ndarray,
                    values: Optional[jnp.ndarray] = None,
                    domain: Optional[jnp.ndarray] = None
                    ) -> Tuple[jnp.ndarray, ...]:
        """Device packing: msb-first uint32 words ((hi, lo) or (lo,)).

        ``(hi << 32) | lo`` equals :meth:`pack_host` bit-for-bit; all
        shifts are static so this lowers to a handful of fused ALU ops
        (plus one small binary search for rank-coded value lanes)."""
        t = tuples.shape[0]
        lo = jnp.zeros((t,), jnp.uint32)
        hi = jnp.zeros((t,), jnp.uint32)
        lane = self.value_lane(values, domain) if self.with_values else None
        for f in self.fields:
            v = lane if f.src == VALUE else tuples[:, f.src].astype(jnp.uint32)
            if f.offset < 32:
                lo = lo | (v << f.offset if f.offset else v)
                if f.offset + f.width > 32:
                    hi = hi | (v >> (32 - f.offset))
            else:
                hi = hi | (v << (f.offset - 32) if f.offset > 32 else v)
        return (hi, lo) if self.words == 2 else (lo,)

    def extract_entity(self, words: Sequence[jnp.ndarray]) -> jnp.ndarray:
        """Recover the e_k column from packed words (e_k is the LSB field)."""
        return (words[-1] & jnp.uint32(self.e_mask)).astype(jnp.int32)

    def extract_values(self, words: Sequence[jnp.ndarray],
                       domain: Optional[jnp.ndarray] = None) -> jnp.ndarray:
        """Recover the float32 value column from packed words (many-valued
        plans only; the value lane sits at bit offset ``e_bits``): sort
        bits invert bijectively, rank lanes gather from the domain."""
        if not self.with_values:
            raise ValueError("plan has no value lane")
        if self.value_bits == 32:
            s = self.e_bits                 # 1 <= s <= 31, value needs 2 words
            u = (words[-1] >> s) | (words[-2] << (32 - s))
            return float_from_sort_bits(u)
        if domain is None:
            raise ValueError("rank-coded value lane needs the domain")
        from .radix import extract_digit
        rank = extract_digit(words, self.e_bits, self.value_bits)
        return domain.astype(jnp.float32)[rank.astype(jnp.int32)]

    def delta_query_words(self, words: Sequence[jnp.ndarray],
                          lane: jnp.ndarray) -> Tuple[jnp.ndarray, ...]:
        """Each key's words with the value lane replaced by ``lane``
        (uint32 codes from :meth:`value_lane`'s encoding) and e_k zeroed
        — the δ-window *lower-bound* query key (OR ``e_mask`` onto the
        last word for the upper bound).  Because the subrelation prefix
        leads the key, a global search with these queries self-clamps to
        the tuple's own segment."""
        if not self.with_values:
            raise ValueError("plan has no value lane")
        eb, ss = self.e_bits, self.seg_shift
        part_lo = lane << eb                # uint32 keeps the low word
        part_hi = lane >> (32 - eb)         # 0 unless the lane spans words
        if len(words) == 1:
            keep = jnp.uint32(~((1 << ss) - 1) & 0xFFFFFFFF)
            return ((words[0] & keep) | part_lo,)
        hi, lo = words
        if ss >= 32:                        # value+e tail fills the low word
            keep = jnp.uint32(~((1 << (ss - 32)) - 1) & 0xFFFFFFFF)
            return ((hi & keep) | part_hi, part_lo)
        keep = jnp.uint32(~((1 << ss) - 1) & 0xFFFFFFFF)
        return (hi, (lo & keep) | part_lo)


def plan_mode_key(sizes: Sequence[int], k: int, with_values: bool,
                  value_slots: Optional[int] = None) -> ModeKeyPlan:
    """Lay out mode ``k``'s sort key (others..., [value,] e_k) msb-first.

    ``value_slots`` — the context's distinct-value count, when known —
    prunes the value lane to rank width (see module docstring)."""
    sizes = tuple(int(s) for s in sizes)
    bits = [entity_bits(s) for s in sizes]
    vb = value_lane_bits(value_slots)
    order = [j for j in range(len(sizes)) if j != k]
    order += ([VALUE] if with_values else []) + [k]
    widths = [vb if j == VALUE else bits[j] for j in order]
    total = sum(widths)
    fields, off = [], total
    for src, w in zip(order, widths):
        off -= w
        fields.append(Field(src, off, w))
    return ModeKeyPlan(
        k=k, sizes=sizes, with_values=with_values, fields=tuple(fields),
        total_bits=total, e_bits=bits[k],
        seg_shift=bits[k] + (vb if with_values else 0), fits=total <= 64,
        value_bits=vb)


def plan_context_keys(sizes: Sequence[int], with_values: bool,
                      value_slots: Optional[int] = None
                      ) -> Tuple[ModeKeyPlan, ...]:
    """One plan per mode.  All plans share ``total_bits``/``fits`` (every
    mode's key covers all columns), so ``plans[0].fits`` decides the
    context's sort path."""
    return tuple(plan_mode_key(sizes, k, with_values, value_slots)
                 for k in range(len(sizes)))


# ---------------------------------------------------------------------------
# Device-side sorting primitives
# ---------------------------------------------------------------------------

def drop_low_bits(words: Tuple[jnp.ndarray, ...],
                  shift: int) -> Tuple[jnp.ndarray, ...]:
    """Words representing ``key >> shift`` (msb-first; order-preserving),
    used to compare subrelation keys without re-materialising columns."""
    if shift == 0:
        return words
    if len(words) == 1:
        return (words[0] >> shift,)
    hi, lo = words
    if shift == 32:
        return (hi,)
    if shift > 32:
        return (hi >> (shift - 32),)
    return (hi, lo >> shift)


def sort_with_payload(words: Sequence[jnp.ndarray],
                      payloads: Sequence[jnp.ndarray],
                      backend: str = "radix",
                      live_bits: Optional[int] = None,
                      use_pallas: bool = False):
    """Stable sort keyed on the packed words with payload columns
    carried along.  The default backend is the bit-plan-pruned LSD
    radix of ``core.radix`` (``live_bits`` prunes the pass schedule to
    the key's live bit count; ``use_pallas`` selects its histogram-
    kernel formulation).  ``backend='lax'`` keeps the one-``lax.sort``
    comparison path, whose comparator reads 1-2 words and carries the
    payloads as sort operands.  Both are bit-identical, permutation
    included (``tests/test_radix_property.py``).

    Returns (sorted_words, sorted_payloads), both tuples."""
    if backend == "radix":
        from . import radix as RX
        return RX.sort_with_payload_radix(
            words, payloads, live_bits or 32 * len(words), use_pallas)
    nw = len(words)
    out = jax.lax.sort(tuple(words) + tuple(payloads), num_keys=nw,
                       is_stable=True)
    return out[:nw], out[nw:]


def search_words(s_words: Sequence[jnp.ndarray],
                 q_words: Sequence[jnp.ndarray], upper: bool) -> jnp.ndarray:
    """Vectorised binary search over sorted packed keys.  Returns, per
    query, the first index whose key is > the query (``upper``) or >= it
    (lower bound); T if none.  Keys compare lexicographically over the
    msb-first word tuples."""
    t = s_words[0].shape[0]
    iters = max(1, int(np.ceil(np.log2(max(t, 2)))) + 1)
    lo = jnp.zeros(q_words[0].shape, jnp.int32)
    hi = jnp.full(q_words[0].shape, t, jnp.int32)
    for _ in range(iters):
        mid = (lo + hi) // 2
        midc = jnp.clip(mid, 0, t - 1)
        if len(s_words) == 2:
            dh, dl = s_words[0][midc], s_words[1][midc]
            qh, ql = q_words
            go_right = ((dh < qh) | ((dh == qh) & (dl <= ql)) if upper
                        else (dh < qh) | ((dh == qh) & (dl < ql)))
        else:
            d, q = s_words[0][midc], q_words[0]
            go_right = (d <= q) if upper else (d < q)
        go_right = go_right & (lo < hi)
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(go_right | (lo >= hi), hi, mid)
    return lo
