"""Core: the paper's primary contribution — prime OAC / multimodal
clustering engines (batch, distributed, streaming, many-valued), all
composed from the shared Stage-1/2/3 pipeline (``core.pipeline``) and
selected through the engine registry: ``mine(ctx, backend=..., variant=...)``."""
from .multimodal import (BatchMiner, DistributedMiner, StreamingMiner,
                         NOACMiner, MiningResult, DistributedResult,
                         NOACResult, PipelineResult, PolyadicContext,
                         tricontext, from_named_triples, pad_tuples,
                         pad_values, make_miner, mine, MineRun,
                         available_engines, resolve_engine)
