"""Core: the paper's primary contribution — prime OAC / multimodal
clustering engines (batch, distributed, streaming, many-valued)."""
from .multimodal import (BatchMiner, DistributedMiner, StreamingMiner,
                         NOACMiner, MiningResult, DistributedResult,
                         NOACResult, PolyadicContext, tricontext,
                         from_named_triples, pad_tuples, make_miner)
