"""Distributed three-stage clustering (the paper's M/R algorithm mapped
onto a TPU mesh with ``shard_map``; DESIGN.md §3/§7).

Both the prime/multimodal variant and the many-valued NOAC variant
(δ/ρ_min/minsup) run here: the per-shard compute is the shared pipeline
of ``core.pipeline`` with the variant's component operator plugged in,
so the distribution strategy is written exactly once.

Tuples are block-partitioned (uniform by construction — this removes the
paper's hash-skew problem) over one or more mesh axes. Two merge
strategies, mirroring the centralise-vs-replicate discussion in the
paper's §1:

* ``replicate`` — all-gather the (small) tuple table over the data axes and
  let every shard run the batch pipeline on the full table, keeping only its
  own block's outputs. Communication: one all-gather of ``T×N`` int32 (plus
  ``T`` float32 values for NOAC); compute is duplicated ×P. This is the
  paper's "data replication" choice, executed as a log-depth ICI collective
  instead of HDFS replication-factor-3.

* ``shuffle`` — the faithful M/R shuffle. Stage 1 routes each tuple's
  ⟨subrelation, e_k[, value]⟩ record to the key's *owner shard* with a
  fixed-capacity ``all_to_all`` (MoE-dispatch pattern); owners
  sort/segment/hash their key ranges — running the variant's component
  operator (whole segment, or δ-range binary searches) — and answer with
  ⟨signature, cardinality⟩ per record (Stage 2 — 16 bytes instead of the
  paper's whole-cumulus shuffle). Stage 3 deduplicates and counts
  generating tuples on 8-byte cluster signatures gathered over the mesh.
  Skew shows up as capacity overflow and is *reported*, not silently
  dropped (a reducer-OOM analogue).

  When the context's sort key fits 64 bits (``core.keys``), senders ship
  the *pre-packed* key words (8 bytes/record instead of (N+1)×4) and
  owners sort the received words directly — entity ids and value columns
  are recovered from the key's bit-fields, so owners never re-pack or
  re-derive the shuffle key.  Wider keys fall back to the original
  column records behind the same API.

Both strategies return bit-identical signatures/densities to the
single-shard ``BatchMiner``/``NOACMiner`` (same hash vectors), which is
what the tests assert.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from . import keys as K
from . import pipeline as PL
from . import radix as RX
from . import runs as RS

Axis = tuple[str, ...]


@dataclasses.dataclass
class DistributedResult:
    """Global per-tuple outputs (sharded over the data axes)."""
    sig_lo: jnp.ndarray
    sig_hi: jnp.ndarray
    is_unique: jnp.ndarray
    gen_count: jnp.ndarray
    volume: jnp.ndarray
    density: jnp.ndarray
    keep: jnp.ndarray
    cardinalities: jnp.ndarray   # (N, T) distinct |component_k| per tuple
    n_clusters: jnp.ndarray      # scalar, replicated
    overflow: jnp.ndarray        # scalar: dropped records (0 == exact)

jax.tree_util.register_dataclass(
    DistributedResult,
    data_fields=["sig_lo", "sig_hi", "is_unique", "gen_count", "volume",
                 "density", "keep", "cardinalities", "n_clusters", "overflow"],
    meta_fields=[])


def _hash_columns(cols: Sequence[jnp.ndarray], salt: int) -> jnp.ndarray:
    """uint32 mix of int32 id columns (key → owner-shard hashing)."""
    h = jnp.full(cols[0].shape, jnp.uint32(salt))
    for c in cols:
        h = (h ^ c.astype(jnp.uint32)) * jnp.uint32(0x9E3779B1)
        h = h ^ (h >> 15)
    return h


def _range_partition(words, plan: K.ModeKeyPlan, axes, n_shards: int,
                     capacity: int, fallback_owner: jnp.ndarray):
    """Owner shard per record from the radix plan's *top-digit*
    histogram: the all-reduced 256-bucket histogram of the subrelation
    prefix's top 8 live bits — the same primitive the radix backend's
    sort is built on, here applied to the pre-shuffle keys — yields
    balanced contiguous key ranges (boundary of shard s at the digit
    where the cumulative count crosses s/n_shards of the total), so
    owners receive contiguous key ranges instead of hash-scattered
    ones.

    Two skew escapes fall back to ``fallback_owner`` (the hash
    partition, which spreads by the full subrelation key); both tests
    are all-reduced so every shard takes the same branch (a key's
    records must all reach one owner):

    * a single bucket exceeding a fair shard share (range cuts can only
      land on digit boundaries, so no contiguous assignment balances —
      e.g. power-law ids concentrating in top digit 0);
    * a source→owner *link* exceeding the dispatch ``capacity``: with
      shard-locally key-clustered data (e.g. block-sharded pre-sorted
      rows) a globally balanced range map still sends one shard's whole
      block to one owner, which hash partitioning never stresses."""
    # the digit may only read *subrelation* bits (above seg_shift) —
    # cutting below them would split a key segment across owners
    top_w = min(RX.HIST_DIGIT_BITS, plan.total_bits - plan.seg_shift)
    dig = RX.extract_digit(words, plan.total_bits - top_w, top_w)
    nb = 1 << top_w
    hist = jnp.zeros((nb,), jnp.int32).at[dig.astype(jnp.int32)].add(1)
    hist = jax.lax.psum(hist, axes)
    cum_before = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(hist, dtype=jnp.int32)[:-1]])
    total = jnp.maximum(cum_before[-1] + hist[-1], 1)
    # boundary math in float32: cum*n_shards overflows int32 at scale,
    # and any digit->shard function is correct (owners sort their own
    # ranges), so rounding at a boundary is harmless
    shard_of_digit = jnp.clip(
        (cum_before.astype(jnp.float32) * jnp.float32(n_shards)
         / total.astype(jnp.float32)).astype(jnp.int32),
        0, n_shards - 1)
    range_owner = shard_of_digit[dig.astype(jnp.int32)]
    local_link = jnp.zeros((n_shards,), jnp.int32).at[range_owner].add(1)
    link_max = jax.lax.pmax(local_link.max(), axes)
    skewed = ((hist.max() > total // jnp.int32(n_shards))
              | (link_max > jnp.int32(capacity)))
    return jnp.where(skewed, fallback_owner, range_owner)


# ---------------------------------------------------------------------------
# Shuffle strategy internals (per shard_map body)
# ---------------------------------------------------------------------------

def _dispatch(records: jnp.ndarray, owner: jnp.ndarray, n_shards: int,
              capacity: int):
    """Pack ``records`` (L, W) into a (n_shards*capacity, W) send buffer by
    owner shard, plus validity mask, slot handle per record and overflow."""
    l = records.shape[0]
    # position of each record within its owner's group
    order = jnp.argsort(owner, stable=True)
    sorted_owner = owner[order]
    pos_in_group = jnp.arange(l) - jnp.searchsorted(sorted_owner, sorted_owner,
                                                    side="left")
    rank = jnp.zeros((l,), jnp.int32).at[order].set(pos_in_group.astype(jnp.int32))
    ok = rank < capacity
    nslots = n_shards * capacity
    # overflowed records go to a trash slot one past the end
    slot_safe = jnp.where(ok, owner * capacity + rank, nslots)
    buf = jnp.zeros((nslots + 1, records.shape[1]), records.dtype)
    buf = buf.at[slot_safe].set(records)[:nslots]
    valid = jnp.zeros((nslots + 1,), bool).at[slot_safe].set(ok)[:nslots]
    overflow = (~ok).sum()
    return buf, valid, slot_safe, ok, overflow


def _sorted_components(w_lo_raw, w_hi_raw, first_occ, seg_flag, s_vals,
                       delta: Optional[float], use_pallas: bool):
    """Per sorted position: (sig_lo, sig_hi, distinct) of the position's
    component — the whole key segment (prime) or the δ-window inside it —
    as boundary differences of the fused masked prefix sums (the same
    reduction the single-shard pipeline runs)."""
    pref_lo, pref_hi, pref_cnt = PL.masked_prefix(w_lo_raw, w_hi_raw,
                                                  first_occ, use_pallas)
    a, b = PL.segment_bounds(seg_flag)
    if delta is not None:
        lo_idx = PL.bsearch(s_vals, a, b, s_vals - jnp.float32(delta),
                            leq=False)
        hi_idx = PL.bsearch(s_vals, a, b, s_vals + jnp.float32(delta),
                            leq=True)
        a, b = lo_idx, hi_idx
    return pref_lo[b] - pref_lo[a], pref_hi[b] - pref_hi[a], \
        pref_cnt[b] - pref_cnt[a]


def _owner_stage(recv: jnp.ndarray, rvalid: jnp.ndarray, n_other: int,
                 r_lo: jnp.ndarray, r_hi: jnp.ndarray,
                 delta: Optional[float], use_pallas: bool = False):
    """Owner-side Reduce-1 (column-record fallback): segment received
    ⟨key, e[, value]⟩ records and run the variant's component operator,
    producing per-record (set-signature, distinct cardinality,
    tuple-first flag)."""
    big = jnp.int32(np.iinfo(np.int32).max)
    key_cols = [jnp.where(rvalid, recv[:, j], big) for j in range(n_other)]
    e_col = jnp.where(rvalid, recv[:, n_other], big)
    l = recv.shape[0]
    if delta is not None:
        vals = jax.lax.bitcast_convert_type(recv[:, n_other + 1], jnp.float32)
        vals = jnp.where(rvalid, vals, jnp.float32(np.inf))
        perm = PL.lex_perm(key_cols + [vals, e_col])
    else:
        vals = None
        perm = PL.lex_perm(key_cols + [e_col])
    s_keys = [c[perm] for c in key_cols]
    s_e = e_col[perm]
    s_valid = rvalid[perm]
    seg_flag = PL.segment_starts(s_keys)
    s_vals = vals[perm] if vals is not None else None
    first_occ = PL.segment_starts(
        s_keys + ([s_vals] if s_vals is not None else []) + [s_e]) & s_valid
    e_safe = jnp.where(s_valid, s_e, 0)
    sig_lo, sig_hi, distinct = _sorted_components(
        r_lo[e_safe], r_hi[e_safe], first_occ, seg_flag, s_vals, delta,
        use_pallas)
    inv = jnp.zeros((l,), jnp.int32).at[perm].set(
        jnp.arange(l, dtype=jnp.int32))
    return sig_lo[inv], sig_hi[inv], distinct[inv], first_occ[inv]


def _validity_words(words, inval: jnp.ndarray, total_bits: int):
    """The key words with the validity flag folded in as one extra MSB
    (live bit ``total_bits``), so the owner sort runs as a single
    (total_bits+1)-bit radix instead of a variadic comparison sort."""
    if total_bits + 1 <= 32:
        return (words[-1] | (inval << total_bits),)
    hi = words[0] if len(words) == 2 else jnp.zeros_like(words[-1])
    return (hi | (inval << (total_bits - 32)), words[-1])


def _owner_stage_packed(recv: jnp.ndarray, rvalid: jnp.ndarray,
                        plan: K.ModeKeyPlan, r_lo: jnp.ndarray,
                        r_hi: jnp.ndarray, delta: Optional[float],
                        use_pallas: bool = False,
                        sort_backend: str = "radix",
                        value_domain=None):
    """Owner-side Reduce-1 over *pre-packed* key words: one stable sort
    keyed on (validity, key words) with the permutation carried as a
    payload; entity ids and value columns are bit-field extractions from
    the shipped key, so owners never re-pack.  The radix backend folds
    the validity flag into the key as one extra MSB (falling back to
    ``lax.sort`` for exactly-64-bit keys, where the flag has no room)."""
    l = recv.shape[0]
    words = tuple(recv[:, i] for i in range(recv.shape[1]))
    inval = (~rvalid).astype(jnp.uint32)   # invalid slots sort last
    iota = jnp.arange(l, dtype=jnp.int32)
    if sort_backend == "radix" and plan.total_bits + 1 <= 64:
        ext = _validity_words(words, inval, plan.total_bits)
        perm = RX.radix_sort_perm(ext, plan.total_bits + 1, use_pallas)
        s_inval = inval[perm]
        s_words = tuple(w[perm] for w in words)
        s_valid = rvalid[perm]
    else:
        out = jax.lax.sort((inval,) + words + (rvalid, iota),
                           num_keys=1 + len(words), is_stable=True)
        s_inval, s_words = out[0], tuple(out[1:1 + len(words)])
        s_valid, perm = out[-2], out[-1]
    seg_flag = PL.segment_starts(
        [s_inval] + list(K.drop_low_bits(s_words, plan.seg_shift)))
    first_occ = PL.segment_starts([s_inval] + list(s_words)) & s_valid
    e_safe = jnp.where(s_valid, plan.extract_entity(s_words), 0)
    s_vals = (plan.extract_values(s_words, domain=value_domain)
              if delta is not None else None)
    sig_lo, sig_hi, distinct = _sorted_components(
        r_lo[e_safe], r_hi[e_safe], first_occ, seg_flag, s_vals, delta,
        use_pallas)
    inv = jnp.zeros((l,), jnp.int32).at[perm].set(iota)
    return sig_lo[inv], sig_hi[inv], distinct[inv], first_occ[inv]


def _shuffle_mode(tuples, values, k, axes, n_shards, capacity, r_lo, r_hi,
                  delta, plan: Optional[K.ModeKeyPlan] = None,
                  use_pallas: bool = False, sort_backend: str = "radix",
                  value_domain=None):
    """Stages 1+2 of the M/R algorithm for one mode over ``axes``.

    With a fitting ``plan``, records on the wire are the packed key
    words (8 bytes each) and owners are key *ranges* balanced by the
    radix top-digit histogram; otherwise the original column records,
    hash-partitioned."""
    n = tuples.shape[1]
    others = [tuples[:, j] for j in range(n) if j != k]
    hash_owner = (_hash_columns(others, 0xA11CE + k) %
                  jnp.uint32(n_shards)).astype(jnp.int32)
    if plan is not None and plan.fits:
        words = plan.pack_device(tuples, values, domain=value_domain)
        owner = (_range_partition(words, plan, axes, n_shards, capacity,
                                  hash_owner)
                 if sort_backend == "radix" else hash_owner)
        records = jnp.stack(words, axis=1)
    else:
        plan = None
        owner = hash_owner
        cols = others + [tuples[:, k]]
        if delta is not None:
            cols = cols + [jax.lax.bitcast_convert_type(values, jnp.int32)]
        records = jnp.stack(cols, axis=1)
    buf, valid, slot, ok, overflow = _dispatch(records, owner, n_shards,
                                               capacity)
    recv = jax.lax.all_to_all(buf, axes, 0, 0, tiled=True)
    rvalid = jax.lax.all_to_all(valid.astype(jnp.int32), axes, 0, 0,
                                tiled=True).astype(bool)
    if plan is not None:
        sig_lo, sig_hi, card, tfirst = _owner_stage_packed(
            recv, rvalid, plan, r_lo, r_hi, delta, use_pallas,
            sort_backend, value_domain)
    else:
        sig_lo, sig_hi, card, tfirst = _owner_stage(
            recv, rvalid, n - 1, r_lo, r_hi, delta, use_pallas)
    resp = jnp.stack([sig_lo, sig_hi, card.astype(jnp.uint32),
                      tfirst.astype(jnp.uint32)], axis=1)
    resp = jax.lax.all_to_all(resp, axes, 0, 0, tiled=True)
    got = resp[slot]   # (L, 4) in original record order (garbage if !ok)
    return (got[:, 0], got[:, 1], got[:, 2].astype(jnp.int32),
            got[:, 3].astype(bool), ok, overflow)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

class DistributedMiner:
    """Multi-device clustering over a mesh — prime *and* NOAC variants.

    Args:
      sizes: mode cardinalities.
      mesh: jax Mesh containing ``axes``.
      axes: data-parallel mesh axis name(s) the tuple table is sharded over.
      theta: minimal density threshold (paper Alg. 7 θ; prime variant).
      strategy: 'replicate' | 'shuffle'.
      capacity_factor: shuffle per-destination buffer slack (≥1).
      delta: many-valued δ — switches the engine to the NOAC variant.
      rho_min: NOAC minimal density (plays θ's role).
      minsup: NOAC minimal per-mode cardinality.
      packed: packed-key sort path (None: auto when the key fits 64 bits;
        False: column lexsort baseline).
      sort_backend: packed word-sort algorithm ('radix' default | 'lax';
        'lexsort' forces the column path).
      use_pallas: fused Pallas segment reductions (None: on TPU only).
    """

    def __init__(self, sizes: Sequence[int], mesh, axes="data",
                 theta: float = 0.0, strategy: str = "replicate",
                 capacity_factor: float = 2.0, seed: int = 0x5EED,
                 max_retries: int = 4, delta: Optional[float] = None,
                 rho_min: float = 0.0, minsup: int = 0,
                 packed: Optional[bool] = None,
                 sort_backend: Optional[str] = None,
                 use_pallas: Optional[bool] = None,
                 prune_values: bool = True,
                 window_budget: Optional[int] = None):
        self.sizes = tuple(int(s) for s in sizes)
        self.prune_values = bool(prune_values)
        #: shared streaming unit (DESIGN.md §3c): windows the incremental
        #: serving snapshot's device pipeline and rounds the shuffle's
        #: per-link dispatch capacity up to whole windows
        self.window_budget = (None if window_budget is None
                              else int(window_budget))
        self.mesh = mesh
        self.axes: Axis = (axes,) if isinstance(axes, str) else tuple(axes)
        self.delta = None if delta is None else float(delta)
        if self.delta is not None and self.delta < 0:
            raise ValueError(f"delta must be >= 0, got {self.delta}")
        self.theta = float(rho_min) if self.delta is not None else float(theta)
        self.minsup = int(minsup)
        self.strategy = strategy
        self.capacity_factor = float(capacity_factor)
        self.max_retries = int(max_retries)
        self.n_shards = int(np.prod([mesh.shape[a] for a in self.axes]))
        self.packed = packed
        self.sort_backend = sort_backend
        self.key_plans = K.plan_context_keys(self.sizes,
                                             with_values=delta is not None)
        self.resolved_sort_backend = RX.resolve_sort_backend(
            sort_backend, packed, self.key_plans[0].fits)
        self.packed_active = self.resolved_sort_backend != "lexsort"
        from ..kernels import ops as kops
        self.use_pallas = (kops.on_tpu() if use_pallas is None
                           else bool(use_pallas))
        vecs = PL.mode_hash_vectors(self.sizes, seed)
        self._lo = [jnp.asarray(lo) for lo, _ in vecs]
        self._hi = [jnp.asarray(hi) for _, hi in vecs]
        if strategy not in ("replicate", "shuffle"):
            raise ValueError(strategy)
        self._fn = None
        self._t_global = None
        # incremental snapshot state (per-shard run stores, DESIGN.md §4)
        self._stores = None
        self._fn_perms = None
        self._t_perms = None
        #: None = auto (runs maintained whenever the key fits); False =
        #: log-only stores, every snapshot re-sorts on device (the
        #: benchmark baseline / memory-lean ingestion)
        self.stream_incremental: Optional[bool] = None
        self.stream_stats = {"snapshots": 0, "full_resorts": 0,
                             "merged_rows": 0, "chunk_sorted_rows": 0,
                             "tombstoned_rows": 0,
                             "incremental": self.key_plans[0].fits}
        # snapshot versioning (serve/service.py): mutating stream calls
        # bump ``stream_version``; snapshots record the version covered
        self.stream_version = 0
        self.snapshot_stream_version = 0
        # per-snapshot dirty-signature tracking (serve delta index);
        # off by default — it syncs the signature lanes to host.  Only
        # ``serving_snapshot`` notes sigs: it is the serving path, and
        # the only one whose result carries the full-table lanes.
        self.track_dirty_sigs = False
        self.last_kept_sigs: Optional[np.ndarray] = None
        self.last_dirty_sigs = 0
        # single-device serving pipeline (full PipelineResult with
        # component windows), compiled lazily per padded capacity
        self._serve_fn = None

    # -- shard bodies -------------------------------------------------------

    def _slice_block(self, res, tl):
        """This shard's block of a full-table ``PipelineResult`` as the
        ``DistributedResult`` both replicate bodies return."""
        shard_id = jax.lax.axis_index(self.axes)
        sl = jax.lax.dynamic_slice_in_dim
        start = shard_id * tl
        return DistributedResult(
            sig_lo=sl(res.sig_lo, start, tl),
            sig_hi=sl(res.sig_hi, start, tl),
            is_unique=sl(res.is_unique, start, tl),
            gen_count=sl(res.gen_count, start, tl),
            volume=sl(res.volume, start, tl),
            density=sl(res.density, start, tl),
            keep=sl(res.keep, start, tl),
            cardinalities=sl(res.cardinalities, start, tl, axis=1),
            n_clusters=res.is_unique.sum(),
            overflow=jnp.int32(0))

    def _body_replicate(self, tuples, values, vdom, lo, hi):
        axes = self.axes
        full = jax.lax.all_gather(tuples, axes, tiled=True)
        vfull = (jax.lax.all_gather(values, axes, tiled=True)
                 if self.delta is not None else None)
        res = PL.mine_tuples(full, lo, hi, values=vfull, delta=self.delta,
                             theta=self.theta, minsup=self.minsup,
                             packed=self.packed,
                             sort_backend=self.sort_backend,
                             use_pallas=self.use_pallas,
                             value_domain=vdom if vdom.shape[0] else None)
        return self._slice_block(res, tuples.shape[0])

    def _body_shuffle(self, tuples, values, vdom, lo, hi):
        axes, nsh = self.axes, self.n_shards
        tl, n = tuples.shape
        capacity = max(1, int(np.ceil(tl / nsh * self.capacity_factor)))
        if self.window_budget:
            # per-link batches ship in whole windows of the shared plan
            # (capacity only sizes the dispatch buffers / overflow check,
            # so rounding up never changes a mined bit)
            wb = int(self.window_budget)
            capacity = -(-capacity // wb) * wb
        # rebuild the plans with the (replicated) value domain's slot
        # count — vdom is empty when pruning is off, restoring the
        # 32-bit float lane
        vdom_opt = vdom if vdom.shape[0] else None
        plans = K.plan_context_keys(
            self.sizes, with_values=self.delta is not None,
            value_slots=None if vdom_opt is None else vdom_opt.shape[0])
        # resolve from the PRUNED plans: a key that only fits thanks to
        # the rank-coded lane still takes the packed path
        backend = RX.resolve_sort_backend(self.sort_backend, self.packed,
                                          plans[0].fits)
        packed_active = backend != "lexsort"
        per_lo, per_hi, cards = [], [], []
        overflow = jnp.int32(0)
        tuple_first = None
        ok_all = jnp.ones((tl,), bool)
        for k in range(n):
            slo, shi, card, tfirst, ok, ovf = _shuffle_mode(
                tuples, values, k, axes, nsh, capacity, lo[k], hi[k],
                self.delta,
                plan=plans[k] if packed_active else None,
                use_pallas=self.use_pallas,
                sort_backend=backend,
                value_domain=vdom_opt)
            per_lo.append(slo)
            per_hi.append(shi)
            cards.append(card)
            overflow = overflow + ovf.astype(jnp.int32)
            ok_all = ok_all & ok
            if k == 0:
                tuple_first = tfirst
        sig_lo, sig_hi = PL.mix_signatures(per_lo, per_hi)
        volume = jnp.ones((tl,), jnp.float32)
        for c in cards:
            volume = volume * c.astype(jnp.float32)
        # Stage 3 on gathered signatures (12 bytes/tuple on the wire).
        g_lo = jax.lax.all_gather(sig_lo, axes, tiled=True)
        g_hi = jax.lax.all_gather(sig_hi, axes, tiled=True)
        g_tf = jax.lax.all_gather(tuple_first, axes, tiled=True)
        s3_backend = RX.resolve_sort_backend(self.sort_backend, self.packed,
                                             True)
        gen_of, is_unique = PL.stage3_dedup(g_lo, g_hi, g_tf,
                                            packed=s3_backend != "lexsort",
                                            sort_backend=s3_backend,
                                            use_pallas=self.use_pallas)
        shard_id = jax.lax.axis_index(axes)
        sl = jax.lax.dynamic_slice_in_dim
        start = shard_id * tl
        gen_l = sl(gen_of, start, tl)
        uniq_l = sl(is_unique, start, tl)
        density = gen_l.astype(jnp.float32) / jnp.maximum(volume, 1.0)
        keep = uniq_l & (density >= jnp.float32(self.theta))
        if self.minsup:
            for c in cards:
                keep = keep & (c >= self.minsup)
        overflow = jax.lax.psum(overflow, axes)
        return DistributedResult(
            sig_lo=sig_lo, sig_hi=sig_hi, is_unique=uniq_l, gen_count=gen_l,
            volume=volume, density=density, keep=keep,
            cardinalities=jnp.stack(cards), n_clusters=is_unique.sum(),
            overflow=overflow)

    def _body_replicate_perms(self, tuples, values, perms, lo, hi):
        """Replicate-strategy body with *precomputed* global per-mode
        permutations (replicated input): the incremental snapshot path —
        Stage 1's sorts are skipped entirely, everything downstream is
        the stock pipeline."""
        axes = self.axes
        full = jax.lax.all_gather(tuples, axes, tiled=True)
        vfull = (jax.lax.all_gather(values, axes, tiled=True)
                 if self.delta is not None else None)
        res = PL.mine_tuples(full, lo, hi, values=vfull, delta=self.delta,
                             theta=self.theta, minsup=self.minsup,
                             perms=perms, packed=self.packed,
                             sort_backend=self.sort_backend,
                             use_pallas=self.use_pallas)
        return self._slice_block(res, tuples.shape[0])

    # -- public -------------------------------------------------------------

    def _out_specs(self):
        data_spec = P(self.axes)
        card_spec = P(None, self.axes)
        return DistributedResult(
            sig_lo=data_spec, sig_hi=data_spec, is_unique=data_spec,
            gen_count=data_spec, volume=data_spec, density=data_spec,
            keep=data_spec, cardinalities=card_spec, n_clusters=P(),
            overflow=P())

    def _build(self, t_global: int):
        body = (self._body_replicate if self.strategy == "replicate"
                else self._body_shuffle)
        fn = PL.shard_map(body, mesh=self.mesh,
                          in_specs=(P(self.axes, None), P(self.axes),
                                    P(), P(), P()),
                          out_specs=self._out_specs())
        return jax.jit(fn)

    def _build_perms(self):
        fn = PL.shard_map(self._body_replicate_perms, mesh=self.mesh,
                          in_specs=(P(self.axes, None), P(self.axes),
                                    P(), P(), P()),
                          out_specs=self._out_specs())
        return jax.jit(fn)

    def _coerce(self, tuples, values):
        tuples = jnp.asarray(tuples, jnp.int32)
        if values is None:
            values = jnp.zeros((tuples.shape[0],), jnp.float32)
        return tuples, jnp.asarray(values, jnp.float32)

    def _value_domain(self, values) -> jnp.ndarray:
        """Sorted distinct values for key-lane pruning, as a replicated
        array (empty = pruning off: prime variant, lexsort path, or
        ``prune_values=False``)."""
        if self.delta is None or not RX.wants_value_pruning(
                self.prune_values, self.packed, self.sort_backend):
            return jnp.zeros((0,), jnp.float32)
        return jnp.asarray(K.value_domain_host(values))

    def lowered(self, tuples, values=None):
        """Lower (no execution) for dry-run / roofline analysis of the
        mining pipeline itself — same artifact path as the LM cells."""
        tuples, values = self._coerce(tuples, values)
        vdom = self._value_domain(values)
        fn = self._build(tuples.shape[0])
        structs = (jax.ShapeDtypeStruct(tuples.shape, jnp.int32),
                   jax.ShapeDtypeStruct(values.shape, jnp.float32),
                   jax.ShapeDtypeStruct(vdom.shape, jnp.float32),
                   [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in self._lo],
                   [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in self._hi])
        with self.mesh:
            return fn.lower(*structs)

    def __call__(self, tuples, values=None) -> DistributedResult:
        """Run the pipeline. On shuffle-capacity overflow (the M/R skew
        failure mode the paper's §1 warns about) the capacity factor is
        doubled and the job re-executed — the analogue of Hadoop re-running
        a failed reducer with more memory."""
        tuples, values = self._coerce(tuples, values)
        t = tuples.shape[0]
        if t % self.n_shards:
            raise ValueError(
                f"tuple count {t} not divisible by shard count "
                f"{self.n_shards}; pad with duplicated rows (idempotent)")
        if self._fn is None or self._t_global != t:
            self._fn = self._build(t)
            self._t_global = t
        vdom = self._value_domain(values)
        res = self._fn(tuples, values, vdom, self._lo, self._hi)
        for _ in range(self.max_retries):
            if self.strategy != "shuffle" or int(res.overflow) == 0:
                break
            self.capacity_factor *= 2.0
            self._fn = self._build(t)
            res = self._fn(tuples, values, vdom, self._lo, self._hi)
        if self.strategy == "shuffle" and int(res.overflow):
            # overflowed records were dropped by _dispatch — returning
            # would hand back silently-wrong clusters
            raise RuntimeError(
                f"shuffle capacity overflow persists after "
                f"{self.max_retries} retries (capacity_factor="
                f"{self.capacity_factor}); the partition is too skewed "
                f"for n_shards={self.n_shards}")
        return res

    # -- incremental snapshots (per-shard run stores, DESIGN.md §4) ---------

    def reset_stream(self) -> None:
        """Drop all ingested stream state (per-shard stores)."""
        self._stores = None
        for k in ("snapshots", "full_resorts", "merged_rows",
                  "chunk_sorted_rows", "tombstoned_rows"):
            self.stream_stats[k] = 0

    def _ensure_stores(self):
        if self._stores is None:
            inc = self.key_plans[0].fits and self.stream_incremental \
                is not False
            radix = self.resolved_sort_backend == "radix"
            n = self.n_shards if inc else 1
            self._stores = [RS.RunStore(self.key_plans, radix=radix,
                                        incremental=inc,
                                        stats=self.stream_stats)
                            for _ in range(n)]
        return self._stores

    def _route(self, rows: np.ndarray) -> np.ndarray:
        stores = self._ensure_stores()
        if len(stores) == 1:
            return np.zeros(rows.shape[0], np.int64)
        return RS.shard_of_rows(rows, stores[0]._identity_plan(),
                                len(stores))

    def _scatter(self, op: str, rows, values=None) -> None:
        """Route rows to their owner shard's store by the fixed
        radix-range partition of the entity-only identity key — the
        host-side analogue of the shuffle's range partitioner — and
        apply ``op`` per shard."""
        rows = np.atleast_2d(np.asarray(rows, np.int32))
        if rows.shape[0] == 0:
            return
        vals = None
        if self.delta is not None and op != "delete":
            vals = (np.zeros(rows.shape[0], np.float32) if values is None
                    else np.asarray(values, np.float32))
        stores = self._ensure_stores()
        owner = self._route(rows)
        for s, store in enumerate(stores):
            sel = np.nonzero(owner == s)[0]
            if sel.size == 0:
                continue
            sub_vals = None if vals is None else vals[sel]
            if op == "delete":
                store.delete(rows[sel])
            else:
                getattr(store, op)(rows[sel], sub_vals)
        self.stream_version += 1

    def ingest(self, rows, values=None) -> None:
        """Stream a chunk into the per-shard run stores (valued streams
        upsert — last write wins, like the batch constructor)."""
        self._scatter("add", rows, values)

    def upsert(self, rows, values=None) -> None:
        self._scatter("upsert", rows, values)

    def delete(self, rows) -> None:
        self._scatter("delete", rows)

    @property
    def stream_count(self) -> int:
        """Live (non-tombstoned) rows across all shard stores."""
        if not self._stores:
            return 0
        return sum(s.count - s.dead for s in self._stores)

    def _gathered(self, with_run: bool):
        """Concatenated survivor tables + (incremental path) the
        globally merged run: shard runs offset into the concatenated
        table and merged linearly — mode 0 concatenates outright, its
        shard key ranges are disjoint by the range routing."""
        stores = [s for s in self._stores if s.count]
        rows = np.concatenate([s.table()[0] for s in stores])
        vals = (np.concatenate([s.table()[1] for s in stores])
                if self.delta is not None else None)
        run, off = None, 0
        if with_run:
            for s in stores:
                r = RS.offset_run(s.runs[0], off)
                if run is None:
                    run = r
                else:
                    run = RS.merge_runs(run, r)
                    self.stream_stats["merged_rows"] += run.size
                off += s.count
        return rows, vals, run

    def snapshot(self, full_remine: bool = False) -> DistributedResult:
        """Mine the current stream exactly.  The incremental path folds
        each shard's runs (linear merges of only what changed), merges
        the per-shard runs into global permutations, and runs the
        replicate body with Stage 1's sorts skipped; ``full_remine=True``
        (or a non-fitting key) is the re-sort-every-shard baseline —
        the padded table through the one-shot ``__call__`` path."""
        if self._stores is None:
            raise ValueError("no data ingested")
        self.snapshot_stream_version = self.stream_version
        incremental = (not full_remine
                       and all(s.incremental for s in self._stores))
        if incremental and self.strategy == "shuffle":
            # the merged-perms body replicates the full table per shard
            # (all_gather) — running it would silently break the memory
            # bound the shuffle strategy was chosen for
            raise ValueError(
                "incremental snapshots run the replicate-with-perms "
                "body; strategy='shuffle' mining is one-shot only — "
                "use snapshot(full_remine=True) or strategy='replicate'")
        self.stream_stats["snapshots"] += 1
        for s in self._stores:
            s.prepare() if incremental else s.compact()
        if self.stream_count == 0:
            raise ValueError("no live rows (everything deleted)")
        rows, vals, run = self._gathered(with_run=incremental)
        count = rows.shape[0]
        cap = RS.snapshot_cap(count, self.n_shards)
        rows, vals = RS.padded_table(rows, vals, cap)
        if not incremental or run is None:
            self.stream_stats["full_resorts"] += 1
            return self(rows, vals)
        perms = RS.padded_perms(run, self.key_plans, rows[:1],
                                None if vals is None else vals[:1],
                                count, cap)
        tuples, values = self._coerce(rows, vals)
        if self._fn_perms is None or self._t_perms != cap:
            self._fn_perms = self._build_perms()
            self._t_perms = cap
        return self._fn_perms(tuples, values,
                              jnp.asarray(perms, jnp.int32),
                              self._lo, self._hi)

    def serving_snapshot(self,
                         full_remine: bool = False) -> PL.PipelineResult:
        """Serving twin of :meth:`snapshot`: a *full-table*
        ``PipelineResult`` — component windows included, which
        ``DistributedResult`` deliberately drops — so a
        ``serve.clusters.ClusterIndex`` can be built straight from a
        distributed stream.  Runs the single-device pipeline on the
        gathered survivor table; on the incremental path the per-shard
        runs are folded and merged into global permutations exactly as
        :meth:`snapshot` does, so Stage 1 never re-sorts here either.
        Signatures are bit-identical to :meth:`snapshot` / the batch
        miner (same hash vectors)."""
        if self._stores is None:
            raise ValueError("no data ingested")
        self.snapshot_stream_version = self.stream_version
        incremental = (not full_remine
                       and all(s.incremental for s in self._stores))
        self.stream_stats["snapshots"] += 1
        for s in self._stores:
            s.prepare() if incremental else s.compact()
        if self.stream_count == 0:
            raise ValueError("no live rows (everything deleted)")
        rows, vals, run = self._gathered(with_run=incremental)
        count = rows.shape[0]
        cap = RS.snapshot_cap(count)
        rows, vals = RS.padded_table(rows, vals, cap)
        targs = jnp.asarray(rows, jnp.int32)
        vargs = None if vals is None else jnp.asarray(vals, jnp.float32)
        if self._serve_fn is None:
            self._serve_fn = jax.jit(functools.partial(
                PL.mine_tuples, delta=self.delta, theta=self.theta,
                minsup=self.minsup, packed=self.packed,
                sort_backend=self.sort_backend,
                use_pallas=self.use_pallas))
        if not incremental or run is None:
            self.stream_stats["full_resorts"] += 1
            # same value-lane pruning the one-shot __call__ applies (the
            # perms path below stays domain-free like snapshot()'s — the
            # store's merged runs carry the unpruned float lane)
            vdom = self._value_domain(vals) if vals is not None else None
            if vdom is not None and not vdom.shape[0]:
                vdom = None
            res = self._serve_fn(targs, self._lo, self._hi, values=vargs,
                                 value_domain=vdom)
        else:
            perms = RS.padded_perms(run, self.key_plans, rows[:1],
                                    None if vals is None else vals[:1],
                                    count, cap)
            if self.window_budget and self.packed_active:
                # windowed serving remine (DESIGN.md §3c): the merged
                # global perms feed the bounded device window loop —
                # bit-identical to the monolithic perms call below
                from . import windowed as WD
                res = WD.mine_windowed(
                    rows, vals, perms, plans=self.key_plans,
                    hash_lo=self._lo, hash_hi=self._hi, delta=self.delta,
                    theta=self.theta, minsup=self.minsup,
                    window_budget=self.window_budget,
                    sort_backend=self.resolved_sort_backend,
                    use_pallas=self.use_pallas)
            else:
                res = self._serve_fn(targs, self._lo, self._hi,
                                     values=vargs,
                                     perms=jnp.asarray(perms, jnp.int32))
        if self.track_dirty_sigs:
            sigs = PL.kept_sig_words(res)
            self.last_dirty_sigs = PL.dirty_sig_count(
                self.last_kept_sigs, sigs)
            self.last_kept_sigs = sigs
        return res


def pad_tuples(tuples: np.ndarray, multiple: int) -> np.ndarray:
    """Pad the tuple table to a multiple by repeating the first row — the
    mining algebra is duplicate-idempotent (paper §5.1 / K3 argument)."""
    t = tuples.shape[0]
    pad = (-t) % multiple
    if pad == 0:
        return tuples
    return np.concatenate([tuples, np.repeat(tuples[:1], pad, 0)], 0)


def pad_values(values: np.ndarray, multiple: int) -> np.ndarray:
    """Value-column companion of ``pad_tuples`` (pads with the first value,
    keeping V a function of the tuple)."""
    t = values.shape[0]
    pad = (-t) % multiple
    if pad == 0:
        return values
    return np.concatenate([values, np.repeat(values[:1], pad, 0)], 0)
