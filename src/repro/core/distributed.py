"""Distributed three-stage multimodal clustering (the paper's M/R algorithm
mapped onto a TPU mesh with ``shard_map``; DESIGN.md §3).

Tuples are block-partitioned (uniform by construction — this removes the
paper's hash-skew problem) over one or more mesh axes. Two merge strategies,
mirroring the centralise-vs-replicate discussion in the paper's §1:

* ``replicate`` — all-gather the (small) tuple table over the data axes and
  let every shard run the batch pipeline on the full table, keeping only its
  own block's outputs. Communication: one all-gather of ``T×N`` int32; compute
  is duplicated ×P. This is the paper's "data replication" choice, executed as
  a log-depth ICI collective instead of HDFS replication-factor-3.

* ``shuffle`` — the faithful M/R shuffle. Stage 1 routes each tuple's
  ⟨subrelation, e_k⟩ record to the key's *owner shard* with a fixed-capacity
  ``all_to_all`` (MoE-dispatch pattern); owners sort/segment/hash their key
  ranges and answer with ⟨signature, cardinality⟩ per record (Stage 2 —
  12 bytes instead of the paper's whole-cumulus shuffle). Stage 3 deduplicates
  and counts generating tuples on 8-byte cluster signatures gathered over the
  mesh. Skew shows up as capacity overflow and is *reported*, not silently
  dropped (a reducer-OOM analogue).

Both strategies return bit-identical signatures/densities to the single-shard
``core.batch.mine`` (same hash vectors), which is what the tests assert.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from . import batch as B

Axis = tuple[str, ...]


@dataclasses.dataclass
class DistributedResult:
    """Global per-tuple outputs (sharded over the data axes)."""
    sig_lo: jnp.ndarray
    sig_hi: jnp.ndarray
    is_unique: jnp.ndarray
    gen_count: jnp.ndarray
    volume: jnp.ndarray
    density: jnp.ndarray
    keep: jnp.ndarray
    cardinalities: jnp.ndarray   # (N, T) distinct |cum_k| per tuple
    n_clusters: jnp.ndarray      # scalar, replicated
    overflow: jnp.ndarray        # scalar: dropped records (0 == exact)

jax.tree_util.register_dataclass(
    DistributedResult,
    data_fields=["sig_lo", "sig_hi", "is_unique", "gen_count", "volume",
                 "density", "keep", "cardinalities", "n_clusters", "overflow"],
    meta_fields=[])


def _hash_columns(cols: Sequence[jnp.ndarray], salt: int) -> jnp.ndarray:
    """uint32 mix of int32 id columns (key → owner-shard hashing)."""
    h = jnp.full(cols[0].shape, jnp.uint32(salt))
    for c in cols:
        h = (h ^ c.astype(jnp.uint32)) * jnp.uint32(0x9E3779B1)
        h = h ^ (h >> 15)
    return h


def _global_sort_stage3(sig_lo, sig_hi, tuple_first, theta):
    """Stage 3 on gathered signature arrays (identical on every shard)."""
    t = sig_lo.shape[0]
    order = B.lex_perm([sig_lo, sig_hi])
    s_lo, s_hi = sig_lo[order], sig_hi[order]
    cstart = B.segment_starts([s_lo, s_hi])
    cseg = jnp.cumsum(cstart) - 1
    gen = jax.ops.segment_sum(tuple_first[order].astype(jnp.int32), cseg,
                              num_segments=t)
    gen_of = jnp.zeros((t,), jnp.int32).at[order].set(gen[cseg])
    pos = jnp.arange(t)
    first_pos = jax.ops.segment_min(
        jnp.where(tuple_first[order], pos, t), cseg, num_segments=t)
    uniq_sorted = (pos == first_pos[cseg]) & tuple_first[order]
    is_unique = jnp.zeros((t,), bool).at[order].set(uniq_sorted)
    return gen_of, is_unique


# ---------------------------------------------------------------------------
# Shuffle strategy internals (per shard_map body)
# ---------------------------------------------------------------------------

def _dispatch(records: jnp.ndarray, owner: jnp.ndarray, n_shards: int,
              capacity: int):
    """Pack ``records`` (L, W) into a (n_shards*capacity, W) send buffer by
    owner shard, plus validity mask, slot handle per record and overflow."""
    l = records.shape[0]
    # position of each record within its owner's group
    order = jnp.argsort(owner, stable=True)
    sorted_owner = owner[order]
    pos_in_group = jnp.arange(l) - jnp.searchsorted(sorted_owner, sorted_owner,
                                                    side="left")
    rank = jnp.zeros((l,), jnp.int32).at[order].set(pos_in_group.astype(jnp.int32))
    ok = rank < capacity
    nslots = n_shards * capacity
    # overflowed records go to a trash slot one past the end
    slot_safe = jnp.where(ok, owner * capacity + rank, nslots)
    buf = jnp.zeros((nslots + 1, records.shape[1]), records.dtype)
    buf = buf.at[slot_safe].set(records)[:nslots]
    valid = jnp.zeros((nslots + 1,), bool).at[slot_safe].set(ok)[:nslots]
    overflow = (~ok).sum()
    return buf, valid, slot_safe, ok, overflow


def _owner_stage(recv: jnp.ndarray, rvalid: jnp.ndarray, n_other: int,
                 r_lo: jnp.ndarray, r_hi: jnp.ndarray):
    """Owner-side Reduce-1: segment received ⟨key, e⟩ records, compute per-
    record (set-signature, distinct cardinality, tuple-first flag)."""
    big = jnp.int32(np.iinfo(np.int32).max)
    key_cols = [jnp.where(rvalid, recv[:, j], big) for j in range(n_other)]
    e_col = jnp.where(rvalid, recv[:, n_other], big)
    l = recv.shape[0]
    perm = B.lex_perm(key_cols + [e_col])
    s_keys = [c[perm] for c in key_cols]
    s_e = e_col[perm]
    s_valid = rvalid[perm]
    seg_flag = B.segment_starts(s_keys)
    seg = jnp.cumsum(seg_flag) - 1
    first_occ = B.segment_starts(s_keys + [s_e]) & s_valid
    e_safe = jnp.where(s_valid, s_e, 0)
    w_lo = jnp.where(first_occ, r_lo[e_safe], jnp.uint32(0))
    w_hi = jnp.where(first_occ, r_hi[e_safe], jnp.uint32(0))
    sig_lo = jax.ops.segment_sum(w_lo, seg, num_segments=l)
    sig_hi = jax.ops.segment_sum(w_hi, seg, num_segments=l)
    distinct = jax.ops.segment_sum(first_occ.astype(jnp.int32), seg,
                                   num_segments=l)
    # per-received-record responses, back in recv-slot order
    inv = jnp.zeros((l,), jnp.int32).at[perm].set(jnp.arange(l, dtype=jnp.int32))
    return (sig_lo[seg][inv], sig_hi[seg][inv], distinct[seg][inv],
            first_occ[inv])


def _shuffle_mode(tuples, k, axes, n_shards, capacity, r_lo, r_hi):
    """Stages 1+2 of the M/R algorithm for one mode over ``axes``."""
    n = tuples.shape[1]
    others = [tuples[:, j] for j in range(n) if j != k]
    owner = (_hash_columns(others, 0xA11CE + k) %
             jnp.uint32(n_shards)).astype(jnp.int32)
    gidx = jnp.arange(tuples.shape[0], dtype=jnp.int32)
    records = jnp.stack(others + [tuples[:, k], gidx], axis=1)
    buf, valid, slot, ok, overflow = _dispatch(records, owner, n_shards,
                                               capacity)
    recv = jax.lax.all_to_all(buf, axes, 0, 0, tiled=True)
    rvalid = jax.lax.all_to_all(valid.astype(jnp.int32), axes, 0, 0,
                                tiled=True).astype(bool)
    sig_lo, sig_hi, card, tfirst = _owner_stage(recv, rvalid, n - 1,
                                                r_lo, r_hi)
    resp = jnp.stack([sig_lo, sig_hi, card.astype(jnp.uint32),
                      tfirst.astype(jnp.uint32)], axis=1)
    resp = jax.lax.all_to_all(resp, axes, 0, 0, tiled=True)
    got = resp[slot]   # (L, 4) in original record order (garbage if !ok)
    return (got[:, 0], got[:, 1], got[:, 2].astype(jnp.int32),
            got[:, 3].astype(bool), ok, overflow)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

class DistributedMiner:
    """Multi-device multimodal clustering over a mesh.

    Args:
      sizes: mode cardinalities.
      mesh: jax Mesh containing ``axes``.
      axes: data-parallel mesh axis name(s) the tuple table is sharded over.
      theta: minimal density threshold (paper Alg. 7 θ).
      strategy: 'replicate' | 'shuffle'.
      capacity_factor: shuffle per-destination buffer slack (≥1).
    """

    def __init__(self, sizes: Sequence[int], mesh, axes="data",
                 theta: float = 0.0, strategy: str = "replicate",
                 capacity_factor: float = 2.0, seed: int = 0x5EED,
                 max_retries: int = 4):
        self.sizes = tuple(int(s) for s in sizes)
        self.mesh = mesh
        self.axes: Axis = (axes,) if isinstance(axes, str) else tuple(axes)
        self.theta = float(theta)
        self.strategy = strategy
        self.capacity_factor = float(capacity_factor)
        self.max_retries = int(max_retries)
        self.n_shards = int(np.prod([mesh.shape[a] for a in self.axes]))
        vecs = B.mode_hash_vectors(self.sizes, seed)
        self._lo = [jnp.asarray(lo) for lo, _ in vecs]
        self._hi = [jnp.asarray(hi) for _, hi in vecs]
        if strategy not in ("replicate", "shuffle"):
            raise ValueError(strategy)
        self._fn = None
        self._t_global = None

    # -- shard bodies -------------------------------------------------------

    def _body_replicate(self, tuples, lo, hi):
        axes = self.axes
        full = jax.lax.all_gather(tuples, axes, tiled=True)
        res = B.mine(full, lo, hi, theta=self.theta)
        # keep this shard's block
        shard_id = jax.lax.axis_index(axes)
        tl = tuples.shape[0]
        sl = jax.lax.dynamic_slice_in_dim
        start = shard_id * tl
        card = jnp.stack([m.seg_distinct[m.seg_of_tuple] for m in res.modes])
        out = DistributedResult(
            sig_lo=sl(res.sig_lo, start, tl),
            sig_hi=sl(res.sig_hi, start, tl),
            is_unique=sl(res.is_unique, start, tl),
            gen_count=sl(res.gen_count, start, tl),
            volume=sl(res.volume, start, tl),
            density=sl(res.density, start, tl),
            keep=sl(res.keep, start, tl),
            cardinalities=sl(card, start, tl, axis=1),
            n_clusters=res.is_unique.sum(),
            overflow=jnp.int32(0))
        return out

    def _body_shuffle(self, tuples, lo, hi):
        axes, nsh = self.axes, self.n_shards
        tl, n = tuples.shape
        capacity = max(1, int(np.ceil(tl / nsh * self.capacity_factor)))
        per_lo, per_hi, cards = [], [], []
        overflow = jnp.int32(0)
        tuple_first = None
        ok_all = jnp.ones((tl,), bool)
        for k in range(n):
            slo, shi, card, tfirst, ok, ovf = _shuffle_mode(
                tuples, k, axes, nsh, capacity, lo[k], hi[k])
            per_lo.append(slo)
            per_hi.append(shi)
            cards.append(card)
            overflow = overflow + ovf.astype(jnp.int32)
            ok_all = ok_all & ok
            if k == 0:
                tuple_first = tfirst
        sig_lo, sig_hi = B._mix_signatures(per_lo, per_hi)
        volume = jnp.ones((tl,), jnp.float32)
        for c in cards:
            volume = volume * c.astype(jnp.float32)
        # Stage 3 on gathered signatures (12 bytes/tuple on the wire).
        g_lo = jax.lax.all_gather(sig_lo, axes, tiled=True)
        g_hi = jax.lax.all_gather(sig_hi, axes, tiled=True)
        g_tf = jax.lax.all_gather(tuple_first, axes, tiled=True)
        gen_of, is_unique = _global_sort_stage3(g_lo, g_hi, g_tf, self.theta)
        shard_id = jax.lax.axis_index(axes)
        sl = jax.lax.dynamic_slice_in_dim
        start = shard_id * tl
        gen_l = sl(gen_of, start, tl)
        uniq_l = sl(is_unique, start, tl)
        density = gen_l.astype(jnp.float32) / jnp.maximum(volume, 1.0)
        keep = uniq_l & (density >= jnp.float32(self.theta))
        overflow = jax.lax.psum(overflow, axes)
        return DistributedResult(
            sig_lo=sig_lo, sig_hi=sig_hi, is_unique=uniq_l, gen_count=gen_l,
            volume=volume, density=density, keep=keep,
            cardinalities=jnp.stack(cards), n_clusters=is_unique.sum(),
            overflow=overflow)

    # -- public -------------------------------------------------------------

    def _build(self, t_global: int):
        body = (self._body_replicate if self.strategy == "replicate"
                else self._body_shuffle)
        data_spec = P(self.axes)
        card_spec = P(None, self.axes)
        out_specs = DistributedResult(
            sig_lo=data_spec, sig_hi=data_spec, is_unique=data_spec,
            gen_count=data_spec, volume=data_spec, density=data_spec,
            keep=data_spec, cardinalities=card_spec, n_clusters=P(),
            overflow=P())
        fn = jax.shard_map(body, mesh=self.mesh,
                           in_specs=(P(self.axes, None), P(), P()),
                           out_specs=out_specs, check_vma=False)
        return jax.jit(fn)

    def lowered(self, tuples):
        """Lower (no execution) for dry-run / roofline analysis of the
        mining pipeline itself — same artifact path as the LM cells."""
        tuples = jnp.asarray(tuples, jnp.int32)
        fn = self._build(tuples.shape[0])
        structs = (jax.ShapeDtypeStruct(tuples.shape, jnp.int32),
                   [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in self._lo],
                   [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in self._hi])
        with self.mesh:
            return fn.lower(*structs)

    def __call__(self, tuples) -> DistributedResult:
        """Run the pipeline. On shuffle-capacity overflow (the M/R skew
        failure mode the paper's §1 warns about) the capacity factor is
        doubled and the job re-executed — the analogue of Hadoop re-running
        a failed reducer with more memory."""
        tuples = jnp.asarray(tuples, jnp.int32)
        t = tuples.shape[0]
        if t % self.n_shards:
            raise ValueError(
                f"tuple count {t} not divisible by shard count "
                f"{self.n_shards}; pad with duplicated rows (idempotent)")
        if self._fn is None or self._t_global != t:
            self._fn = self._build(t)
            self._t_global = t
        res = self._fn(tuples, self._lo, self._hi)
        for _ in range(self.max_retries):
            if self.strategy != "shuffle" or int(res.overflow) == 0:
                break
            self.capacity_factor *= 2.0
            self._fn = self._build(t)
            res = self._fn(tuples, self._lo, self._hi)
        return res


def pad_tuples(tuples: np.ndarray, multiple: int) -> np.ndarray:
    """Pad the tuple table to a multiple by repeating the first row — the
    mining algebra is duplicate-idempotent (paper §5.1 / K3 argument)."""
    t = tuples.shape[0]
    pad = (-t) % multiple
    if pad == 0:
        return tuples
    return np.concatenate([tuples, np.repeat(tuples[:1], pad, 0)], 0)
