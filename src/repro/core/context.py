"""Polyadic formal contexts: the input data structure of the paper.

A polyadic context K_N = (A_1, ..., A_N, I ⊆ A_1 × ... × A_N) is stored as

  * ``sizes``  — tuple (n_1, ..., n_N) of mode cardinalities,
  * ``tuples`` — int32 array of shape (T, N), one row per element of I,
  * optional ``values`` — float32 array (T,) for many-valued contexts
    (the valuation function V of §3.2 of the paper),
  * optional ``names`` — per-mode list of entity names (host-side only;
    everything on device is integer ids, see DESIGN.md §3).

Duplicated rows are legal (M/R at-least-once semantics, paper §5.1: the
algebra must be idempotent under duplicates) — except in many-valued
contexts, where V must be a *function* of the tuple (§3.2).  Duplicate
rows of a valued context are therefore canonicalised at construction:
one row per distinct tuple, the **last** value winning (the upsert
semantics of the paper's online Algorithm 1).  Without this, duplicate
rows carrying conflicting values make every NOAC engine's output
depend on which copy it happens to see first — the historical
seq-vs-par MISMATCH of ``benchmarks/table5.py``.  (The streaming
engine ingests raw arrays, bypassing this constructor, but applies the
*same* last-write-wins rule through the run store's tombstones — a
valued ``add`` is an upsert; see ``core/runs.py``.)
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class PolyadicContext:
    sizes: tuple[int, ...]
    tuples: np.ndarray  # (T, N) int32
    values: Optional[np.ndarray] = None  # (T,) float32, many-valued contexts
    names: Optional[tuple[list, ...]] = None  # host-side entity labels

    def __post_init__(self):
        t = np.asarray(self.tuples, dtype=np.int32)
        object.__setattr__(self, "tuples", t)
        if t.ndim != 2 or t.shape[1] != len(self.sizes):
            raise ValueError(
                f"tuples shape {t.shape} incompatible with sizes {self.sizes}")
        if t.size and (t.min() < 0 or (t.max(axis=0) >= np.asarray(self.sizes)).any()):
            raise ValueError("entity id out of range")
        if self.values is not None:
            v = np.asarray(self.values, dtype=np.float32)
            if v.shape != (t.shape[0],):
                raise ValueError("values must be (T,)")
            object.__setattr__(self, "values", v)
            if t.shape[0]:
                # canonicalise: V is a function of the tuple (§3.2) —
                # keep one row per distinct tuple in first-occurrence
                # order, last value winning (upsert semantics).  Row
                # order is preserved so duplicate-free workloads — and
                # the sort benchmarks — see the input exactly as given.
                uniq, first, inv = np.unique(t, axis=0, return_index=True,
                                             return_inverse=True)
                if uniq.shape[0] != t.shape[0]:
                    inv = inv.ravel()
                    last = np.empty(uniq.shape[0], np.intp)
                    last[inv] = np.arange(t.shape[0])
                    order = np.argsort(first, kind="stable")
                    object.__setattr__(self, "tuples", uniq[order])
                    object.__setattr__(self, "values", v[last][order])

    @property
    def arity(self) -> int:
        return len(self.sizes)

    @property
    def num_tuples(self) -> int:
        return int(self.tuples.shape[0])

    @property
    def volume(self) -> int:
        return int(np.prod(self.sizes))

    @property
    def density(self) -> float:
        uniq = np.unique(self.tuples, axis=0)
        return len(uniq) / self.volume

    def dense(self) -> np.ndarray:
        """Dense boolean incidence tensor (use only for small contexts)."""
        out = np.zeros(self.sizes, dtype=bool)
        out[tuple(self.tuples.T)] = True
        return out

    def deduplicated(self) -> "PolyadicContext":
        """Distinct-row view.  Valued contexts are already canonicalised
        at construction (one row per tuple, last value wins — the only
        dedup policy), so they return themselves unchanged."""
        if self.values is not None:
            return self
        uniq = np.unique(self.tuples, axis=0)
        if uniq.shape[0] == self.tuples.shape[0]:
            return self
        return PolyadicContext(self.sizes, uniq, None, self.names)

    def subsample(self, n: int, seed: int = 0) -> "PolyadicContext":
        rng = np.random.default_rng(seed)
        idx = rng.choice(self.num_tuples, size=min(n, self.num_tuples),
                         replace=False)
        vals = self.values[idx] if self.values is not None else None
        return PolyadicContext(self.sizes, self.tuples[idx], vals, self.names)


def tricontext(sizes: Sequence[int], triples, values=None,
               names=None) -> PolyadicContext:
    """Triadic convenience constructor K = (G, M, B, I)."""
    if len(sizes) != 3:
        raise ValueError("tricontext needs exactly three modes")
    return PolyadicContext(tuple(int(s) for s in sizes),
                           np.asarray(triples, np.int32), values, names)


def from_named_triples(triples: Sequence[tuple]) -> PolyadicContext:
    """Build a context from (name, name, ..., name) tuples, like the paper's
    tab-separated IMDB input (§5.1 'Input data example')."""
    if not triples:
        raise ValueError("empty input")
    arity = len(triples[0])
    vocabs: list[dict] = [dict() for _ in range(arity)]
    rows = np.empty((len(triples), arity), dtype=np.int32)
    for r, tup in enumerate(triples):
        for k, name in enumerate(tup):
            vocab = vocabs[k]
            if name not in vocab:
                vocab[name] = len(vocab)
            rows[r, k] = vocab[name]
    names = tuple([list(v.keys()) for v in vocabs])
    sizes = tuple(len(v) for v in vocabs)
    return PolyadicContext(sizes, rows, names=names)
