"""Shared Stage-1/2/3 mining pipeline — the one skeleton behind every
engine (DESIGN.md §3, "Unified pipeline").

The paper's M/R algorithm is the *same* three jobs for the prime OAC,
multimodal (N-ary) and many-valued (NOAC, §3.2/§4.3) variants; only the
per-key *component operator* differs.  This module is that factoring:

  Stage 1  ``sort_mode``            per-mode lexicographic sort of the
           tuple table by the mode's shuffle key (the N-1 "other"
           columns, plus the value column for many-valued contexts) and
           segmentation of the sorted order — the Hadoop
           shuffle-by-subrelation as a sort.
  comp-op  ``prime_components``     cumulus = the whole key segment.
           ``delta_components``     δ-range inside the key segment
                                    (two vectorised binary searches).
           This is the only place the variants differ.
  Stage 2  ``mix_signatures``       gather per-mode ⟨signature,
           cardinality⟩ aggregates back to each generating tuple.
  Stage 3  ``stage3_dedup``         order-independent dedup + distinct
           generating-tuple counts on 2×32-bit set signatures, via one
           more sort; density is the paper-faithful Alg. 7 estimate
           ``#distinct generating tuples / volume``.

``mine_tuples`` composes the stages into the full jit-able pipeline;
``batch``, ``distributed``, ``streaming`` and ``manyvalued`` are thin
drivers around it (single shard / shard_map mesh / incremental sorted
runs).  All signatures are *order-independent modular sums of
first-occurrence-masked hash weights*, which makes every engine
duplicate-idempotent (M/R at-least-once, §5.1) and lets the distributed
and streaming engines reproduce single-shard results bit-exactly.

Shapes are static in ``T`` (tuples) and ``N`` (arity), so each engine
jits once per context shape.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

# jax version compatibility (canonical home: repro._compat)
from .._compat import shard_map  # noqa: F401  (re-export for the engines)


# ---------------------------------------------------------------------------
# Hashing
# ---------------------------------------------------------------------------

# Per-mode multipliers for mixing mode signatures into a cluster signature.
# Odd constants (invertible mod 2^32) from splitmix64 / Weyl sequences.
_MIX = np.array([0x9E3779B1, 0x85EBCA77, 0xC2B2AE3D, 0x27D4EB2F,
                 0x165667B1, 0xD3A2646D, 0xFD7046C5, 0xB55A4F09],
                dtype=np.uint32)


def mode_hash_vectors(sizes: Sequence[int], seed: int = 0x5EED):
    """Two independent uint32 hash vectors per mode (host-side, fixed seed).

    Every engine built from the same (sizes, seed) produces bit-identical
    cluster signatures — the cross-backend parity guarantee."""
    rng = np.random.Generator(np.random.Philox(seed))
    return [
        (rng.integers(1, 2**32, size=n, dtype=np.uint32),
         rng.integers(1, 2**32, size=n, dtype=np.uint32))
        for n in sizes
    ]


def mix_signatures(per_mode_lo, per_mode_hi):
    """Combine per-mode set signatures into one 2×32-bit cluster signature."""
    lo = jnp.zeros_like(per_mode_lo[0])
    hi = jnp.zeros_like(per_mode_hi[0])
    for k, (slo, shi) in enumerate(zip(per_mode_lo, per_mode_hi)):
        lo = lo + jnp.uint32(_MIX[k % len(_MIX)]) * slo
        hi = hi + jnp.uint32(_MIX[(k + 3) % len(_MIX)]) * shi
    # final avalanche
    lo = (lo ^ (lo >> 16)) * jnp.uint32(0x7FEB352D)
    hi = (hi ^ (hi >> 15)) * jnp.uint32(0x846CA68B)
    return lo, hi


# ---------------------------------------------------------------------------
# Sorting / segmentation primitives
# ---------------------------------------------------------------------------

def lex_perm(columns: Sequence[jnp.ndarray]) -> jnp.ndarray:
    """Permutation sorting rows lexicographically by ``columns`` (first column
    is the most significant key)."""
    return jnp.lexsort(tuple(reversed(list(columns))))


def segment_starts(sorted_key_cols: Sequence[jnp.ndarray]) -> jnp.ndarray:
    """Boolean start-of-segment flags for already-sorted key columns."""
    t = sorted_key_cols[0].shape[0]
    change = jnp.zeros((t,), bool).at[0].set(True)
    for c in sorted_key_cols:
        change = change | jnp.concatenate(
            [jnp.ones((1,), bool), c[1:] != c[:-1]])
    return change


@dataclasses.dataclass
class SortedMode:
    """Stage-1 output for one mode: the tuple table sorted by the mode's
    shuffle key and segmented by it.  All arrays have length T;
    ``seg_start``/``seg_len`` are indexed by segment id (padded to T)."""
    perm: jnp.ndarray         # sorted order of tuples
    inv: jnp.ndarray          # inverse permutation (original → sorted pos)
    seg: jnp.ndarray          # segment id per *sorted* position
    seg_start: jnp.ndarray    # first sorted position of each segment
    seg_len: jnp.ndarray      # total entries (with duplicates)
    sorted_e: jnp.ndarray     # mode-k entity column under perm
    sorted_vals: Optional[jnp.ndarray]  # values under perm (None: prime)
    first_occ: jnp.ndarray    # per sorted position: first of its
                              # identical (key[, value], e) run

jax.tree_util.register_dataclass(
    SortedMode, data_fields=["perm", "inv", "seg", "seg_start", "seg_len",
                             "sorted_e", "sorted_vals", "first_occ"],
    meta_fields=[])


def sort_mode(tuples: jnp.ndarray, k: int,
              values: Optional[jnp.ndarray] = None,
              perm: Optional[jnp.ndarray] = None) -> SortedMode:
    """Stage 1 for mode k.  Sort key: (other columns..., [value,] e_k), so
    duplicates of a (key[, value], e) pair land adjacent and the
    ``first_occ`` mask makes all downstream sums duplicate-idempotent.

    ``perm`` short-circuits the sort with a precomputed permutation (the
    streaming engine maintains one by merging sorted runs)."""
    t, n = tuples.shape
    others = [tuples[:, j] for j in range(n) if j != k]
    tail = ([values] if values is not None else []) + [tuples[:, k]]
    if perm is None:
        perm = lex_perm(others + tail)
    s_others = [c[perm] for c in others]
    s_e = tuples[perm, k]
    s_vals = values[perm] if values is not None else None
    seg_flag = segment_starts(s_others)
    seg = jnp.cumsum(seg_flag) - 1
    pos = jnp.arange(t)
    seg_start = jax.ops.segment_min(pos, seg, num_segments=t)
    seg_len = jax.ops.segment_sum(jnp.ones((t,), jnp.int32), seg,
                                  num_segments=t)
    first_occ = segment_starts(
        s_others + ([s_vals] if s_vals is not None else []) + [s_e])
    inv = jnp.zeros((t,), jnp.int32).at[perm].set(pos.astype(jnp.int32))
    return SortedMode(perm, inv, seg, seg_start, seg_len, s_e, s_vals,
                      first_occ)


# ---------------------------------------------------------------------------
# Component operators (the pluggable part)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ModeComponents:
    """One mode's component per tuple, in *original* tuple order.

    ``range_lo``/``range_hi`` delimit the component as a half-open window
    of the mode's sorted order — the cumulus tables of the paper shrink
    from O(|I|·Σ|A_j|) dictionary bytes to O(|I|) ranges."""
    sig_lo: jnp.ndarray     # order-independent set hash of the component
    sig_hi: jnp.ndarray
    card: jnp.ndarray       # distinct entity count
    range_lo: jnp.ndarray   # window start in sorted order
    range_hi: jnp.ndarray   # window end (exclusive)

jax.tree_util.register_dataclass(
    ModeComponents, data_fields=["sig_lo", "sig_hi", "card", "range_lo",
                                 "range_hi"],
    meta_fields=[])


def prime_components(sm: SortedMode, r_lo: jnp.ndarray,
                     r_hi: jnp.ndarray) -> ModeComponents:
    """Prime cumulus operator (Alg. 2+3): the component of a tuple along a
    mode is its *whole* key segment.  Signatures/cardinalities are segment
    sums of first-occurrence-masked hash weights."""
    t = sm.sorted_e.shape[0]
    w_lo = jnp.where(sm.first_occ, r_lo[sm.sorted_e], jnp.uint32(0))
    w_hi = jnp.where(sm.first_occ, r_hi[sm.sorted_e], jnp.uint32(0))
    sig_lo = jax.ops.segment_sum(w_lo, sm.seg, num_segments=t)
    sig_hi = jax.ops.segment_sum(w_hi, sm.seg, num_segments=t)
    distinct = jax.ops.segment_sum(sm.first_occ.astype(jnp.int32), sm.seg,
                                   num_segments=t)
    my = sm.seg[sm.inv]
    start = sm.seg_start[my].astype(jnp.int32)
    return ModeComponents(sig_lo[my], sig_hi[my], distinct[my], start,
                          start + sm.seg_len[my].astype(jnp.int32))


def bsearch(vals: jnp.ndarray, lo0: jnp.ndarray, hi0: jnp.ndarray,
            target: jnp.ndarray, leq: bool) -> jnp.ndarray:
    """Vectorised binary search. Returns, per query, the first index in
    [lo0, hi0) where vals[idx] >= target (leq=False: lower bound) or
    vals[idx] > target (leq=True: upper bound); hi0 if none."""
    t = vals.shape[0]
    iters = max(1, int(np.ceil(np.log2(max(t, 2)))) + 1)
    lo, hi = lo0, hi0
    for _ in range(iters):
        mid = (lo + hi) // 2
        v = vals[jnp.clip(mid, 0, t - 1)]
        go_right = (v <= target) if leq else (v < target)
        go_right = go_right & (lo < hi)
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(go_right | (lo >= hi), hi, mid)
    return lo


def delta_components(sm: SortedMode, r_lo: jnp.ndarray, r_hi: jnp.ndarray,
                     values: jnp.ndarray, delta: float) -> ModeComponents:
    """δ-range operator (NOAC, §3.2/§4.3): the component of a tuple with
    value v0 is the contiguous value-window [v0-δ, v0+δ] *inside* its key
    segment, found with two binary searches.  Signatures are differences
    of prefix sums of first-occurrence-masked hash weights (modular
    arithmetic makes range differences exact)."""
    t = sm.sorted_e.shape[0]
    w_lo = jnp.where(sm.first_occ, r_lo[sm.sorted_e], jnp.uint32(0))
    w_hi = jnp.where(sm.first_occ, r_hi[sm.sorted_e], jnp.uint32(0))
    zero_u = jnp.zeros((1,), jnp.uint32)
    pref_lo = jnp.concatenate([zero_u, jnp.cumsum(w_lo, dtype=jnp.uint32)])
    pref_hi = jnp.concatenate([zero_u, jnp.cumsum(w_hi, dtype=jnp.uint32)])
    pref_cnt = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32),
         jnp.cumsum(sm.first_occ.astype(jnp.int32), dtype=jnp.int32)])
    # per-tuple query window inside its own segment
    my = sm.seg[sm.inv]
    a = sm.seg_start[my]
    b = a + sm.seg_len[my]
    lo_idx = bsearch(sm.sorted_vals, a, b, values - jnp.float32(delta),
                     leq=False)
    hi_idx = bsearch(sm.sorted_vals, a, b, values + jnp.float32(delta),
                     leq=True)
    return ModeComponents(pref_lo[hi_idx] - pref_lo[lo_idx],
                          pref_hi[hi_idx] - pref_hi[lo_idx],
                          pref_cnt[hi_idx] - pref_cnt[lo_idx],
                          lo_idx.astype(jnp.int32),
                          hi_idx.astype(jnp.int32))


# ---------------------------------------------------------------------------
# Stage 3: dedup + generating-tuple counts
# ---------------------------------------------------------------------------

def stage3_dedup(sig_lo: jnp.ndarray, sig_hi: jnp.ndarray,
                 tuple_first: jnp.ndarray):
    """Dedup clusters on their signatures with one sort; count *distinct*
    generating tuples per cluster (Alg. 6+7 reducer semantics).

    Returns (gen_count, is_unique) in original tuple order; ``is_unique``
    marks the first distinct generating tuple of each cluster."""
    t = sig_lo.shape[0]
    order = lex_perm([sig_lo, sig_hi])
    s_lo, s_hi = sig_lo[order], sig_hi[order]
    s_first = tuple_first[order]
    cstart = segment_starts([s_lo, s_hi])
    cseg = jnp.cumsum(cstart) - 1
    gen = jax.ops.segment_sum(s_first.astype(jnp.int32), cseg,
                              num_segments=t)
    gen_of = jnp.zeros((t,), jnp.int32).at[order].set(gen[cseg])
    pos = jnp.arange(t)
    first_pos = jax.ops.segment_min(jnp.where(s_first, pos, t), cseg,
                                    num_segments=t)
    uniq_sorted = (pos == first_pos[cseg]) & s_first
    is_unique = jnp.zeros((t,), bool).at[order].set(uniq_sorted)
    return gen_of, is_unique


# ---------------------------------------------------------------------------
# The full pipeline
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PipelineResult:
    """Unified per-tuple mining output (original tuple order; length-T
    arrays), shared by every backend and variant."""
    sig_lo: jnp.ndarray        # cluster signature of the tuple's cluster
    sig_hi: jnp.ndarray
    is_unique: jnp.ndarray     # bool: first distinct generating tuple
    gen_count: jnp.ndarray     # distinct generating tuples of the cluster
    volume: jnp.ndarray        # float32 Π_k |component_k|
    density: jnp.ndarray       # Alg. 7 estimate  gen_count / volume
    keep: jnp.ndarray          # unique & density ≥ θ (& minsup)
    cardinalities: jnp.ndarray  # (N, T) distinct |component_k| per tuple
    range_lo: jnp.ndarray      # (N, T) component window starts (sorted ord.)
    range_hi: jnp.ndarray      # (N, T) window ends (exclusive)
    sorted_e: jnp.ndarray      # (N, T) per-mode entity columns, sorted order
    perms: jnp.ndarray         # (N, T) per-mode sort permutations

jax.tree_util.register_dataclass(
    PipelineResult,
    data_fields=["sig_lo", "sig_hi", "is_unique", "gen_count", "volume",
                 "density", "keep", "cardinalities", "range_lo", "range_hi",
                 "sorted_e", "perms"],
    meta_fields=[])


def mine_tuples(tuples: jnp.ndarray, hash_lo: Sequence[jnp.ndarray],
                hash_hi: Sequence[jnp.ndarray], *,
                values: Optional[jnp.ndarray] = None,
                delta: Optional[float] = None, theta: float = 0.0,
                minsup: int = 0,
                perms: Optional[jnp.ndarray] = None) -> PipelineResult:
    """The full three-stage pipeline on one shard (jit-able; T, N static).

    ``delta=None`` runs the prime cumulus operator (multimodal/OAC);
    otherwise the δ-range operator (NOAC) with ``theta`` acting as ρ_min
    and ``minsup`` as the per-mode minimal cardinality.  ``perms``
    (N, T) supplies precomputed per-mode sort orders (streaming)."""
    t, n = tuples.shape
    comps, sms = [], []
    for k in range(n):
        sm = sort_mode(tuples, k, values=values,
                       perm=None if perms is None else perms[k])
        if delta is None:
            comps.append(prime_components(sm, hash_lo[k], hash_hi[k]))
        else:
            comps.append(delta_components(sm, hash_lo[k], hash_hi[k],
                                          values, delta))
        sms.append(sm)
    # Stage 2: per-tuple cluster = mix of per-mode component aggregates.
    sig_lo, sig_hi = mix_signatures([c.sig_lo for c in comps],
                                    [c.sig_hi for c in comps])
    volume = jnp.ones((t,), jnp.float32)
    for c in comps:
        volume = volume * c.card.astype(jnp.float32)
    # Stage 3.  Mode 0's sort key covers the whole row, so its
    # first-of-run flags already mark the lowest-index copy of each
    # duplicate row (stable sorts) — no extra full-table sort needed.
    tfirst = jnp.zeros((t,), bool).at[sms[0].perm].set(sms[0].first_occ)
    gen_of, is_unique = stage3_dedup(sig_lo, sig_hi, tfirst)
    density = gen_of.astype(jnp.float32) / jnp.maximum(volume, 1.0)
    keep = is_unique & (density >= jnp.float32(theta))
    if minsup:
        for c in comps:
            keep = keep & (c.card >= minsup)
    return PipelineResult(
        sig_lo, sig_hi, is_unique, gen_of, volume, density, keep,
        cardinalities=jnp.stack([c.card for c in comps]),
        range_lo=jnp.stack([c.range_lo for c in comps]),
        range_hi=jnp.stack([c.range_hi for c in comps]),
        sorted_e=jnp.stack([sm.sorted_e for sm in sms]),
        perms=jnp.stack([sm.perm.astype(jnp.int32) for sm in sms]))


# ---------------------------------------------------------------------------
# Host-side materialisation (shared by all engines with component ranges)
# ---------------------------------------------------------------------------

def materialise(result: PipelineResult, only_kept: bool = True):
    """Extract cluster component sets [(components, density), ...] for kept
    (or all unique) tuples by slicing the per-mode sorted windows."""
    flag = np.asarray(result.keep if only_kept else result.is_unique)
    rlo, rhi = np.asarray(result.range_lo), np.asarray(result.range_hi)
    sorted_e = np.asarray(result.sorted_e)
    dens = np.asarray(result.density)
    n = sorted_e.shape[0]
    out = []
    for i in np.nonzero(flag)[0]:
        comps = []
        for k in range(n):
            window = sorted_e[k][rlo[k, i]:rhi[k, i]]
            comps.append(frozenset(np.unique(window).tolist()))
        out.append((tuple(comps), float(dens[i])))
    return out


class PipelineMiner:
    """Base driver: jit-compiled single-shard pipeline over fixed sizes.

    Subclasses (``BatchMiner``, ``NOACMiner``) pin the component operator;
    everything else — hashing, jit caching, materialisation — is shared."""

    def __init__(self, sizes: Sequence[int], *, theta: float = 0.0,
                 delta: Optional[float] = None, minsup: int = 0,
                 seed: int = 0x5EED):
        self.sizes = tuple(int(s) for s in sizes)
        self.theta = float(theta)
        self.delta = None if delta is None else float(delta)
        self.minsup = int(minsup)
        vecs = mode_hash_vectors(self.sizes, seed)
        self._lo = [jnp.asarray(lo) for lo, _ in vecs]
        self._hi = [jnp.asarray(hi) for _, hi in vecs]
        self._fn = jax.jit(functools.partial(
            mine_tuples, delta=self.delta, theta=self.theta,
            minsup=self.minsup))

    def __call__(self, tuples, values=None) -> PipelineResult:
        tuples = jnp.asarray(tuples, jnp.int32)
        if self.delta is not None:
            if values is None:
                values = jnp.zeros((tuples.shape[0],), jnp.float32)
            values = jnp.asarray(values, jnp.float32)
        else:
            values = None
        return self._fn(tuples, self._lo, self._hi, values=values)

    def materialise(self, result: PipelineResult, tuples=None,
                    only_kept: bool = True):
        """``tuples`` is accepted for API compatibility and unused — the
        result carries its own component windows."""
        return materialise(result, only_kept)
