"""Shared Stage-1/2/3 mining pipeline — the one skeleton behind every
engine (DESIGN.md §3, "Unified pipeline").

The paper's M/R algorithm is the *same* three jobs for the prime OAC,
multimodal (N-ary) and many-valued (NOAC, §3.2/§4.3) variants; only the
per-key *component operator* differs.  This module is that factoring:

  Stage 1  ``sort_mode``            per-mode sort of the tuple table by
           the mode's shuffle key (the N-1 "other" columns, plus the
           value column for many-valued contexts) and segmentation of
           the sorted order — the Hadoop shuffle-by-subrelation as a
           sort.  When the key fits 64 bits (``core.keys`` plans), the
           sort is ONE stable sort over the packed key word(s) — the
           bit-plan-pruned radix backend (``core.radix``) by default,
           or one ``lax.sort`` with payloads as sort operands
           (``sort_backend='lax'``); otherwise the N+1-column lexsort
           fallback runs behind the same API.
  comp-op  ``prime_components``     cumulus = the whole key segment.
           ``delta_components``     δ-range inside the key segment
                                    (two vectorised binary searches).
           This is the only place the variants differ.
  Stage 2  ``mix_signatures``       gather per-mode ⟨signature,
           cardinality⟩ aggregates back to each generating tuple.
  Stage 3  ``stage3_dedup``         order-independent dedup + distinct
           generating-tuple counts on 2×32-bit set signatures, via one
           more sort; density is the paper-faithful Alg. 7 estimate
           ``#distinct generating tuples / volume``.

``mine_tuples`` composes the stages into the full jit-able pipeline;
``batch``, ``distributed``, ``streaming`` and ``manyvalued`` are thin
drivers around it (single shard / shard_map mesh / incremental sorted
runs).  All signatures are *order-independent modular sums of
first-occurrence-masked hash weights*, which makes every engine
duplicate-idempotent (M/R at-least-once, §5.1) and lets the distributed
and streaming engines reproduce single-shard results bit-exactly.

Shapes are static in ``T`` (tuples) and ``N`` (arity), so each engine
jits once per context shape.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

# jax version compatibility (canonical home: repro._compat)
from .._compat import shard_map  # noqa: F401  (re-export for the engines)
from ..kernels import ops as kops
from . import keys as K
from . import radix as RX


# ---------------------------------------------------------------------------
# Hashing
# ---------------------------------------------------------------------------

# Per-mode multipliers for mixing mode signatures into a cluster signature.
# Odd constants (invertible mod 2^32) from splitmix64 / Weyl sequences.
_MIX = np.array([0x9E3779B1, 0x85EBCA77, 0xC2B2AE3D, 0x27D4EB2F,
                 0x165667B1, 0xD3A2646D, 0xFD7046C5, 0xB55A4F09],
                dtype=np.uint32)


def mode_hash_vectors(sizes: Sequence[int], seed: int = 0x5EED):
    """Two independent uint32 hash vectors per mode (host-side, fixed seed).

    Every engine built from the same (sizes, seed) produces bit-identical
    cluster signatures — the cross-backend parity guarantee."""
    rng = np.random.Generator(np.random.Philox(seed))
    return [
        (rng.integers(1, 2**32, size=n, dtype=np.uint32),
         rng.integers(1, 2**32, size=n, dtype=np.uint32))
        for n in sizes
    ]


def mix_signatures(per_mode_lo, per_mode_hi):
    """Combine per-mode set signatures into one 2×32-bit cluster signature."""
    lo = jnp.zeros_like(per_mode_lo[0])
    hi = jnp.zeros_like(per_mode_hi[0])
    for k, (slo, shi) in enumerate(zip(per_mode_lo, per_mode_hi)):
        lo = lo + jnp.uint32(_MIX[k % len(_MIX)]) * slo
        hi = hi + jnp.uint32(_MIX[(k + 3) % len(_MIX)]) * shi
    # final avalanche
    lo = (lo ^ (lo >> 16)) * jnp.uint32(0x7FEB352D)
    hi = (hi ^ (hi >> 15)) * jnp.uint32(0x846CA68B)
    return lo, hi


# ---------------------------------------------------------------------------
# Sorting / segmentation primitives
# ---------------------------------------------------------------------------

def lex_perm(columns: Sequence[jnp.ndarray]) -> jnp.ndarray:
    """Permutation sorting rows lexicographically by ``columns`` (first column
    is the most significant key)."""
    return jnp.lexsort(tuple(reversed(list(columns))))


def segment_starts(sorted_key_cols: Sequence[jnp.ndarray]) -> jnp.ndarray:
    """Boolean start-of-segment flags for already-sorted key columns."""
    t = sorted_key_cols[0].shape[0]
    change = jnp.zeros((t,), bool).at[0].set(True)
    for c in sorted_key_cols:
        change = change | jnp.concatenate(
            [jnp.ones((1,), bool), c[1:] != c[:-1]])
    return change


def segment_bounds(flags: jnp.ndarray):
    """Per sorted position: the [a, b) window of its own run, where
    ``flags`` marks run starts (``flags[0]`` must be True).

    Two O(T) scans — a forward cummax and a reverse cummin — instead of
    the segment-id cumsum + ``segment_min``/``segment_sum`` scatter
    formulation, which dominates Stage-1 time on scatter-unfriendly
    backends."""
    t = flags.shape[0]
    pos = jnp.arange(t, dtype=jnp.int32)
    a = jax.lax.cummax(jnp.where(flags, pos, 0))
    suff = jax.lax.cummin(jnp.where(flags, pos, jnp.int32(t)), reverse=True)
    b = jnp.concatenate([suff[1:], jnp.full((1,), t, jnp.int32)])
    return a, b


@dataclasses.dataclass
class SortedMode:
    """Stage-1 output for one mode: the tuple table sorted by the mode's
    shuffle key and segmented by it.  All arrays have length T and are
    indexed by *sorted* position; ``seg_a``/``seg_b`` delimit each
    position's own key segment as a half-open window of sorted order."""
    perm: jnp.ndarray         # sorted order of tuples
    inv: jnp.ndarray          # inverse permutation (original → sorted pos)
    seg_a: jnp.ndarray        # segment start per sorted position
    seg_b: jnp.ndarray        # segment end (exclusive) per sorted position
    sorted_e: jnp.ndarray     # mode-k entity column under perm
    sorted_vals: Optional[jnp.ndarray]  # values under perm (None: prime)
    first_occ: jnp.ndarray    # per sorted position: first of its
                              # identical (key[, value], e) run
    sorted_words: Optional[tuple] = None  # packed key words (packed path)
    plan: Optional[K.ModeKeyPlan] = None  # the key layout (packed path)

jax.tree_util.register_dataclass(
    SortedMode, data_fields=["perm", "inv", "seg_a", "seg_b",
                             "sorted_e", "sorted_vals", "first_occ",
                             "sorted_words"],
    meta_fields=["plan"])


def mode_key_columns(tuples: jnp.ndarray, k: int,
                     values: Optional[jnp.ndarray] = None):
    """Mode ``k``'s lexicographic sort-key columns — (others..., [value,]
    e_k) — as (others, tail) lists.  THE column order of Stage 1's sort
    (shared by ``sort_mode`` and the benchmark probes, so what the
    benchmarks time is what the pipeline runs)."""
    n = tuples.shape[1]
    others = [tuples[:, j] for j in range(n) if j != k]
    tail = ([values] if values is not None else []) + [tuples[:, k]]
    return others, tail


def mode_sort_perm(tuples: jnp.ndarray, k: int,
                   values: Optional[jnp.ndarray] = None,
                   plan: Optional[K.ModeKeyPlan] = None,
                   sort_backend: str = "radix",
                   use_pallas: bool = False,
                   value_domain: Optional[jnp.ndarray] = None):
    """Exactly Stage 1's sort — the part the sort backend swaps: key
    packing + the stable word sort (packed plans) or the column lexsort.
    Returns (perm, sorted_words-or-None).  ``sort_mode`` builds on this;
    ``benchmarks/packed.py`` times it in isolation (``stage1_sort_ms``)."""
    t = tuples.shape[0]
    if plan is not None and plan.fits:
        words = plan.pack_device(tuples, values, domain=value_domain)
        s_words, (perm,) = K.sort_with_payload(
            words, (jnp.arange(t, dtype=jnp.int32),),
            backend=sort_backend, live_bits=plan.total_bits,
            use_pallas=use_pallas)
        return perm, s_words
    others, tail = mode_key_columns(tuples, k, values)
    return lex_perm(others + tail), None


def sort_mode(tuples: jnp.ndarray, k: int,
              values: Optional[jnp.ndarray] = None,
              perm: Optional[jnp.ndarray] = None,
              plan: Optional[K.ModeKeyPlan] = None,
              sort_backend: str = "radix",
              use_pallas: bool = False,
              value_domain: Optional[jnp.ndarray] = None) -> SortedMode:
    """Stage 1 for mode k.  Sort key: (other columns..., [value,] e_k), so
    duplicates of a (key[, value], e) pair land adjacent and the
    ``first_occ`` mask makes all downstream sums duplicate-idempotent.

    ``plan`` (a fitting ``keys.ModeKeyPlan``) selects the packed-key
    path: one stable sort on 1–2 uint32 key words — the bit-plan-pruned
    radix backend by default, or one ``lax.sort`` carrying the
    permutation iota as payload (``sort_backend='lax'``); the entity
    and value columns are decoded from the sorted key's bit-fields, and
    segment/first-occurrence flags are 1–2 word comparisons.  Without a
    plan (or when the key exceeds 64 bits) the N+1-column lexsort
    fallback runs.  All paths are bit-identical (the packed word order
    *is* the lexicographic column order, and every sort is stable).

    ``perm`` short-circuits the sort with a precomputed permutation (the
    streaming engine maintains one by merging sorted runs)."""
    t, n = tuples.shape
    s_words = None
    if plan is not None and plan.fits:
        if perm is None:
            perm, s_words = mode_sort_perm(tuples, k, values, plan,
                                           sort_backend, use_pallas,
                                           value_domain)
        else:
            words = plan.pack_device(tuples, values, domain=value_domain)
            s_words = tuple(w[perm] for w in words)
        # the sorted value column is a bit-field of the sorted key — decode
        # it instead of carrying a float payload through the sort
        s_vals = (plan.extract_values(s_words, domain=value_domain)
                  if values is not None else None)
        s_e = plan.extract_entity(s_words)
        seg_flag = segment_starts(K.drop_low_bits(s_words, plan.seg_shift))
        first_occ = segment_starts(s_words)
    else:
        plan = None
        others, tail = mode_key_columns(tuples, k, values)
        if perm is None:
            perm = lex_perm(others + tail)
        s_others = [c[perm] for c in others]
        s_e = tuples[perm, k]
        s_vals = values[perm] if values is not None else None
        seg_flag = segment_starts(s_others)
        first_occ = segment_starts(
            s_others + ([s_vals] if s_vals is not None else []) + [s_e])
    seg_a, seg_b = segment_bounds(seg_flag)
    pos = jnp.arange(t, dtype=jnp.int32)
    inv = jnp.zeros((t,), jnp.int32).at[perm].set(pos)
    return SortedMode(perm, inv, seg_a, seg_b, s_e, s_vals, first_occ,
                      s_words, plan)


# ---------------------------------------------------------------------------
# Component operators (the pluggable part)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ModeComponents:
    """One mode's component per tuple, in *original* tuple order.

    ``range_lo``/``range_hi`` delimit the component as a half-open window
    of the mode's sorted order — the cumulus tables of the paper shrink
    from O(|I|·Σ|A_j|) dictionary bytes to O(|I|) ranges."""
    sig_lo: jnp.ndarray     # order-independent set hash of the component
    sig_hi: jnp.ndarray
    card: jnp.ndarray       # distinct entity count
    range_lo: jnp.ndarray   # window start in sorted order
    range_hi: jnp.ndarray   # window end (exclusive)

jax.tree_util.register_dataclass(
    ModeComponents, data_fields=["sig_lo", "sig_hi", "card", "range_lo",
                                 "range_hi"],
    meta_fields=[])


def masked_prefix(w_lo: jnp.ndarray, w_hi: jnp.ndarray,
                  first_occ: jnp.ndarray, use_pallas: bool = False):
    """Exclusive (length T+1) prefix sums of first-occurrence-masked hash
    weights and of the mask — the one segment-reduction sweep both
    component operators consume (``kernels/segment_reduce`` fuses the
    three sums into a single pass; ``use_pallas=False`` runs the
    bit-identical jnp oracle)."""
    lo, hi, cnt = kops.segment_reduce(w_lo, w_hi, first_occ,
                                      use_pallas=use_pallas)
    zu = jnp.zeros((1,), jnp.uint32)
    return (jnp.concatenate([zu, lo]), jnp.concatenate([zu, hi]),
            jnp.concatenate([jnp.zeros((1,), jnp.int32), cnt]))


def prime_components(sm: SortedMode, r_lo: jnp.ndarray, r_hi: jnp.ndarray,
                     use_pallas: bool = False) -> ModeComponents:
    """Prime cumulus operator (Alg. 2+3): the component of a tuple along a
    mode is its *whole* key segment.  Signatures/cardinalities are
    boundary differences of the fused masked prefix sums (modular uint32
    arithmetic makes them exactly the segment sums)."""
    pref_lo, pref_hi, pref_cnt = masked_prefix(
        r_lo[sm.sorted_e], r_hi[sm.sorted_e], sm.first_occ, use_pallas)
    a = sm.seg_a[sm.inv]
    b = sm.seg_b[sm.inv]
    return ModeComponents(pref_lo[b] - pref_lo[a], pref_hi[b] - pref_hi[a],
                          pref_cnt[b] - pref_cnt[a], a, b)


def bsearch(vals: jnp.ndarray, lo0: jnp.ndarray, hi0: jnp.ndarray,
            target: jnp.ndarray, leq: bool) -> jnp.ndarray:
    """Vectorised binary search. Returns, per query, the first index in
    [lo0, hi0) where vals[idx] >= target (leq=False: lower bound) or
    vals[idx] > target (leq=True: upper bound); hi0 if none."""
    t = vals.shape[0]
    iters = max(1, int(np.ceil(np.log2(max(t, 2)))) + 1)
    lo, hi = lo0, hi0
    for _ in range(iters):
        mid = (lo + hi) // 2
        v = vals[jnp.clip(mid, 0, t - 1)]
        go_right = (v <= target) if leq else (v < target)
        go_right = go_right & (lo < hi)
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(go_right | (lo >= hi), hi, mid)
    return lo


def delta_components(sm: SortedMode, r_lo: jnp.ndarray, r_hi: jnp.ndarray,
                     values: jnp.ndarray, delta: float,
                     use_pallas: bool = False,
                     value_domain: Optional[jnp.ndarray] = None
                     ) -> ModeComponents:
    """δ-range operator (NOAC, §3.2/§4.3): the component of a tuple with
    value v0 is the contiguous value-window [v0-δ, v0+δ] *inside* its key
    segment, found with two binary searches.  Signatures are differences
    of the fused masked prefix sums (modular arithmetic makes range
    differences exact)."""
    pref_lo, pref_hi, pref_cnt = masked_prefix(
        r_lo[sm.sorted_e], r_hi[sm.sorted_e], sm.first_occ, use_pallas)
    # per-tuple query window inside its own segment
    if sm.sorted_words is not None and sm.plan is not None \
            and sm.plan.with_values:
        # packed path: δ-window bounds by *global* search over the sorted
        # key words — the query key carries the tuple's own subrelation
        # prefix with the value lane set to v∓δ and e_k at its extreme,
        # so the search self-clamps to the segment and no per-query
        # window (or segment_bounds scan) is needed.  -0.0 targets are
        # normalised so word order agrees with float order.
        plan, d = sm.plan, jnp.float32(delta)
        t_lo, t_hi = sm.sorted_vals - d, sm.sorted_vals + d
        if plan.value_bits == 32:
            t_lo = jnp.where(t_lo == 0, jnp.float32(0.0), t_lo)
            t_hi = jnp.where(t_hi == 0, jnp.float32(0.0), t_hi)
            lane_lo = K.float_sort_bits(t_lo)
            lane_hi = K.float_sort_bits(t_hi)
        else:
            # rank-coded lane: the window bounds are domain ranks.  Every
            # value ≥ v-δ has rank ≥ searchsorted-left(v-δ); every value
            # ≤ v+δ has rank ≤ searchsorted-right(v+δ)-1 (≥ 0: the
            # tuple's own value is in the domain and ≤ v+δ).
            dom = value_domain.astype(jnp.float32)
            lane_lo = jnp.searchsorted(dom, t_lo,
                                       side="left").astype(jnp.uint32)
            lane_hi = (jnp.searchsorted(dom, t_hi, side="right")
                       - 1).astype(jnp.uint32)
        q_lo = plan.delta_query_words(sm.sorted_words, lane_lo)
        q_hi = plan.delta_query_words(sm.sorted_words, lane_hi)
        q_hi = q_hi[:-1] + (q_hi[-1] | jnp.uint32(plan.e_mask),)
        lo_idx = K.search_words(sm.sorted_words, q_lo, upper=False)[sm.inv]
        hi_idx = K.search_words(sm.sorted_words, q_hi, upper=True)[sm.inv]
    else:
        a = sm.seg_a[sm.inv]
        b = sm.seg_b[sm.inv]
        lo_idx = bsearch(sm.sorted_vals, a, b, values - jnp.float32(delta),
                         leq=False)
        hi_idx = bsearch(sm.sorted_vals, a, b, values + jnp.float32(delta),
                         leq=True)
    return ModeComponents(pref_lo[hi_idx] - pref_lo[lo_idx],
                          pref_hi[hi_idx] - pref_hi[lo_idx],
                          pref_cnt[hi_idx] - pref_cnt[lo_idx],
                          lo_idx.astype(jnp.int32),
                          hi_idx.astype(jnp.int32))


# ---------------------------------------------------------------------------
# Stage 3: dedup + generating-tuple counts
# ---------------------------------------------------------------------------

def stage3_dedup(sig_lo: jnp.ndarray, sig_hi: jnp.ndarray,
                 tuple_first: jnp.ndarray, packed: bool = True,
                 sort_backend: str = "radix", use_pallas: bool = False):
    """Dedup clusters on their signatures with one sort; count *distinct*
    generating tuples per cluster (Alg. 6+7 reducer semantics).

    ``packed`` keys the sort on the (sig_lo, sig_hi) pair — the 2×32-bit
    cluster signature as one uint64 word, all 64 bits live for the
    radix backend (signatures are avalanched hashes); the lexsort
    branch is the bit-identical baseline kept for benchmarking.

    Returns (gen_count, is_unique) in original tuple order; ``is_unique``
    marks the first distinct generating tuple of each cluster."""
    t = sig_lo.shape[0]
    if packed:
        (s_lo, s_hi), (order,) = K.sort_with_payload(
            (sig_lo, sig_hi), (jnp.arange(t, dtype=jnp.int32),),
            backend=sort_backend, live_bits=64, use_pallas=use_pallas)
    else:
        order = lex_perm([sig_lo, sig_hi])
        s_lo, s_hi = sig_lo[order], sig_hi[order]
    s_first = tuple_first[order]
    cstart = segment_starts([s_lo, s_hi])
    a, b = segment_bounds(cstart)
    # distinct generating tuples per cluster: prefix-count differences at
    # the cluster window bounds (no scatter); a tuple is the cluster's
    # unique representative iff it is the window's first s_first entry.
    pref = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32),
         jnp.cumsum(s_first.astype(jnp.int32), dtype=jnp.int32)])
    pos = jnp.arange(t, dtype=jnp.int32)
    uniq_sorted = s_first & (pref[pos] == pref[a])
    # one inverse-permutation scatter + two gathers (scatters dominate
    # the non-sort cost of the pipeline on scatter-unfriendly backends)
    inv_order = jnp.zeros((t,), jnp.int32).at[order].set(pos)
    gen_of = (pref[b] - pref[a])[inv_order]
    is_unique = uniq_sorted[inv_order]
    return gen_of, is_unique


# ---------------------------------------------------------------------------
# The full pipeline
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class PipelineResult:
    """Unified per-tuple mining output (original tuple order; length-T
    arrays), shared by every backend and variant."""
    sig_lo: jnp.ndarray        # cluster signature of the tuple's cluster
    sig_hi: jnp.ndarray
    is_unique: jnp.ndarray     # bool: first distinct generating tuple
    gen_count: jnp.ndarray     # distinct generating tuples of the cluster
    volume: jnp.ndarray        # float32 Π_k |component_k|
    density: jnp.ndarray       # Alg. 7 estimate  gen_count / volume
    keep: jnp.ndarray          # unique & density ≥ θ (& minsup)
    cardinalities: jnp.ndarray  # (N, T) distinct |component_k| per tuple
    range_lo: jnp.ndarray      # (N, T) component window starts (sorted ord.)
    range_hi: jnp.ndarray      # (N, T) window ends (exclusive)
    sorted_e: jnp.ndarray      # (N, T) per-mode entity columns, sorted order
    perms: jnp.ndarray         # (N, T) per-mode sort permutations

jax.tree_util.register_dataclass(
    PipelineResult,
    data_fields=["sig_lo", "sig_hi", "is_unique", "gen_count", "volume",
                 "density", "keep", "cardinalities", "range_lo", "range_hi",
                 "sorted_e", "perms"],
    meta_fields=[])


def mine_tuples(tuples: jnp.ndarray, hash_lo: Sequence[jnp.ndarray],
                hash_hi: Sequence[jnp.ndarray], *,
                values: Optional[jnp.ndarray] = None,
                delta: Optional[float] = None, theta: float = 0.0,
                minsup: int = 0,
                perms: Optional[jnp.ndarray] = None,
                packed: Optional[bool] = None,
                sort_backend: Optional[str] = None,
                use_pallas: Optional[bool] = None,
                value_domain: Optional[jnp.ndarray] = None) -> PipelineResult:
    """The full three-stage pipeline on one shard (jit-able; T, N static).

    ``delta=None`` runs the prime cumulus operator (multimodal/OAC);
    otherwise the δ-range operator (NOAC) with ``theta`` acting as ρ_min
    and ``minsup`` as the per-mode minimal cardinality.  ``perms``
    (N, T) supplies precomputed per-mode sort orders (streaming).

    ``packed`` selects the single-word Stage-1/3 sort path (None: packed
    whenever the context's key fits 64 bits; False: always lexsort — the
    benchmarking baseline); ``sort_backend`` picks the word-sort
    algorithm ('radix' — the bit-plan-pruned LSD default — or 'lax';
    'lexsort' forces the column path like ``packed=False``).
    ``use_pallas`` routes the Stage-2 segment reductions (and the radix
    backend's histogram/rank sweeps) through the fused Pallas kernels
    (None: on TPU only).  ``value_domain`` — the sorted distinct values
    of the many-valued column, when the caller knows them — prunes the
    key's value lane to rank width (``core.keys``), shrinking the radix
    pass schedule; orderings are unchanged (rank coding is
    order-isomorphic), so all sort paths stay bit-identical."""
    t, n = tuples.shape
    if delta is not None and delta < 0:
        raise ValueError(f"delta must be >= 0, got {delta}")
    if use_pallas is None:
        use_pallas = kops.on_tpu()
    if values is None:
        value_domain = None
    plans = K.plan_context_keys(
        [h.shape[0] for h in hash_lo], with_values=values is not None,
        value_slots=(None if value_domain is None
                     else value_domain.shape[0]))
    backend = RX.resolve_sort_backend(sort_backend, packed, plans[0].fits)
    use_packed = backend != "lexsort"
    # the (sig_lo, sig_hi) pair always fits two words, so Stage 3 keeps
    # its packed sort even when the context's own key does not fit
    s3_backend = RX.resolve_sort_backend(sort_backend, packed, True)
    comps, sms = [], []
    for k in range(n):
        sm = sort_mode(tuples, k, values=values,
                       perm=None if perms is None else perms[k],
                       plan=plans[k] if use_packed else None,
                       sort_backend=backend, use_pallas=use_pallas,
                       value_domain=value_domain)
        if delta is None:
            comps.append(prime_components(sm, hash_lo[k], hash_hi[k],
                                          use_pallas))
        else:
            comps.append(delta_components(sm, hash_lo[k], hash_hi[k],
                                          values, delta, use_pallas,
                                          value_domain=value_domain))
        sms.append(sm)
    # Stage 2: per-tuple cluster = mix of per-mode component aggregates.
    sig_lo, sig_hi = mix_signatures([c.sig_lo for c in comps],
                                    [c.sig_hi for c in comps])
    volume = jnp.ones((t,), jnp.float32)
    for c in comps:
        volume = volume * c.card.astype(jnp.float32)
    # Stage 3.  Mode 0's sort key covers the whole row, so its
    # first-of-run flags already mark the lowest-index copy of each
    # duplicate row (stable sorts) — no extra full-table sort needed;
    # gathering through mode 0's inverse permutation avoids a scatter.
    tfirst = sms[0].first_occ[sms[0].inv]
    gen_of, is_unique = stage3_dedup(sig_lo, sig_hi, tfirst,
                                     packed=s3_backend != "lexsort",
                                     sort_backend=s3_backend,
                                     use_pallas=use_pallas)
    density = gen_of.astype(jnp.float32) / jnp.maximum(volume, 1.0)
    keep = is_unique & (density >= jnp.float32(theta))
    if minsup:
        for c in comps:
            keep = keep & (c.card >= minsup)
    return PipelineResult(
        sig_lo, sig_hi, is_unique, gen_of, volume, density, keep,
        cardinalities=jnp.stack([c.card for c in comps]),
        range_lo=jnp.stack([c.range_lo for c in comps]),
        range_hi=jnp.stack([c.range_hi for c in comps]),
        sorted_e=jnp.stack([sm.sorted_e for sm in sms]),
        perms=jnp.stack([sm.perm.astype(jnp.int32) for sm in sms]))


# ---------------------------------------------------------------------------
# Host-side materialisation (shared by all engines with component ranges)
# ---------------------------------------------------------------------------

def materialise(result: PipelineResult, only_kept: bool = True):
    """Extract cluster component sets [(components, density), ...] for kept
    (or all unique) tuples by slicing the per-mode sorted windows."""
    flag = np.asarray(result.keep if only_kept else result.is_unique)
    rlo, rhi = np.asarray(result.range_lo), np.asarray(result.range_hi)
    sorted_e = np.asarray(result.sorted_e)
    dens = np.asarray(result.density)
    n = sorted_e.shape[0]
    out = []
    for i in np.nonzero(flag)[0]:
        comps = []
        for k in range(n):
            window = sorted_e[k][rlo[k, i]:rhi[k, i]]
            comps.append(frozenset(np.unique(window).tolist()))
        out.append((tuple(comps), float(dens[i])))
    return out


def kept_sig_words(result) -> np.ndarray:
    """Sorted packed ``(sig_hi << 32) | sig_lo`` words of the kept
    clusters of one result — the per-snapshot signature *set* the
    serving layer diffs to find dirty clusters (``serve.clusters``
    packs identically; Stage 3 sorts the same word)."""
    keep = np.asarray(result.keep).astype(bool)
    m = np.uint64(0xFFFFFFFF)
    lo = np.asarray(result.sig_lo)[keep].astype(np.uint64) & m
    hi = np.asarray(result.sig_hi)[keep].astype(np.uint64) & m
    return np.unique((hi << np.uint64(32)) | lo)


def dirty_sig_count(prev: Optional[np.ndarray],
                    cur: np.ndarray) -> int:
    """Size of the symmetric difference of two sorted signature-word
    sets — how many clusters changed identity between two consecutive
    snapshots (the delta-index workload, surfaced by miners when
    ``track_dirty_sigs`` is on)."""
    if prev is None:
        return int(cur.size)
    inter = np.intersect1d(cur, prev, assume_unique=True).size
    return int(cur.size) + int(prev.size) - 2 * int(inter)


def _active_obs(obs):
    """The enabled observability hub or None — the pipeline's
    zero-overhead-when-disabled gate.  Duck-typed (``.enabled``,
    ``.metrics``, ``.tracer``) so ``core`` never imports ``repro.obs``;
    callers pass a ``repro.obs.Obs`` (or nothing)."""
    return obs if (obs is not None
                   and getattr(obs, "enabled", False)) else None


class PipelineMiner:
    """Base driver: jit-compiled single-shard pipeline over fixed sizes.

    Subclasses (``BatchMiner``, ``NOACMiner``) pin the component operator;
    everything else — hashing, jit caching, materialisation — is shared.

    ``obs`` (an enabled ``repro.obs.Obs``) turns on per-stage wall-time
    profiling: host run-sort vs device mine split, per-window stage
    timings and memory peaks on the windowed path.  ``obs=None`` (the
    default) keeps every hot loop at a single predicate test."""

    def __init__(self, sizes: Sequence[int], *, theta: float = 0.0,
                 delta: Optional[float] = None, minsup: int = 0,
                 seed: int = 0x5EED, packed: Optional[bool] = None,
                 sort_backend: Optional[str] = None,
                 use_pallas: Optional[bool] = None,
                 prune_values: bool = True,
                 window_budget: Optional[int] = None,
                 obs=None):
        self.obs = obs
        self.sizes = tuple(int(s) for s in sizes)
        self.window_budget = (None if window_budget is None
                              else int(window_budget))
        self.theta = float(theta)
        self.delta = None if delta is None else float(delta)
        if self.delta is not None and self.delta < 0:
            # a negative δ makes the window [v-δ, v+δ] empty; the rank-
            # coded lane's searchsorted bounds would underflow instead
            raise ValueError(f"delta must be >= 0, got {self.delta}")
        self.minsup = int(minsup)
        self.packed = packed
        self.sort_backend = sort_backend
        self.use_pallas = use_pallas
        self.prune_values = bool(prune_values)
        self.key_plans = K.plan_context_keys(self.sizes,
                                             with_values=delta is not None)
        vecs = mode_hash_vectors(self.sizes, seed)
        self._lo = [jnp.asarray(lo) for lo, _ in vecs]
        self._hi = [jnp.asarray(hi) for _, hi in vecs]
        self._fn = jax.jit(functools.partial(
            mine_tuples, delta=self.delta, theta=self.theta,
            minsup=self.minsup, packed=packed, sort_backend=sort_backend,
            use_pallas=use_pallas))

    @property
    def resolved_sort_backend(self) -> str:
        """The actual Stage-1 sort path: 'radix' | 'lax' | 'lexsort'."""
        return RX.resolve_sort_backend(self.sort_backend, self.packed,
                                       self.key_plans[0].fits)

    @property
    def packed_active(self) -> bool:
        """True when Stage 1 runs the packed single-sort path."""
        return self.resolved_sort_backend != "lexsort"

    def value_domain(self, values) -> Optional[jnp.ndarray]:
        """Sorted distinct values for lane pruning (None when pruning is
        off or the caller forced the lexsort path — the shared
        ``radix.wants_value_pruning`` gate)."""
        if values is None or not RX.wants_value_pruning(
                self.prune_values, self.packed, self.sort_backend):
            return None
        return jnp.asarray(K.value_domain_host(values))

    def __call__(self, tuples, values=None) -> PipelineResult:
        obs = _active_obs(self.obs)
        t0 = time.perf_counter() if obs is not None else 0.0
        tuples = jnp.asarray(tuples, jnp.int32)
        if self.delta is not None:
            if values is None:
                values = jnp.zeros((tuples.shape[0],), jnp.float32)
            # domain from the caller's (usually host-side) array, before
            # the device transfer — np.unique never round-trips the
            # device column
            vdom = self.value_domain(values)
            values = jnp.asarray(values, jnp.float32)
        else:
            values, vdom = None, None
        res = self._fn(tuples, self._lo, self._hi, values=values,
                       value_domain=vdom)
        if obs is not None:
            # profiling forces the async dispatch to completion: the
            # measured figure is the real device wall time, and the
            # next stage's timer starts clean
            jax.block_until_ready(res)
            obs.metrics.histogram(
                "pipeline_stage_ms", stage="mine_monolithic").observe(
                    (time.perf_counter() - t0) * 1e3)
        return res

    def materialise(self, result: PipelineResult, tuples=None,
                    only_kept: bool = True):
        """``tuples`` is accepted for API compatibility and unused — the
        result carries its own component windows."""
        return materialise(result, only_kept)

    def mine_chunked(self, chunks, values=None,
                     chunk_budget: Optional[int] = None,
                     stats: Optional[dict] = None) -> PipelineResult:
        """Out-of-core chunked Stage 1 (DESIGN.md §4): build a host-side
        ``core.runs.RunStore`` chunk-by-chunk — each chunk sorted with
        O(chunk) working set, runs merged linearly — and feed the merged
        per-mode permutations to the jitted pipeline via ``perms``, so
        the device never sorts and the host never holds more than the
        row log plus one chunk's sort scratch.  Bit-identical to the
        in-core ``__call__`` on the same table (the store's host packers
        are the device packers, and stable merges preserve sort order).

        ``chunks`` is a single (T, N) table or an iterable of row
        chunks (``values`` aligned likewise for the δ variant);
        ``chunk_budget`` bounds rows-per-chunk, re-splitting anything
        larger.  A budget *smaller than the largest key segment* is
        fine — chunk runs merge stably, so a segment spanning many
        chunks reassembles exactly (``tests/test_window_property.py``
        regression-tests this); only degenerate budgets (< 1) raise.
        Valued tables get the constructor's last-write-wins
        canonicalisation (``core.runs``) — already-canonical contexts
        pass through unchanged.  Contexts whose key exceeds 64 bits
        fall back to one device sort of the assembled table."""
        from . import runs as RS
        if chunk_budget is not None and int(chunk_budget) < 1:
            raise ValueError(
                f"chunk_budget must be >= 1, got {chunk_budget}; pass "
                "None to ingest chunks as offered")
        obs = _active_obs(self.obs)
        t0 = time.perf_counter() if obs is not None else 0.0
        store = RS.RunStore(self.key_plans,
                            radix=self.resolved_sort_backend == "radix",
                            incremental=self.key_plans[0].fits,
                            stats=stats if stats is not None else {})
        for rows, vals in RS.iter_chunks(chunks, values, chunk_budget,
                                         with_values=self.delta is not None):
            store.add(rows, vals)
        store.prepare()
        if obs is not None:
            # the host run sort IS Stage 1's sort on this path
            obs.metrics.histogram(
                "pipeline_stage_ms", stage="stage1_sort").observe(
                    (time.perf_counter() - t0) * 1e3)
            t0 = time.perf_counter()
        if store.count == 0:
            raise ValueError("no data ingested")
        rows, vals = store.table()
        targs = jnp.asarray(rows, jnp.int32)
        vargs = None if vals is None else jnp.asarray(vals, jnp.float32)
        perms = store.perms()
        if perms is None:      # key exceeds 64 bits: no host runs
            # one device sort of the assembled table — with the same
            # value-lane pruning __call__ applies, so a key rescued by
            # the rank-coded lane still takes the packed path
            res = self._fn(targs, self._lo, self._hi, values=vargs,
                           value_domain=self.value_domain(vals))
        else:
            res = self._fn(targs, self._lo, self._hi, values=vargs,
                           perms=jnp.asarray(perms, jnp.int32))
        if obs is not None:
            jax.block_until_ready(res)
            obs.metrics.histogram(
                "pipeline_stage_ms", stage="device_mine").observe(
                    (time.perf_counter() - t0) * 1e3)
        return res

    def mine_windowed(self, chunks, values=None,
                      window_budget: Optional[int] = None,
                      stats: Optional[dict] = None,
                      probe=None) -> PipelineResult:
        """Fully windowed out-of-core mining (DESIGN.md §3c): the host
        run sort of :meth:`mine_chunked` *and* a device pipeline that
        streams Stage 1–3 through ``window_budget``-sized slices of
        the merged sorted order (``core.windowed``), so peak
        incremental device memory is O(window), not O(T).  The sort
        chunking and the device window loop share the one budget
        (``radix.plan_windows``).  Bit-identical to the in-core
        ``__call__`` on the same table; ``window_budget=None`` runs a
        single in-core window through the same code path.

        Raises for configurations the windowed path cannot honour
        bit-exactly (>64-bit keys, the forced-lexsort baseline) and
        for degenerate budgets — never a silent seam split."""
        from . import runs as RS
        from . import windowed as WD
        if window_budget is None:
            window_budget = self.window_budget
        if not self.key_plans[0].fits:
            raise ValueError(
                "mine_windowed needs 64-bit-packable keys; this "
                "context's key exceeds 64 bits — use mine_chunked")
        backend = self.resolved_sort_backend
        if backend == "lexsort":
            raise ValueError(
                "mine_windowed has no lexsort path (packed=False / "
                "sort_backend='lexsort'); use the monolithic pipeline "
                "for the lexsort baseline")
        if window_budget is not None and int(window_budget) < 1:
            raise ValueError(
                f"window_budget must be >= 1, got {window_budget}; "
                "pass None for a single in-core window")
        obs = _active_obs(self.obs)
        t0 = time.perf_counter() if obs is not None else 0.0
        store = RS.RunStore(self.key_plans, radix=backend == "radix",
                            incremental=True,
                            stats=stats if stats is not None else {})
        for rows, vals in RS.iter_chunks(chunks, values, window_budget,
                                         with_values=self.delta is not None):
            store.add(rows, vals)
        store.prepare()
        if obs is not None:
            obs.metrics.histogram(
                "pipeline_stage_ms", stage="stage1_sort").observe(
                    (time.perf_counter() - t0) * 1e3)
        if store.count == 0:
            raise ValueError("no data ingested")
        rows, vals = store.table()
        return WD.mine_windowed(
            rows, vals, store.perms(), plans=self.key_plans,
            hash_lo=self._lo, hash_hi=self._hi, delta=self.delta,
            theta=self.theta, minsup=self.minsup,
            window_budget=window_budget, sort_backend=backend,
            use_pallas=self.use_pallas, probe=probe, obs=obs)
