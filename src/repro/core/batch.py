"""Batch (single-shard) JAX engine for prime OAC / multimodal clustering.

TPU-native reformulation of the paper's dictionaries (DESIGN.md §3):

* The Hadoop shuffle-by-subrelation of Stage 1 becomes a **lexicographic
  sort** of the tuple table by the N-1 "other" columns of each mode.
  After the sort, every cumulus is a *contiguous slice* of the sorted
  mode-k column — the cumulus tables of the paper shrink from
  ``O(|I|·Σ|A_j|)`` dictionary bytes to ``O(|I|)`` (start, length) ranges.
* Stage 2 (re-join of cumuli to generating tuples) becomes an inverse
  permutation gather.
* Stage 3 (dedup + density) is done on order-independent 2×32-bit
  signatures: ``sig_k(segment) = Σ_{distinct e} r_k[e] (mod 2^32)``,
  mixed across modes; duplicates and filters are resolved by one more
  sort over signatures. Density is the paper-faithful Alg. 7 estimate
  ``#distinct generating tuples / volume``.

All shapes are static in ``T`` (number of tuples) and ``N`` (arity), so the
whole pipeline jits once per context shape. Everything here is also the
per-shard compute of the distributed engine (core/distributed.py).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .context import PolyadicContext

# Per-mode multipliers for mixing mode signatures into a cluster signature.
# Odd constants (invertible mod 2^32) from splitmix64 / Weyl sequences.
_MIX = np.array([0x9E3779B1, 0x85EBCA77, 0xC2B2AE3D, 0x27D4EB2F,
                 0x165667B1, 0xD3A2646D, 0xFD7046C5, 0xB55A4F09],
                dtype=np.uint32)


def mode_hash_vectors(sizes: Sequence[int], seed: int = 0x5EED):
    """Two independent uint32 hash vectors per mode (host-side, fixed seed)."""
    rng = np.random.Generator(np.random.Philox(seed))
    return [
        (rng.integers(1, 2**32, size=n, dtype=np.uint32),
         rng.integers(1, 2**32, size=n, dtype=np.uint32))
        for n in sizes
    ]


# ---------------------------------------------------------------------------
# Sorting / segmentation primitives
# ---------------------------------------------------------------------------

def lex_perm(columns: Sequence[jnp.ndarray]) -> jnp.ndarray:
    """Permutation sorting rows lexicographically by ``columns`` (first column
    is the most significant key)."""
    return jnp.lexsort(tuple(reversed(list(columns))))


def segment_starts(sorted_key_cols: Sequence[jnp.ndarray]) -> jnp.ndarray:
    """Boolean start-of-segment flags for already-sorted key columns."""
    t = sorted_key_cols[0].shape[0]
    change = jnp.zeros((t,), bool).at[0].set(True)
    for c in sorted_key_cols:
        change = change | jnp.concatenate(
            [jnp.ones((1,), bool), c[1:] != c[:-1]])
    return change


@dataclasses.dataclass
class ModeCumuli:
    """Cumuli of one mode, as contiguous ranges over a sorted column.

    All arrays have length T. ``seg_of_tuple`` is indexed by *original*
    tuple order; the rest by segment id (padded to T segments).
    """
    perm: jnp.ndarray           # sorted order of tuples
    sorted_vals: jnp.ndarray    # e_k column under perm
    seg_of_tuple: jnp.ndarray   # segment id per original tuple
    seg_start: jnp.ndarray      # first sorted position of each segment
    seg_len: jnp.ndarray        # total entries (with duplicates)
    seg_distinct: jnp.ndarray   # distinct entity count per segment
    sig_lo: jnp.ndarray         # order-independent set hash per segment
    sig_hi: jnp.ndarray
    first_occ: jnp.ndarray      # per sorted position: first of (key, e) pair

jax.tree_util.register_dataclass(
    ModeCumuli, data_fields=["perm", "sorted_vals", "seg_of_tuple",
                             "seg_start", "seg_len", "seg_distinct",
                             "sig_lo", "sig_hi", "first_occ"],
    meta_fields=[])


def mode_cumuli(tuples: jnp.ndarray, k: int, r_lo: jnp.ndarray,
                r_hi: jnp.ndarray) -> ModeCumuli:
    """Stage 1 for mode k: sort by the other columns, segment, hash."""
    t, n = tuples.shape
    others = [tuples[:, j] for j in range(n) if j != k]
    ek = tuples[:, k]
    # Sort by (other columns..., e_k): duplicates of (key, e) are adjacent.
    perm = lex_perm(others + [ek])
    s_others = [c[perm] for c in others]
    s_ek = ek[perm]
    seg_flag = segment_starts(s_others)
    seg = jnp.cumsum(seg_flag) - 1                       # segment id / position
    first_occ = segment_starts(s_others + [s_ek])        # distinct (key, e)
    pos = jnp.arange(t)
    seg_start = jax.ops.segment_min(pos, seg, num_segments=t)
    seg_len = jax.ops.segment_sum(jnp.ones((t,), jnp.int32), seg,
                                  num_segments=t)
    seg_distinct = jax.ops.segment_sum(first_occ.astype(jnp.int32), seg,
                                       num_segments=t)
    w_lo = jnp.where(first_occ, r_lo[s_ek], jnp.uint32(0))
    w_hi = jnp.where(first_occ, r_hi[s_ek], jnp.uint32(0))
    sig_lo = jax.ops.segment_sum(w_lo, seg, num_segments=t)
    sig_hi = jax.ops.segment_sum(w_hi, seg, num_segments=t)
    seg_of_tuple = jnp.zeros((t,), jnp.int32).at[perm].set(seg)
    return ModeCumuli(perm, s_ek, seg_of_tuple, seg_start, seg_len,
                      seg_distinct, sig_lo, sig_hi, first_occ)


# ---------------------------------------------------------------------------
# Full mining pipeline (stages 1-3)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MiningResult:
    """Per-tuple mining output (original tuple order; length T arrays)."""
    sig_lo: jnp.ndarray        # cluster signature of the tuple's cluster
    sig_hi: jnp.ndarray
    is_unique: jnp.ndarray     # bool: first generating tuple of its cluster
    gen_count: jnp.ndarray     # distinct generating tuples of the cluster
    volume: jnp.ndarray        # float32 Π_k |cum_k|
    density: jnp.ndarray       # Alg. 7 estimate  gen_count / volume
    keep: jnp.ndarray          # is_unique & density >= theta
    seg_of_tuple: jnp.ndarray  # (N, T) segment handle per mode
    modes: list                # list[ModeCumuli] — cumulus content handles

jax.tree_util.register_dataclass(
    MiningResult, data_fields=["sig_lo", "sig_hi", "is_unique", "gen_count",
                               "volume", "density", "keep", "seg_of_tuple",
                               "modes"],
    meta_fields=[])


def _mix_signatures(per_mode_lo, per_mode_hi):
    lo = jnp.zeros_like(per_mode_lo[0])
    hi = jnp.zeros_like(per_mode_hi[0])
    for k, (slo, shi) in enumerate(zip(per_mode_lo, per_mode_hi)):
        lo = lo + jnp.uint32(_MIX[k % len(_MIX)]) * slo
        hi = hi + jnp.uint32(_MIX[(k + 3) % len(_MIX)]) * shi
    # final avalanche
    lo = (lo ^ (lo >> 16)) * jnp.uint32(0x7FEB352D)
    hi = (hi ^ (hi >> 15)) * jnp.uint32(0x846CA68B)
    return lo, hi


def _tuple_first_occurrence(tuples: jnp.ndarray) -> jnp.ndarray:
    """Bool per tuple: is it the first occurrence of an identical row."""
    t, n = tuples.shape
    perm = lex_perm([tuples[:, j] for j in range(n)])
    srt = [tuples[perm, j] for j in range(n)]
    first = segment_starts(srt)
    return jnp.zeros((t,), bool).at[perm].set(first)


def mine(tuples: jnp.ndarray, hash_lo: Sequence[jnp.ndarray],
         hash_hi: Sequence[jnp.ndarray], theta: float = 0.0) -> MiningResult:
    """The full three-stage pipeline on one shard. jit-able; T, N static."""
    t, n = tuples.shape
    modes = [mode_cumuli(tuples, k, hash_lo[k], hash_hi[k]) for k in range(n)]
    # Stage 2: per-tuple cluster = gather per-mode segment aggregates.
    per_lo = [m.sig_lo[m.seg_of_tuple] for m in modes]
    per_hi = [m.sig_hi[m.seg_of_tuple] for m in modes]
    sig_lo, sig_hi = _mix_signatures(per_lo, per_hi)
    volume = jnp.ones((t,), jnp.float32)
    for m in modes:
        volume = volume * m.seg_distinct[m.seg_of_tuple].astype(jnp.float32)
    # Stage 3: dedup + generating-tuple counts via one sort over signatures.
    tuple_first = _tuple_first_occurrence(tuples)
    order = lex_perm([sig_lo, sig_hi])
    s_lo, s_hi = sig_lo[order], sig_hi[order]
    cluster_start = segment_starts([s_lo, s_hi])
    cseg = jnp.cumsum(cluster_start) - 1
    gen = jax.ops.segment_sum(tuple_first[order].astype(jnp.int32), cseg,
                              num_segments=t)
    gen_of_tuple = jnp.zeros((t,), jnp.int32).at[order].set(gen[cseg])
    # unique = first *distinct* generating tuple of its cluster
    s_first = tuple_first[order]
    pos = jnp.arange(t)
    first_distinct_pos = jax.ops.segment_min(
        jnp.where(s_first, pos, t), cseg, num_segments=t)
    is_uniq_sorted = (pos == first_distinct_pos[cseg]) & s_first
    is_unique = jnp.zeros((t,), bool).at[order].set(is_uniq_sorted)
    density = gen_of_tuple.astype(jnp.float32) / jnp.maximum(volume, 1.0)
    keep = is_unique & (density >= jnp.float32(theta))
    seg_of_tuple = jnp.stack([m.seg_of_tuple for m in modes])
    return MiningResult(sig_lo, sig_hi, is_unique, gen_of_tuple, volume,
                        density, keep, seg_of_tuple, modes)


# ---------------------------------------------------------------------------
# User-facing engine
# ---------------------------------------------------------------------------

class BatchMiner:
    """jit-compiled multimodal clustering of a polyadic context."""

    def __init__(self, sizes: Sequence[int], theta: float = 0.0,
                 seed: int = 0x5EED):
        self.sizes = tuple(int(s) for s in sizes)
        self.theta = float(theta)
        vecs = mode_hash_vectors(self.sizes, seed)
        self._lo = [jnp.asarray(lo) for lo, _ in vecs]
        self._hi = [jnp.asarray(hi) for _, hi in vecs]
        self._mine = jax.jit(functools.partial(mine, theta=self.theta))

    def __call__(self, tuples) -> MiningResult:
        return self._mine(jnp.asarray(tuples, jnp.int32), self._lo, self._hi)

    # -- host-side materialisation (numpy; used by tests/examples) ---------
    def materialise(self, result: MiningResult, tuples: np.ndarray,
                    only_kept: bool = True):
        """Extract cluster component sets for kept (or all unique) tuples."""
        keep = np.asarray(result.keep if only_kept else result.is_unique)
        out = []
        modes = result.modes
        sorted_vals = [np.asarray(m.sorted_vals) for m in modes]
        seg_start = [np.asarray(m.seg_start) for m in modes]
        seg_len = [np.asarray(m.seg_len) for m in modes]
        seg_of = np.asarray(result.seg_of_tuple)
        dens = np.asarray(result.density)
        for i in np.nonzero(keep)[0]:
            comps = []
            for k in range(len(modes)):
                s = seg_of[k, i]
                a, l = seg_start[k][s], seg_len[k][s]
                comps.append(frozenset(np.unique(sorted_vals[k][a:a + l])
                                       .tolist()))
            out.append((tuple(comps), float(dens[i])))
        return out

    def mine_context(self, ctx: PolyadicContext, only_kept: bool = True):
        if ctx.sizes != self.sizes:
            raise ValueError("context sizes mismatch")
        res = self(ctx.tuples)
        return self.materialise(res, ctx.tuples, only_kept)


# ---------------------------------------------------------------------------
# Dense backend (small contexts; validation + exact density)
# ---------------------------------------------------------------------------

def dense_tensor(tuples: jnp.ndarray, sizes: Sequence[int]) -> jnp.ndarray:
    """Dense boolean incidence tensor via scatter (idempotent under dups)."""
    flat = jnp.zeros((int(np.prod(sizes)),), bool)
    idx = jnp.zeros(tuples.shape[0], jnp.int32)
    for k, s in enumerate(sizes):
        idx = idx * jnp.int32(s) + tuples[:, k]
    return flat.at[idx].set(True).reshape(tuple(sizes))


def fibers(tensor: jnp.ndarray, tuples: jnp.ndarray):
    """Prime sets of each generating tuple: the N fibers through it.

    Returns list over modes of (T, n_k) boolean masks — the tricluster
    extent/intent/modus of the paper's §2 in mask form.
    """
    n = tuples.shape[1]
    out = []
    for k in range(n):
        moved = jnp.moveaxis(tensor, k, -1)          # (others..., n_k)
        flat = moved.reshape((-1, tensor.shape[k]))
        idx = jnp.zeros(tuples.shape[0], jnp.int32)
        for j in range(n):
            if j != k:
                idx = idx * jnp.int32(tensor.shape[j]) + tuples[:, j]
        out.append(flat[idx])
    return out


def exact_density_dense(tensor: jnp.ndarray, masks) -> jnp.ndarray:
    """Exact density |box ∩ I| / vol for each tuple's cluster (beyond-paper).

    ``masks`` — list over modes of (T, n_k) bool. Pure-jnp oracle for the
    Pallas tricluster_density kernel (triadic fast path in kernels/ops.py).
    """
    n = len(masks)
    letters = "abcdefgh"[:n]
    expr = ",".join(f"t{c}" for c in letters) + "," + letters + "->t"
    args = [m.astype(jnp.float32) for m in masks] + [tensor.astype(jnp.float32)]
    num = jnp.einsum(expr, *args)
    vol = jnp.ones(masks[0].shape[0], jnp.float32)
    for m in masks:
        vol = vol * m.sum(-1).astype(jnp.float32)
    return num / jnp.maximum(vol, 1.0)
