"""Batch (single-shard) engine for prime OAC / multimodal clustering.

A thin driver over the shared Stage-1/2/3 pipeline (``core.pipeline``,
DESIGN.md §3) with the *prime cumulus* component operator:

* Stage 1's Hadoop shuffle-by-subrelation becomes a lexicographic sort of
  the tuple table by the N-1 "other" columns of each mode; every cumulus
  is then a contiguous slice of the sorted mode-k column.
* Stage 2 is an inverse-permutation gather of per-segment aggregates.
* Stage 3 dedups on order-independent 2×32-bit set signatures and
  estimates density as Alg. 7's ``#distinct generating tuples / volume``.

The same jitted pipeline is the per-shard compute of the distributed
engine (core/distributed.py) and the post-merge compute of the streaming
engine (core/streaming.py).  This module adds only the dense validation
backend (small contexts; exact density oracle for the Pallas kernel).
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import pipeline as P
from .context import PolyadicContext

# Re-exported shared primitives (canonical home: core.pipeline).
lex_perm = P.lex_perm
segment_starts = P.segment_starts
mode_hash_vectors = P.mode_hash_vectors
_mix_signatures = P.mix_signatures

# The unified result type; kept under its historical name.
MiningResult = P.PipelineResult


def mine(tuples: jnp.ndarray, hash_lo: Sequence[jnp.ndarray],
         hash_hi: Sequence[jnp.ndarray], theta: float = 0.0) -> MiningResult:
    """The full three-stage prime pipeline on one shard (jit-able)."""
    return P.mine_tuples(tuples, hash_lo, hash_hi, theta=theta)


class BatchMiner(P.PipelineMiner):
    """jit-compiled multimodal clustering of a polyadic context."""

    def __init__(self, sizes: Sequence[int], theta: float = 0.0,
                 seed: int = 0x5EED, packed: Optional[bool] = None,
                 sort_backend: Optional[str] = None,
                 use_pallas: Optional[bool] = None,
                 prune_values: bool = True,
                 window_budget: Optional[int] = None):
        super().__init__(sizes, theta=theta, seed=seed, packed=packed,
                         sort_backend=sort_backend, use_pallas=use_pallas,
                         prune_values=prune_values,
                         window_budget=window_budget)

    def mine_context(self, ctx: PolyadicContext, only_kept: bool = True):
        if ctx.sizes != self.sizes:
            raise ValueError("context sizes mismatch")
        return self.materialise(self(ctx.tuples), ctx.tuples, only_kept)


# ---------------------------------------------------------------------------
# Dense backend (small contexts; validation + exact density)
# ---------------------------------------------------------------------------

def dense_tensor(tuples: jnp.ndarray, sizes: Sequence[int]) -> jnp.ndarray:
    """Dense boolean incidence tensor via scatter (idempotent under dups)."""
    flat = jnp.zeros((int(np.prod(sizes)),), bool)
    idx = jnp.zeros(tuples.shape[0], jnp.int32)
    for k, s in enumerate(sizes):
        idx = idx * jnp.int32(s) + tuples[:, k]
    return flat.at[idx].set(True).reshape(tuple(sizes))


def fibers(tensor: jnp.ndarray, tuples: jnp.ndarray):
    """Prime sets of each generating tuple: the N fibers through it.

    Returns list over modes of (T, n_k) boolean masks — the tricluster
    extent/intent/modus of the paper's §2 in mask form.
    """
    n = tuples.shape[1]
    out = []
    for k in range(n):
        moved = jnp.moveaxis(tensor, k, -1)          # (others..., n_k)
        flat = moved.reshape((-1, tensor.shape[k]))
        idx = jnp.zeros(tuples.shape[0], jnp.int32)
        for j in range(n):
            if j != k:
                idx = idx * jnp.int32(tensor.shape[j]) + tuples[:, j]
        out.append(flat[idx])
    return out


def exact_density_dense(tensor: jnp.ndarray, masks) -> jnp.ndarray:
    """Exact density |box ∩ I| / vol for each tuple's cluster (beyond-paper).

    ``masks`` — list over modes of (T, n_k) bool. Pure-jnp oracle for the
    Pallas tricluster_density kernel (triadic fast path in kernels/ops.py).
    """
    n = len(masks)
    letters = "abcdefgh"[:n]
    expr = ",".join(f"t{c}" for c in letters) + "," + letters + "->t"
    args = [m.astype(jnp.float32) for m in masks] + [tensor.astype(jnp.float32)]
    num = jnp.einsum(expr, *args)
    vol = jnp.ones(masks[0].shape[0], jnp.float32)
    for m in masks:
        vol = vol * m.sum(-1).astype(jnp.float32)
    return num / jnp.maximum(vol, 1.0)
