"""Engine registry: one front-end for every (backend, variant) pair.

``repro.core.mine(ctx, backend=..., variant=...)`` is the single entry
point the launchers, serving surface and benchmarks use instead of
importing backends directly.  Engines register themselves under a
``(backend, variant)`` key; unknown combinations fail with an error that
lists every valid choice.

Backends: ``batch`` (single shard), ``distributed`` (shard_map mesh,
'replicate' or 'shuffle' merge), ``streaming`` (incremental sorted-run
ingestion), ``reference`` (pure-python oracle).
Variants: ``prime`` (OAC/multimodal) and ``noac`` (many-valued δ).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import numpy as np

from .batch import BatchMiner
from .context import PolyadicContext
from .distributed import DistributedMiner, pad_tuples, pad_values
from .manyvalued import NOACMiner
from .streaming import StreamingMiner

BACKENDS = ("batch", "distributed", "streaming", "reference")
VARIANTS = ("prime", "noac")

_REGISTRY: dict[tuple[str, str], Callable] = {}


def register_engine(backend: str, variant: str):
    """Class decorator-style registration of an engine runner."""
    def deco(fn):
        _REGISTRY[(backend, variant)] = fn
        return fn
    return deco


def available_engines() -> list[tuple[str, str]]:
    """Sorted (backend, variant) pairs with a registered engine."""
    return sorted(_REGISTRY)


def resolve_engine(backend: str, variant: str) -> Callable:
    try:
        return _REGISTRY[(backend, variant)]
    except KeyError:
        valid = ", ".join(f"{b}/{v}" for b, v in available_engines())
        raise ValueError(
            f"no engine for backend={backend!r} variant={variant!r}; "
            f"valid combinations: {valid}") from None


@dataclasses.dataclass
class MineRun:
    """Outcome of one ``mine()`` call."""
    backend: str
    variant: str
    n_clusters: int              # kept clusters
    elapsed_s: float             # wall time of the first mining execution
                                 # (includes jit compile; excludes miner
                                 # construction and materialisation)
    clusters: Optional[list]     # [(components, density), ...] or None
    result: Any                  # backend-native result object (or None)
    miner: Any                   # the engine instance (None for reference)
    rerun: Any = None            # zero-arg warm re-execution of the mining
                                 # step (no re-compile); returns the result
                                 # and records its time in ``rerun.last_s``

    @property
    def tuples_per_s(self) -> float:
        return 0.0 if not self.elapsed_s else self._n_tuples / self.elapsed_s

    _n_tuples: int = 0


def mine(ctx: PolyadicContext, backend: str = "batch",
         variant: str = "prime", **params) -> MineRun:
    """Mine ``ctx`` with the selected backend/variant.

    Common params: ``theta`` (prime min density), ``delta``/``rho_min``/
    ``minsup`` (noac), ``seed``, ``packed`` (packed-key sort path; None =
    auto, False = lexsort baseline), ``sort_backend`` ('radix' — the
    bit-plan-pruned LSD default — | 'lax' | 'lexsort'), ``use_pallas``
    (fused Pallas kernels; None = on TPU only).  Backend-specific:
    ``mesh``/``axes``/``strategy``/``capacity_factor`` (distributed),
    ``chunks``/``incremental`` (streaming; ``incremental=True`` on the
    distributed backend switches it to chunked ingestion + merged
    per-shard-run snapshots), ``chunk_budget`` (batch: out-of-core
    chunked Stage 1 via ``mine_chunked`` — host-sorted runs, the device
    never sorts), ``window_budget`` (the fully windowed device
    pipeline, DESIGN.md §3c: Stage 1–3 stream through bounded device
    windows; on the batch backend via ``mine_windowed``, on streaming/
    distributed it windows the incremental snapshot remine and sizes
    the shuffle's per-link dispatch batches).  All incremental/chunked
    paths run on the shared ``core.runs`` storage layer (DESIGN.md §4).
    ``variant='noac'`` requires ``delta``.
    """
    if variant == "noac" and params.get("delta") is None:
        raise ValueError("variant='noac' requires delta=<float>")
    engine = resolve_engine(backend, variant)
    t0 = time.perf_counter()
    n_clusters, clusters, result, miner, rerun = engine(ctx, params)
    total = time.perf_counter() - t0
    elapsed = getattr(rerun, "last_s", None) or total
    return MineRun(backend=backend, variant=variant, n_clusters=n_clusters,
                   elapsed_s=elapsed, clusters=clusters, result=result,
                   miner=miner, rerun=rerun, _n_tuples=ctx.num_tuples)


def _noac_ctx(ctx: PolyadicContext) -> PolyadicContext:
    """NOAC precondition: deduplicated, with a value column (§3.2: W={0,1},
    δ=0 degenerates to prime operators when values are absent)."""
    if ctx.values is None:
        ctx = PolyadicContext(ctx.sizes, ctx.tuples,
                              np.zeros(ctx.num_tuples, np.float32), ctx.names)
    return ctx.deduplicated()


# ---------------------------------------------------------------------------
# Engine runners.  Each returns (n_clusters, clusters, result, miner, rerun)
# where ``rerun`` re-executes the mining step warm (no re-compile).
# ---------------------------------------------------------------------------

def _pipe_kw(p):
    """Pipeline-core params shared by every jax backend."""
    return {"packed": p.get("packed"),
            "sort_backend": p.get("sort_backend"),
            "use_pallas": p.get("use_pallas"),
            "prune_values": p.get("prune_values", True),
            "window_budget": p.get("window_budget")}


def _timed(step, block=True):
    """Wrap a mining step: each call blocks on the device result (when it
    has one) and records its wall time in ``go.last_s``."""
    def go():
        t0 = time.perf_counter()
        out = step()
        if block:
            np.asarray(out.keep)
        go.last_s = time.perf_counter() - t0
        return out
    go.last_s = None
    return go


def _batch_step(miner, p, tuples, values=None):
    """One-shot in-core mining; out-of-core chunked Stage 1 when
    ``chunk_budget`` is set (``PipelineMiner.mine_chunked``); the fully
    windowed device pipeline when ``window_budget`` is set
    (``PipelineMiner.mine_windowed`` — host run sort *and* bounded
    device windows sharing the one budget)."""
    wb = p.get("window_budget")
    if wb:
        return lambda: miner.mine_windowed(tuples, values=values,
                                           window_budget=int(wb))
    budget = p.get("chunk_budget")
    if budget:
        return lambda: miner.mine_chunked(tuples, values=values,
                                          chunk_budget=int(budget))
    if values is not None:
        return lambda: miner(tuples, values)
    return lambda: miner(tuples)


@register_engine("batch", "prime")
def _batch_prime(ctx, p):
    miner = BatchMiner(ctx.sizes, theta=p.get("theta", 0.0),
                       seed=p.get("seed", 0x5EED), **_pipe_kw(p))
    rerun = _timed(_batch_step(miner, p, ctx.tuples))
    res = rerun()
    clusters = miner.materialise(res)
    return len(clusters), clusters, res, miner, rerun


@register_engine("batch", "noac")
def _batch_noac(ctx, p):
    ctx = _noac_ctx(ctx)
    miner = NOACMiner(ctx.sizes, delta=p["delta"],
                      rho_min=p.get("rho_min", 0.0),
                      minsup=p.get("minsup", 0), seed=p.get("seed", 0x5EED),
                      **_pipe_kw(p))
    rerun = _timed(_batch_step(miner, p, ctx.tuples, ctx.values))
    res = rerun()
    clusters = miner.materialise(res)
    return len(clusters), clusters, res, miner, rerun


def _local_mesh():
    from ..launch.mesh import make_local_mesh
    return make_local_mesh()


def _run_distributed(ctx, p, values, **variant_kw):
    mesh = p.get("mesh") or _local_mesh()
    miner = DistributedMiner(
        ctx.sizes, mesh, axes=p.get("axes", "data"),
        strategy=p.get("strategy", "replicate"),
        capacity_factor=p.get("capacity_factor", 2.0),
        seed=p.get("seed", 0x5EED), **_pipe_kw(p), **variant_kw)
    if p.get("incremental"):
        # chunked ingestion + merged per-shard-run snapshot (core.runs)
        step = -(-ctx.num_tuples // max(1, int(p.get("chunks", 8))))

        def ingest_and_snapshot():
            miner.reset_stream()
            for lo in range(0, ctx.num_tuples, step):
                hi = lo + step
                miner.ingest(ctx.tuples[lo:hi],
                             values[lo:hi] if values is not None else None)
            return miner.snapshot()

        rerun = _timed(ingest_and_snapshot)
    else:
        tuples = pad_tuples(ctx.tuples, miner.n_shards)
        values = (pad_values(values, miner.n_shards)
                  if values is not None else None)
        rerun = _timed(lambda: miner(tuples, values))
    res = rerun()
    return int(np.asarray(res.keep).sum()), None, res, miner, rerun


@register_engine("distributed", "prime")
def _distributed_prime(ctx, p):
    return _run_distributed(ctx, p, None, theta=p.get("theta", 0.0))


@register_engine("distributed", "noac")
def _distributed_noac(ctx, p):
    ctx = _noac_ctx(ctx)
    return _run_distributed(ctx, p, ctx.values, delta=p["delta"],
                            rho_min=p.get("rho_min", 0.0),
                            minsup=p.get("minsup", 0))


def _run_streaming(ctx, p, values, **variant_kw):
    miner = StreamingMiner(ctx.sizes, seed=p.get("seed", 0x5EED),
                           incremental=p.get("incremental", True),
                           **_pipe_kw(p), **variant_kw)
    chunks = max(1, int(p.get("chunks", 8)))
    step = -(-ctx.num_tuples // chunks)

    def ingest_and_snapshot():
        miner.state = None
        for lo in range(0, ctx.num_tuples, step):
            hi = lo + step
            miner.add(ctx.tuples[lo:hi],
                      values[lo:hi] if values is not None else None)
        return miner.snapshot()

    rerun = _timed(ingest_and_snapshot)
    res = rerun()
    clusters = miner.materialise(res)
    return len(clusters), clusters, res, miner, rerun


@register_engine("streaming", "prime")
def _streaming_prime(ctx, p):
    return _run_streaming(ctx, p, None, theta=p.get("theta", 0.0))


@register_engine("streaming", "noac")
def _streaming_noac(ctx, p):
    ctx = _noac_ctx(ctx)
    return _run_streaming(ctx, p, ctx.values, delta=p["delta"],
                          rho_min=p.get("rho_min", 0.0),
                          minsup=p.get("minsup", 0))


@register_engine("reference", "prime")
def _reference_prime(ctx, p):
    from . import reference as R
    rerun = _timed(lambda: R.multimodal_clusters(ctx,
                                                 theta=p.get("theta", 0.0)),
                   block=False)
    _, _, density, kept = rerun()
    clusters = [(cl, density[tuple(tuple(sorted(c)) for c in cl)])
                for cl in kept]
    return len(clusters), clusters, None, None, rerun


@register_engine("reference", "noac")
def _reference_noac(ctx, p):
    from . import reference as R
    ctx = _noac_ctx(ctx)
    rerun = _timed(lambda: R.noac(ctx, p["delta"],
                                  rho_min=p.get("rho_min", 0.0),
                                  minsup=p.get("minsup", 0)), block=False)
    kept = rerun()
    clusters = [(cl, float("nan")) for cl in kept]
    return len(clusters), clusters, None, None, rerun
