"""Many-valued δ-triclustering (paper §3.2) / NOAC (paper §4.3).

A thin driver over the shared Stage-1/2/3 pipeline (``core.pipeline``,
DESIGN.md §3) with the *δ-range* component operator: each mode's table is
sorted by (other columns, value), so every δ-cumulus is a contiguous
value range inside a contiguous key segment, found with two vectorised
binary searches — O(T log T) total, versus the O(T · |A_k|) dictionary
walks of the C#/.Net NOAC implementation the paper benchmarks in §6.

Set signatures of ranges come from per-mode prefix sums of
first-occurrence-masked uint32 hash weights (modular arithmetic makes
range differences exact), so the engine is duplicate-idempotent like the
prime variant: V must be a *function* of the tuple (paper §3.2), but the
tuple table itself may contain duplicates (e.g. shard padding or
at-least-once delivery) without changing any output.

Validity checks (per §4.3): minimal per-mode cardinality (minsup) and
minimal density ρ_min, with density estimated exactly as the M/R stage 3
does (distinct generating tuples / volume), so all engines agree.  NOAC
also runs distributed (core/distributed.py, both merge strategies) and
streaming (core/streaming.py) through the same pipeline, bit-identical
to this single-shard engine.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from . import pipeline as P
from .context import PolyadicContext

_bsearch = P.bsearch                 # canonical home: core.pipeline
NOACResult = P.PipelineResult        # unified result type


def noac_mine(tuples, values, hash_lo, hash_hi, delta: float,
              rho_min: float = 0.0, minsup: int = 0) -> NOACResult:
    """The full three-stage δ pipeline on one shard (jit-able)."""
    return P.mine_tuples(tuples, hash_lo, hash_hi, values=values,
                         delta=delta, theta=rho_min, minsup=minsup)


class NOACMiner(P.PipelineMiner):
    """jit-compiled many-valued (δ) multimodal clustering."""

    def __init__(self, sizes: Sequence[int], delta: float,
                 rho_min: float = 0.0, minsup: int = 0, seed: int = 0x5EED,
                 packed: Optional[bool] = None,
                 sort_backend: Optional[str] = None,
                 use_pallas: Optional[bool] = None,
                 prune_values: bool = True,
                 window_budget: Optional[int] = None):
        super().__init__(sizes, theta=rho_min, delta=delta, minsup=minsup,
                         seed=seed, packed=packed,
                         sort_backend=sort_backend, use_pallas=use_pallas,
                         prune_values=prune_values,
                         window_budget=window_budget)
        self.rho_min = float(rho_min)

    def mine_context(self, ctx: PolyadicContext):
        if ctx.values is None:
            # §3.2: W={0,1}, δ=0 degenerates to prime operators
            ctx = PolyadicContext(ctx.sizes, ctx.tuples,
                                  np.zeros(ctx.num_tuples, np.float32),
                                  ctx.names)
        ctx = ctx.deduplicated()
        return self.materialise(self(ctx.tuples, ctx.values), ctx.tuples)
