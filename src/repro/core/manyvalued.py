"""Many-valued δ-triclustering (paper §3.2) / NOAC (paper §4.3) in JAX.

For a many-valued context K_V = (A_1..A_N, W, I, V) the δ-operator along
mode k of a generating tuple i with value v0 keeps the entities of the
tuple's cumulus whose triple value is within δ of v0.

TPU-native formulation: sort each mode's table by (other columns, value).
Then every δ-cumulus is a *contiguous value range inside a contiguous key
segment*, found with two vectorised binary searches — O(T log T) total,
versus the O(T · |A_k|) dictionary walks of the C#/.Net NOAC implementation
the paper benchmarks in §6.

Set signatures of ranges come from per-mode prefix sums of uint32 hash
weights (modular arithmetic makes range differences exact). Precondition:
the tuple table is deduplicated — V is a *function* of the tuple (paper
§3.2), so duplicates carry no information; ``NOACMiner`` dedups host-side.

Validity checks (per §4.3): minimal per-mode cardinality (minsup) and
minimal density ρ_min, with density estimated exactly as the M/R stage 3
does (distinct generating tuples / volume), so the two engines agree.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import batch as B
from .context import PolyadicContext


def _bsearch(vals: jnp.ndarray, lo0: jnp.ndarray, hi0: jnp.ndarray,
             target: jnp.ndarray, leq: bool) -> jnp.ndarray:
    """Vectorised binary search. Returns, per query, the first index in
    [lo0, hi0) where vals[idx] >= target (leq=False: lower bound) or
    vals[idx] > target (leq=True: upper bound); hi0 if none."""
    t = vals.shape[0]
    iters = max(1, int(np.ceil(np.log2(max(t, 2)))) + 1)
    lo, hi = lo0, hi0
    for _ in range(iters):
        mid = (lo + hi) // 2
        v = vals[jnp.clip(mid, 0, t - 1)]
        go_right = (v <= target) if leq else (v < target)
        go_right = go_right & (lo < hi)
        lo = jnp.where(go_right, mid + 1, lo)
        hi = jnp.where(go_right | (lo >= hi), hi, mid)
    return lo


@dataclasses.dataclass
class NOACResult:
    sig_lo: jnp.ndarray
    sig_hi: jnp.ndarray
    is_unique: jnp.ndarray
    gen_count: jnp.ndarray
    volume: jnp.ndarray
    density: jnp.ndarray
    keep: jnp.ndarray         # unique & minsup & density filters
    range_lo: jnp.ndarray     # (N, T) start of the δ-range (sorted order)
    range_hi: jnp.ndarray     # (N, T) end (exclusive)
    perms: jnp.ndarray        # (N, T) per-mode sort permutations

jax.tree_util.register_dataclass(
    NOACResult, data_fields=["sig_lo", "sig_hi", "is_unique", "gen_count",
                             "volume", "density", "keep", "range_lo",
                             "range_hi", "perms"],
    meta_fields=[])


def noac_mine(tuples: jnp.ndarray, values: jnp.ndarray,
              hash_lo: Sequence[jnp.ndarray], hash_hi: Sequence[jnp.ndarray],
              delta: float, rho_min: float = 0.0,
              minsup: int = 0) -> NOACResult:
    t, n = tuples.shape
    per_lo, per_hi, range_lo_all, range_hi_all, perms = [], [], [], [], []
    volume = jnp.ones((t,), jnp.float32)
    for k in range(n):
        others = [tuples[:, j] for j in range(n) if j != k]
        # segment by key, ordered by value inside each segment
        perm = B.lex_perm(others + [values, tuples[:, k]])
        s_others = [c[perm] for c in others]
        s_vals = values[perm]
        s_e = tuples[perm, k]
        seg_flag = B.segment_starts(s_others)
        seg = jnp.cumsum(seg_flag) - 1
        pos = jnp.arange(t)
        seg_start = jax.ops.segment_min(pos, seg, num_segments=t)
        seg_len = jax.ops.segment_sum(jnp.ones((t,), jnp.int32), seg,
                                      num_segments=t)
        # prefix (exclusive) of hash weights along the sorted order
        w_lo = hash_lo[k][s_e]
        w_hi = hash_hi[k][s_e]
        pref_lo = jnp.concatenate([jnp.zeros((1,), jnp.uint32),
                                   jnp.cumsum(w_lo, dtype=jnp.uint32)])
        pref_hi = jnp.concatenate([jnp.zeros((1,), jnp.uint32),
                                   jnp.cumsum(w_hi, dtype=jnp.uint32)])
        # per-tuple query in its own segment
        inv = jnp.zeros((t,), jnp.int32).at[perm].set(pos.astype(jnp.int32))
        my_seg = seg[inv]
        a = seg_start[my_seg]
        b = a + seg_len[my_seg]
        lo_idx = _bsearch(s_vals, a, b, values - jnp.float32(delta), leq=False)
        hi_idx = _bsearch(s_vals, a, b, values + jnp.float32(delta), leq=True)
        card = (hi_idx - lo_idx).astype(jnp.int32)
        sig_lo_k = pref_lo[hi_idx] - pref_lo[lo_idx]
        sig_hi_k = pref_hi[hi_idx] - pref_hi[lo_idx]
        per_lo.append(sig_lo_k)
        per_hi.append(sig_hi_k)
        range_lo_all.append(lo_idx.astype(jnp.int32))
        range_hi_all.append(hi_idx.astype(jnp.int32))
        perms.append(perm.astype(jnp.int32))
        volume = volume * card.astype(jnp.float32)
    sig_lo, sig_hi = B._mix_signatures(per_lo, per_hi)
    card_ok = jnp.ones((t,), bool)
    for lo_idx, hi_idx in zip(range_lo_all, range_hi_all):
        card_ok = card_ok & ((hi_idx - lo_idx) >= minsup)
    # stage-3 dedup / generating counts (tuples are pre-deduplicated)
    order = B.lex_perm([sig_lo, sig_hi])
    cstart = B.segment_starts([sig_lo[order], sig_hi[order]])
    cseg = jnp.cumsum(cstart) - 1
    gen = jax.ops.segment_sum(jnp.ones((t,), jnp.int32), cseg, num_segments=t)
    gen_of = jnp.zeros((t,), jnp.int32).at[order].set(gen[cseg])
    is_unique = jnp.zeros((t,), bool).at[order].set(cstart)
    density = gen_of.astype(jnp.float32) / jnp.maximum(volume, 1.0)
    keep = is_unique & card_ok & (density >= jnp.float32(rho_min))
    return NOACResult(sig_lo, sig_hi, is_unique, gen_of, volume, density,
                      keep, jnp.stack(range_lo_all), jnp.stack(range_hi_all),
                      jnp.stack(perms))


class NOACMiner:
    """jit-compiled many-valued (δ) multimodal clustering."""

    def __init__(self, sizes: Sequence[int], delta: float,
                 rho_min: float = 0.0, minsup: int = 0, seed: int = 0x5EED):
        self.sizes = tuple(int(s) for s in sizes)
        self.delta, self.rho_min, self.minsup = float(delta), float(rho_min), int(minsup)
        vecs = B.mode_hash_vectors(self.sizes, seed)
        self._lo = [jnp.asarray(lo) for lo, _ in vecs]
        self._hi = [jnp.asarray(hi) for _, hi in vecs]
        self._fn = jax.jit(functools.partial(
            noac_mine, delta=self.delta, rho_min=self.rho_min,
            minsup=self.minsup))

    def __call__(self, tuples, values) -> NOACResult:
        return self._fn(jnp.asarray(tuples, jnp.int32),
                        jnp.asarray(values, jnp.float32), self._lo, self._hi)

    def mine_context(self, ctx: PolyadicContext):
        if ctx.values is None:
            # §3.2: W={0,1}, δ=0 degenerates to prime operators
            ctx = PolyadicContext(ctx.sizes, ctx.tuples,
                                  np.zeros(ctx.num_tuples, np.float32),
                                  ctx.names)
        ctx = ctx.deduplicated()
        res = self(ctx.tuples, ctx.values)
        return self.materialise(res, ctx)

    def materialise(self, res: NOACResult, ctx: PolyadicContext):
        keep = np.asarray(res.keep)
        rlo, rhi = np.asarray(res.range_lo), np.asarray(res.range_hi)
        perms = np.asarray(res.perms)
        dens = np.asarray(res.density)
        out = []
        for i in np.nonzero(keep)[0]:
            comps = []
            for k in range(ctx.arity):
                idx = perms[k][rlo[k, i]:rhi[k, i]]
                comps.append(frozenset(ctx.tuples[idx, k].tolist()))
            out.append((tuple(comps), float(dens[i])))
        return out
