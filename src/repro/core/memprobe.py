"""Peak device-allocation probe for the windowed pipeline benchmarks.

Three measurement sources, best available first:

* ``device.memory_stats()`` — real allocator telemetry on accelerator
  backends (TPU/GPU expose ``bytes_in_use``; the probe prefers it and
  resets nothing, reporting deltas from the probe's baseline).
* ``jax.live_arrays()`` — on backends without allocator stats (XLA-CPU)
  the summed ``nbytes`` of live device buffers is an exact census of
  *materialised* arrays.  Sampled at stage boundaries it misses
  transient compiler scratch, but that scratch is itself sized by the
  operand shapes being compared, so the O(window)-vs-O(T) contrast the
  benchmark gates on survives the approximation.
* RSS delta (``resource.getrusage``) — last-resort fallback when jax
  introspection is unavailable; peak RSS only grows, so only useful as
  a coarse upper bound.

``MemProbe`` is the ``probe`` callback of ``core.windowed``: call it
with a stage name at each sampling point; ``peak_bytes`` / ``stages``
report high-water deltas from the construction-time baseline.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax


def device_bytes() -> int:
    """Current device allocation estimate in bytes (see module doc)."""
    try:
        dev = jax.local_devices()[0]
        stats = dev.memory_stats()
        if stats and "bytes_in_use" in stats:
            return int(stats["bytes_in_use"])
    except Exception:
        pass
    try:
        return int(sum(a.nbytes for a in jax.live_arrays()))
    except Exception:
        import resource
        return int(resource.getrusage(resource.RUSAGE_SELF).ru_maxrss * 1024)


class MemProbe:
    """High-water allocation tracker relative to a baseline sample."""

    def __init__(self):
        self.baseline = device_bytes()
        self.stages: Dict[str, int] = {}
        self.peak_bytes = 0

    def __call__(self, stage: str = "total") -> int:
        delta = max(0, device_bytes() - self.baseline)
        self.stages[stage] = max(self.stages.get(stage, 0), delta)
        self.peak_bytes = max(self.peak_bytes, delta)
        return delta

    def report(self) -> Dict[str, int]:
        return {"peak_bytes": int(self.peak_bytes),
                "stages": {k: int(v) for k, v in sorted(self.stages.items())}}


def measure_result_bytes(result) -> int:
    """Device bytes held live by a result pytree (0 for host/numpy
    leaves) — what a monolithic run keeps resident after it returns."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(result):
        if isinstance(leaf, jax.Array):
            total += int(leaf.nbytes)
    return total
