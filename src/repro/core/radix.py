"""Bit-plan-pruned LSD radix sort: the default backend of
``keys.sort_with_payload`` (DESIGN.md §3b).

The packed keys of ``core.keys`` are fixed-width words whose *live* bit
count is known statically from the bit-width plans, which makes an LSD
radix sort strictly cheaper than a comparison sort: only digits that
overlap live bit ranges get a pass, so a 28-bit movielens key is two
passes and a 60-bit NOAC key four — never a function of the 64-bit
container.

Two device formulations, both producing the *same stable permutation*
as ``lax.sort`` bit-for-bit (what ``tests/test_radix_property.py``
asserts):

* **Composite-word passes** (default off-TPU).  The measured CPU cost
  model (MEMORY: cpu-perf-cost-model) shows XLA-CPU's *variadic* sort —
  any ``lax.sort`` carrying a payload operand — runs ~16x slower than
  its single-array fast path (~100 ms vs ~6 ms at T=120k), and every
  scatter costs ~9-15 ms.  So each pass sorts ONE uint32 word
  ``(digit << pos_bits) | position``: the embedded position makes the
  word unique (stability for free) and *is* the back-pointer, so the
  pass permutation comes out of the sorted word's low bits — histogram,
  rank and scatter all disappear.  The digit width is the complement of
  the position bits (``32 - ceil(log2 T)``, 15 bits at T=120k), which
  also minimises the pass count.

* **Histogram ranks** (``use_pallas``, auto-enabled on TPU like
  ``segment_reduce``).  The classic 8-bit-digit formulation: one sweep
  over the words builds the per-pass histograms for *every* pruned
  digit position (``kernels/radix_sort.radix_histogram`` — the same
  top-digit histogram primitive the distributed shuffle runs on its
  pre-shuffle keys as a range partitioner), then each pass ranks
  elements as ``bucket_start[digit]
  + running occurrence`` with a chained-carry one-sweep kernel
  (``radix_rank``) and applies the rank with one scatter.

``lax.sort`` remains available behind the same API (``backend='lax'``),
and contexts whose key exceeds 64 bits keep the N+1-column lexsort path
exactly as before — the selector only ever touches fitting packed keys.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

#: Digit width of the histogram (Pallas) formulation.
HIST_DIGIT_BITS = 8
HIST_BUCKETS = 1 << HIST_DIGIT_BITS

#: Valid values of the ``sort_backend`` selector threaded through the
#: engines.  ``None``/'auto' resolve to 'radix' for fitting keys.
SORT_BACKENDS = ("radix", "lax", "lexsort")


def pos_bits(t: int) -> int:
    """Bits needed to embed positions 0..t-1 in a composite word."""
    return max(1, int(np.ceil(np.log2(max(int(t), 2)))))


@dataclasses.dataclass(frozen=True)
class RadixPlan:
    """Static pass schedule for sorting ``live_bits``-wide keys of a
    length-``t`` array: ``shifts[p]``/``widths[p]`` give pass p's digit
    as a bit range of the conceptual ≤64-bit key (LSB first)."""
    t: int
    live_bits: int
    pos_bits: int
    shifts: Tuple[int, ...]
    widths: Tuple[int, ...]

    @property
    def passes(self) -> int:
        return len(self.shifts)


def plan_radix(live_bits: int, t: int,
               digit_bits: Optional[int] = None) -> RadixPlan:
    """Pass schedule covering exactly the live bits (bit-plan pruning):
    ``ceil(live_bits / digit_bits)`` passes, digit width defaulting to
    the composite-word maximum ``32 - pos_bits(t)``."""
    live_bits = max(1, int(live_bits))
    pb = pos_bits(t)
    w = int(digit_bits) if digit_bits else 32 - pb
    if not 0 < w < 32:
        raise ValueError(f"digit width {w} out of range")
    shifts, widths, s = [], [], 0
    while s < live_bits:
        shifts.append(s)
        widths.append(min(w, live_bits - s))
        s += w
    return RadixPlan(int(t), live_bits, pb, tuple(shifts), tuple(widths))


def extract_digit(words: Sequence[jnp.ndarray], shift: int,
                  width: int) -> jnp.ndarray:
    """Bits [shift, shift+width) of msb-first packed uint32 words, as a
    uint32 digit.  ``width`` < 32 (a radix digit never spans a whole
    word of the plan)."""
    mask = jnp.uint32((1 << width) - 1)
    if len(words) == 1:
        return (words[0] >> shift) & mask
    hi, lo = words
    if shift >= 32:
        return (hi >> (shift - 32)) & mask
    if shift + width <= 32:
        return (lo >> shift) & mask
    return ((lo >> shift) | (hi << (32 - shift))) & mask


# ---------------------------------------------------------------------------
# Device sort
# ---------------------------------------------------------------------------

def _perm_composite(words, plan: RadixPlan) -> jnp.ndarray:
    """Stable sort permutation via composite-word passes (no payload
    operands, no scatters — see module docstring)."""
    t = plan.t
    iota = jnp.arange(t, dtype=jnp.uint32)
    pmask = jnp.uint32((1 << plan.pos_bits) - 1)
    perm = None
    for shift, width in zip(plan.shifts, plan.widths):
        dig = extract_digit(words, shift, width)
        if perm is not None:
            dig = dig[perm]
        (s,) = jax.lax.sort(((dig << plan.pos_bits) | iota,), num_keys=1,
                            is_stable=False)
        src = (s & pmask).astype(jnp.int32)
        perm = src if perm is None else perm[src]
    return perm


def _perm_histogram(words, plan: RadixPlan, use_pallas: bool) -> jnp.ndarray:
    """Stable sort permutation via histogram ranks over ``plan``'s digit
    schedule (the ``kernels/radix_sort`` pair; one rank scatter per
    pass).  The plan must use ≤``HIST_DIGIT_BITS``-wide digits."""
    from ..kernels import ops as kops
    hists = kops.radix_histogram(words, plan.shifts, plan.widths,
                                 use_pallas=use_pallas)
    t = plan.t
    iota = jnp.arange(t, dtype=jnp.int32)
    perm = None
    for p, (shift, width) in enumerate(zip(plan.shifts, plan.widths)):
        starts = jnp.concatenate(
            [jnp.zeros((1,), jnp.int32),
             jnp.cumsum(hists[p], dtype=jnp.int32)[:-1]])
        dig = extract_digit(words, shift, width)
        if perm is not None:
            dig = dig[perm]
        rank = kops.radix_rank(dig, starts, use_pallas=use_pallas)
        src = jnp.zeros((t,), jnp.int32).at[rank].set(iota)
        perm = src if perm is None else perm[src]
    return perm


def radix_sort_perm(words: Sequence[jnp.ndarray], live_bits: int,
                    use_pallas: bool = False,
                    max_passes: Optional[int] = None) -> jnp.ndarray:
    """Permutation stably sorting msb-first packed ``words`` ascending,
    bit-identical to ``lax.sort`` with an iota payload.

    ``max_passes`` truncates the LSD schedule (benchmark per-pass
    attribution only — a truncated sort is *not* a total order); it
    counts passes of the formulation actually run (composite-word
    digits, or 8-bit histogram digits under ``use_pallas``)."""
    plan = plan_radix(live_bits, words[0].shape[0],
                      digit_bits=HIST_DIGIT_BITS if use_pallas else None)
    if max_passes is not None:
        plan = dataclasses.replace(plan, shifts=plan.shifts[:max_passes],
                                   widths=plan.widths[:max_passes])
    if use_pallas:
        return _perm_histogram(words, plan, use_pallas)
    return _perm_composite(words, plan)


def sort_with_payload_radix(words: Sequence[jnp.ndarray],
                            payloads: Sequence[jnp.ndarray],
                            live_bits: int, use_pallas: bool = False):
    """Drop-in for ``keys.sort_with_payload``: same (sorted_words,
    sorted_payloads) tuples, stability included, via the radix
    permutation + gathers instead of carrying payload sort operands."""
    perm = radix_sort_perm(words, live_bits, use_pallas)
    return (tuple(w[perm] for w in words),
            tuple(p[perm] for p in payloads))


# ---------------------------------------------------------------------------
# Host sort (streaming chunk runs)
# ---------------------------------------------------------------------------

def radix_argsort_host(keys: np.ndarray, live_bits: int) -> np.ndarray:
    """Stable ascending argsort of uint64 packed keys, LSD over 16-bit
    digits — numpy's stable sort is a radix sort for ≤16-bit integers,
    so each pass rides that fast path instead of a 64-bit mergesort.
    Bit-identical to ``np.argsort(keys, kind='stable')``."""
    keys = np.ascontiguousarray(keys, np.uint64)
    order = np.arange(keys.shape[0], dtype=np.int64)
    cur = keys
    shift = 0
    live_bits = max(1, int(live_bits))
    while shift < live_bits:
        w = min(16, live_bits - shift)
        dig = ((cur >> np.uint64(shift))
               & np.uint64((1 << w) - 1)).astype(np.uint16)
        o = np.argsort(dig, kind="stable")
        order = order[o]
        cur = cur[o]
        shift += w
    return order


# ---------------------------------------------------------------------------
# Window plan (shared sort/reduce streaming unit)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WindowPlan:
    """Static schedule of contiguous ``[start, stop)`` slices covering a
    length-``t`` sorted order in ``budget``-row windows.  This is the
    *one* streaming unit of the out-of-core path (DESIGN.md §3c): the
    host run sort chunks on it (``RunStore`` ``chunk_budget``), the
    device Stage-1/2/3 window loop iterates it, and the distributed
    shuffle rounds its per-link capacity up to a multiple of it — the
    same way the radix histogram sweep's block grid tiles one pass."""
    t: int
    budget: int

    @property
    def n_windows(self) -> int:
        return -(-self.t // self.budget)

    @property
    def bounds(self) -> Tuple[Tuple[int, int], ...]:
        return tuple((lo, min(lo + self.budget, self.t))
                     for lo in range(0, self.t, self.budget))


def plan_windows(t: int, budget: Optional[int] = None) -> WindowPlan:
    """Build the shared window plan.  ``budget=None`` (or >= t) is a
    single in-core window.  Degenerate budgets raise instead of being
    silently clamped: a silently-widened or silently-split window is
    exactly the failure mode the seam-carry contract exists to rule
    out, so misuse must be loud."""
    t = int(t)
    if t < 1:
        raise ValueError(f"window plan needs a non-empty table, got t={t}")
    if budget is None:
        return WindowPlan(t, t)
    budget = int(budget)
    if budget < 1:
        raise ValueError(
            f"window_budget must be >= 1, got {budget}; pass None for a "
            "single in-core window")
    return WindowPlan(t, min(budget, t))


# ---------------------------------------------------------------------------
# Backend resolution (single source of truth for every engine)
# ---------------------------------------------------------------------------

def resolve_sort_backend(sort_backend: Optional[str],
                         packed: Optional[bool], fits: bool) -> str:
    """Map the user-facing (sort_backend, packed) pair onto the actual
    Stage-1/3 sort path: 'radix' (default for fitting keys), 'lax' (the
    packed comparison-sort baseline) or 'lexsort' (column fallback —
    forced, or required because the key exceeds 64 bits)."""
    if sort_backend not in (None, "auto") + SORT_BACKENDS:
        raise ValueError(
            f"sort_backend={sort_backend!r}; valid: {SORT_BACKENDS}")
    if sort_backend == "lexsort" or packed is False or not fits:
        return "lexsort"
    if sort_backend in (None, "auto"):
        return "radix"
    return sort_backend


def wants_value_pruning(prune_values, packed, sort_backend) -> bool:
    """Single definition of "should this engine compute the lane-pruning
    value domain?" — pruning is off only when disabled or when the
    caller forced the lexsort path.  Deliberately independent of the
    un-pruned ``fits``: a key that overflows 64 bits only because of
    the 32-bit float lane packs fine once pruned, so the sort path is
    re-resolved from the pruned plans afterwards."""
    return (bool(prune_values) and packed is not False
            and sort_backend != "lexsort")
