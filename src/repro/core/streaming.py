"""Online / streaming clustering (paper §2 online setting) with
merge-based incremental snapshots and upsert (tombstone) streams.

The paper's online Algorithm 1 keeps dictionaries and appends pointers
per incoming triple.  The accelerator analogue keeps, per mode, the
tuple table's *sorted order* as a set of sorted runs — the shared
``core.runs.RunStore`` storage layer (DESIGN.md §4), which this engine
drives against the shared pipeline of ``core.pipeline``:

* ``add(chunk)`` sorts **only the chunk** (O(c log c) per mode) into a
  new run; geometric compaction merges runs linearly, so every tuple is
  merged O(log T) times over the stream's lifetime.
* ``upsert(rows, values)`` / ``delete(rows)`` tombstone superseded
  versions in the store — last-write-wins, exactly the batch
  constructor's canonicalisation (``core.context``) — which lifts the
  historical precondition that valued streams be per-tuple
  value-consistent: a valued ``add`` *is* an upsert.
* ``snapshot()`` compacts tombstones away, k-way-merges the surviving
  runs into full per-mode permutations (linear in T, no re-sort) and
  hands them to the jitted pipeline via its ``perms`` argument, which
  skips Stage 1's sorts and recomputes segments/signatures/dedup from
  the pre-sorted order.

This cuts the amortised per-snapshot cost of Stage 1 — the dominant
term of the one-pass pipeline — from O(T log T) re-sorting to
O(chunk log T) merging; Stage 3's signature dedup still sorts the
(8-byte) signature array on device.  Snapshots are *exact*: identical
cluster sets (and bit-identical signatures) to a full re-mine of the
survivor table, which is what the tests assert.  Both variants stream:
prime/multimodal (θ) and NOAC (δ/ρ_min/minsup).

The store merges host-packed uint64 keys from the *same* ``core.keys``
bit-width plans the device pipeline sorts by, so host-merged
permutations and device sorts order identically by construction.  The
streaming plans keep the un-pruned float value lane (runs must stay
mergeable when later chunks introduce unseen values).  If a context's
key does not fit in 64 bits, the engine transparently falls back to
exact full re-sorting per snapshot and reports it in
``stats['incremental']``; upsert/delete still work (tombstones live in
the log, not the runs).

Properties kept from the paper's online algorithm:
* one pass over the data (each tuple enters the log once),
* per-chunk latency O(c log c + merge debt) with O(log T) total
  recompilations (power-of-two padding),
* checkpointable: ``state.checkpoint()`` serialises the run arrays and
  tombstones themselves, so restore is O(T) array loads — no re-sort
  (legacy buffer-only blobs still restore via one lazy rebuild sort).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from . import pipeline as P
from . import runs as RS

#: Checkpoint/restore entry point (kept under its historical name; the
#: state object *is* the shared run store).
StreamState = RS.RunStore


class StreamingMiner(P.PipelineMiner):
    """Online one-pass mining with exact snapshot-on-demand semantics.

    Ingestion: ``add`` (append; valued streams upsert — see module
    docstring), ``upsert`` (insert-or-replace by tuple, last write
    wins), ``delete`` (tombstone).  ``snapshot()`` mines the current
    survivor set exactly."""

    def __init__(self, sizes, theta: float = 0.0, seed: int = 0x5EED,
                 delta: Optional[float] = None, rho_min: float = 0.0,
                 minsup: int = 0, incremental: bool = True,
                 packed: Optional[bool] = None,
                 sort_backend: Optional[str] = None,
                 use_pallas: Optional[bool] = None,
                 prune_values: bool = True,
                 window_budget: Optional[int] = None):
        # prune_values is accepted for registry-kwarg uniformity but has
        # no effect on snapshots: the streaming device pipeline shares
        # the host store's un-pruned float value lane (see module
        # docstring) — only a direct PipelineMiner.__call__ would prune.
        super().__init__(sizes, theta=(rho_min if delta is not None
                                       else theta),
                         delta=delta, minsup=minsup, seed=seed,
                         packed=packed, sort_backend=sort_backend,
                         use_pallas=use_pallas, prune_values=prune_values,
                         window_budget=window_budget)
        # host packing shares the device pipeline's bit-width plans
        # (core.keys) — the packers are bit-identical by construction
        self._codecs = self.key_plans
        self.incremental = bool(incremental) and all(c.fits
                                                     for c in self._codecs)
        self.state: Optional[RS.RunStore] = None
        self.stats = {"snapshots": 0, "full_resorts": 0, "merged_rows": 0,
                      "chunk_sorted_rows": 0, "tombstoned_rows": 0,
                      "incremental": self.incremental}
        # snapshot versioning (serve/service.py): every mutating call
        # bumps ``stream_version``; ``snapshot()`` records the version it
        # covers, so a published snapshot can be tagged with exactly the
        # writes it reflects
        self.stream_version = 0
        self.snapshot_stream_version = 0
        # per-snapshot dirty-signature tracking (serve delta index):
        # off by default — it forces a host transfer of the signature
        # lanes inside snapshot(), which mining benchmarks must not pay
        self.track_dirty_sigs = False
        self.last_kept_sigs: Optional[np.ndarray] = None
        self.last_dirty_sigs = 0
        # kept for API compatibility: the snapshot materialiser
        self.miner = self

    # -- ingestion ----------------------------------------------------------

    def _store(self) -> RS.RunStore:
        """The run store, created on first use and re-adopted after a
        checkpoint restore (a restored store may lack plans — legacy
        blobs — or carry its own stats dict)."""
        if self.state is None:
            self.state = RS.RunStore(
                self._codecs, radix=self.resolved_sort_backend == "radix",
                incremental=self.incremental, stats=self.stats)
        s = self.state
        if s.plans is None:
            s.plans = self._codecs
        s.radix = self.resolved_sort_backend == "radix"
        s.incremental = s.incremental and self.incremental
        s.stats = self.stats
        return s

    def add(self, chunk: np.ndarray, values=None) -> None:
        self._store().add(chunk, values if self.delta is not None else None)
        self.stream_version += 1

    def upsert(self, rows: np.ndarray, values=None) -> None:
        self._store().upsert(rows,
                             values if self.delta is not None else None)
        self.stream_version += 1

    def delete(self, rows: np.ndarray) -> None:
        self._store().delete(rows)
        self.stream_version += 1

    # -- snapshots ----------------------------------------------------------

    def _padded(self):
        s = self.state
        buf, vals = s.table()
        count = s.count
        cap = RS.snapshot_cap(count)
        buf, vals = RS.padded_table(buf, vals, cap)
        return buf, vals, count, cap

    def snapshot(self, full_remine: bool = False) -> P.PipelineResult:
        """Current cluster set of the survivor table (exact; padding is
        idempotent).

        ``full_remine=True`` forces the one-shot batch path (device
        sorts) — the baseline the incremental path is verified and
        benchmarked against."""
        if self.state is None or self.state.count == 0:
            raise ValueError("no data ingested")
        self.snapshot_stream_version = self.stream_version
        s = self._store()
        if full_remine or not s.incremental:
            s.compact()          # survivor set only; leave runs unmerged
        else:
            s.prepare()
        if s.count == 0:
            raise ValueError("no live rows (everything deleted)")
        buf, vals, count, cap = self._padded()
        self.stats["snapshots"] += 1
        import jax.numpy as jnp
        targs = jnp.asarray(buf, jnp.int32)
        vargs = None if vals is None else jnp.asarray(vals, jnp.float32)
        if full_remine or not s.incremental:
            self.stats["full_resorts"] += 1
            res = self._fn(targs, self._lo, self._hi, values=vargs)
        else:
            perms = s.perms(cap)
            if self.window_budget and self.packed_active:
                # windowed snapshot remine (DESIGN.md §3c): the merged
                # perms feed the bounded device window loop instead of
                # one monolithic O(T) pipeline call — bit-identical
                from . import windowed as WD
                res = WD.mine_windowed(
                    buf, vals, perms, plans=self.key_plans,
                    hash_lo=self._lo, hash_hi=self._hi, delta=self.delta,
                    theta=self.theta, minsup=self.minsup,
                    window_budget=self.window_budget,
                    sort_backend=self.resolved_sort_backend,
                    use_pallas=self.use_pallas)
            else:
                res = self._fn(targs, self._lo, self._hi, values=vargs,
                               perms=jnp.asarray(perms, jnp.int32))
        if self.track_dirty_sigs:
            self._note_sigs(res)
        return res

    def _note_sigs(self, result) -> None:
        """Record this snapshot's kept-signature set and how many
        signatures changed vs the previous snapshot (the serving
        layer's delta-index workload)."""
        sigs = P.kept_sig_words(result)
        self.last_dirty_sigs = P.dirty_sig_count(self.last_kept_sigs, sigs)
        self.last_kept_sigs = sigs

    def snapshot_clusters(self, only_kept: bool = True):
        return self.materialise(self.snapshot(), only_kept=only_kept)
