"""Online / streaming multimodal clustering (paper §2 online setting).

The paper's online Algorithm 1 keeps dictionaries and appends pointers per
incoming triple. The accelerator analogue here is *amortised batch
re-mining*: a capacity-doubling device buffer accumulates tuples; after
each ingested chunk the current tricluster set is available via
``snapshot()`` which runs the one-pass batch pipeline over the (padded)
buffer. Padding repeats the first row — the mining algebra is
duplicate-idempotent (DESIGN.md §3), so snapshots are exact at any point.

Properties kept from the paper's online algorithm:
* one pass over the data (each tuple enters the buffer once),
* per-chunk latency O(|buffer| log |buffer|) with O(log T) total
  recompilations (power-of-two buckets),
* checkpointable: the state is two numpy-convertible arrays.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from .batch import BatchMiner, MiningResult


@dataclasses.dataclass
class StreamState:
    buffer: np.ndarray    # (capacity, N) int32; rows >= count are padding
    count: int

    def checkpoint(self) -> dict:
        return {"buffer": self.buffer[:self.count].copy(),
                "count": self.count}

    @staticmethod
    def restore(blob: dict) -> "StreamState":
        buf = np.asarray(blob["buffer"], np.int32)
        return StreamState(buf, int(blob["count"]))


class StreamingMiner:
    """Online one-pass mining with snapshot-on-demand semantics."""

    def __init__(self, sizes, theta: float = 0.0, seed: int = 0x5EED):
        self.sizes = tuple(int(s) for s in sizes)
        self.miner = BatchMiner(self.sizes, theta=theta, seed=seed)
        self.state: Optional[StreamState] = None

    def add(self, chunk: np.ndarray) -> None:
        chunk = np.asarray(chunk, np.int32)
        if self.state is None:
            self.state = StreamState(chunk.copy(), chunk.shape[0])
        else:
            self.state = StreamState(
                np.concatenate([self.state.buffer[:self.state.count], chunk]),
                self.state.count + chunk.shape[0])

    def _padded(self) -> np.ndarray:
        buf, count = self.state.buffer[:self.state.count], self.state.count
        cap = 1 << max(0, int(np.ceil(np.log2(max(count, 1)))))
        if cap < count:
            cap *= 2
        pad = cap - count
        if pad:
            buf = np.concatenate([buf, np.repeat(buf[:1], pad, 0)])
        return buf

    def snapshot(self) -> MiningResult:
        """Current tricluster set (exact; padding is idempotent)."""
        if self.state is None or self.state.count == 0:
            raise ValueError("no data ingested")
        return self.miner(self._padded())

    def snapshot_clusters(self, only_kept: bool = True):
        buf = self._padded()
        return self.miner.materialise(self.snapshot(), buf, only_kept)
