"""Online / streaming clustering (paper §2 online setting) with
merge-based incremental snapshots.

The paper's online Algorithm 1 keeps dictionaries and appends pointers
per incoming triple.  The accelerator analogue here keeps, per mode, the
tuple table's *sorted order* as a set of sorted runs (an LSM-style
structure over the shared pipeline of ``core.pipeline``):

* ``add(chunk)`` sorts **only the chunk** (O(c log c) per mode) into a new
  run, then compacts geometrically-sized runs by linear two-run merges —
  every tuple is merged O(log T) times over the stream's lifetime.
* ``snapshot()`` k-way-merges the surviving runs into full per-mode
  permutations (linear in T, no re-sort) and hands them to the jitted
  pipeline via its ``perms`` argument, which skips Stage 1's lexsorts and
  recomputes segments/signatures/dedup from the pre-sorted order.

This cuts the amortised per-snapshot cost of Stage 1 — the dominant term
of the one-pass pipeline — from O(T log T) re-sorting to O(chunk log T)
merging; Stage 3's signature dedup still sorts the (8-byte) signature
array on device.  Snapshots are *exact*: identical cluster sets (and
bit-identical signatures) to a full re-mine of the buffer, which is what
the tests assert.  Both variants stream: prime/multimodal (θ) and NOAC
(δ/ρ_min/minsup) — the value column simply joins each mode's sort key.

Mechanics: run merging works on per-mode uint64-packed sort keys from
``core.keys`` (entity-id bit-fields, plus an order-preserving float32
encoding for the value column) — the *same* bit-width plans the device
pipeline sorts by, so host-merged permutations and device sorts order
identically by construction.  If a context's key does not fit in 64
bits, the engine transparently falls back to exact full re-sorting per
snapshot and reports it in ``stats['incremental']``.

Properties kept from the paper's online algorithm:
* one pass over the data (each tuple enters the buffer once),
* per-chunk latency O(c log c + merge debt) with O(log T) total
  recompilations (power-of-two padding),
* checkpointable: the state is numpy-convertible arrays (runs are
  rebuilt lazily after a restore).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from . import keys as K
from . import pipeline as P
from . import radix as RX


@dataclasses.dataclass
class _Run:
    """One sorted run: per-mode sorted keys + buffer-row indices."""
    keys: List[np.ndarray]   # per mode, (L,) uint64, ascending
    idx: List[np.ndarray]    # per mode, (L,) int32 indices into the buffer

    @property
    def size(self) -> int:
        return int(self.idx[0].shape[0])


def _merge_two(a: _Run, b: _Run) -> _Run:
    """Linear stable merge of two sorted runs (a's elements win ties)."""
    keys, idx = [], []
    for ka, ia, kb, ib in zip(a.keys, a.idx, b.keys, b.idx):
        pa = np.searchsorted(kb, ka, side="left") + np.arange(ka.size)
        pb = np.searchsorted(ka, kb, side="right") + np.arange(kb.size)
        mk = np.empty(ka.size + kb.size, np.uint64)
        mi = np.empty(ka.size + kb.size, np.int32)
        mk[pa], mk[pb] = ka, kb
        mi[pa], mi[pb] = ia, ib
        keys.append(mk)
        idx.append(mi)
    return _Run(keys, idx)


# ---------------------------------------------------------------------------
# Stream state
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StreamState:
    buffer: np.ndarray                    # (count, N) int32
    count: int
    values: Optional[np.ndarray] = None   # (count,) float32, NOAC streams
    runs: List[_Run] = dataclasses.field(default_factory=list)
    covered: int = 0                      # rows already inside ``runs``

    def checkpoint(self) -> dict:
        blob = {"buffer": self.buffer[:self.count].copy(),
                "count": self.count}
        if self.values is not None:
            blob["values"] = self.values[:self.count].copy()
        return blob

    @staticmethod
    def restore(blob: dict) -> "StreamState":
        buf = np.asarray(blob["buffer"], np.int32)
        vals = (np.asarray(blob["values"], np.float32)
                if blob.get("values") is not None else None)
        # runs are rebuilt lazily (covered=0): one O(T log T) sort at resume
        return StreamState(buf, int(blob["count"]), vals)


class StreamingMiner(P.PipelineMiner):
    """Online one-pass mining with exact snapshot-on-demand semantics.

    Many-valued streams: ingestion is append-only (duplicate rows are
    idempotent under the mining algebra), so a duplicate tuple arriving
    with a *conflicting* value is a precondition violation — V must be
    a function of the tuple (§3.2).  Batch/distributed inputs get this
    canonicalised at ``PolyadicContext`` construction (last value
    wins); a raw-array stream must be value-consistent itself.  True
    upsert streaming (replacing a row inside already-sorted runs) needs
    LSM tombstones — a ROADMAP item, not a property of this engine."""

    def __init__(self, sizes, theta: float = 0.0, seed: int = 0x5EED,
                 delta: Optional[float] = None, rho_min: float = 0.0,
                 minsup: int = 0, incremental: bool = True,
                 packed: Optional[bool] = None,
                 sort_backend: Optional[str] = None,
                 use_pallas: Optional[bool] = None,
                 prune_values: bool = True):
        # prune_values is accepted for registry-kwarg uniformity but has
        # no effect on snapshots: the streaming device pipeline shares
        # the host codecs' un-pruned float value lane (see module
        # docstring) — only a direct PipelineMiner.__call__ would prune.
        super().__init__(sizes, theta=(rho_min if delta is not None
                                       else theta),
                         delta=delta, minsup=minsup, seed=seed,
                         packed=packed, sort_backend=sort_backend,
                         use_pallas=use_pallas, prune_values=prune_values)
        # host packing shares the device pipeline's bit-width plans
        # (core.keys) — the packers are bit-identical by construction
        self._codecs = self.key_plans
        self.incremental = bool(incremental) and all(c.fits
                                                     for c in self._codecs)
        self.state: Optional[StreamState] = None
        self.stats = {"snapshots": 0, "full_resorts": 0, "merged_rows": 0,
                      "chunk_sorted_rows": 0,
                      "incremental": self.incremental}
        # kept for API compatibility: the snapshot materialiser
        self.miner = self

    # -- ingestion ----------------------------------------------------------

    def add(self, chunk: np.ndarray, values=None) -> None:
        chunk = np.atleast_2d(np.asarray(chunk, np.int32))
        vals = None
        if self.delta is not None:
            vals = (np.zeros(chunk.shape[0], np.float32) if values is None
                    else np.asarray(values, np.float32))
        if self.state is None:
            self.state = StreamState(chunk.copy(), chunk.shape[0],
                                     vals.copy() if vals is not None
                                     else None)
        else:
            s = self.state
            buf = np.concatenate([s.buffer[:s.count], chunk])
            v = (np.concatenate([s.values[:s.count], vals])
                 if vals is not None else None)
            self.state = StreamState(buf, buf.shape[0], v, s.runs, s.covered)
        if self.incremental:
            self._absorb_tail()

    def _absorb_tail(self) -> None:
        """Sort any rows not yet covered by runs (normally just the new
        chunk; the whole buffer after a checkpoint restore) into a fresh
        run, then compact geometrically."""
        s = self.state
        lo, hi = s.covered, s.count
        if lo >= hi:
            return
        rows = s.buffer[lo:hi]
        vals = s.values[lo:hi] if s.values is not None else None
        # the chunk sort mirrors the device's sort backend: host LSD
        # radix over the same bit plans, or numpy's comparison sort
        radix = self.resolved_sort_backend == "radix"
        keys, idx = [], []
        for codec in self._codecs:
            k = codec.pack_host(rows, vals)
            order = (RX.radix_argsort_host(k, codec.total_bits) if radix
                     else np.argsort(k, kind="stable"))
            keys.append(k[order])
            idx.append((order + lo).astype(np.int32))
        s.runs.append(_Run(keys, idx))
        s.covered = hi
        self.stats["chunk_sorted_rows"] += hi - lo
        while len(s.runs) >= 2 and s.runs[-2].size <= 2 * s.runs[-1].size:
            merged = _merge_two(s.runs[-2], s.runs[-1])
            self.stats["merged_rows"] += merged.size
            s.runs[-2:] = [merged]

    # -- snapshots ----------------------------------------------------------

    def _padded(self):
        s = self.state
        buf, count = s.buffer[:s.count], s.count
        cap = 1 << max(0, int(np.ceil(np.log2(max(count, 1)))))
        if cap < count:
            cap *= 2
        pad = cap - count
        if pad:
            buf = np.concatenate([buf, np.repeat(buf[:1], pad, 0)])
        vals = None
        if self.delta is not None:
            vals = s.values[:count]
            if pad:
                vals = np.concatenate([vals, np.repeat(vals[:1], pad)])
        return buf, vals, count, cap

    def _merged_perms(self, count: int, cap: int) -> np.ndarray:
        """Collapse all runs into one and extend it with the pad rows
        (duplicates of row 0 — idempotent), giving (N, cap) permutations."""
        s = self.state
        run = s.runs[0]
        for other in s.runs[1:]:
            run = _merge_two(run, other)
            self.stats["merged_rows"] += run.size
        s.runs = [run]
        if cap == count:
            return np.stack(run.idx)
        row0 = s.buffer[:1]
        val0 = s.values[:1] if s.values is not None else None
        pad_idx = np.arange(count, cap, dtype=np.int32)
        perms = []
        for codec, keys, idx in zip(self._codecs, run.keys, run.idx):
            key0 = codec.pack_host(row0, val0)[0]
            pos = int(np.searchsorted(keys, key0, side="right"))
            perms.append(np.insert(idx, pos, pad_idx))
        return np.stack(perms)

    def snapshot(self, full_remine: bool = False) -> P.PipelineResult:
        """Current cluster set (exact; padding is idempotent).

        ``full_remine=True`` forces the one-shot batch path (device
        lexsorts) — the baseline the incremental path is verified and
        benchmarked against."""
        if self.state is None or self.state.count == 0:
            raise ValueError("no data ingested")
        buf, vals, count, cap = self._padded()
        self.stats["snapshots"] += 1
        import jax.numpy as jnp
        targs = jnp.asarray(buf, jnp.int32)
        vargs = None if vals is None else jnp.asarray(vals, jnp.float32)
        if full_remine or not self.incremental:
            self.stats["full_resorts"] += 1
            return self._fn(targs, self._lo, self._hi, values=vargs)
        self._absorb_tail()
        perms = self._merged_perms(count, cap)
        return self._fn(targs, self._lo, self._hi, values=vargs,
                        perms=jnp.asarray(perms, jnp.int32))

    def snapshot_clusters(self, only_kept: bool = True):
        return self.materialise(self.snapshot(), only_kept=only_kept)
