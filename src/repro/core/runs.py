"""Sorted-run storage layer: the one LSM-style structure under every
engine's incremental / out-of-core path (DESIGN.md §4).

The paper's online Algorithm 1 and its MapReduce variants reduce to the
same primitive — maintaining per-mode *sorted order* of the tuple table
incrementally instead of re-sorting it — and three engine features are
built on exactly that primitive through this module:

* **streaming snapshots** (``core.streaming``): chunks are sorted on
  arrival, snapshots merge runs into full permutations;
* **out-of-core batch Stage 1** (``PipelineMiner.mine_chunked``): the
  table is sorted chunk-by-chunk on the host with O(chunk) working set,
  and the device pipeline receives the merged permutations instead of
  sorting;
* **incremental distributed snapshots** (``DistributedMiner.ingest`` /
  ``snapshot``): per-shard stores absorb the trickle, snapshots merge
  per-shard runs instead of re-sorting every shard.

A ``RunStore`` owns an append-only row log plus, per mode, a set of
sorted :class:`Run` s of packed key words (``core.keys`` plans — the
*same* bit layouts the device pipeline sorts by, so host-merged
permutations and device sorts order identically by construction):

* ``add(chunk)`` sorts **only the chunk** (O(c log c) per mode, host LSD
  radix from ``core.radix`` by default) into a new run, then compacts
  geometrically-sized runs by linear two-run merges — every tuple is
  merged O(log T) times over the store's lifetime.
* **Tombstones**: ``upsert(rows, values)`` and ``delete(rows)`` mark
  superseded log rows dead in an ``alive`` bitmap — the record itself
  is the tombstone, no sentinel keys enter the sorted order — giving
  last-write-wins semantics matching the batch constructor's
  canonicalisation (``core.context``: one row per distinct tuple, last
  value wins).  Valued ``add`` *is* ``upsert``, which lifts the
  historical value-consistency precondition on many-valued streams.
  Run merges drop dead entries; ``prepare()``/``compact()`` rewrite the
  log to the survivor set before a snapshot.
* ``prepare()`` folds the surviving runs into one per-mode permutation
  of the compacted survivor table (linear in T, no re-sort);
  ``perms(cap)`` pads it with duplicates of row 0 (idempotent under the
  mining algebra) to a static device shape.
* The whole state is numpy arrays: ``checkpoint()`` serialises the run
  arrays and tombstones themselves, so ``restore`` is O(T) array loads
  — no re-sort (old buffer-only blobs still restore via the lazy
  rebuild path: ``covered=0`` re-sorts once on resume).

Rows are identified (for upsert/delete) by an *entity-only* packed key
— mode 0's layout without the value lane — so versions of a tuple with
different values collapse onto one identity; contexts whose identity
key exceeds 64 bits fall back to row-byte keys.  Unvalued stores build
the identity index lazily on the first upsert/delete, so pure append
streams pay nothing for it; valued stores maintain it from the first
chunk (their adds ARE upserts) — an O(rows) host dict pass per chunk,
amortised once per row over the stream.
"""
from __future__ import annotations

import dataclasses
import struct
from typing import List, Optional, Sequence, Tuple

import numpy as np

from . import keys as K
from . import radix as RX


@dataclasses.dataclass
class Run:
    """One sorted run: per-mode sorted packed keys + log-row indices."""
    keys: List[np.ndarray]   # per mode, (L,) uint64, ascending
    idx: List[np.ndarray]    # per mode, (L,) int32 indices into the log

    @property
    def size(self) -> int:
        return int(self.idx[0].shape[0])


def merge_runs(a: Run, b: Run) -> Run:
    """Linear stable merge of two sorted runs (a's elements win ties).
    Disjoint key ranges (e.g. radix-range-partitioned shards, mode 0)
    short-circuit to a concatenation."""
    keys, idx = [], []
    for ka, ia, kb, ib in zip(a.keys, a.idx, b.keys, b.idx):
        if ka.size == 0 or kb.size == 0 or ka[-1] <= kb[0]:
            keys.append(np.concatenate([ka, kb]))
            idx.append(np.concatenate([ia, ib]))
            continue
        if kb[-1] < ka[0]:          # strict: ties must keep a first
            keys.append(np.concatenate([kb, ka]))
            idx.append(np.concatenate([ib, ia]))
            continue
        pa = np.searchsorted(kb, ka, side="left") + np.arange(ka.size)
        pb = np.searchsorted(ka, kb, side="right") + np.arange(kb.size)
        mk = np.empty(ka.size + kb.size, np.uint64)
        mi = np.empty(ka.size + kb.size, np.int32)
        mk[pa], mk[pb] = ka, kb
        mi[pa], mi[pb] = ia, ib
        keys.append(mk)
        idx.append(mi)
    return Run(keys, idx)


def offset_run(run: Run, offset: int) -> Run:
    """The run with all log indices shifted (cross-store merges)."""
    if offset == 0:
        return run
    return Run(run.keys, [i + np.int32(offset) for i in run.idx])


def padded_perms(run: Run, plans: Sequence[K.ModeKeyPlan],
                 row0: np.ndarray, val0: Optional[np.ndarray],
                 count: int, cap: int) -> np.ndarray:
    """(N, cap) permutations from a full merged run over ``count`` rows,
    extended with pad indices [count, cap) at the sort positions of row
    0's key — pad rows are duplicates of row 0, idempotent under the
    mining algebra."""
    if cap == count:
        return np.stack(run.idx)
    pad_idx = np.arange(count, cap, dtype=np.int32)
    perms = []
    for plan, keys, idx in zip(plans, run.keys, run.idx):
        key0 = plan.pack_host(row0, val0)[0]
        pos = int(np.searchsorted(keys, key0, side="right"))
        perms.append(np.insert(idx, pos, pad_idx))
    return np.stack(perms)


def padded_table(rows: np.ndarray, values: Optional[np.ndarray],
                 cap: int) -> Tuple[np.ndarray, Optional[np.ndarray]]:
    """(rows, values) extended to ``cap`` with duplicates of row 0 — the
    SAME pad rule :func:`padded_perms` assumes (pad indices are inserted
    at row 0's key positions), kept in one place so the table and perm
    sides can never drift."""
    pad = cap - rows.shape[0]
    if pad:
        rows = np.concatenate([rows, np.repeat(rows[:1], pad, 0)])
        if values is not None:
            values = np.concatenate([values, np.repeat(values[:1], pad)])
    return rows, values


def snapshot_cap(count: int, multiple: int = 1) -> int:
    """Static device shape for a growing stream: next power of two
    (O(log T) recompiles over a stream's lifetime), rounded up to a
    multiple (shard divisibility) if needed."""
    cap = 1 << max(0, int(np.ceil(np.log2(max(count, 1)))))
    if cap < count:
        cap *= 2
    if cap % multiple:
        cap = -(-cap // multiple) * multiple
    return cap


def shard_of_rows(rows: np.ndarray, id_plan: K.ModeKeyPlan,
                  n_shards: int) -> np.ndarray:
    """Owner shard per row from the *fixed* radix-range partition: the
    top ``HIST_DIGIT_BITS`` of the entity-only identity key's
    subrelation prefix, mapped uniformly onto shards — the same
    top-digit primitive the distributed shuffle's range partitioner
    runs on its pre-shuffle keys (``core.distributed``), applied on the
    host to route ingestion.  Deterministic per *tuple* (the identity
    key has no value lane), so every version of a row lands in the
    shard that holds its predecessors."""
    if n_shards <= 1:
        return np.zeros(rows.shape[0], np.int64)
    top_w = min(RX.HIST_DIGIT_BITS,
                max(1, id_plan.total_bits - id_plan.e_bits))
    keys = id_plan.pack_host(rows)
    dig = (keys >> np.uint64(id_plan.total_bits - top_w)).astype(np.int64)
    return (dig * n_shards) >> top_w


def iter_chunks(chunks, values=None, chunk_budget: Optional[int] = None,
                with_values: bool = False):
    """Normalise ``mine_chunked``-style input into (rows, values) chunk
    pairs: a single (T, N) array is split by ``chunk_budget``; an
    iterable of arrays is re-split whenever a chunk exceeds the budget.
    ``values`` may be None, a single (T,) array (aligned with a single
    table), or an iterable aligned with ``chunks``."""
    if isinstance(chunks, np.ndarray) or (
            hasattr(chunks, "shape") and getattr(chunks, "ndim", 0) == 2):
        chunks = [np.asarray(chunks)]
        if values is not None:
            values = [np.asarray(values)]
    chunk_list = [np.asarray(c, np.int32) for c in chunks]
    if values is None:
        value_list = [None] * len(chunk_list)
    else:
        value_list = [np.asarray(v, np.float32) for v in values]
        if len(value_list) != len(chunk_list):
            raise ValueError("values chunks must align with row chunks")
    for rows, vals in zip(chunk_list, value_list):
        rows = np.atleast_2d(rows)
        if with_values and vals is None:
            vals = np.zeros(rows.shape[0], np.float32)
        step = rows.shape[0] if not chunk_budget \
            else max(1, int(chunk_budget))
        for lo in range(0, rows.shape[0], step):
            hi = lo + step
            yield rows[lo:hi], None if vals is None else vals[lo:hi]


class RunStore:
    """Per-mode sorted-run storage of one (possibly valued) tuple log.

    ``plans`` are the context's ``core.keys`` bit-width plans (one per
    mode; ``plans[0].with_values`` decides whether the store carries a
    value column).  ``radix=True`` sorts chunks with the host LSD radix
    (``core.radix``), mirroring the device default; ``incremental=False``
    keeps only the log + tombstones (non-fitting keys: the caller
    re-sorts on device).  ``stats`` may be a shared dict — the store
    increments ``chunk_sorted_rows`` / ``merged_rows`` /
    ``tombstoned_rows`` / ``compacted_rows`` in place so engines expose
    one ledger."""

    def __init__(self, plans: Optional[Sequence[K.ModeKeyPlan]] = None,
                 radix: bool = True, incremental: bool = True,
                 stats: Optional[dict] = None):
        self.plans = tuple(plans) if plans is not None else None
        self.radix = bool(radix)
        self.incremental = bool(incremental) and (
            plans is None or all(p.fits for p in self.plans))
        self.rows = np.zeros((0, len(plans) if plans else 0), np.int32)
        self.values: Optional[np.ndarray] = None
        self.count = 0
        self.alive = np.zeros((0,), bool)
        self.dead = 0
        self.runs: List[Run] = []
        self.covered = 0
        self.stats = stats if stats is not None else {}
        self._index: Optional[dict] = None
        self._id_plan: Optional[K.ModeKeyPlan] = None

    # -- properties ---------------------------------------------------------

    @property
    def with_values(self) -> bool:
        return bool(self.plans and self.plans[0].with_values)

    @property
    def buffer(self) -> np.ndarray:
        """The row log (compat alias used by older callers)."""
        return self.rows

    def _bump(self, key: str, n: int) -> None:
        self.stats[key] = self.stats.get(key, 0) + int(n)

    # -- identity (upsert/delete keys) --------------------------------------

    def _identity_plan(self) -> K.ModeKeyPlan:
        if self._id_plan is None:
            self._id_plan = K.plan_mode_key(self.plans[0].sizes, 0,
                                            with_values=False)
        return self._id_plan

    def _identity(self, rows: np.ndarray):
        """Hashable per-row identity: entity-only packed key (the value
        lane is deliberately absent — all versions of a tuple collapse),
        or row bytes when the key exceeds 64 bits."""
        plan = self._identity_plan()
        if plan.fits:
            return plan.pack_host(rows).tolist()
        rows = np.ascontiguousarray(rows, np.int32)
        return [r.tobytes() for r in rows]

    def _ensure_index(self) -> dict:
        if self._index is None:
            idx: dict = {}
            live = np.nonzero(self.alive[:self.count])[0]
            for key, i in zip(self._identity(self.rows[live]),
                              live.tolist()):
                idx.setdefault(key, []).append(i)
            self._index = idx
        return self._index

    # -- ingestion ----------------------------------------------------------

    def _coerce(self, rows, values):
        rows = np.atleast_2d(np.asarray(rows, np.int32))
        if self.with_values:
            values = (np.zeros(rows.shape[0], np.float32) if values is None
                      else np.asarray(values, np.float32))
        else:
            values = None
        return rows, values

    def _append(self, rows: np.ndarray, values) -> np.ndarray:
        lo = self.count
        self.rows = np.concatenate([self.rows[:lo], rows])
        if self.with_values:
            base = (self.values[:lo] if self.values is not None
                    else np.zeros((0,), np.float32))
            self.values = np.concatenate([base, values])
        self.count = lo + rows.shape[0]
        self.alive = np.concatenate(
            [self.alive[:lo], np.ones(rows.shape[0], bool)])
        return np.arange(lo, self.count)

    def add(self, rows, values=None) -> None:
        """Ingest a chunk.  Unvalued stores append (duplicate rows are
        idempotent under the mining algebra); valued stores route
        through :meth:`upsert` — V must be a function of the tuple
        (§3.2), so a duplicate arrival *replaces* its predecessor, the
        same last-write-wins rule the batch constructor applies."""
        rows, values = self._coerce(rows, values)
        if rows.shape[0] == 0:
            return
        if self.with_values:
            self._upsert_coerced(rows, values)
            return
        new = self._append(rows, None)
        if self._index is not None:
            for key, i in zip(self._identity(rows), new.tolist()):
                self._index.setdefault(key, []).append(i)
        self.absorb()

    def upsert(self, rows, values=None) -> None:
        """Insert-or-replace: every alive prior version of each row's
        *tuple* (value ignored) is tombstoned, then the new version is
        appended — last write wins, exactly the constructor's
        canonicalisation."""
        rows, values = self._coerce(rows, values)
        if rows.shape[0] == 0:
            return
        self._upsert_coerced(rows, values)

    def _upsert_coerced(self, rows, values) -> None:
        index = self._ensure_index()
        new = self._append(rows, values)
        killed = 0
        for key, i in zip(self._identity(rows), new.tolist()):
            prior = index.get(key)
            if prior:
                for p in prior:
                    self.alive[p] = False
                killed += len(prior)
            index[key] = [i]
        self.dead += killed
        self._bump("tombstoned_rows", killed)
        self.absorb()

    def delete(self, rows) -> None:
        """Tombstone every alive version of the given tuples (rows never
        ingested are ignored).  Values are irrelevant to deletion."""
        rows = np.atleast_2d(np.asarray(rows, np.int32))
        if rows.shape[0] == 0:
            return
        index = self._ensure_index()
        killed = 0
        for key in self._identity(rows):
            prior = index.pop(key, None)
            if prior:
                for p in prior:
                    self.alive[p] = False
                killed += len(prior)
        self.dead += killed
        self._bump("tombstoned_rows", killed)

    # -- run maintenance ----------------------------------------------------

    def absorb(self) -> None:
        """Sort any rows not yet covered by runs (normally just the new
        chunk; the whole log after a lazy restore) into a fresh run,
        then compact geometrically-sized runs by linear merges.  Rows
        already tombstoned never enter the run."""
        lo, hi = self.covered, self.count
        if lo >= hi:
            return
        self.covered = hi
        if not self.incremental:
            return
        self._bump("chunk_sorted_rows", hi - lo)
        sel = (np.arange(lo, hi, dtype=np.int64)
               if self.alive[lo:hi].all()
               else np.nonzero(self.alive[lo:hi])[0] + lo)
        if sel.size == 0:
            return
        rows = self.rows[sel]
        vals = self.values[sel] if self.with_values else None
        keys, idx = [], []
        for plan in self.plans:
            k = plan.pack_host(rows, vals)
            order = (RX.radix_argsort_host(k, plan.total_bits)
                     if self.radix else np.argsort(k, kind="stable"))
            keys.append(k[order])
            idx.append(sel[order].astype(np.int32))
        self.runs.append(Run(keys, idx))
        while (len(self.runs) >= 2
               and self.runs[-2].size <= 2 * self.runs[-1].size):
            merged = merge_runs(self._filtered(self.runs[-2]),
                                self._filtered(self.runs[-1]))
            self._bump("merged_rows", merged.size)
            self.runs[-2:] = [merged]

    def _filtered(self, run: Run) -> Run:
        """The run without tombstoned entries (merges drop superseded
        versions — the LSM compaction rule)."""
        masks = [self.alive[i] for i in run.idx]
        if masks[0].all():
            return run
        return Run([k[m] for k, m in zip(run.keys, masks)],
                   [i[m] for i, m in zip(run.idx, masks)])

    def compact(self) -> None:
        """Rewrite the log to the survivor set (first-ingestion order of
        the surviving versions) and remap every run's indices.  Keys are
        untouched — survivor order is preserved — so no re-sort."""
        self.absorb()
        if not self.dead:
            return
        keep = self.alive[:self.count]
        remap = (np.cumsum(keep) - 1).astype(np.int32)
        self._bump("compacted_rows", self.count - int(keep.sum()))
        self.runs = [Run(r.keys, [remap[i] for i in r.idx])
                     for r in map(self._filtered, self.runs)]
        self.covered = int(remap[self.covered - 1]) + 1 if self.covered \
            else 0
        self.rows = self.rows[:self.count][keep]
        if self.with_values:
            self.values = self.values[:self.count][keep]
        self.count = int(keep.sum())
        self.alive = np.ones(self.count, bool)
        self.dead = 0
        self._index = None

    def prepare(self) -> None:
        """Make the store snapshot-ready: absorb the tail, drop every
        superseded version, compact the log, and fold all runs into one
        full per-mode permutation of the survivor table (linear merges —
        no re-sort)."""
        self.compact()
        if not self.incremental:
            return
        while len(self.runs) > 1:
            merged = merge_runs(self.runs[-2], self.runs[-1])
            self._bump("merged_rows", merged.size)
            self.runs[-2:] = [merged]

    # -- snapshot surface ---------------------------------------------------

    def table(self) -> Tuple[np.ndarray, Optional[np.ndarray]]:
        """(rows, values) of the current log (call after
        :meth:`prepare`/:meth:`compact` for the survivor set)."""
        return (self.rows[:self.count],
                self.values[:self.count] if self.with_values else None)

    def perms(self, cap: Optional[int] = None) -> Optional[np.ndarray]:
        """(N, cap) merged per-mode permutations of the prepared store
        (``cap=None``: exactly ``count``), or None for non-incremental
        stores (the caller re-sorts on device)."""
        if not self.incremental:
            return None
        if len(self.runs) != 1 or self.dead or self.covered != self.count:
            raise ValueError("store not prepared; call prepare() first")
        cap = self.count if cap is None else int(cap)
        row0, val0 = self.rows[:1], (self.values[:1] if self.with_values
                                     else None)
        return padded_perms(self.runs[0], self.plans, row0, val0,
                            self.count, cap)

    # -- checkpoint ---------------------------------------------------------

    def checkpoint(self) -> dict:
        """Serialisable state *including* the run arrays, so restore is
        O(T) array loads — no re-sort.  The log is compacted first, so
        the blob carries exactly the survivor set (tombstones never
        outlive a checkpoint, and stripping a blob down to its buffer —
        the legacy format — cannot resurrect deleted rows)."""
        self.compact()
        blob = {"buffer": self.rows[:self.count].copy(),
                "count": self.count,
                "covered": self.covered,
                "runs": [{"keys": [k.copy() for k in r.keys],
                          "idx": [i.copy() for i in r.idx]}
                         for r in self.runs],
                "incremental": self.incremental}
        if self.plans is not None:
            blob["sizes"] = tuple(self.plans[0].sizes)
            blob["with_values"] = self.with_values
        if self.with_values:
            blob["values"] = self.values[:self.count].copy()
        return blob

    @staticmethod
    def restore(blob: dict,
                plans: Optional[Sequence[K.ModeKeyPlan]] = None
                ) -> "RunStore":
        """Rebuild a store from :meth:`checkpoint` output.  New-format
        blobs restore their runs and tombstones directly; legacy
        buffer-only blobs take the lazy path (``covered=0``) — one full
        chunk sort on the next absorb.  ``plans`` may be omitted for
        new-format blobs (rebuilt from the recorded sizes); a restoring
        engine re-attaches its own plans either way."""
        if plans is None and "sizes" in blob:
            plans = K.plan_context_keys(blob["sizes"],
                                        with_values=blob.get("with_values",
                                                             blob.get("values")
                                                             is not None))
        store = RunStore(plans, incremental=blob.get("incremental", True))
        rows = np.asarray(blob["buffer"], np.int32)
        store.rows = rows
        store.count = int(blob["count"])
        if blob.get("values") is not None:
            store.values = np.asarray(blob["values"], np.float32)
        store.alive = (np.asarray(blob["alive"], bool).copy()
                       if blob.get("alive") is not None
                       else np.ones(store.count, bool))
        store.dead = int(store.count - store.alive[:store.count].sum())
        if blob.get("runs"):
            store.runs = [Run([np.asarray(k, np.uint64) for k in r["keys"]],
                              [np.asarray(i, np.int32) for i in r["idx"]])
                          for r in blob["runs"]]
            store.covered = int(blob.get("covered", 0))
        else:
            store.runs, store.covered = [], 0   # lazy rebuild on absorb
        return store


# -- durable checkpoints (crash recovery) -----------------------------------

#: checkpoint frame: magic + ``<QI`` (payload length, CRC32 of payload),
#: followed by the ``.npz`` payload.  Files without the magic are
#: legacy plain-npz checkpoints and load without verification.
CKPT_MAGIC = b"RCK1"
_CKPT_HDR = struct.Struct("<QI")


class CheckpointCorruptError(RuntimeError):
    """A framed checkpoint failed its length/CRC check: the bytes on
    disk are not the bytes that were persisted.  Callers quarantine the
    file and fall back to the previous generation (DESIGN.md §9)."""


def save_checkpoint(blob: dict, path: str, meta: Optional[dict] = None
                    ) -> None:
    """Persist a :meth:`RunStore.checkpoint` blob to ``path`` as a
    CRC32-framed ``.npz`` (nested run arrays flattened to named
    entries), written atomically — ``path.tmp`` then ``os.replace`` —
    so a crash mid-write can never leave a half-checkpoint where a
    restart would read it; the :data:`CKPT_MAGIC` header carries the
    payload length and checksum so :func:`load_checkpoint` can tell
    bit rot or truncation from a valid blob.  ``meta`` rides along
    (JSON-encoded) for engine-level counters the blob itself does not
    carry (e.g. the serving plane's ``stream_version`` / publish
    version)."""
    import io as _io
    import json as _json
    import os as _os
    import zlib as _zlib
    arrays = {"buffer": np.asarray(blob["buffer"], np.int32),
              "scalars": np.asarray(
                  [int(blob["count"]), int(blob.get("covered", 0)),
                   int(bool(blob.get("incremental", True))),
                   len(blob.get("runs") or ()),
                   int(bool(blob.get("with_values", False)))], np.int64)}
    if blob.get("values") is not None:
        arrays["values"] = np.asarray(blob["values"], np.float32)
    if blob.get("alive") is not None:
        arrays["alive"] = np.asarray(blob["alive"], bool)
    if "sizes" in blob:
        arrays["sizes"] = np.asarray(blob["sizes"], np.int64)
    for ri, r in enumerate(blob.get("runs") or ()):
        for m, (k, i) in enumerate(zip(r["keys"], r["idx"])):
            arrays[f"run{ri}_keys{m}"] = np.asarray(k, np.uint64)
            arrays[f"run{ri}_idx{m}"] = np.asarray(i, np.int32)
    arrays["meta_json"] = np.frombuffer(
        _json.dumps(meta or {}).encode(), np.uint8)
    buf = _io.BytesIO()
    np.savez(buf, **arrays)
    payload = buf.getvalue()
    tmp = f"{path}.tmp"
    with open(tmp, "wb") as f:
        f.write(CKPT_MAGIC)
        f.write(_CKPT_HDR.pack(len(payload), _zlib.crc32(payload)))
        f.write(payload)
        f.flush()
        _os.fsync(f.fileno())
    _os.replace(tmp, path)


def load_checkpoint(path: str) -> Tuple[dict, dict]:
    """Inverse of :func:`save_checkpoint`: returns ``(blob, meta)``
    ready for :meth:`RunStore.restore`.  Framed checkpoints are
    verified against their recorded length and CRC32 first — a
    truncated or bit-rotted file raises
    :class:`CheckpointCorruptError` instead of restoring garbage."""
    import io as _io
    import json as _json
    import zlib as _zlib
    with open(path, "rb") as f:
        head = f.read(len(CKPT_MAGIC))
        if head == CKPT_MAGIC:
            hdr = f.read(_CKPT_HDR.size)
            if len(hdr) < _CKPT_HDR.size:
                raise CheckpointCorruptError(
                    f"{path}: truncated frame header")
            length, crc = _CKPT_HDR.unpack(hdr)
            payload = f.read(length + 1)  # +1 detects trailing bytes
            if len(payload) != length:
                raise CheckpointCorruptError(
                    f"{path}: payload is {len(payload)} bytes, "
                    f"frame promised {length}")
            if _zlib.crc32(payload) != crc:
                raise CheckpointCorruptError(
                    f"{path}: payload CRC mismatch")
            src = _io.BytesIO(payload)
        else:
            src = path      # legacy plain .npz: no frame to verify
    with np.load(src) as z:
        count, covered, incremental, n_runs, with_values = (
            int(v) for v in z["scalars"])
        blob = {"buffer": z["buffer"], "count": count, "covered": covered,
                "incremental": bool(incremental),
                "with_values": bool(with_values)}
        if "values" in z.files:
            blob["values"] = z["values"]
        if "alive" in z.files:
            blob["alive"] = z["alive"]
        if "sizes" in z.files:
            blob["sizes"] = tuple(int(s) for s in z["sizes"])
        runs = []
        for ri in range(n_runs):
            keys, idx = [], []
            m = 0
            while f"run{ri}_keys{m}" in z.files:
                keys.append(z[f"run{ri}_keys{m}"])
                idx.append(z[f"run{ri}_idx{m}"])
                m += 1
            runs.append({"keys": keys, "idx": idx})
        blob["runs"] = runs
        meta = _json.loads(bytes(z["meta_json"].tobytes()).decode()
                           or "{}")
    return blob, meta
