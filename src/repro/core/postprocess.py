"""Post-processing: selection, ranking and export of mined clusters.

The paper (§2) treats duplicate elimination and user-constraint selection
as post-processing with O(|I|) cost; these helpers operate on the host
over the unified ``PipelineResult`` / ``DistributedResult`` arrays (every
engine returns per-tuple ``cardinalities``).
"""
from __future__ import annotations

from typing import Iterable, Optional

import numpy as np


def select(result, min_density: float = 0.0, min_gen: int = 1,
           max_volume: Optional[float] = None,
           min_cardinality: int = 0) -> np.ndarray:
    """Indices of kept unique clusters under user constraints."""
    uniq = np.asarray(result.is_unique)
    dens = np.asarray(result.density)
    gen = np.asarray(result.gen_count)
    vol = np.asarray(result.volume)
    mask = uniq & (dens >= min_density) & (gen >= min_gen)
    if max_volume is not None:
        mask &= vol <= max_volume
    if min_cardinality:
        card = np.asarray(result.cardinalities)
        mask &= (card >= min_cardinality).all(axis=0)
    return np.nonzero(mask)[0]


def top_k_by_density(result, k: int) -> np.ndarray:
    idx = select(result)
    dens = np.asarray(result.density)[idx]
    return idx[np.argsort(-dens, kind="stable")[:k]]


def format_cluster(components: Iterable, names=None,
                   density: Optional[float] = None) -> str:
    """Paper §5.2 output format: one '{...}' line per modality."""
    lines = ["{"]
    for k, comp in enumerate(components):
        items = sorted(comp)
        if names is not None:
            items = [str(names[k][e]) for e in items]
        else:
            items = [str(e) for e in items]
        lines.append("{" + ", ".join(items) + "}")
    if density is not None:
        lines.append(f"# density={density:.4f}")
    lines.append("}")
    return "\n".join(lines)


def cluster_set(materialised) -> set:
    """Canonical comparable set from [(components, density), ...]."""
    return {tuple(tuple(sorted(c)) for c in comps)
            for comps, _ in materialised}
