"""Windowed device pipeline: Stage 1–3 through bounded HBM key windows
(DESIGN.md §3c).

The monolithic ``pipeline.mine_tuples`` materialises every Stage-1/2/3
intermediate at full table length T on the device, so a single
accelerator can only mine tables that fit in device memory.  This
module streams the *same* three stages through ``window_budget``-sized
slices of the merged sorted order (the ``RunStore`` per-mode host
permutations are the window iterator), carrying the open segment's
seam state across windows, and is leaf-for-leaf bit-identical to the
monolithic path:

* **Stage 1** — per mode, the device scans ``budget``-row slices of
  the sorted packed key words through the fused segment reduction
  (``kernels/segment_reduce`` via ``kops.segment_reduce``, exactly
  what ``pipeline.masked_prefix`` runs).  The seam carry is three
  scalars — the running masked prefix sums (hash-lane lo/hi, distinct
  counter) — plus the previous window's last key word(s): uint32/int32
  addition is associative, so adding the carried last inclusive value
  to the next window's local scan reproduces the global prefix sums
  *exactly*, no matter how many windows a single key segment (or NOAC
  δ-window) spans.  The host assembles the exclusive (T+1) prefix
  arrays and derives segment bounds / δ-window bounds from the sorted
  uint64 keys it already holds (``pack_host`` ≡ ``pack_device``
  bit-for-bit, and ``np.searchsorted`` over the packed uint64 keys is
  ``keys.search_words`` by construction).

* **Stage 2** — the signature mix and volume product are elementwise,
  so they run as ``budget``-sized device maps over original tuple
  order, reusing ``pipeline.mix_signatures`` verbatim.

* **Stage 3** — each original-order window is sorted on the packed
  2×32-bit cluster signature on device (``keys.sort_with_payload``,
  the same Stage-3 sort the monolithic path runs), then a host-side
  k-way combine merges the per-window runs keyed on the packed
  signature word — the same two-searchsorted stable merge as
  ``runs.merge_runs``, earlier windows on the a-side, so the combined
  order equals the monolithic stable sort's (sig, original position)
  order.  Group stats (distinct generating tuples, uniqueness) are the
  monolithic prefix-difference formulas on the combined order.

Memory model: the device holds O(window) stage buffers plus the O(n_k)
hash vectors; the host holds the O(T) table, sorted keys and result
arrays — which it must hold anyway (the table comes from the host run
store, and results are consumed host-side).  Peak *incremental* device
memory is O(window), not O(T); ``benchmarks/packed.py`` gates this
with a live-allocation probe (``core.memprobe``).

The window plan (``radix.plan_windows``) is shared with the host run
sort (``RunStore`` ``chunk_budget``) and the distributed shuffle's
per-link batch capacity — one streaming unit end to end.

Results are returned as **host (numpy) arrays** inside the usual
``PipelineResult``: shipping the O(T) result back to the device would
reintroduce the O(T) device footprint the windowing exists to avoid,
and every consumer (``materialise``, serving snapshots, tests) reads
results through ``np.asarray`` anyway.
"""
from __future__ import annotations

import time
from typing import Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kops
from . import keys as K
from . import pipeline as P
from . import radix as RX

#: Stage names reported through the ``probe`` callback (one call per
#: device window dispatch, after the transfer back blocks).
STAGES = ("stage1_scan", "stage2_mix", "stage3_sort")

_U64_FULL = 0xFFFFFFFFFFFFFFFF


# ---------------------------------------------------------------------------
# Jitted window bodies (cached per static configuration; jax re-traces
# per window shape, which is constant = the budget)
# ---------------------------------------------------------------------------

_FN_CACHE: dict = {}


def _scan_fn(nwords: int, e_mask: int, use_pallas: bool):
    """Stage-1 window body: first-occurrence flags from the key words
    (seam-aware via ``first0``), fused masked segment reduction, carry
    addition.  Returns the window's inclusive global prefix sums and
    the new carry (its last elements — window padding repeats the last
    real key, so pads have ``first=False`` and contribute nothing)."""
    key = ("scan", nwords, e_mask, use_pallas)
    if key not in _FN_CACHE:
        def f(words, first0, c_lo, c_hi, c_cnt, r_lo, r_hi):
            flag = words[0][1:] != words[0][:-1]
            for w in words[1:]:
                flag = flag | (w[1:] != w[:-1])
            first = jnp.concatenate([first0[None], flag])
            e = (words[-1] & jnp.uint32(e_mask)).astype(jnp.int32)
            lo, hi, cnt = kops.segment_reduce(r_lo[e], r_hi[e], first,
                                              use_pallas=use_pallas)
            lo = lo + c_lo
            hi = hi + c_hi
            cnt = cnt + c_cnt
            return lo, hi, cnt, lo[-1], hi[-1], cnt[-1]
        _FN_CACHE[key] = jax.jit(f)
    return _FN_CACHE[key]


def _mix_fn(n_modes: int):
    """Stage-2 window body: ``pipeline.mix_signatures`` + the volume
    product over (N, B) per-mode stacks — elementwise, so windows are
    trivially independent."""
    key = ("mix", n_modes)
    if key not in _FN_CACHE:
        def f(slo, shi, card):
            lo, hi = P.mix_signatures([slo[k] for k in range(n_modes)],
                                      [shi[k] for k in range(n_modes)])
            vol = jnp.ones(slo.shape[1:], jnp.float32)
            for k in range(n_modes):
                vol = vol * card[k].astype(jnp.float32)
            return lo, hi, vol
        _FN_CACHE[key] = jax.jit(f)
    return _FN_CACHE[key]


def _s3_fn(backend: str, use_pallas: bool):
    """Stage-3 window body: one stable device sort of the window's
    packed signatures with an iota payload (the monolithic Stage-3
    sort at window size)."""
    key = ("s3", backend, use_pallas)
    if key not in _FN_CACHE:
        def f(sig_lo, sig_hi):
            t = sig_lo.shape[0]
            (s_lo, s_hi), (idx,) = K.sort_with_payload(
                (sig_lo, sig_hi), (jnp.arange(t, dtype=jnp.int32),),
                backend=backend, live_bits=64, use_pallas=use_pallas)
            return s_lo, s_hi, idx
        _FN_CACHE[key] = jax.jit(f)
    return _FN_CACHE[key]


# ---------------------------------------------------------------------------
# Host helpers (numpy mirrors of the pipeline's segment primitives)
# ---------------------------------------------------------------------------

def _split_words(keys_u64: np.ndarray, nwords: int) -> Tuple[np.ndarray, ...]:
    """Host uint64 keys -> the device's msb-first uint32 word tuple."""
    lo = (keys_u64 & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    if nwords == 1:
        return (lo,)
    return ((keys_u64 >> np.uint64(32)).astype(np.uint32), lo)


def _diff_flags(sorted_keys: np.ndarray) -> np.ndarray:
    """Host ``segment_starts`` over one sorted uint64 key column."""
    f = np.empty(sorted_keys.shape[0], bool)
    f[0] = True
    np.not_equal(sorted_keys[1:], sorted_keys[:-1], out=f[1:])
    return f


def _seg_bounds(flags: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Host ``pipeline.segment_bounds``: forward cummax / reverse
    cummin over start flags -> per-position [a, b) segment windows."""
    t = flags.shape[0]
    pos = np.arange(t, dtype=np.int32)
    a = np.maximum.accumulate(np.where(flags, pos, 0)).astype(np.int32)
    suff = np.minimum.accumulate(
        np.where(flags, pos, np.int32(t))[::-1])[::-1]
    b = np.concatenate([suff[1:], np.full(1, t, np.int32)]).astype(np.int32)
    return a, b


def _scatter(perm: np.ndarray, sorted_arr: np.ndarray) -> np.ndarray:
    """Sorted-order array -> original tuple order (the inverse-perm
    gather of the monolithic path, as one scatter)."""
    out = np.empty(sorted_arr.shape[0], sorted_arr.dtype)
    out[perm] = sorted_arr
    return out


def _pad_tail(arr: np.ndarray, budget: int, fill=None) -> np.ndarray:
    """Pad a tail window to the full budget (constant window shapes ->
    one jit trace per stage).  ``fill=None`` repeats the last element
    (Stage 1: equal keys keep ``first=False`` on the pads)."""
    short = budget - arr.shape[0]
    if short <= 0:
        return arr
    pad = np.full(short, arr[-1] if fill is None else fill, arr.dtype)
    return np.concatenate([arr, pad])


def _merge_pair(a, b):
    """Stable two-searchsorted merge of two (sig_word, orig_idx) runs,
    a-side winning ties — ``runs.merge_runs`` on signature words."""
    ka, ia = a
    kb, ib = b
    if ka.size == 0:
        return b
    if kb.size == 0:
        return a
    if ka[-1] <= kb[0]:
        return np.concatenate([ka, kb]), np.concatenate([ia, ib])
    if kb[-1] < ka[0]:
        return np.concatenate([kb, ka]), np.concatenate([ib, ia])
    pa = np.searchsorted(kb, ka, side="left") + np.arange(ka.size)
    pb = np.searchsorted(ka, kb, side="right") + np.arange(kb.size)
    mk = np.empty(ka.size + kb.size, np.uint64)
    mi = np.empty(ka.size + kb.size, np.int64)
    mk[pa] = ka
    mk[pb] = kb
    mi[pa] = ia
    mi[pb] = ib
    return mk, mi


def _kway_combine(parts):
    """Balanced k-way combine of per-window signature runs.  Adjacent
    pairs merge with the left (earlier windows, smaller original
    indices) on the a-side, so ties resolve to ascending original
    position — the stable global Stage-3 order."""
    parts = list(parts)
    while len(parts) > 1:
        parts = [parts[i] if i + 1 == len(parts)
                 else _merge_pair(parts[i], parts[i + 1])
                 for i in range(0, len(parts), 2)]
    return parts[0]


# ---------------------------------------------------------------------------
# The windowed driver
# ---------------------------------------------------------------------------

def mine_windowed(rows, values, perms, *,
                  plans: Sequence[K.ModeKeyPlan],
                  hash_lo, hash_hi,
                  delta: Optional[float] = None, theta: float = 0.0,
                  minsup: int = 0,
                  window_budget: Optional[int] = None,
                  sort_backend: str = "radix",
                  use_pallas: Optional[bool] = None,
                  probe: Optional[Callable[[str], None]] = None,
                  obs=None) -> P.PipelineResult:
    """Mine ``rows`` through bounded device windows; bit-identical to
    ``pipeline.mine_tuples`` on the same table (every ``PipelineResult``
    leaf, permutations included).

    ``rows``/``values`` is the host table, ``perms`` the (N, T) merged
    per-mode sort permutations (``RunStore.perms``).  ``plans`` must be
    the *un-pruned* context key plans (float value lane — the same
    plans the run store packed with); ``hash_lo``/``hash_hi`` the
    per-mode hash vectors.  ``window_budget=None`` runs a single
    in-core window through the same code path.

    ``probe`` (optional) is called with a :data:`STAGES` name after
    each device window dispatch completes — the peak-memory
    instrumentation hook of ``benchmarks/packed.py``.

    ``obs`` (an *enabled* ``repro.obs.Obs``, duck-typed) turns on
    per-stage profiling: per-window and per-stage wall-time
    histograms, the seam-carry count (windows entered mid-segment),
    and — when no ``probe`` is supplied — a ``core.memprobe`` peak
    sample per stage, all folded into the hub's registry plus one
    ``pipeline.windowed`` span.  ``obs=None`` keeps the loop at one
    predicate test per window.

    Raises ``ValueError`` for degenerate budgets (< 1) and for
    configurations the windowed path cannot honour bit-exactly
    (non-fitting >64-bit keys, the forced-lexsort baseline, rank-coded
    value lanes) instead of silently widening or splitting — the loud
    twin of the seam-carry contract (DESIGN.md §3c).
    """
    if not plans[0].fits:
        raise ValueError(
            "windowed mining needs 64-bit-packable keys (plans[0].fits); "
            "this context's key exceeds 64 bits — use mine_chunked or the "
            "monolithic lexsort path instead")
    if sort_backend not in ("radix", "lax"):
        raise ValueError(
            f"windowed mining supports sort_backend 'radix' or 'lax', got "
            f"{sort_backend!r}; the lexsort baseline has no packed host "
            "keys to window over")
    if use_pallas is None:
        use_pallas = kops.on_tpu()
    rows = np.asarray(rows, np.int32)
    t, n = rows.shape
    if delta is not None:
        if delta < 0:
            raise ValueError(f"delta must be >= 0, got {delta}")
        if values is None:
            values = np.zeros((t,), np.float32)
        values = np.asarray(values, np.float32)
        if not plans[0].with_values or plans[0].value_bits != 32:
            raise ValueError(
                "windowed mining needs the un-pruned float value lane "
                "(plan_context_keys(..., value_slots=None))")
    else:
        values = None
    perms = np.asarray(perms)
    if perms.shape != (n, t):
        raise ValueError(f"perms shape {perms.shape} != {(n, t)}")
    wplan = RX.plan_windows(t, window_budget)   # raises on budget < 1
    budget = wplan.budget

    prof = obs if (obs is not None
                   and getattr(obs, "enabled", False)) else None
    mp = None
    if prof is not None:
        if probe is None:
            from . import memprobe as MP
            mp = MP.MemProbe()
            probe = mp
        win_hist = {st: prof.metrics.histogram("pipeline_window_ms",
                                               stage=st)
                    for st in STAGES}
        stage_ms = {st: 0.0 for st in STAGES}
        seam_carries = 0
        sp = prof.tracer.start("pipeline.windowed", rows=t, modes=n,
                               budget=budget, windows=len(wplan.bounds))

    hash_lo = [jnp.asarray(h) for h in hash_lo]
    hash_hi = [jnp.asarray(h) for h in hash_hi]

    # ---- Stage 1: per-mode windowed masked-prefix scans + host bounds
    mode_sig_lo = np.empty((n, t), np.uint32)
    mode_sig_hi = np.empty((n, t), np.uint32)
    mode_card = np.empty((n, t), np.int32)
    mode_rlo = np.empty((n, t), np.int32)
    mode_rhi = np.empty((n, t), np.int32)
    sorted_e = np.empty((n, t), np.int32)
    tfirst = None
    for k in range(n):
        plan = plans[k]
        perm = perms[k].astype(np.int64)
        sk = plan.pack_host(rows, values)[perm]
        scan = _scan_fn(plan.words, plan.e_mask, use_pallas)
        pref_lo = np.zeros(t + 1, np.uint32)
        pref_hi = np.zeros(t + 1, np.uint32)
        pref_cnt = np.zeros(t + 1, np.int32)
        c_lo, c_hi, c_cnt = (jnp.uint32(0), jnp.uint32(0), jnp.int32(0))
        for w0, w1 in wplan.bounds:
            tw = time.perf_counter() if prof is not None else 0.0
            win = _pad_tail(sk[w0:w1], budget)
            words = tuple(jnp.asarray(w) for w in
                          _split_words(win, plan.words))
            first0 = bool(w0 == 0 or sk[w0] != sk[w0 - 1])
            f0 = jnp.asarray(first0)
            lo, hi, cnt, c_lo, c_hi, c_cnt = scan(
                words, f0, c_lo, c_hi, c_cnt, hash_lo[k], hash_hi[k])
            pref_lo[w0 + 1:w1 + 1] = np.asarray(lo)[:w1 - w0]
            pref_hi[w0 + 1:w1 + 1] = np.asarray(hi)[:w1 - w0]
            pref_cnt[w0 + 1:w1 + 1] = np.asarray(cnt)[:w1 - w0]
            if probe is not None:
                probe("stage1_scan")
            if prof is not None:
                if not first0:      # entered mid-segment: a seam carry
                    seam_carries += 1
                ms = (time.perf_counter() - tw) * 1e3
                stage_ms["stage1_scan"] += ms
                win_hist["stage1_scan"].observe(ms)
        # component windows in sorted order: whole key segment (prime)
        # or the δ-value range inside it (NOAC, global self-clamping
        # search — the host twin of keys.search_words)
        if delta is None:
            a, b = _seg_bounds(_diff_flags(sk >> np.uint64(plan.seg_shift)))
        else:
            d = np.float32(delta)
            s_vals = values[perm]
            t_lo = (s_vals - d).astype(np.float32)
            t_hi = (s_vals + d).astype(np.float32)
            t_lo = np.where(t_lo == 0, np.float32(0.0), t_lo)
            t_hi = np.where(t_hi == 0, np.float32(0.0), t_hi)
            lane_lo = K.float_sort_bits_host(t_lo).astype(np.uint64)
            lane_hi = K.float_sort_bits_host(t_hi).astype(np.uint64)
            base = sk & np.uint64(~((1 << plan.seg_shift) - 1) & _U64_FULL)
            eb = np.uint64(plan.e_bits)
            q_lo = base | (lane_lo << eb)
            q_hi = base | (lane_hi << eb) | np.uint64(plan.e_mask)
            a = np.searchsorted(sk, q_lo, side="left").astype(np.int32)
            b = np.searchsorted(sk, q_hi, side="right").astype(np.int32)
        bl, al = b.astype(np.int64), a.astype(np.int64)
        mode_sig_lo[k] = _scatter(perm, pref_lo[bl] - pref_lo[al])
        mode_sig_hi[k] = _scatter(perm, pref_hi[bl] - pref_hi[al])
        mode_card[k] = _scatter(perm, pref_cnt[bl] - pref_cnt[al])
        mode_rlo[k] = _scatter(perm, a)
        mode_rhi[k] = _scatter(perm, b)
        sorted_e[k] = rows[perm, k]
        if k == 0:
            # mode 0's key covers the whole row: its first-occurrence
            # flags mark the lowest-index copy of each duplicate row
            tfirst = _scatter(perm, _diff_flags(sk))

    # ---- Stage 2: elementwise mix/volume windows over original order
    mixfn = _mix_fn(n)
    sig_lo = np.empty(t, np.uint32)
    sig_hi = np.empty(t, np.uint32)
    volume = np.empty(t, np.float32)
    for w0, w1 in wplan.bounds:
        tw = time.perf_counter() if prof is not None else 0.0
        wl = w1 - w0
        pad = budget - wl
        slo = np.pad(mode_sig_lo[:, w0:w1], ((0, 0), (0, pad)))
        shi = np.pad(mode_sig_hi[:, w0:w1], ((0, 0), (0, pad)))
        scd = np.pad(mode_card[:, w0:w1], ((0, 0), (0, pad)))
        lo, hi, vol = mixfn(jnp.asarray(slo), jnp.asarray(shi),
                            jnp.asarray(scd))
        sig_lo[w0:w1] = np.asarray(lo)[:wl]
        sig_hi[w0:w1] = np.asarray(hi)[:wl]
        volume[w0:w1] = np.asarray(vol)[:wl]
        if probe is not None:
            probe("stage2_mix")
        if prof is not None:
            ms = (time.perf_counter() - tw) * 1e3
            stage_ms["stage2_mix"] += ms
            win_hist["stage2_mix"].observe(ms)

    # ---- Stage 3: per-window device signature sorts + host combine
    s3fn = _s3_fn(sort_backend, use_pallas)
    parts = []
    for w0, w1 in wplan.bounds:
        tw = time.perf_counter() if prof is not None else 0.0
        wl = w1 - w0
        s_lo, s_hi, idx = s3fn(
            jnp.asarray(_pad_tail(sig_lo[w0:w1], budget, fill=0)),
            jnp.asarray(_pad_tail(sig_hi[w0:w1], budget, fill=0)))
        s_lo, s_hi = np.asarray(s_lo), np.asarray(s_hi)
        idx = np.asarray(idx)
        # drop tail pads: a stable sort's real-element subsequence is
        # exactly the stable sort of the real elements alone
        m = idx < wl
        # the Stage-3 sort keys (sig_lo, sig_hi) msb-first — sig_lo is
        # the high word of the packed signature the combine merges on
        word = ((s_lo[m].astype(np.uint64) << np.uint64(32))
                | s_hi[m].astype(np.uint64))
        parts.append((word, (w0 + idx[m]).astype(np.int64)))
        if probe is not None:
            probe("stage3_sort")
        if prof is not None:
            ms = (time.perf_counter() - tw) * 1e3
            stage_ms["stage3_sort"] += ms
            win_hist["stage3_sort"].observe(ms)
    s_word, order = _kway_combine(parts)
    # group stats on the combined order — the monolithic stage3_dedup
    # prefix-difference formulas on host
    s_first = tfirst[order]
    a3, b3 = _seg_bounds(_diff_flags(s_word))
    pref = np.concatenate([np.zeros(1, np.int32),
                           np.cumsum(s_first.astype(np.int32),
                                     dtype=np.int32)])
    pos = np.arange(t, dtype=np.int32)
    uniq_sorted = s_first & (pref[pos] == pref[a3])
    gen_sorted = pref[b3.astype(np.int64)] - pref[a3.astype(np.int64)]
    gen_count = np.empty(t, np.int32)
    gen_count[order] = gen_sorted
    is_unique = np.empty(t, bool)
    is_unique[order] = uniq_sorted

    density = gen_count.astype(np.float32) / np.maximum(volume,
                                                        np.float32(1.0))
    keep = is_unique & (density >= np.float32(theta))
    if minsup:
        for k in range(n):
            keep = keep & (mode_card[k] >= minsup)
    if prof is not None:
        m = prof.metrics
        for st in STAGES:
            m.histogram("pipeline_stage_ms", stage=st).observe(
                stage_ms[st])
            sp.set(f"{st}_ms", stage_ms[st])
        m.counter("pipeline_seam_carries_total").inc(seam_carries)
        m.gauge("pipeline_windows").set(len(wplan.bounds))
        m.gauge("pipeline_window_budget").set(budget)
        if mp is not None:
            for st, peak in mp.report()["stages"].items():
                m.gauge("pipeline_window_peak_bytes", stage=st).set(peak)
            sp.set("peak_bytes", mp.peak_bytes)
        sp.set("seam_carries", seam_carries)
        sp.finish()
    return P.PipelineResult(
        sig_lo=sig_lo, sig_hi=sig_hi, is_unique=is_unique,
        gen_count=gen_count, volume=volume, density=density, keep=keep,
        cardinalities=mode_card, range_lo=mode_rlo, range_hi=mode_rhi,
        sorted_e=sorted_e, perms=perms.astype(np.int32))
