"""Public API: multimodal (N-ary) OAC clustering with selectable backend.

Mirrors the paper's naming: the three M/R stages of §4.1 correspond to

  Stage 1 (Alg. 2+3)  -> per-mode sort/segment + set hashing
  Stage 2 (Alg. 4+5)  -> gather cumuli back to generating tuples
  Stage 3 (Alg. 6+7)  -> signature dedup + density (θ) filtering

All engines compose the shared pipeline core (``core.pipeline``); backend
and variant selection goes through the engine registry
(``core.engines.mine`` / ``make_miner``).
"""
from __future__ import annotations

from typing import Optional, Sequence

from .batch import BatchMiner, MiningResult
from .context import PolyadicContext, from_named_triples, tricontext
from .distributed import (DistributedMiner, DistributedResult, pad_tuples,
                          pad_values)
from .engines import MineRun, available_engines, mine, resolve_engine
from .manyvalued import NOACMiner, NOACResult
from .pipeline import PipelineResult
from .streaming import StreamingMiner

__all__ = [
    "BatchMiner", "DistributedMiner", "StreamingMiner", "NOACMiner",
    "MiningResult", "DistributedResult", "NOACResult", "PipelineResult",
    "PolyadicContext", "tricontext", "from_named_triples", "pad_tuples",
    "pad_values", "make_miner", "mine", "MineRun", "available_engines",
    "resolve_engine",
]


def make_miner(sizes: Sequence[int], backend: str = "batch",
               theta: float = 0.0, mesh=None, axes="data",
               strategy: str = "replicate", delta: Optional[float] = None,
               rho_min: float = 0.0, minsup: int = 0, **kw):
    """Factory selecting the backend (the paper's algorithm variants).

    Thin compatibility wrapper over the engine registry; prefer
    ``repro.core.mine(ctx, backend=..., variant=...)`` for one-shot runs.
    """
    variant = "noac" if delta is not None else "prime"
    resolve_engine(backend, variant)  # clear error on unknown combinations
    if backend == "reference":
        raise ValueError("the reference oracle has no miner object; "
                         "use repro.core.mine(ctx, backend='reference')")
    if variant == "noac":
        if backend == "batch":
            return NOACMiner(sizes, delta=delta, rho_min=rho_min,
                             minsup=minsup, **kw)
        if backend == "streaming":
            return StreamingMiner(sizes, delta=delta, rho_min=rho_min,
                                  minsup=minsup, **kw)
        if mesh is None:
            raise ValueError("distributed backend needs a mesh")
        return DistributedMiner(sizes, mesh, axes=axes, strategy=strategy,
                                delta=delta, rho_min=rho_min, minsup=minsup,
                                **kw)
    if backend == "batch":
        return BatchMiner(sizes, theta=theta, **kw)
    if backend == "streaming":
        return StreamingMiner(sizes, theta=theta, **kw)
    if mesh is None:
        raise ValueError("distributed backend needs a mesh")
    return DistributedMiner(sizes, mesh, axes=axes, theta=theta,
                            strategy=strategy, **kw)
