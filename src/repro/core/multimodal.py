"""Public API: multimodal (N-ary) OAC clustering with selectable backend.

Mirrors the paper's naming: the three M/R stages of §4.1 correspond to

  Stage 1 (Alg. 2+3)  -> per-mode sort/segment + set hashing
  Stage 2 (Alg. 4+5)  -> gather cumuli back to generating tuples
  Stage 3 (Alg. 6+7)  -> signature dedup + density (θ) filtering

Backends: ``batch`` (single shard), ``distributed`` (shard_map mesh,
'replicate' or 'shuffle' merge strategy), ``streaming`` (online ingestion).
"""
from __future__ import annotations

from typing import Optional, Sequence

from .batch import BatchMiner, MiningResult
from .context import PolyadicContext, from_named_triples, tricontext
from .distributed import DistributedMiner, DistributedResult, pad_tuples
from .manyvalued import NOACMiner, NOACResult
from .streaming import StreamingMiner

__all__ = [
    "BatchMiner", "DistributedMiner", "StreamingMiner", "NOACMiner",
    "MiningResult", "DistributedResult", "NOACResult",
    "PolyadicContext", "tricontext", "from_named_triples", "pad_tuples",
    "make_miner",
]


def make_miner(sizes: Sequence[int], backend: str = "batch",
               theta: float = 0.0, mesh=None, axes="data",
               strategy: str = "replicate", delta: Optional[float] = None,
               rho_min: float = 0.0, minsup: int = 0, **kw):
    """Factory selecting the backend (the paper's algorithm variants)."""
    if delta is not None:
        return NOACMiner(sizes, delta=delta, rho_min=rho_min, minsup=minsup,
                         **kw)
    if backend == "batch":
        return BatchMiner(sizes, theta=theta, **kw)
    if backend == "streaming":
        return StreamingMiner(sizes, theta=theta, **kw)
    if backend == "distributed":
        if mesh is None:
            raise ValueError("distributed backend needs a mesh")
        return DistributedMiner(sizes, mesh, axes=axes, theta=theta,
                                strategy=strategy, **kw)
    raise ValueError(f"unknown backend {backend!r}")
