"""jax API compatibility shims (single home; see DESIGN.md §6).

jax 0.4.x lacks ``jax.shard_map``, ``jax.sharding.AxisType`` and returns
``cost_analysis()`` as a one-dict-per-program list.  Everything in this
repo goes through these wrappers instead of the moving jax surface.
"""
from __future__ import annotations

import jax


def shard_map(f, mesh, in_specs, out_specs):
    """``jax.shard_map`` on new jax; the experimental module on older
    releases (0.4.x), where replication checking is ``check_rep``."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def make_mesh(shape, names):
    """``jax.make_mesh`` with explicit Auto axis types where supported."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(shape, names,
                             axis_types=(jax.sharding.AxisType.Auto,)
                             * len(names))
    return jax.make_mesh(shape, names)
