"""Mamba2 (SSD) layer: chunked matmul training path + recurrent decode.

TPU adaptation (DESIGN.md §3/§7): the SSD inter-chunk recurrence is a
``jax.lax.associative_scan`` over chunk states — log-depth, loop-free HLO
(exact in cost_analysis and MXU-friendly), instead of the sequential CUDA
chunk scan of the reference implementation. Intra-chunk work is two
batched matmuls per chunk, which is where the MXU time goes.

Shapes: x (B,S,D) -> (B,S,D); heads H = d_inner/ssm_head_dim sharded over
the model axis; the state dim N stays replicated (N=64).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from .common import rmsnorm

_LOG_MIN = -60.0


def _depthwise_causal_conv(x: jnp.ndarray, w: jnp.ndarray,
                           state: jnp.ndarray = None):
    """x (B,S,C), w (W,C) depthwise causal conv. With ``state`` (B,W-1,C)
    (decode path, S==1) returns (y, new_state)."""
    width = w.shape[0]
    if state is not None:
        window = jnp.concatenate([state, x], axis=1)        # (B,W,C)
        y = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                       w.astype(jnp.float32))[:, None]
        return y.astype(x.dtype), window[:, 1:]
    pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]].astype(jnp.float32)
            * w[i].astype(jnp.float32) for i in range(width))
    return y.astype(x.dtype), None


def ssd_forward(cfg: ModelConfig, p: dict, x: jnp.ndarray,
                return_state: bool = False):
    """Training / prefill forward of one Mamba2 layer (chunked SSD).

    With ``return_state`` also returns (ssm_state (B,H,hp,N), conv_state
    (B,W-1,di+2N)) after the last position — the prefill handoff."""
    b, s, d = x.shape
    di, n, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_head_dim
    h = cfg.ssm_heads
    chunk = min(cfg.ssm_chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    z = jnp.einsum("bsd,de->bse", x, p["wz"].astype(x.dtype))
    xs = jnp.einsum("bsd,de->bse", x, p["wx"].astype(x.dtype))
    bmat = jnp.einsum("bsd,dn->bsn", x, p["wB"].astype(x.dtype))
    cmat = jnp.einsum("bsd,dn->bsn", x, p["wC"].astype(x.dtype))
    dt_raw = jnp.einsum("bsd,dh->bsh", x, p["wdt"].astype(x.dtype))

    conv_in = jnp.concatenate([xs, bmat, cmat], axis=-1)
    conv_out, _ = _depthwise_causal_conv(conv_in, p["conv_w"])
    conv_out = jax.nn.silu(conv_out + p["conv_b"].astype(conv_out.dtype))
    final_conv_state = conv_in[:, s - (cfg.conv_width - 1):, :]
    xs, bmat, cmat = jnp.split(conv_out, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))   # (B,S,H)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))               # (H,)
    la = dt * a                                                # log-decay <=0

    xh = xs.reshape(b, s, h, hp).astype(jnp.float32)
    xbar = xh * dt[..., None]
    bm = bmat.astype(jnp.float32).reshape(b, nc, chunk, n)
    cm = cmat.astype(jnp.float32).reshape(b, nc, chunk, n)
    lac = la.reshape(b, nc, chunk, h)
    xbc = xbar.reshape(b, nc, chunk, h, hp)

    cum = jnp.cumsum(lac, axis=2)                              # (B,nc,L,H)
    # intra-chunk: scores[b,c,h,i,j] = (C_i·B_j)·exp(cum_i−cum_j), j<=i
    cb = jnp.einsum("bcin,bcjn->bcij", cm, bm)
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]       # (B,nc,i,j,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.exp(jnp.clip(diff, _LOG_MIN, 0.0))
    scores = cb[..., None] * decay * tri[None, None, :, :, None]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, xbc)

    # chunk states S_c[b,c,h,n,p] = Σ_j exp(cum_L−cum_j)·B_j ⊗ xbar_j
    tail = jnp.exp(jnp.clip(cum[:, :, -1:, :] - cum, _LOG_MIN, 0.0))
    st = jnp.einsum("bcjh,bcjn,bcjhp->bchnp", tail, bm, xbc)
    dchunk = jnp.exp(jnp.clip(cum[:, :, -1, :], _LOG_MIN, 0.0))  # (B,nc,H)

    # inter-chunk recurrence h_c = d_c·h_{c-1} + S_c  (associative scan)
    def combine(lhs, rhs):
        d1, s1 = lhs
        d2, s2 = rhs
        return d1 * d2, s2 + d2[..., None, None] * s1

    dacc, sacc = jax.lax.associative_scan(combine, (dchunk, st), axis=1)
    # state entering chunk c is sacc[c-1]
    h_prev = jnp.concatenate([jnp.zeros_like(sacc[:, :1]), sacc[:, :-1]], 1)
    y_inter = jnp.einsum("bcin,bchnp,bcih->bcihp", cm, h_prev,
                         jnp.exp(jnp.clip(cum, _LOG_MIN, 0.0)))

    y = (y_intra + y_inter).reshape(b, s, h, hp)
    y = y + p["D_skip"].astype(jnp.float32)[None, None, :, None] * xh
    y = y.reshape(b, s, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_scale"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
    if return_state:
        # final SSM state: last entry of the inclusive chunk-state scan,
        # transposed to the decode layout (B,H,hp,N)
        final = sacc[:, -1].transpose(0, 1, 3, 2)          # (B,H,hp,N)
        return out, final, final_conv_state.astype(x.dtype)
    return out


def ssd_decode(cfg: ModelConfig, p: dict, x: jnp.ndarray,
               ssm_state: jnp.ndarray, conv_state: jnp.ndarray):
    """One-token recurrent step. x (B,1,D); ssm_state (B,H,hp,N);
    conv_state (B,W-1,di+2N). Returns (y, ssm_state', conv_state')."""
    b = x.shape[0]
    di, n, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_head_dim
    h = cfg.ssm_heads

    z = jnp.einsum("bsd,de->bse", x, p["wz"].astype(x.dtype))
    xs = jnp.einsum("bsd,de->bse", x, p["wx"].astype(x.dtype))
    bmat = jnp.einsum("bsd,dn->bsn", x, p["wB"].astype(x.dtype))
    cmat = jnp.einsum("bsd,dn->bsn", x, p["wC"].astype(x.dtype))
    dt_raw = jnp.einsum("bsd,dh->bsh", x, p["wdt"].astype(x.dtype))

    conv_in = jnp.concatenate([xs, bmat, cmat], axis=-1)
    conv_out, conv_state = _depthwise_causal_conv(conv_in, p["conv_w"],
                                                  conv_state)
    conv_out = jax.nn.silu(conv_out + p["conv_b"].astype(conv_out.dtype))
    xs, bmat, cmat = jnp.split(conv_out[:, 0], [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))   # (B,H)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * a)                                    # (B,H)

    xh = xs.reshape(b, h, hp).astype(jnp.float32)
    xbar = xh * dt[..., None]
    upd = jnp.einsum("bhp,bn->bhpn", xbar, bmat.astype(jnp.float32))
    ssm_state = ssm_state * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", ssm_state, cmat.astype(jnp.float32))
    y = y + p["D_skip"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_scale"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
    return out, ssm_state, conv_state
