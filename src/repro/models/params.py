"""Parameter declaration machinery.

Each model family declares its parameters once as a nested dict of
``ParamDef`` (shape + logical axes + init); from that single table we
derive initialisation, sharding specs (structure-match guaranteed),
parameter counts, and ShapeDtypeStructs for the dry-run.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..sharding.rules import MeshRules


class ParamDef(NamedTuple):
    shape: tuple
    axes: tuple                  # logical axis names (len == len(shape))
    init: str = "normal"         # normal | zeros | ones
    scale: Optional[float] = None  # None -> 1/sqrt(fan_in) with fan_in=shape[-2 or -1]

    def fan_in(self) -> int:
        if len(self.shape) == 1:
            return self.shape[0]
        return int(np.prod(self.shape[:-1])) if len(self.shape) == 2 else \
            int(np.prod(self.shape[-2:-1]))


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def map_defs(fn, defs):
    """Map a function over every ParamDef leaf of a nested dict."""
    if _is_def(defs):
        return fn(defs)
    return {k: map_defs(fn, v) for k, v in defs.items()}


def init_params(defs, key, dtype=jnp.float32):
    """Initialise a parameter tree from its declaration (deterministic)."""
    leaves = []

    def collect(d, path):
        if _is_def(d):
            leaves.append((path, d))
        else:
            for k in sorted(d):
                collect(d[k], path + (k,))

    collect(defs, ())
    keys = jax.random.split(key, max(len(leaves), 1))
    out: dict = {}
    for (path, d), k in zip(leaves, keys):
        if d.init == "zeros":
            arr = jnp.zeros(d.shape, dtype)
        elif d.init == "ones":
            arr = jnp.ones(d.shape, dtype)
        else:
            scale = d.scale if d.scale is not None else 1.0 / math.sqrt(
                max(d.shape[-2] if len(d.shape) >= 2 else d.shape[-1], 1))
            arr = (jax.random.normal(k, d.shape, jnp.float32) * scale
                   ).astype(dtype)
        node = out
        for p in path[:-1]:
            node = node.setdefault(p, {})
        node[path[-1]] = arr
    return out


def param_specs(defs, rules: MeshRules):
    """PartitionSpec tree matching the parameter tree structure."""
    return map_defs(lambda d: rules.spec(d.axes, d.shape), defs)


def param_shardings(defs, rules: MeshRules):
    return map_defs(lambda d: rules.sharding(d.axes, d.shape), defs)


def param_structs(defs, rules: Optional[MeshRules] = None,
                  dtype=jnp.float32):
    """ShapeDtypeStruct tree (dry-run stand-ins; no allocation)."""
    if rules is None:
        return map_defs(lambda d: jax.ShapeDtypeStruct(d.shape, dtype), defs)
    return map_defs(
        lambda d: jax.ShapeDtypeStruct(
            d.shape, dtype, sharding=rules.sharding(d.axes, d.shape)),
        defs)


def count_params(defs) -> int:
    total = 0

    def add(d):
        nonlocal total
        total += int(np.prod(d.shape))
        return d

    map_defs(add, defs)
    return total
