"""Model telemetry: expose internal routing decisions as mineable
relations (the paper-technique integration point, DESIGN.md §5).

``collect_moe_routing`` runs a MoE forward pass and returns the Boolean
routing relation — for every routed (token, expert, layer) slot one
triple. That relation IS a triadic formal context: feeding it to the
OAC pipeline yields triclusters of co-activated (token-group × expert-
group × layer-group), the expert-specialisation patterns.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from . import common
from ..core.context import PolyadicContext


def collect_moe_routing(cfg: ModelConfig, params, tokens) -> np.ndarray:
    """tokens (B,S) int32 -> routes (L, B, S, k) int32 expert ids."""
    if not cfg.is_moe:
        raise ValueError("routing telemetry needs a MoE config "
                         "(DESIGN.md §5 Arch-applicability)")
    compute = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x = params["embed"].astype(compute)[tokens]
    b, s, _ = x.shape
    positions = jnp.arange(s, dtype=jnp.int32)
    lp = params["layers"]
    routes = []
    for i in range(cfg.n_layers):
        p = jax.tree.map(lambda a: a[i], lp)
        h = common.rmsnorm(x, p["attn_norm"], cfg.norm_eps)
        x = x + common.attention(cfg, p["attn"], h, positions,
                                 impl=cfg.attn_impl, q_block=cfg.q_block)
        h = common.rmsnorm(x, p["mlp_norm"], cfg.norm_eps)
        logits = jnp.einsum("bsd,de->bse", h,
                            p["moe"]["router"].astype(h.dtype))
        _, top_e = jax.lax.top_k(logits.astype(jnp.float32), cfg.top_k)
        routes.append(top_e.astype(jnp.int32))
        y, _ = common.moe_ffn(cfg, p["moe"], h)
        x = x + y
    return np.asarray(jnp.stack(routes))          # (L,B,S,k)


def routing_context(cfg: ModelConfig, tokens, routes) -> PolyadicContext:
    """(vocab-token, expert, layer) triples from collected routes."""
    l, b, s, k = routes.shape
    tok = np.broadcast_to(np.asarray(tokens)[None, :, :, None],
                          routes.shape)
    lay = np.broadcast_to(np.arange(l)[:, None, None, None], routes.shape)
    triples = np.stack([tok.reshape(-1), routes.reshape(-1),
                        lay.reshape(-1)], axis=1)
    triples = np.unique(triples, axis=0)
    return PolyadicContext((int(cfg.vocab_size), int(cfg.n_experts), l),
                           triples)
