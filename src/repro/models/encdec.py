"""Encoder-decoder transformer (seamless-m4t): speech encoder (stub fbank
frontend) + text decoder with cross-attention.

Train: (frames (B,Se,frontend_dim), tokens (B,Sd)) -> next-token loss.
Serve: ``encode`` once, then prefill/decode over the decoder with a self
KV ring plus a fixed cross-attention KV computed from the encoder output.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import common
from .lm import _L, _map_cache, _maybe_remat, cache_len, _ring_pack
from .params import ParamDef


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def _attn_defs(cfg, stack):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    sa = ("layers",) * len(stack)
    return {
        "wq": ParamDef(stack + (d, h, hd), sa + (None, "heads", None)),
        "wk": ParamDef(stack + (d, kv, hd), sa + (None, "kv_heads", None)),
        "wv": ParamDef(stack + (d, kv, hd), sa + (None, "kv_heads", None)),
        "wo": ParamDef(stack + (h * hd, d), sa + ("heads", None)),
    }


def _mlp_defs(cfg, stack):
    d, f = cfg.d_model, cfg.d_ff
    sa = ("layers",) * len(stack)
    return {
        "w_gate": ParamDef(stack + (d, f), sa + (None, "ff")),
        "w_up": ParamDef(stack + (d, f), sa + (None, "ff")),
        "w_down": ParamDef(stack + (f, d), sa + ("ff", None)),
    }


def param_defs(cfg: ModelConfig) -> dict:
    d, v = cfg.d_model, cfg.vocab_size
    le, ld = cfg.enc_layers, cfg.n_layers
    out = {
        "embed": ParamDef((v, d), ("vocab", "embed"), "normal", 1.0),
        "frontend_adapter": ParamDef((cfg.frontend_dim, d), (None, "embed")),
        "enc_out_norm": ParamDef((d,), (None,), "ones"),
        "out_norm": ParamDef((d,), (None,), "ones"),
        "lm_head": ParamDef((d, v), ("embed", "vocab")),
        "encoder": {
            "attn_norm": ParamDef((le, d), ("layers", None), "ones"),
            "attn": _attn_defs(cfg, (le,)),
            "mlp_norm": ParamDef((le, d), ("layers", None), "ones"),
            "mlp": _mlp_defs(cfg, (le,)),
        },
        "decoder": {
            "attn_norm": ParamDef((ld, d), ("layers", None), "ones"),
            "attn": _attn_defs(cfg, (ld,)),
            "cross_norm": ParamDef((ld, d), ("layers", None), "ones"),
            "cross": _attn_defs(cfg, (ld,)),
            "mlp_norm": ParamDef((ld, d), ("layers", None), "ones"),
            "mlp": _mlp_defs(cfg, (ld,)),
        },
    }
    return out


# ---------------------------------------------------------------------------
# Encoder
# ---------------------------------------------------------------------------

def _cross_attention(cfg, p, x, enc_k, enc_v):
    """x (B,Sq,D) queries against precomputed encoder K/V (B,Se,KV,hd)."""
    b, sq, d = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    group = cfg.n_heads // cfg.n_kv_heads
    kk = jnp.repeat(enc_k, group, axis=2).astype(x.dtype)
    vv = jnp.repeat(enc_v, group, axis=2).astype(x.dtype)
    scale = cfg.head_dim ** -0.5
    s = jnp.einsum("bqhk,bthk->bhqt", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * scale
    a = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqt,bthk->bqhk", a, vv.astype(jnp.float32)
                   ).astype(x.dtype)
    o = o.reshape(b, sq, cfg.n_heads * cfg.head_dim)
    return jnp.einsum("bse,ed->bsd", o, p["wo"].astype(x.dtype)
                      .reshape(-1, d))


def encode(cfg: ModelConfig, params, frames, rules=None):
    """frames (B,Se,frontend_dim) -> encoder output (B,Se,D) and the
    per-decoder-layer cross K/V (Ld,B,Se,KV,hd)."""
    compute = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x = jnp.einsum("bsf,fd->bsd", frames.astype(compute),
                   params["frontend_adapter"].astype(compute))
    if rules is not None:
        x = rules.constrain(x, "batch", None, None)
    b, se, _ = x.shape
    positions = jnp.arange(se, dtype=jnp.int32)

    def body(xx, p):
        h = common.rmsnorm(xx, p["attn_norm"], cfg.norm_eps)
        q, k, v = common._qkv(cfg, p["attn"], h, positions)
        group = cfg.n_heads // cfg.n_kv_heads
        kk = jnp.repeat(k, group, axis=2)
        vv = jnp.repeat(v, group, axis=2)
        mask = jnp.ones((1, se, se), bool)      # bidirectional
        o = common._sdpa(q, kk, vv, mask, cfg.head_dim ** -0.5)
        o = o.reshape(b, se, cfg.n_heads * cfg.head_dim)
        xx = xx + jnp.einsum("bse,ed->bsd", o,
                             p["attn"]["wo"].astype(xx.dtype)
                             .reshape(-1, xx.shape[-1]))
        h = common.rmsnorm(xx, p["mlp_norm"], cfg.norm_eps)
        return xx + common.swiglu(p["mlp"], h), None

    wrapped = _maybe_remat(cfg, lambda xx, p: body(xx, p)[0])
    if cfg.scan_layers:
        x, _ = jax.lax.scan(lambda c, sl: (wrapped(c, sl), None), x,
                            params["encoder"])
    else:
        for i in range(cfg.enc_layers):
            x = wrapped(x, jax.tree.map(lambda a: a[i], params["encoder"]))
    x = common.rmsnorm(x, params["enc_out_norm"], cfg.norm_eps)

    # precompute cross K/V per decoder layer
    dec = params["decoder"]["cross"]

    def kv(p):
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
        v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
        return k, v

    if cfg.scan_layers:
        ks, vs = jax.lax.map(kv, dec)
    else:
        pairs = [kv(jax.tree.map(lambda a: a[i], dec))
                 for i in range(cfg.n_layers)]
        ks = jnp.stack([p[0] for p in pairs])
        vs = jnp.stack([p[1] for p in pairs])
    return x, ks, vs


# ---------------------------------------------------------------------------
# Decoder
# ---------------------------------------------------------------------------

def _dec_block(cfg, p, x, positions, cross_k, cross_v):
    h = common.rmsnorm(x, p["attn_norm"], cfg.norm_eps)
    x = x + common.attention(cfg, p["attn"], h, positions,
                             impl=cfg.attn_impl, q_block=cfg.q_block)
    h = common.rmsnorm(x, p["cross_norm"], cfg.norm_eps)
    x = x + _cross_attention(cfg, p["cross"], h, cross_k, cross_v)
    h = common.rmsnorm(x, p["mlp_norm"], cfg.norm_eps)
    return x + common.swiglu(p["mlp"], h)


def forward(cfg: ModelConfig, params, batch, rules=None):
    """Training forward: logits (B,Sd,V), aux=0."""
    compute = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    _, cross_k, cross_v = encode(cfg, params, batch["frames"], rules)
    tokens = batch["tokens"]
    x = params["embed"].astype(compute)[tokens]
    if rules is not None:
        x = rules.constrain(x, "batch", None, None)
    b, sd, _ = x.shape
    positions = jnp.arange(sd, dtype=jnp.int32)

    wrapped = _maybe_remat(
        cfg, lambda xx, sl: _dec_block(cfg, sl[0], xx, positions,
                                       sl[1], sl[2]))
    xs = (params["decoder"], cross_k, cross_v)
    if cfg.scan_layers:
        x, _ = jax.lax.scan(lambda c, sl: (wrapped(c, sl), None), x, xs)
    else:
        for i in range(cfg.n_layers):
            x = wrapped(x, jax.tree.map(lambda a: a[i], xs))
    x = common.rmsnorm(x, params["out_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    if cfg.logits_fp32:
        logits = logits.astype(jnp.float32)
    return logits, jnp.zeros((), jnp.float32)


def loss_fn(cfg: ModelConfig, params, batch, rules=None):
    logits, aux = forward(cfg, params, batch, rules)
    labels = batch["labels"]
    valid = labels >= 0
    safe = jnp.maximum(labels, 0)
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(lp, safe[..., None], axis=-1)[..., 0]
    loss = jnp.sum(nll * valid) / jnp.maximum(valid.sum(), 1)
    return loss, {"nll": loss, "aux": aux}


# ---------------------------------------------------------------------------
# Serving: cache = decoder self-KV ring + fixed cross K/V
# ---------------------------------------------------------------------------

def cache_defs(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> dict:
    sc = cache_len(cfg, max_len)
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    l, se = cfg.n_layers, cfg.frontend_len
    seq_ax = "long_seq" if batch == 1 else "kv_seq"
    return {
        "pos": _L((), jnp.int32, 0, ()),
        "k": _L((l, batch, sc, kv, hd), dtype, 0,
                (None, "batch", seq_ax, "kv_heads", None)),
        "v": _L((l, batch, sc, kv, hd), dtype, 0,
                (None, "batch", seq_ax, "kv_heads", None)),
        "slot_pos": _L((sc,), jnp.int32, -1, (None,)),
        "cross_k": _L((l, batch, se, kv, hd), dtype, 0,
                      (None, "batch", "kv_seq", "kv_heads", None)),
        "cross_v": _L((l, batch, se, kv, hd), dtype, 0,
                      (None, "batch", "kv_seq", "kv_heads", None)),
    }


def init_cache(cfg, batch, max_len, dtype=jnp.bfloat16, rules=None):
    defs = cache_defs(cfg, batch, max_len, dtype)

    def make(l: _L):
        arr = jnp.full(l.shape, l.fill, l.dtype)
        if rules is not None and l.axes:
            arr = rules.constrain(arr, *l.axes)
        return arr

    return _map_cache(make, defs)


def cache_structs(cfg, batch, max_len, rules, dtype=jnp.bfloat16):
    defs = cache_defs(cfg, batch, max_len, dtype)
    return _map_cache(
        lambda l: jax.ShapeDtypeStruct(
            l.shape, l.dtype, sharding=rules.sharding(l.axes, l.shape)),
        defs)


def decode_step(cfg: ModelConfig, params, cache, tokens, rules=None):
    """One decoder token for all sequences; cross K/V fixed in the cache."""
    compute = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x = params["embed"].astype(compute)[tokens][:, None]
    pos = cache["pos"]
    slot_pos = cache["slot_pos"]

    def body(carry, sl):
        xx, sp = carry
        p, kc, vc, ck, cv = sl
        h = common.rmsnorm(xx, p["attn_norm"], cfg.norm_eps)
        y, kc, vc, sp = common.attention_decode(cfg, p["attn"], h, kc, vc,
                                                sp, pos, rules)
        xx = xx + y
        h = common.rmsnorm(xx, p["cross_norm"], cfg.norm_eps)
        xx = xx + _cross_attention(cfg, p["cross"], h, ck, cv)
        h = common.rmsnorm(xx, p["mlp_norm"], cfg.norm_eps)
        xx = xx + common.swiglu(p["mlp"], h)
        return (xx, sp), (kc, vc)

    xs = (params["decoder"], cache["k"], cache["v"],
          cache["cross_k"], cache["cross_v"])
    if cfg.scan_layers:
        (x, slot_pos), (ks, vs) = jax.lax.scan(body, (x, slot_pos), xs)
    else:
        ks, vs = [], []
        for i in range(cfg.n_layers):
            (x, slot_pos), (kc, vc) = body(
                (x, slot_pos), jax.tree.map(lambda a: a[i], xs))
            ks.append(kc)
            vs.append(vc)
        ks, vs = jnp.stack(ks), jnp.stack(vs)
    new = dict(cache)
    new.update(k=ks, v=vs, slot_pos=slot_pos, pos=pos + 1)
    x = common.rmsnorm(x, params["out_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x,
                        params["lm_head"].astype(x.dtype))[:, 0]
    return new, logits.astype(jnp.float32)


def prefill(cfg: ModelConfig, params, batch_inputs, max_len: int,
            rules=None):
    """Encode frames + run the decoder over the prompt tokens, returning a
    populated cache and last-position logits."""
    compute = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    frames, tokens = batch_inputs["frames"], batch_inputs["tokens"]
    _, cross_k, cross_v = encode(cfg, params, frames, rules)
    x = params["embed"].astype(compute)[tokens]
    b, sd, _ = x.shape
    positions = jnp.arange(sd, dtype=jnp.int32)
    sc = cache_len(cfg, max_len)

    def body(xx, sl):
        p, ck, cv = sl
        h = common.rmsnorm(xx, p["attn_norm"], cfg.norm_eps)
        q, k, v = common._qkv(cfg, p["attn"], h, positions)
        kr, slot_pos = _ring_pack(k, sc, sd)
        vr, _ = _ring_pack(v, sc, sd)
        group = cfg.n_heads // cfg.n_kv_heads
        kk = jnp.repeat(k, group, axis=2)
        vv = jnp.repeat(v, group, axis=2)
        mask = common._mask(positions[None], positions[None], cfg.window)
        o = common._sdpa(q, kk, vv, mask, cfg.head_dim ** -0.5)
        o = o.reshape(b, sd, cfg.n_heads * cfg.head_dim)
        xx = xx + jnp.einsum("bse,ed->bsd", o,
                             p["attn"]["wo"].astype(xx.dtype)
                             .reshape(-1, xx.shape[-1]))
        h = common.rmsnorm(xx, p["cross_norm"], cfg.norm_eps)
        xx = xx + _cross_attention(cfg, p["cross"], h, ck, cv)
        h = common.rmsnorm(xx, p["mlp_norm"], cfg.norm_eps)
        xx = xx + common.swiglu(p["mlp"], h)
        return xx, (kr.astype(compute), vr.astype(compute), slot_pos)

    wrapped = _maybe_remat(cfg, body)
    xs = (params["decoder"], cross_k, cross_v)
    if cfg.scan_layers:
        x, (ks, vs, sps) = jax.lax.scan(lambda c, sl: wrapped(c, sl), x, xs)
        slot_pos = sps[0]
    else:
        ks, vs = [], []
        slot_pos = None
        for i in range(cfg.n_layers):
            x, (kr, vr, sp) = wrapped(x, jax.tree.map(lambda a: a[i], xs))
            ks.append(kr)
            vs.append(vr)
            slot_pos = sp
        ks, vs = jnp.stack(ks), jnp.stack(vs)
    cache = {
        "pos": jnp.asarray(sd, jnp.int32), "k": ks, "v": vs,
        "slot_pos": slot_pos,
        "cross_k": cross_k.astype(compute), "cross_v": cross_v.astype(compute),
    }
    x = common.rmsnorm(x[:, -1:], params["out_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x,
                        params["lm_head"].astype(x.dtype))[:, 0]
    return cache, logits.astype(jnp.float32)
