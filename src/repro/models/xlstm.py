"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

Training uses the parallel stabilised formulation of the mLSTM (decay-
masked attention-like matmuls — MXU-friendly, exact under cost_analysis)
and a ``lax.scan`` over time for the sLSTM (inherently sequential; the
xLSTM paper keeps few sLSTM blocks for exactly this reason — the
analysis module adds its per-step recurrent FLOPs analytically, see
the per-layer analysis in analysis/report.py). Decode is recurrent for both.

mLSTM block: up-proj ×2 → (branch, gate z); per-head q,k,v + i,f gates;
h = (S ⊙ D) v / n; headwise norm; h ⊙ silu(z) → down-proj.
sLSTM block: 4 gates with per-head block-diagonal recurrent matrices,
then a gated-MLP (projection factor 4/3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from .common import rmsnorm

_NEG = -1e30


def _headwise_rmsnorm(x: jnp.ndarray, scale: jnp.ndarray,
                      eps: float) -> jnp.ndarray:
    """x (B,S,H,P); normalise per head (GroupNorm analogue)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y.reshape(*x.shape[:-2], -1)
            * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def _mlstm_chunk(q, k, v, i_raw, logf, state):
    """One chunk of the chunkwise-parallel stabilised mLSTM.

    q/k/v (B,L,H,P); i_raw/logf (B,L,H); state = (C (B,H,P,P), n (B,H,P),
    m (B,H)). Returns (h (B,L,H,P), new_state). Exactly composes the
    per-step recurrence of ``mlstm_decode`` over L steps.
    """
    cum = jnp.cumsum(logf, axis=1)                        # (B,L,H)
    total = cum[:, -1]                                    # (B,H)
    c_prev, n_prev, m_prev = state
    l = q.shape[1]
    # intra-chunk decay matrix
    logd = (cum[:, :, None, :] - cum[:, None, :, :]
            + i_raw[:, None, :, :])                       # (B,i,j,H)
    tri = jnp.tril(jnp.ones((l, l), bool))[None, :, :, None]
    logd = jnp.where(tri, logd, _NEG)
    m_intra = jnp.max(logd, axis=2)                       # (B,L,H)
    m_inter = cum + m_prev[:, None, :]                    # decay from start
    m_t = jnp.maximum(m_intra, m_inter)
    dmat = jnp.exp(logd - m_t[:, :, None, :])
    scores = jnp.einsum("bihp,bjhp->bijh", q, k) * dmat
    inter_w = jnp.exp(m_inter - m_t)                      # (B,L,H)
    qc = jnp.einsum("bihp,bhpq->bihq", q, c_prev)
    num = jnp.einsum("bijh,bjhp->bihp", scores, v) + inter_w[..., None] * qc
    qn = jnp.einsum("bihp,bhp->bih", q, n_prev)
    den = jnp.maximum(jnp.abs(scores.sum(axis=2) + inter_w * qn),
                      jnp.exp(-m_t))
    hv = num / den[..., None]
    # state update (decay everything to the chunk end)
    logw = total[:, None, :] - cum + i_raw                # (B,L,H)
    m_w = logw.max(axis=1)                                # (B,H)
    m_new = jnp.maximum(total + m_prev, m_w)
    carry_w = jnp.exp(total + m_prev - m_new)
    wgt = jnp.exp(logw - m_new[:, None, :])
    c_new = (carry_w[..., None, None] * c_prev
             + jnp.einsum("bjh,bjhp,bjhq->bhpq", wgt, k, v))
    n_new = (carry_w[..., None] * n_prev
             + jnp.einsum("bjh,bjhp->bhp", wgt, k))
    return hv, (c_new, n_new, m_new)


def mlstm_forward(cfg: ModelConfig, p: dict, x: jnp.ndarray,
                  return_state: bool = False):
    """Parallel (training/prefill) mLSTM block. x (B,S,D) -> (B,S,D).
    With ``return_state``: also (C (B,H,P,P), n (B,H,P), m (B,H)).

    Sequences longer than ``cfg.ssm_chunk`` run the chunkwise-parallel
    form (lax.scan over chunks carrying (C,n,m)): peak decay-matrix memory
    drops from O(S²·H) to O(L²·H) and FLOPs from O(S²) to O(S·L) — the
    §Perf X1 iteration (the monolithic form was 600 s memory-bound at
    32k). Short sequences keep the one-shot S×S form (identical math).
    """
    b, s, d = x.shape
    dm = int(d * cfg.mlstm_proj)
    h = cfg.n_heads
    hp = dm // h

    up = jnp.einsum("bsd,de->bse", x, p["w_up"].astype(x.dtype))
    u, z = jnp.split(up, 2, axis=-1)                      # (B,S,dm) each
    q = jnp.einsum("bse,ef->bsf", u, p["wq"].astype(x.dtype))
    k = jnp.einsum("bse,ef->bsf", u, p["wk"].astype(x.dtype)) / np.sqrt(hp)
    v = jnp.einsum("bse,ef->bsf", u, p["wv"].astype(x.dtype))
    q = q.reshape(b, s, h, hp).astype(jnp.float32)
    k = k.reshape(b, s, h, hp).astype(jnp.float32)
    v = v.reshape(b, s, h, hp).astype(jnp.float32)
    i_raw = jnp.einsum("bse,eh->bsh", u, p["wi"].astype(x.dtype)
                       ).astype(jnp.float32)
    f_raw = jnp.einsum("bse,eh->bsh", u, p["wf"].astype(x.dtype)
                       ).astype(jnp.float32)
    logf = jax.nn.log_sigmoid(f_raw)                      # (B,S,H)

    chunk = cfg.ssm_chunk or 256
    state0 = (jnp.zeros((b, h, hp, hp), jnp.float32),
              jnp.zeros((b, h, hp), jnp.float32),
              jnp.full((b, h), -1e30, jnp.float32))
    if s > chunk and s % chunk == 0:
        nc = s // chunk

        def to_chunks(a):
            return jnp.moveaxis(
                a.reshape(b, nc, chunk, *a.shape[2:]), 1, 0)

        def body(st, ch):
            hv_c, st = _mlstm_chunk(*ch, st)
            return st, hv_c

        xs = tuple(to_chunks(a) for a in (q, k, v, i_raw, logf))
        state, hv = jax.lax.scan(body, state0, xs)
        hv = jnp.moveaxis(hv, 0, 1).reshape(b, s, h, hp)
    else:
        hv, state = _mlstm_chunk(q, k, v, i_raw, logf, state0)
    c_fin, n_fin, m_fin = state
    hv = _headwise_rmsnorm(hv, p["norm_scale"], cfg.norm_eps)  # (B,S,dm)
    out = hv.astype(x.dtype) * jax.nn.silu(z)
    y = jnp.einsum("bse,ed->bsd", out, p["w_down"].astype(x.dtype))
    if return_state:
        return y, c_fin, n_fin, m_fin
    return y


def mlstm_decode(cfg: ModelConfig, p: dict, x: jnp.ndarray,
                 c_state: jnp.ndarray, n_state: jnp.ndarray,
                 m_state: jnp.ndarray):
    """Recurrent step. x (B,1,D); c (B,H,P,P); n (B,H,P); m (B,H)."""
    b, _, d = x.shape
    dm = int(d * cfg.mlstm_proj)
    h = cfg.n_heads
    hp = dm // h

    up = jnp.einsum("bsd,de->bse", x, p["w_up"].astype(x.dtype))
    u, z = jnp.split(up, 2, axis=-1)
    u1 = u[:, 0]
    q = (u1 @ p["wq"].astype(x.dtype)).reshape(b, h, hp).astype(jnp.float32)
    k = (u1 @ p["wk"].astype(x.dtype)).reshape(b, h, hp).astype(jnp.float32)
    k = k / np.sqrt(hp)
    v = (u1 @ p["wv"].astype(x.dtype)).reshape(b, h, hp).astype(jnp.float32)
    i_raw = (u1 @ p["wi"].astype(x.dtype)).astype(jnp.float32)   # (B,H)
    f_raw = (u1 @ p["wf"].astype(x.dtype)).astype(jnp.float32)

    logf = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(logf + m_state, i_raw)
    alpha = jnp.exp(logf + m_state - m_new)
    beta = jnp.exp(i_raw - m_new)
    c_state = (c_state * alpha[..., None, None]
               + beta[..., None, None] * k[..., :, None] * v[..., None, :])
    n_state = n_state * alpha[..., None] + beta[..., None] * k
    num = jnp.einsum("bhp,bhpq->bhq", q, c_state)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", q, n_state)),
                      jnp.exp(-m_new))
    hv = (num / den[..., None])[:, None]                  # (B,1,H,P)
    hv = _headwise_rmsnorm(hv, p["norm_scale"], cfg.norm_eps)
    out = hv.astype(x.dtype) * jax.nn.silu(z)
    y = jnp.einsum("bse,ed->bsd", out, p["w_down"].astype(x.dtype))
    return y, c_state, n_state, m_new


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def _slstm_step(cfg: ModelConfig, p, carry, gx):
    """One recurrence step. carry = (c, n, hs, m) each (B,H,P) / m (B,H);
    gx = precomputed input projections (B, 4, D)."""
    c, n, hs, m = carry
    b = c.shape[0]
    h, hp = cfg.n_heads, cfg.d_model // cfg.n_heads
    hr = hs.reshape(b, h, hp)
    rec = jnp.einsum("bhp,ghpq->bghq", hr,
                     p["r_gates"].astype(hs.dtype))        # (B,4,H,P)
    g = gx.reshape(b, 4, h, hp).astype(jnp.float32) + rec.astype(jnp.float32)
    i_raw, f_raw, z_raw, o_raw = g[:, 0], g[:, 1], g[:, 2], g[:, 3]
    i_raw = i_raw + p["b_i"].astype(jnp.float32).reshape(h, hp)
    f_raw = f_raw + p["b_f"].astype(jnp.float32).reshape(h, hp)
    logf = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(logf + m[..., None], i_raw).max(-1)   # (B,H) shared
    alpha = jnp.exp(logf + m[..., None] - m_new[..., None])
    beta = jnp.exp(i_raw - m_new[..., None])
    c = alpha * c.reshape(b, h, hp) + beta * jnp.tanh(z_raw)
    n = alpha * n.reshape(b, h, hp) + beta
    hv = jax.nn.sigmoid(o_raw) * c / jnp.maximum(n, 1e-6)
    hs_new = hv.reshape(b, -1).astype(hs.dtype)
    return (c.reshape(b, h, hp), n.reshape(b, h, hp), hs_new, m_new), hs_new


def slstm_forward(cfg: ModelConfig, p: dict, x: jnp.ndarray,
                  return_state: bool = False):
    """sLSTM block (sequential over S). x (B,S,D) -> (B,S,D)."""
    b, s, d = x.shape
    h, hp = cfg.n_heads, d // cfg.n_heads
    gx = jnp.einsum("bsd,dge->bsge", x,
                    p["w_gates"].astype(x.dtype).reshape(d, 4, d))
    carry = (jnp.zeros((b, h, hp), jnp.float32),
             jnp.zeros((b, h, hp), jnp.float32),
             jnp.zeros((b, d), x.dtype),
             jnp.full((b, h), -1e30, jnp.float32))
    final, hs = jax.lax.scan(
        lambda c, g: _slstm_step(cfg, p, c, g),
        carry, gx.transpose(1, 0, 2, 3))
    hs = hs.transpose(1, 0, 2)                             # (B,S,D)
    hs = rmsnorm(hs, p["norm_scale"], cfg.norm_eps)
    # gated MLP, projection factor slstm_proj
    up = jnp.einsum("bsd,de->bse", hs.astype(x.dtype),
                    p["w_mlp_up"].astype(x.dtype))
    g, u = jnp.split(up, 2, axis=-1)
    y = jnp.einsum("bse,ed->bsd", jax.nn.gelu(g) * u,
                   p["w_mlp_down"].astype(x.dtype))
    if return_state:
        return y, final
    return y


def slstm_decode(cfg: ModelConfig, p: dict, x: jnp.ndarray, state):
    """One-token step; state = (c, n, hs, m)."""
    d = x.shape[-1]
    gx = jnp.einsum("bsd,dge->bsge", x,
                    p["w_gates"].astype(x.dtype).reshape(d, 4, d))[:, 0]
    state, hs = _slstm_step(cfg, p, state, gx)
    hs = rmsnorm(hs[:, None], p["norm_scale"], cfg.norm_eps)
    up = jnp.einsum("bsd,de->bse", hs.astype(x.dtype),
                    p["w_mlp_up"].astype(x.dtype))
    g, u = jnp.split(up, 2, axis=-1)
    y = jnp.einsum("bse,ed->bsd", jax.nn.gelu(g) * u,
                   p["w_mlp_down"].astype(x.dtype))
    return y, state
