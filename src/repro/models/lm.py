"""Unified decoder-only LM: dense / MoE / hybrid-SSM (Zamba2) / xLSTM.

One parameter-declaration table per family (``param_defs``), one forward
for training/prefill (``forward``), and a recurrent ``decode_step`` over a
typed cache. Layers run under ``lax.scan`` with stacked parameters
(production path: small HLO, fast GSPMD partitioning — DESIGN.md §7);
``cfg.scan_layers=False`` unrolls them (used by per-layer analysis for
exact per-layer cost accounting).

Block patterns:
  dense/moe    — homogeneous stack of L blocks.
  hybrid_ssm   — groups of ``attn_every`` Mamba2 layers, each group ending
                 with the single *shared* attention+MLP block (Zamba2
                 weight sharing); L %% attn_every tail Mamba2 layers.
  xlstm        — groups of ``slstm_every`` blocks: (period-1) mLSTM + one
                 sLSTM; tail of mLSTM blocks.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from . import common, ssm, xlstm
from .params import ParamDef


# ---------------------------------------------------------------------------
# Parameter declaration
# ---------------------------------------------------------------------------

def _attn_defs(cfg: ModelConfig, stack: tuple = ()) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    sa = ("layers",) * len(stack)
    out = {
        "wq": ParamDef(stack + (d, h, hd), sa + (None, "heads", None)),
        "wk": ParamDef(stack + (d, kv, hd), sa + (None, "kv_heads", None)),
        "wv": ParamDef(stack + (d, kv, hd), sa + (None, "kv_heads", None)),
        "wo": ParamDef(stack + (h * hd, d), sa + ("heads", None)),
    }
    if cfg.qk_norm:
        out["q_norm"] = ParamDef(stack + (hd,), sa + (None,), "ones")
        out["k_norm"] = ParamDef(stack + (hd,), sa + (None,), "ones")
    return out


def _mlp_defs(cfg: ModelConfig, stack: tuple = ()) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    sa = ("layers",) * len(stack)
    return {
        "w_gate": ParamDef(stack + (d, f), sa + (None, "ff")),
        "w_up": ParamDef(stack + (d, f), sa + (None, "ff")),
        "w_down": ParamDef(stack + (f, d), sa + ("ff", None)),
    }


def _moe_defs(cfg: ModelConfig, stack: tuple = ()) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    sa = ("layers",) * len(stack)
    return {
        "router": ParamDef(stack + (d, e), sa + (None, "experts")),
        "w_gate": ParamDef(stack + (e, d, f), sa + ("experts", None, "moe_ff")),
        "w_up": ParamDef(stack + (e, d, f), sa + ("experts", None, "moe_ff")),
        "w_down": ParamDef(stack + (e, f, d), sa + ("experts", "moe_ff", None)),
    }


def _mamba_defs(cfg: ModelConfig, stack: tuple = ()) -> dict:
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    h, w = cfg.ssm_heads, cfg.conv_width
    sa = ("layers",) * len(stack)
    return {
        "norm": ParamDef(stack + (d,), sa + (None,), "ones"),
        "wz": ParamDef(stack + (d, di), sa + (None, "ssm_inner")),
        "wx": ParamDef(stack + (d, di), sa + (None, "ssm_inner")),
        "wB": ParamDef(stack + (d, n), sa + (None, None)),
        "wC": ParamDef(stack + (d, n), sa + (None, None)),
        "wdt": ParamDef(stack + (d, h), sa + (None, "ssm_heads")),
        "conv_w": ParamDef(stack + (w, di + 2 * n), sa + (None, None),
                           "normal", 0.5),
        "conv_b": ParamDef(stack + (di + 2 * n,), sa + (None,), "zeros"),
        "dt_bias": ParamDef(stack + (h,), sa + ("ssm_heads",), "zeros"),
        "A_log": ParamDef(stack + (h,), sa + ("ssm_heads",), "zeros"),
        "D_skip": ParamDef(stack + (h,), sa + ("ssm_heads",), "ones"),
        "norm_scale": ParamDef(stack + (di,), sa + ("ssm_inner",), "ones"),
        "out_proj": ParamDef(stack + (di, d), sa + ("ssm_inner", None)),
    }


def _mlstm_defs(cfg: ModelConfig, stack: tuple = ()) -> dict:
    d = cfg.d_model
    dm = int(d * cfg.mlstm_proj)
    h = cfg.n_heads
    sa = ("layers",) * len(stack)
    return {
        "norm": ParamDef(stack + (d,), sa + (None,), "ones"),
        "w_up": ParamDef(stack + (d, 2 * dm), sa + (None, "ff")),
        "wq": ParamDef(stack + (dm, dm), sa + (None, "ff")),
        "wk": ParamDef(stack + (dm, dm), sa + (None, "ff")),
        "wv": ParamDef(stack + (dm, dm), sa + (None, "ff")),
        "wi": ParamDef(stack + (dm, h), sa + (None, "heads")),
        "wf": ParamDef(stack + (dm, h), sa + (None, "heads")),
        "norm_scale": ParamDef(stack + (dm,), sa + ("ff",), "ones"),
        "w_down": ParamDef(stack + (dm, d), sa + ("ff", None)),
    }


def _slstm_defs(cfg: ModelConfig, stack: tuple = ()) -> dict:
    d = cfg.d_model
    h, hp = cfg.n_heads, cfg.d_model // cfg.n_heads
    ds = int(2 * d * cfg.slstm_proj)      # gated MLP: up to 2×(proj·d)
    sa = ("layers",) * len(stack)
    return {
        "norm": ParamDef(stack + (d,), sa + (None,), "ones"),
        "w_gates": ParamDef(stack + (d, 4, d), sa + (None, None, None)),
        "r_gates": ParamDef(stack + (4, h, hp, hp),
                            sa + (None, "heads", None, None), "normal", 0.1),
        "b_i": ParamDef(stack + (d,), sa + (None,), "zeros"),
        "b_f": ParamDef(stack + (d,), sa + (None,), "ones"),
        "norm_scale": ParamDef(stack + (d,), sa + (None,), "ones"),
        "w_mlp_up": ParamDef(stack + (d, ds), sa + (None, "ff")),
        "w_mlp_down": ParamDef(stack + (ds // 2, d), sa + ("ff", None)),
    }


def _pattern(cfg: ModelConfig) -> tuple[int, int, int]:
    """(n_groups, period, tail) of the block pattern."""
    period = cfg.layer_pattern_period
    return cfg.n_layers // period, period, cfg.n_layers % period


def param_defs(cfg: ModelConfig) -> dict:
    d, v = cfg.d_model, cfg.vocab_size
    out: dict = {
        "embed": ParamDef((v, d), ("vocab", "embed"), "normal", 1.0),
        "out_norm": ParamDef((d,), (None,), "ones"),
    }
    if not cfg.tie_embeddings:
        out["lm_head"] = ParamDef((d, v), ("embed", "vocab"))
    if cfg.frontend == "patch":
        out["frontend_adapter"] = ParamDef((cfg.frontend_dim, d),
                                           (None, "embed"))
    layers: dict = {}
    if cfg.family == "dense":
        stack = (cfg.n_layers,)
        layers = {
            "attn_norm": ParamDef(stack + (d,), ("layers", None), "ones"),
            "attn": _attn_defs(cfg, stack),
            "mlp_norm": ParamDef(stack + (d,), ("layers", None), "ones"),
            "mlp": _mlp_defs(cfg, stack),
        }
    elif cfg.family == "moe":
        stack = (cfg.n_layers,)
        layers = {
            "attn_norm": ParamDef(stack + (d,), ("layers", None), "ones"),
            "attn": _attn_defs(cfg, stack),
            "mlp_norm": ParamDef(stack + (d,), ("layers", None), "ones"),
            "moe": _moe_defs(cfg, stack),
        }
    elif cfg.family == "hybrid_ssm":
        ng, period, tail = _pattern(cfg)
        layers = {"mamba_main": _mamba_defs(cfg, (ng, period))}
        if tail:
            layers["mamba_tail"] = _mamba_defs(cfg, (tail,))
        out["shared"] = {
            "attn_norm": ParamDef((d,), (None,), "ones"),
            "attn": _attn_defs(cfg),
            "mlp_norm": ParamDef((d,), (None,), "ones"),
            "mlp": _mlp_defs(cfg),
        }
    elif cfg.family == "xlstm":
        ng, period, tail = _pattern(cfg)
        if cfg.slstm_every:
            layers = {"mlstm_main": _mlstm_defs(cfg, (ng, period - 1)),
                      "slstm": _slstm_defs(cfg, (ng,))}
            if tail:
                layers["mlstm_tail"] = _mlstm_defs(cfg, (tail,))
        else:
            layers = {"mlstm_main": _mlstm_defs(cfg, (cfg.n_layers, 1))}
    else:
        raise ValueError(cfg.family)
    out["layers"] = layers
    return out


# ---------------------------------------------------------------------------
# Blocks (forward)
# ---------------------------------------------------------------------------

def _dense_block(cfg, p, x, positions, aux, rules=None):
    h = common.rmsnorm(x, p["attn_norm"], cfg.norm_eps)
    x = x + common.attention(cfg, p["attn"], h, positions,
                             impl=cfg.attn_impl, q_block=cfg.q_block)
    h = common.rmsnorm(x, p["mlp_norm"], cfg.norm_eps)
    if cfg.is_moe:
        y, a = common.moe_ffn(cfg, p["moe"], h, rules)
        aux = aux + a
    else:
        y = common.swiglu(p["mlp"], h)
    return x + y, aux


def _mamba_block(cfg, p, x):
    h = common.rmsnorm(x, p["norm"], cfg.norm_eps)
    return x + ssm.ssd_forward(cfg, p, h)


def _shared_attn_block(cfg, p, x, positions):
    h = common.rmsnorm(x, p["attn_norm"], cfg.norm_eps)
    x = x + common.attention(cfg, p["attn"], h, positions,
                             impl=cfg.attn_impl, q_block=cfg.q_block)
    h = common.rmsnorm(x, p["mlp_norm"], cfg.norm_eps)
    return x + common.swiglu(p["mlp"], h)


def _mlstm_block(cfg, p, x):
    h = common.rmsnorm(x, p["norm"], cfg.norm_eps)
    return x + xlstm.mlstm_forward(cfg, p, h)


def _slstm_block(cfg, p, x):
    h = common.rmsnorm(x, p["norm"], cfg.norm_eps)
    return x + xlstm.slstm_forward(cfg, p, h)


def _maybe_remat(cfg, fn):
    return jax.remat(fn) if cfg.remat == "block" else fn


def _scan_blocks(cfg, body, x, stacked, *closure):
    """scan (or unrolled loop) of ``body(x, slice) -> x`` over stacked
    params. ``closure`` is threaded untouched."""
    wrapped = _maybe_remat(cfg, lambda xx, sl: body(xx, sl, *closure))
    n = jax.tree.leaves(stacked)[0].shape[0]
    if cfg.scan_layers:
        def sbody(carry, sl):
            return wrapped(carry, sl), None
        x, _ = jax.lax.scan(sbody, x, stacked)
        return x
    for i in range(n):
        x = wrapped(x, jax.tree.map(lambda a: a[i], stacked))
    return x


def _scan_blocks_aux(cfg, body, x, aux, stacked, *closure):
    """Like _scan_blocks but with an (x, aux) carry (MoE aux losses)."""
    wrapped = _maybe_remat(cfg, lambda xx, a, sl: body(xx, sl, a, *closure))
    n = jax.tree.leaves(stacked)[0].shape[0]
    if cfg.scan_layers:
        def sbody(carry, sl):
            xx, a = carry
            xx, a = wrapped(xx, a, sl)
            return (xx, a), None
        (x, aux), _ = jax.lax.scan(sbody, (x, aux), stacked)
        return x, aux
    for i in range(n):
        x, aux = wrapped(x, aux, jax.tree.map(lambda a: a[i], stacked))
    return x, aux


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def embed_tokens(cfg: ModelConfig, params, tokens, patches=None,
                 compute_dtype=jnp.bfloat16):
    """tokens (B,St) [+ patches (B,Fl,frontend_dim)] -> x (B,S,D)."""
    emb = params["embed"].astype(compute_dtype)
    x = emb[tokens]
    if cfg.frontend == "patch":
        assert patches is not None
        pe = jnp.einsum("bpf,fd->bpd", patches.astype(compute_dtype),
                        params["frontend_adapter"].astype(compute_dtype))
        x = jnp.concatenate([pe, x], axis=1)
    return x


def lm_logits(cfg: ModelConfig, params, x):
    x = common.rmsnorm(x, params["out_norm"], cfg.norm_eps)
    pref = jnp.float32 if cfg.logits_fp32 else x.dtype
    if cfg.tie_embeddings:
        # contract against embed directly — `.T` materialises a transposed
        # copy of the full table (§Perf iteration D2)
        w = params["embed"].astype(x.dtype)
        return jnp.einsum("bsd,vd->bsv", x, w, preferred_element_type=pref)
    w = params["lm_head"].astype(x.dtype)
    return jnp.einsum("bsd,dv->bsv", x, w, preferred_element_type=pref)


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def forward(cfg: ModelConfig, params, tokens, patches=None,
            positions=None, rules=None):
    """Full-sequence forward -> (logits (B,S,V), aux_loss scalar)."""
    compute = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x = embed_tokens(cfg, params, tokens, patches, compute)
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s, dtype=jnp.int32)
    if rules is not None:
        x = rules.constrain(x, "batch", None, None)
    aux = jnp.zeros((), jnp.float32)
    lp = params["layers"]
    if cfg.family in ("dense", "moe"):
        x, aux = _scan_blocks_aux(cfg, _dense_block_scan, x, aux,
                                  lp, cfg, positions, rules)
    elif cfg.family == "hybrid_ssm":
        shared = params["shared"]

        def group(xx, sl):
            period = jax.tree.leaves(sl)[0].shape[0]
            for i in range(period):
                xx = _mamba_block(cfg, jax.tree.map(lambda a: a[i], sl), xx)
            return _shared_attn_block(cfg, shared, xx, positions)

        x = _scan_blocks(cfg, lambda xx, sl: group(xx, sl), x,
                         lp["mamba_main"])
        if "mamba_tail" in lp:
            x = _scan_blocks(cfg, lambda xx, sl: _mamba_block(cfg, sl, xx),
                             x, lp["mamba_tail"])
    elif cfg.family == "xlstm":
        def group(xx, sl):
            msl = sl["m"]
            nm = jax.tree.leaves(msl)[0].shape[0]
            for i in range(nm):
                xx = _mlstm_block(cfg, jax.tree.map(lambda a: a[i], msl), xx)
            if "s" in sl:
                xx = _slstm_block(cfg, sl["s"], xx)
            return xx

        stacked = {"m": lp["mlstm_main"]}
        if "slstm" in lp:
            stacked["s"] = lp["slstm"]
        x = _scan_blocks(cfg, group, x, stacked)
        if "mlstm_tail" in lp:
            x = _scan_blocks(cfg, lambda xx, sl: _mlstm_block(cfg, sl, xx),
                             x, lp["mlstm_tail"])
    else:
        raise ValueError(cfg.family)
    if rules is not None:
        x = rules.constrain(x, "batch", None, None)
    return lm_logits(cfg, params, x), aux


def _dense_block_scan(x, sl, aux, cfg, positions, rules=None):
    return _dense_block(cfg, sl, x, positions, aux, rules)


def loss_fn(cfg: ModelConfig, params, batch, rules=None):
    """Next-token cross entropy; label -100 is ignored."""
    logits, aux = forward(cfg, params, batch["tokens"],
                          batch.get("patches"), rules=rules)
    labels = batch["labels"]
    if cfg.frontend == "patch":   # patch positions carry no labels
        pad = jnp.full((labels.shape[0], cfg.frontend_len), -100,
                       labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    valid = labels >= 0
    safe = jnp.maximum(labels, 0)
    lp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(lp, safe[..., None], axis=-1)[..., 0]
    loss = jnp.sum(nll * valid) / jnp.maximum(valid.sum(), 1)
    return loss + cfg.router_aux_weight * aux, {"nll": loss, "aux": aux}


# ---------------------------------------------------------------------------
# Decode cache
# ---------------------------------------------------------------------------

class _L:
    """Cache-leaf declaration: (shape, dtype, fill, logical axes)."""
    def __init__(self, shape, dtype, fill, axes):
        self.shape, self.dtype, self.fill, self.axes = shape, dtype, fill, axes


def cache_len(cfg: ModelConfig, max_len: int) -> int:
    return min(max_len, cfg.window) if cfg.window else max_len


def cache_defs(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> dict:
    """Declaration tree of the decode cache. ``pos`` = tokens consumed.
    From this single table we derive init (zeros/fills), shardings, and
    dry-run ShapeDtypeStructs."""
    sc = cache_len(cfg, max_len)
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    long_ctx = batch == 1
    seq_ax = "long_seq" if long_ctx else "kv_seq"
    c: dict[str, Any] = {"pos": _L((), jnp.int32, 0, ())}

    def kvcache(lead):
        la = (None,) * len(lead)
        return {
            "k": _L(lead + (batch, sc, kv, hd), dtype, 0,
                    la + ("batch", seq_ax, "kv_heads", None)),
            "v": _L(lead + (batch, sc, kv, hd), dtype, 0,
                    la + ("batch", seq_ax, "kv_heads", None)),
        }

    if cfg.family in ("dense", "moe"):
        c.update(kvcache((cfg.n_layers,)))
        c["slot_pos"] = _L((sc,), jnp.int32, -1, (None,))
    elif cfg.family == "hybrid_ssm":
        ng, period, tail = _pattern(cfg)
        h, hp, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        di, w = cfg.d_inner, cfg.conv_width
        c["ssm_main"] = _L((ng, period, batch, h, hp, n), jnp.float32, 0,
                           (None, None, "batch", "ssm_heads", None, None))
        c["conv_main"] = _L((ng, period, batch, w - 1, di + 2 * n), dtype, 0,
                            (None, None, "batch", None, "ssm_inner"))
        if tail:
            c["ssm_tail"] = _L((tail, batch, h, hp, n), jnp.float32, 0,
                               (None, "batch", "ssm_heads", None, None))
            c["conv_tail"] = _L((tail, batch, w - 1, di + 2 * n), dtype, 0,
                                (None, "batch", None, "ssm_inner"))
        c.update(kvcache((ng,)))
        c["slot_pos"] = _L((sc,), jnp.int32, -1, (None,))
    elif cfg.family == "xlstm":
        ng, period, tail = _pattern(cfg)
        h = cfg.n_heads
        dm = int(cfg.d_model * cfg.mlstm_proj)
        hp = dm // h
        hps = cfg.d_model // h

        def mstate(lead):
            la = (None,) * len(lead)
            return {
                "c": _L(lead + (batch, h, hp, hp), jnp.float32, 0,
                        la + ("batch", "heads", None, None)),
                "n": _L(lead + (batch, h, hp), jnp.float32, 0,
                        la + ("batch", "heads", None)),
                "m": _L(lead + (batch, h), jnp.float32, -1e30,
                        la + ("batch", "heads")),
            }

        if cfg.slstm_every:
            c["mlstm_main"] = mstate((ng, period - 1))
            c["slstm"] = {
                "c": _L((ng, batch, h, hps), jnp.float32, 0,
                        (None, "batch", "heads", None)),
                "n": _L((ng, batch, h, hps), jnp.float32, 0,
                        (None, "batch", "heads", None)),
                "h": _L((ng, batch, cfg.d_model), dtype, 0,
                        (None, "batch", None)),
                "m": _L((ng, batch, h), jnp.float32, -1e30,
                        (None, "batch", "heads")),
            }
            if tail:
                c["mlstm_tail"] = mstate((tail,))
        else:
            c["mlstm_main"] = mstate((cfg.n_layers, 1))
    else:
        raise ValueError(cfg.family)
    return c


def _map_cache(fn, defs):
    if isinstance(defs, _L):
        return fn(defs)
    return {k: _map_cache(fn, v) for k, v in defs.items()}


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, rules=None):
    defs = cache_defs(cfg, batch, max_len, dtype)

    def make(l: _L):
        arr = jnp.full(l.shape, l.fill, l.dtype)
        if rules is not None and l.axes:
            arr = rules.constrain(arr, *l.axes)
        return arr

    return _map_cache(make, defs)


def cache_structs(cfg: ModelConfig, batch: int, max_len: int,
                  rules, dtype=jnp.bfloat16):
    """Sharded ShapeDtypeStructs of the cache (dry-run inputs)."""
    defs = cache_defs(cfg, batch, max_len, dtype)
    return _map_cache(
        lambda l: jax.ShapeDtypeStruct(
            l.shape, l.dtype, sharding=rules.sharding(l.axes, l.shape)),
        defs)


# ---------------------------------------------------------------------------
# Decode step
# ---------------------------------------------------------------------------

def _attn_block_decode(cfg, p, x, kc, vc, slot_pos, pos, rules=None):
    h = common.rmsnorm(x, p["attn_norm"], cfg.norm_eps)
    y, kc, vc, slot_pos = common.attention_decode(
        cfg, p["attn"], h, kc, vc, slot_pos, pos, rules)
    x = x + y
    h = common.rmsnorm(x, p["mlp_norm"], cfg.norm_eps)
    if cfg.is_moe:
        y, _ = common.moe_ffn(cfg, p["moe"], h)
    else:
        y = common.swiglu(p["mlp"], h)
    return x + y, kc, vc, slot_pos


def decode_step(cfg: ModelConfig, params, cache, tokens, rules=None):
    """One decode step for all sequences. tokens (B,) int32.
    Returns (new_cache, logits (B, V))."""
    compute = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x = params["embed"].astype(compute)[tokens][:, None]      # (B,1,D)
    pos = cache["pos"]
    lp = params["layers"]
    new = dict(cache)
    if cfg.family in ("dense", "moe"):
        slot_pos = cache["slot_pos"]

        def body(carry, sl):
            xx, sp = carry
            p, kc, vc = sl
            xx, kc, vc, sp = _attn_block_decode(cfg, p, xx, kc, vc, sp, pos,
                                                rules)
            return (xx, sp), (kc, vc)

        if cfg.scan_layers:
            (x, slot_pos), (ks, vs) = jax.lax.scan(
                body, (x, slot_pos), (lp, cache["k"], cache["v"]))
        else:
            ks, vs = [], []
            for i in range(cfg.n_layers):
                sl = (jax.tree.map(lambda a: a[i], lp),
                      cache["k"][i], cache["v"][i])
                (x, slot_pos), (kc, vc) = body((x, slot_pos), sl)
                ks.append(kc)
                vs.append(vc)
            ks, vs = jnp.stack(ks), jnp.stack(vs)
        new.update(k=ks, v=vs, slot_pos=slot_pos)
    elif cfg.family == "hybrid_ssm":
        shared = params["shared"]
        slot_pos = cache["slot_pos"]

        def group(carry, sl):
            xx, sp = carry
            p, sstate, cstate, kc, vc = sl
            period = jax.tree.leaves(p)[0].shape[0]
            s_out, c_out = [], []
            for i in range(period):
                pi = jax.tree.map(lambda a: a[i], p)
                h = common.rmsnorm(xx, pi["norm"], cfg.norm_eps)
                y, s_new, c_new = ssm.ssd_decode(cfg, pi, h, sstate[i],
                                                 cstate[i])
                xx = xx + y
                s_out.append(s_new)
                c_out.append(c_new)
            h = common.rmsnorm(xx, shared["attn_norm"], cfg.norm_eps)
            y, kc, vc, sp = common.attention_decode(
                cfg, shared["attn"], h, kc, vc, sp, pos, rules)
            xx = xx + y
            h = common.rmsnorm(xx, shared["mlp_norm"], cfg.norm_eps)
            xx = xx + common.swiglu(shared["mlp"], h)
            return (xx, sp), (jnp.stack(s_out), jnp.stack(c_out), kc, vc)

        xs = (lp["mamba_main"], cache["ssm_main"], cache["conv_main"],
              cache["k"], cache["v"])
        if cfg.scan_layers:
            (x, slot_pos), (sm, cm, ks, vs) = jax.lax.scan(
                group, (x, slot_pos), xs)
        else:
            outs = []
            ng = jax.tree.leaves(lp["mamba_main"])[0].shape[0]
            for i in range(ng):
                sl = jax.tree.map(lambda a: a[i], xs)
                (x, slot_pos), o = group((x, slot_pos), sl)
                outs.append(o)
            sm, cm, ks, vs = (jnp.stack([o[j] for o in outs])
                              for j in range(4))
        new.update(ssm_main=sm, conv_main=cm, k=ks, v=vs, slot_pos=slot_pos)
        if "mamba_tail" in lp:
            def tail_body(xx, sl):
                p, sstate, cstate = sl
                h = common.rmsnorm(xx, p["norm"], cfg.norm_eps)
                y, s_new, c_new = ssm.ssd_decode(cfg, p, h, sstate, cstate)
                return xx + y, (s_new, c_new)

            xs_t = (lp["mamba_tail"], cache["ssm_tail"], cache["conv_tail"])
            if cfg.scan_layers:
                x, (st, ct) = jax.lax.scan(tail_body, x, xs_t)
            else:
                st, ct = [], []
                nt = jax.tree.leaves(lp["mamba_tail"])[0].shape[0]
                for i in range(nt):
                    x, (s1, c1) = tail_body(
                        x, jax.tree.map(lambda a: a[i], xs_t))
                    st.append(s1)
                    ct.append(c1)
                st, ct = jnp.stack(st), jnp.stack(ct)
            new.update(ssm_tail=st, conv_tail=ct)
    elif cfg.family == "xlstm":
        def mblock(xx, p, st):
            h = common.rmsnorm(xx, p["norm"], cfg.norm_eps)
            y, c, n, m = xlstm.mlstm_decode(cfg, p, h, st["c"], st["n"],
                                            st["m"])
            return xx + y, {"c": c, "n": n, "m": m}

        def group(xx, sl):
            p, st = sl["m"]
            nm = jax.tree.leaves(p)[0].shape[0]
            sts = []
            for i in range(nm):
                xx, s1 = mblock(xx, jax.tree.map(lambda a: a[i], p),
                                jax.tree.map(lambda a: a[i], st))
                sts.append(s1)
            out = {"m": jax.tree.map(lambda *a: jnp.stack(a), *sts)}
            if "s" in sl:
                ps, ss = sl["s"]
                h = common.rmsnorm(xx, ps["norm"], cfg.norm_eps)
                y, (c, n, hs, m) = xlstm.slstm_decode(
                    cfg, ps, h, (ss["c"], ss["n"], ss["h"], ss["m"]))
                xx = xx + y
                out["s"] = {"c": c, "n": n, "h": hs, "m": m}
            return xx, out

        xs = {"m": (lp["mlstm_main"], cache["mlstm_main"])}
        if "slstm" in lp:
            xs["s"] = (lp["slstm"], cache["slstm"])
        if cfg.scan_layers:
            def sbody(xx, sl):
                return group(xx, sl)
            x, outs = jax.lax.scan(sbody, x, xs)
        else:
            ng = jax.tree.leaves(lp["mlstm_main"])[0].shape[0]
            acc = []
            for i in range(ng):
                x, o = group(x, jax.tree.map(lambda a: a[i], xs))
                acc.append(o)
            outs = jax.tree.map(lambda *a: jnp.stack(a), *acc)
        new["mlstm_main"] = outs["m"]
        if "slstm" in lp:
            new["slstm"] = outs["s"]
        if "mlstm_tail" in lp:
            p, st = lp["mlstm_tail"], cache["mlstm_tail"]
            if cfg.scan_layers:
                def tbody(xx, sl):
                    pp, ss = sl
                    xx, s1 = mblock(xx, pp, ss)
                    return xx, s1
                x, st_new = jax.lax.scan(tbody, x, (p, st))
            else:
                sts = []
                nt = jax.tree.leaves(p)[0].shape[0]
                for i in range(nt):
                    x, s1 = mblock(x, jax.tree.map(lambda a: a[i], p),
                                   jax.tree.map(lambda a: a[i], st))
                    sts.append(s1)
                st_new = jax.tree.map(lambda *a: jnp.stack(a), *sts)
            new["mlstm_tail"] = st_new
    else:
        raise ValueError(cfg.family)
    new["pos"] = pos + 1
    logits = lm_logits(cfg, params, x)[:, 0]
    return new, logits


# ---------------------------------------------------------------------------
# Batched prefill (build the cache from one full forward pass)
# ---------------------------------------------------------------------------

def _ring_pack(full, sc: int, s: int):
    """Pack per-position k/v (B,S,...) into a ring cache (B,sc,...):
    slot i holds the largest pos < s with pos ≡ i (mod sc); -1 = empty."""
    slots = jnp.arange(sc)
    pos = slots + ((s - 1 - slots) // sc) * sc             # (sc,)
    valid = pos >= 0
    packed = jnp.take(full, jnp.maximum(pos, 0), axis=1)
    packed = jnp.where(valid[None, :, None, None], packed, 0)
    return packed, jnp.where(valid, pos, -1).astype(jnp.int32)


def prefill(cfg: ModelConfig, params, tokens, max_len: int,
            patches=None, rules=None):
    """Batched prefill: one full forward that also populates the decode
    cache (KV rings / SSM states / LSTM states). Returns (cache, logits of
    the last position (B, V))."""
    compute = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x = embed_tokens(cfg, params, tokens, patches, compute)
    b, s, _ = x.shape
    positions = jnp.arange(s, dtype=jnp.int32)
    if rules is not None:
        x = rules.constrain(x, "batch", None, None)
    sc = cache_len(cfg, max_len)
    lp = params["layers"]
    new: dict[str, Any] = {"pos": jnp.asarray(s, jnp.int32)}

    def attn_with_cache(p, xx):
        """Attention block that also returns the packed KV ring."""
        h = common.rmsnorm(xx, p["attn_norm"], cfg.norm_eps)
        q, k, v = common._qkv(cfg, p["attn"], h, positions)
        kr, slot_pos = _ring_pack(k, sc, s)
        vr, _ = _ring_pack(v, sc, s)
        group = cfg.n_heads // cfg.n_kv_heads
        kk = jnp.repeat(k, group, axis=2)
        vv = jnp.repeat(v, group, axis=2)
        scale = cfg.head_dim ** -0.5
        if cfg.attn_impl == "blocked" and s > cfg.q_block:
            o = common.blocked_sdpa(q, kk, vv, positions, cfg.window, scale,
                                    cfg.q_block)
        else:
            mask = common._mask(positions[None], positions[None], cfg.window)
            o = common._sdpa(q, kk, vv, mask, scale)
        o = o.reshape(b, s, cfg.n_heads * cfg.head_dim)
        y = jnp.einsum("bse,ed->bsd", o,
                       p["attn"]["wo"].astype(xx.dtype).reshape(-1, xx.shape[-1]))
        return xx + y, kr.astype(compute), vr.astype(compute), slot_pos

    if cfg.family in ("dense", "moe"):
        def body(xx, p):
            xx, kr, vr, slot_pos = attn_with_cache(p, xx)
            h = common.rmsnorm(xx, p["mlp_norm"], cfg.norm_eps)
            if cfg.is_moe:
                y, _ = common.moe_ffn(cfg, p["moe"], h, rules)
            else:
                y = common.swiglu(p["mlp"], h)
            return xx + y, (kr, vr, slot_pos)

        wrapped = _maybe_remat(cfg, body)
        if cfg.scan_layers:
            x, (ks, vs, sps) = jax.lax.scan(
                lambda c, sl: wrapped(c, sl), x, lp)
        else:
            ks, vs, sps = [], [], []
            n = cfg.n_layers
            for i in range(n):
                x, (kr, vr, sp) = wrapped(
                    x, jax.tree.map(lambda a: a[i], lp))
                ks.append(kr)
                vs.append(vr)
                sps.append(sp)
            ks, vs, sps = jnp.stack(ks), jnp.stack(vs), jnp.stack(sps)
        new.update(k=ks, v=vs, slot_pos=sps[0] if sps.ndim > 1 else sps)
    elif cfg.family == "hybrid_ssm":
        shared = params["shared"]

        def group(xx, sl):
            period = jax.tree.leaves(sl)[0].shape[0]
            s_out, c_out = [], []
            for i in range(period):
                pi = jax.tree.map(lambda a: a[i], sl)
                h = common.rmsnorm(xx, pi["norm"], cfg.norm_eps)
                y, st, cst = ssm.ssd_forward(cfg, pi, h, return_state=True)
                xx = xx + y
                s_out.append(st)
                c_out.append(cst)
            p2 = {"attn_norm": shared["attn_norm"], "attn": shared["attn"]}
            xx, kr, vr, slot_pos = attn_with_cache(
                {**p2, "mlp_norm": shared["mlp_norm"]}, xx)
            h = common.rmsnorm(xx, shared["mlp_norm"], cfg.norm_eps)
            xx = xx + common.swiglu(shared["mlp"], h)
            return xx, (jnp.stack(s_out), jnp.stack(c_out), kr, vr, slot_pos)

        wrapped = _maybe_remat(cfg, group)
        if cfg.scan_layers:
            x, (sm, cm, ks, vs, sps) = jax.lax.scan(
                lambda c, sl: wrapped(c, sl), x, lp["mamba_main"])
        else:
            accs = []
            ng = jax.tree.leaves(lp["mamba_main"])[0].shape[0]
            for i in range(ng):
                x, o = wrapped(x, jax.tree.map(lambda a: a[i],
                                               lp["mamba_main"]))
                accs.append(o)
            sm, cm, ks, vs, sps = (jnp.stack([a[j] for a in accs])
                                   for j in range(5))
        new.update(ssm_main=sm, conv_main=cm, k=ks, v=vs,
                   slot_pos=sps[0] if sps.ndim > 1 else sps)
        if "mamba_tail" in lp:
            def tail_body(xx, p):
                h = common.rmsnorm(xx, p["norm"], cfg.norm_eps)
                y, st, cst = ssm.ssd_forward(cfg, p, h, return_state=True)
                return xx + y, (st, cst)

            wrapped_t = _maybe_remat(cfg, tail_body)
            if cfg.scan_layers:
                x, (st, ct) = jax.lax.scan(lambda c, sl: wrapped_t(c, sl),
                                           x, lp["mamba_tail"])
            else:
                st, ct = [], []
                nt = jax.tree.leaves(lp["mamba_tail"])[0].shape[0]
                for i in range(nt):
                    x, (s1, c1) = wrapped_t(
                        x, jax.tree.map(lambda a: a[i], lp["mamba_tail"]))
                    st.append(s1)
                    ct.append(c1)
                st, ct = jnp.stack(st), jnp.stack(ct)
            new.update(ssm_tail=st, conv_tail=ct)
    elif cfg.family == "xlstm":
        def mblock_state(xx, p):
            h = common.rmsnorm(xx, p["norm"], cfg.norm_eps)
            y, c, n, m = xlstm.mlstm_forward(cfg, p, h, return_state=True)
            return xx + y, {"c": c, "n": n, "m": m}

        def group(xx, sl):
            msl = sl["m"]
            nm = jax.tree.leaves(msl)[0].shape[0]
            sts = []
            for i in range(nm):
                xx, s1 = mblock_state(xx, jax.tree.map(lambda a: a[i], msl))
                sts.append(s1)
            out = {"m": jax.tree.map(lambda *a: jnp.stack(a), *sts)}
            if "s" in sl:
                ps = sl["s"]
                h = common.rmsnorm(xx, ps["norm"], cfg.norm_eps)
                y, (c, n, hs, m) = xlstm.slstm_forward(cfg, ps, h,
                                                       return_state=True)
                xx = xx + y
                out["s"] = {"c": c, "n": n, "h": hs, "m": m}
            return xx, out

        stacked = {"m": lp["mlstm_main"]}
        if "slstm" in lp:
            stacked["s"] = lp["slstm"]
        wrapped = _maybe_remat(cfg, group)
        if cfg.scan_layers:
            x, outs = jax.lax.scan(lambda c, sl: wrapped(c, sl), x, stacked)
        else:
            acc = []
            ng = jax.tree.leaves(lp["mlstm_main"])[0].shape[0]
            for i in range(ng):
                x, o = wrapped(x, jax.tree.map(lambda a: a[i], stacked))
                acc.append(o)
            outs = jax.tree.map(lambda *a: jnp.stack(a), *acc)
        new["mlstm_main"] = outs["m"]
        if "slstm" in lp:
            new["slstm"] = outs["s"]
        if "mlstm_tail" in lp:
            wrapped_t = _maybe_remat(cfg, mblock_state)
            if cfg.scan_layers:
                x, st_new = jax.lax.scan(lambda c, sl: wrapped_t(c, sl),
                                         x, lp["mlstm_tail"])
            else:
                sts = []
                nt = jax.tree.leaves(lp["mlstm_tail"])[0].shape[0]
                for i in range(nt):
                    x, s1 = wrapped_t(
                        x, jax.tree.map(lambda a: a[i], lp["mlstm_tail"]))
                    sts.append(s1)
                st_new = jax.tree.map(lambda *a: jnp.stack(a), *sts)
            new["mlstm_tail"] = st_new
    else:
        raise ValueError(cfg.family)
    logits = lm_logits(cfg, params, x[:, -1:])[:, 0]
    return new, logits
