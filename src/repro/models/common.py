"""Shared model primitives: norms, RoPE, attention (train/prefill/decode),
SwiGLU MLP, and the capacity-dispatch MoE layer.

All functions are pure; parameters are plain dicts produced by
``params.init_params`` from the family's ``param_defs`` table. The MoE
dispatch is the same fixed-capacity sort-and-route pattern as the
triclustering shuffle engine (core/distributed.py) — the paper's M/R
shuffle and GShard-style expert dispatch are one mechanism (DESIGN.md §3).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .._compat import shard_map as _shard_map
from ..configs.base import ModelConfig
from ..kernels import ops

_NEG = -1e30


# ---------------------------------------------------------------------------
# Norms / RoPE
# ---------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5,
            use_pallas: bool = False) -> jnp.ndarray:
    if use_pallas:
        return ops.rmsnorm(x, scale, eps)
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)


def rope_tables(positions: jnp.ndarray, head_dim: int,
                theta: float) -> tuple[jnp.ndarray, jnp.ndarray]:
    """cos/sin tables (..., head_dim/2) for integer positions."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray,
               sin: jnp.ndarray) -> jnp.ndarray:
    """x (..., S, H, hd); cos/sin (..., S, hd/2) — llama half-rotation."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c, s = cos[..., None, :], sin[..., None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def _qkv(cfg: ModelConfig, p: dict, x: jnp.ndarray,
         positions: jnp.ndarray):
    """Project + (optional) per-head QK-norm + RoPE.
    x (B,S,D) -> q (B,S,H,hd), k/v (B,S,KV,hd)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    cos, sin = rope_tables(positions, cfg.head_dim, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    return q, k, v


def _mask(q_pos, k_pos, window: Optional[int]) -> jnp.ndarray:
    """(..., Sq, Sk) causal/window mask from position arrays."""
    m = k_pos[..., None, :] <= q_pos[..., :, None]
    if window is not None:
        m &= k_pos[..., None, :] > q_pos[..., :, None] - window
    return m


def _sdpa(q, k, v, mask, scale: float) -> jnp.ndarray:
    """q (B,Sq,H,hd), k/v (B,Sk,H,hd), mask (B or 1, Sq, Sk).
    bf16 operands with fp32 MXU accumulation (`preferred_element_type`) —
    casting operands to fp32 materialises full-size fp32 copies of K/V
    (§Perf iteration D2)."""
    s = jnp.einsum("bqhk,bthk->bhqt", q, k,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask[:, None], s, _NEG)
    a = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqt,bthk->bqhk", a.astype(v.dtype), v,
                      preferred_element_type=jnp.float32).astype(q.dtype)


def blocked_sdpa(q, k, v, positions, window, scale: float,
                 q_block: int) -> jnp.ndarray:
    """Tiled attention: ``lax.scan`` over q blocks. The scan carry
    serialises the blocks, so peak live scores = ONE (B,H,q_block,S)
    tile; a python loop would let XLA schedule all blocks concurrently
    and the peak becomes S/q_block tiles (§Perf iteration P2). q/k/v are
    (B,S,H,hd) with H already GQA-expanded."""
    b, s = q.shape[0], q.shape[1]
    nb = s // q_block

    def qblock(_, i):
        qi = jax.lax.dynamic_slice_in_dim(q, i * q_block, q_block, 1)
        pi = jax.lax.dynamic_slice_in_dim(positions, i * q_block,
                                          q_block, 0)
        mask = _mask(pi[None], positions[None], window)
        return 0, _sdpa(qi, k, v, mask, scale)

    _, o = jax.lax.scan(qblock, 0, jnp.arange(nb, dtype=jnp.int32))
    o = jnp.moveaxis(o, 0, 1).reshape(b, nb * q_block, *o.shape[3:])
    if nb * q_block < s:                 # ragged tail
        qi = jax.lax.dynamic_slice_in_dim(q, nb * q_block,
                                          s - nb * q_block, 1)
        pi = positions[nb * q_block:]
        mask = _mask(pi[None], positions[None], window)
        o = jnp.concatenate([o, _sdpa(qi, k, v, mask, scale)], 1)
    return o


def attention(cfg: ModelConfig, p: dict, x: jnp.ndarray,
              positions: jnp.ndarray, *, impl: str = "einsum",
              q_block: int = 2048) -> jnp.ndarray:
    """Full-sequence causal/SWA GQA attention (train / prefill).

    impl:
      einsum  — materialised (B,H,S,S) scores (baseline; memory-bound at
                32k — see EXPERIMENTS.md §Perf).
      blocked — statically unrolled q-blocks, peak scores (B,H,q_block,S).
      pallas  — kernels/flash_attention (TPU runtime path; opaque to
                cost_analysis, so analysis runs use einsum/blocked).
    """
    b, s, d = x.shape
    q, k, v = _qkv(cfg, p, x, positions)
    scale = cfg.head_dim ** -0.5
    group = cfg.n_heads // cfg.n_kv_heads
    if impl == "pallas":
        o = ops.flash_attention(q.transpose(0, 2, 1, 3),
                                k.transpose(0, 2, 1, 3),
                                v.transpose(0, 2, 1, 3),
                                causal=True, window=cfg.window, scale=scale)
        o = o.transpose(0, 2, 1, 3)
    else:
        k = jnp.repeat(k, group, axis=2)   # GQA expand (KV replication, §6)
        v = jnp.repeat(v, group, axis=2)
        if impl == "einsum" or s <= q_block:
            mask = _mask(positions[None], positions[None], cfg.window)
            o = _sdpa(q, k, v, mask, scale)
        elif impl == "blocked":
            o = blocked_sdpa(q, k, v, positions, cfg.window, scale, q_block)
        else:
            raise ValueError(impl)
    o = o.reshape(b, s, cfg.n_heads * cfg.head_dim)
    return jnp.einsum("bse,ed->bsd", o, p["wo"].astype(x.dtype)
                      .reshape(-1, d))


def attention_decode(cfg: ModelConfig, p: dict, x: jnp.ndarray,
                     k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     slot_pos: jnp.ndarray, pos: jnp.ndarray, rules=None):
    """One-token decode with ring-buffer KV cache — *sequence-parallel*.

    x (B,1,D); k_cache/v_cache (B,Sc,KV,hd); slot_pos (Sc,) stored position
    per slot (-1 = empty); pos scalar int32 = current absolute position.
    Returns (out (B,1,D), k_cache', v_cache', slot_pos').

    GQA is computed *without* materialising the head-repeated cache: the
    query is reshaped to (B,1,KV,G,hd) and contracted against the cache
    directly. This keeps the cache in its (batch, kv_seq)-sharded layout —
    the repeat-to-H formulation made GSPMD reshard the whole cache to a
    head-sharded layout every step (an involuntary full rematerialisation,
    §Perf iteration D1). Scores are pinned to kv_seq sharding, so decode
    runs as split-KV flash-decode: local partial scores per seq shard, two
    tiny cross-shard reductions (softmax max/sum), one psum for the values.
    """
    b = x.shape[0]
    kv, group = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    q, k, v = _qkv(cfg, p, x, pos[None])
    sc = k_cache.shape[1]
    slot = (pos % sc).astype(jnp.int32)
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k.astype(k_cache.dtype), slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v.astype(v_cache.dtype), slot, axis=1)
    slot_pos = jax.lax.dynamic_update_slice_in_dim(
        slot_pos, pos[None].astype(slot_pos.dtype), slot, axis=0)
    scale = cfg.head_dim ** -0.5
    q5 = q.reshape(b, 1, kv, group, cfg.head_dim).astype(k_cache.dtype)
    s = jnp.einsum("bqkgh,btkh->bkgqt", q5, k_cache,
                   preferred_element_type=jnp.float32) * scale  # (B,KV,G,1,Sc)
    seq_ax = "long_seq" if b == 1 else "kv_seq"
    if rules is not None:
        s = rules.constrain(s, "batch", None, None, None, seq_ax)
    valid = (slot_pos >= 0) & (slot_pos <= pos)
    if cfg.window is not None:
        valid &= slot_pos > pos - cfg.window
    s = jnp.where(valid[None, None, None, None, :], s, _NEG)
    a = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqt,btkh->bqkgh", a.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32).astype(x.dtype)
    o = o.reshape(b, 1, cfg.n_heads * cfg.head_dim)
    out = jnp.einsum("bse,ed->bsd", o,
                     p["wo"].astype(x.dtype).reshape(-1, x.shape[-1]))
    return out, k_cache, v_cache, slot_pos


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u,
                      p["w_down"].astype(x.dtype))


# ---------------------------------------------------------------------------
# MoE (fixed-capacity sort-and-dispatch; per-sequence capacity)
# ---------------------------------------------------------------------------

def _dispatch_row(x_row, eid, tok, w, n_experts: int, cap: int):
    """One sequence: route S·k (token, expert) slots into (E, cap) buffers.
    Same fixed-capacity pattern as core.distributed._dispatch."""
    l = eid.shape[0]
    order = jnp.argsort(eid, stable=True)
    sorted_eid = eid[order]
    rank = (jnp.arange(l) - jnp.searchsorted(sorted_eid, sorted_eid,
                                             side="left")).astype(jnp.int32)
    ok = rank < cap
    slot = jnp.where(ok, sorted_eid * cap + rank, n_experts * cap)
    buf = jnp.zeros((n_experts * cap + 1, x_row.shape[-1]), x_row.dtype)
    buf = buf.at[slot].set(x_row[tok[order]])[:-1]
    return buf, slot, order, ok


def _moe_dispatch_ffn(cfg: ModelConfig, p: dict, x, top_e, top_w,
                      model_axes: tuple):
    """Local (per-shard) dispatch → expert SwiGLU → combine. Called either
    directly (GSPMD path) or inside shard_map with x batch-LOCAL; under
    shard_map ``model_axes`` carries the TP axis for the w_down psum."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = int(math.ceil(s * k / e * cfg.capacity_factor))
    eid = top_e.reshape(b, s * k)
    tok = jnp.broadcast_to(jnp.arange(s)[:, None], (s, k)).reshape(-1)
    tok = jnp.broadcast_to(tok, (b, s * k))
    w = top_w.reshape(b, s * k)

    buf, slot, order, ok = jax.vmap(
        lambda xr, er, tr, wr: _dispatch_row(xr, er, tr, wr, e, cap)
    )(x, eid, tok, w)
    buf = buf.reshape(b, e, cap, d)
    g = jnp.einsum("becd,edf->becf", buf, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("becd,edf->becf", buf, p["w_up"].astype(x.dtype))
    y_buf = jnp.einsum("becf,efd->becd", jax.nn.silu(g) * u,
                       p["w_down"].astype(x.dtype)).reshape(b, e * cap, d)
    y_buf = jnp.concatenate([y_buf, jnp.zeros((b, 1, d), y_buf.dtype)], 1)

    def combine_row(y_row, slot_r, order_r, ok_r, w_r, tok_r):
        contrib = y_row[slot_r] * jnp.where(
            ok_r, w_r[order_r], 0.0)[:, None].astype(y_row.dtype)
        out = jnp.zeros((s, d), y_row.dtype)
        return out.at[tok_r[order_r]].add(contrib)

    y = jax.vmap(combine_row)(y_buf, slot, order, ok, w, tok)
    for ax in model_axes:   # w_down row-parallel partial sums
        y = jax.lax.psum(y, ax)
    return y


def moe_ffn(cfg: ModelConfig, p: dict, x: jnp.ndarray, rules=None):
    """Top-k MoE with per-sequence capacity. x (B,S,D) -> (y, aux_loss).

    Two dispatch paths (§Perf iteration M1):

    * ``gspmd`` — let the partitioner shard the scatter/gather dispatch.
      GSPMD cannot keep the batch dim sharded through the scatter, so it
      *replicates* the dispatch buffers and every device computes the full
      microbatch's expert FFN: data_shards× redundant FLOPs + the reshard
      collectives (the baseline rows in EXPERIMENTS.md §Perf).
    * ``shard_map`` (default) — dispatch/FFN/combine run *per data shard*
      (the dispatch is per-sequence, so batch-locality is exact), Megatron
      row-parallel over the model axis with one explicit psum of y.

    S == 1 (decode) uses the dense all-expert combine (standard small-batch
    TPU path; the FLOP overcount E/k× is visible in §Roofline).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(x.dtype)
                        ).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                  # (B,S,k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    # load-balancing aux (switch-style)
    sel = jax.nn.one_hot(top_e, e, dtype=jnp.float32).sum(2)  # (B,S,E)
    frac_tokens = sel.mean((0, 1)) / k
    frac_prob = probs.mean((0, 1))
    aux = e * jnp.sum(frac_tokens * frac_prob)

    if (s > 1 and rules is not None and cfg.moe_impl == "shard_map"):
        mesh = rules.mesh
        data_axes = tuple(a for a in ("pod", "data")
                          if a in mesh.axis_names)
        model_axes = tuple(a for a in ("model",) if a in mesh.axis_names)
        if b % max(rules.data_size, 1) == 0:
            P_ = jax.sharding.PartitionSpec
            bspec = (data_axes if len(data_axes) > 1
                     else (data_axes[0] if data_axes else None))
            xs = P_(bspec, None, None)
            ks = P_(bspec, None, None)
            ws = {"w_gate": P_(None, None, "model" if model_axes else None),
                  "w_up": P_(None, None, "model" if model_axes else None),
                  "w_down": P_(None, "model" if model_axes else None, None)}
            pw = {k2: p[k2] for k2 in ws}
            y = _shard_map(
                lambda pw_, x_, te_, tw_: _moe_dispatch_ffn(
                    cfg, pw_, x_, te_, tw_, model_axes),
                mesh=mesh,
                in_specs=(ws, xs, ks, ks),
                out_specs=xs)(pw, x, top_e, top_w.astype(x.dtype))
            return y.astype(x.dtype), aux

    if s == 1:
        # dense all-expert combine
        g = jnp.einsum("bqd,edf->beqf", x, p["w_gate"].astype(x.dtype))
        u = jnp.einsum("bqd,edf->beqf", x, p["w_up"].astype(x.dtype))
        y_all = jnp.einsum("beqf,efd->beqd", jax.nn.silu(g) * u,
                           p["w_down"].astype(x.dtype))
        comb = jnp.zeros((b, e), jnp.float32)
        comb = comb.at[jnp.arange(b)[:, None], top_e[:, 0]].add(top_w[:, 0])
        y = jnp.einsum("beld,be->bld", y_all.astype(jnp.float32), comb)
        return y.astype(x.dtype), aux

    y = _moe_dispatch_ffn(cfg, p, x, top_e, top_w.astype(x.dtype), ())
    return y.astype(x.dtype), aux


def moe_dropped_fraction(cfg: ModelConfig, p: dict, x: jnp.ndarray):
    """Diagnostics: fraction of (token, slot) routes dropped by capacity."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(x.dtype))
    _, top_e = jax.lax.top_k(logits.astype(jnp.float32), k)
    cap = int(math.ceil(s * k / e * cfg.capacity_factor))
    eid = top_e.reshape(b, s * k)
    counts = jax.vmap(lambda r: jnp.bincount(r, length=e))(eid)
    dropped = jnp.maximum(counts - cap, 0).sum()
    return dropped / (b * s * k)
