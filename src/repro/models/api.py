"""Unified model API: one ``Model`` namespace per config, dispatched on
family. Every driver (train, serve, dryrun, tests) goes through this.

  model = get_model(cfg)
  params = model.init(cfg, key)
  loss, metrics = model.loss(cfg, params, batch)
  cache, logits = model.prefill(cfg, params, inputs, max_len)
  cache, logits = model.decode_step(cfg, params, cache, tokens)
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import encdec, lm
from .params import init_params, param_shardings, param_specs, param_structs


class Model(NamedTuple):
    param_defs: Callable
    forward: Callable          # (cfg, params, batch, rules) -> (logits, aux)
    loss: Callable             # (cfg, params, batch, rules) -> (loss, metrics)
    prefill: Callable          # (cfg, params, inputs, max_len, rules) -> (cache, logits)
    decode_step: Callable      # (cfg, params, cache, tokens, rules) -> (cache, logits)
    cache_defs: Callable
    init_cache: Callable
    cache_structs: Callable

    def init(self, cfg: ModelConfig, key, dtype=jnp.float32):
        return init_params(self.param_defs(cfg), key, dtype)

    def shardings(self, cfg: ModelConfig, rules):
        return param_shardings(self.param_defs(cfg), rules)

    def specs(self, cfg: ModelConfig, rules):
        return param_specs(self.param_defs(cfg), rules)

    def structs(self, cfg: ModelConfig, rules=None, dtype=jnp.float32):
        return param_structs(self.param_defs(cfg), rules, dtype)


def _lm_forward(cfg, params, batch, rules=None):
    return lm.forward(cfg, params, batch["tokens"], batch.get("patches"),
                      rules=rules)


def _lm_prefill(cfg, params, inputs, max_len, rules=None):
    return lm.prefill(cfg, params, inputs["tokens"], max_len,
                      inputs.get("patches"), rules=rules)


_LM = Model(
    param_defs=lm.param_defs,
    forward=_lm_forward,
    loss=lm.loss_fn,
    prefill=_lm_prefill,
    decode_step=lm.decode_step,
    cache_defs=lm.cache_defs,
    init_cache=lm.init_cache,
    cache_structs=lm.cache_structs,
)

_ENCDEC = Model(
    param_defs=encdec.param_defs,
    forward=encdec.forward,
    loss=encdec.loss_fn,
    prefill=encdec.prefill,
    decode_step=encdec.decode_step,
    cache_defs=encdec.cache_defs,
    init_cache=encdec.init_cache,
    cache_structs=encdec.cache_structs,
)


def get_model(cfg: ModelConfig) -> Model:
    if cfg.family == "encdec":
        return _ENCDEC
    if cfg.family in ("dense", "moe", "hybrid_ssm", "xlstm"):
        return _LM
    raise ValueError(f"unknown family {cfg.family!r}")


def input_specs(cfg: ModelConfig, shape, rules=None, pad_vocab: bool = False):
    """ShapeDtypeStruct stand-ins for every model input of one dry-run cell
    (weak-type-correct, shardable, no device allocation).

    For train/prefill kinds: the token/label/frontend batch.
    For decode: the (B,) token vector (the cache is produced separately via
    ``Model.cache_structs``)."""
    b, s = shape.global_batch, shape.seq_len

    def sds(shp, dtype, *axes):
        if rules is None:
            return jax.ShapeDtypeStruct(shp, dtype)
        return jax.ShapeDtypeStruct(shp, dtype,
                                    sharding=rules.sharding(axes, shp))

    if shape.kind == "decode":
        return {"tokens": sds((b,), jnp.int32, "batch")}
    if cfg.family == "encdec":
        out = {"frames": sds((b, s, cfg.frontend_dim), jnp.float32,
                             "batch", None, None),
               "tokens": sds((b, s), jnp.int32, "batch", None)}
        if shape.kind == "train":
            out["labels"] = sds((b, s), jnp.int32, "batch", None)
        return out
    out = {}
    s_text = s
    if cfg.frontend == "patch":
        s_text = s - cfg.frontend_len
        out["patches"] = sds((b, cfg.frontend_len, cfg.frontend_dim),
                             jnp.float32, "batch", None, None)
    out["tokens"] = sds((b, s_text), jnp.int32, "batch", None)
    if shape.kind == "train":
        out["labels"] = sds((b, s_text), jnp.int32, "batch", None)
    return out
