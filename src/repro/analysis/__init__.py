"""Compiled-artifact analysis: HLO collective parsing + roofline terms."""
from .hlo import (CollectiveStats, HLOProfile, parse_collectives,
                  profile_module)
from .roofline import (HW, RooflineReport, model_flops, roofline_from_compiled,
                       roofline_report)

__all__ = ["CollectiveStats", "HLOProfile", "parse_collectives",
           "profile_module", "HW", "RooflineReport", "model_flops",
           "roofline_from_compiled", "roofline_report"]
