"""Scan-aware post-optimisation HLO profiler (DESIGN.md §10).

``compiled.as_text()`` of an SPMD executable is the *per-device* module:
every shape literal is a shard shape and the SPMD partitioner has already
inserted the explicit collectives. Two things XLA's built-in
``cost_analysis()`` gets wrong for our purposes:

* a ``while`` body (scan-over-layers) is counted **once**, not
  ``trip_count`` times — an 80-layer model looks like a 1-layer model;
* collective traffic is not reported at all.

This module re-derives all three roofline inputs from the HLO text with a
call-graph walk:

1. parse computations and instructions (name -> dtype/dims, opcode, refs);
2. propagate *multiplicity* from ENTRY through the call graph — ``while``
   bodies/conditions multiply by ``backend_config.known_trip_count``,
   fusions/calls/branches inherit the caller's multiplicity;
3. FLOPs: ``dot`` = 2·|result|·K (K from ``lhs_contracting_dims``),
   ``convolution`` = 2·|result|·|kernel|/out_channels, elementwise = |result|
   (fusion internals traversed, since they execute per fusion call);
4. HBM traffic: Σ over *top-level* instructions (fusion internals excluded —
   they live in registers/VMEM) of unique-operand bytes + result bytes;
5. collectives: operand/wire bytes × multiplicity, grouped by kind.

The result is the profile the perf loop iterates on (the brief's
"your profile is ``lowered.as_text()`` + ``cost_analysis()``").
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "f16": 2, "bf16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

# ops that move no HBM bytes at the top level
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "partition-id", "replica-id", "iota", "while",
             "conditional", "call", "custom-call", "domain", "token",
             "opt-barrier"}

# 1-flop-per-element arithmetic (XLA-style); transcendentals included
_EW_OPS = {"add", "subtract", "multiply", "divide", "maximum", "minimum",
           "exponential", "tanh", "log", "rsqrt", "sqrt", "power", "negate",
           "abs", "cosine", "sine", "logistic", "remainder", "atan2",
           "exponential-minus-one", "log-plus-one", "cbrt", "erf"}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(.*)$")
# computation header: "%name (params) -> type {" — params may nest parens
# (tuple-typed args), so anchor on the trailing "-> ... {" instead
_COMP_RE = re.compile(r"^(ENTRY\s+)?(%?[\w.\-~!]+)\s+\(.*->.*\{\s*$")
_NAME_RE = re.compile(r"%[\w.\-~!]+")
_OPCODE_RE = re.compile(r"^\s*([\w\-]+)\(")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*(\d+)')
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_DIMLABELS_RE = re.compile(r"dim_labels=([\w?]+)_([\w?]+)->([\w?]+)")


def _parse_shapes(text: str):
    """All dtype[dims] literals -> list of (dtype, [dims])."""
    out = []
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        out.append((dtype, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _shapes_bytes(shapes) -> int:
    total = 0
    for dtype, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


def _elems(shapes) -> int:
    total = 0
    for _, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    result_shapes: list          # [(dtype, dims), ...]
    operand_names: list
    line: str

    @property
    def result_bytes(self) -> int:
        return _shapes_bytes(self.result_shapes)

    @property
    def result_elems(self) -> int:
        return _elems(self.result_shapes)


@dataclasses.dataclass
class Computation:
    name: str
    instrs: dict                  # name -> Instr
    order: list                   # instruction names in text order

    @property
    def root(self) -> Optional["Instr"]:
        for iname in reversed(self.order):
            if "ROOT " in self.instrs[iname].line:
                return self.instrs[iname]
        return self.instrs[self.order[-1]] if self.order else None


def parse_module(hlo: str) -> tuple[dict, str]:
    """-> ({computation name: Computation}, entry_name)."""
    comps: dict[str, Computation] = {}
    entry = None
    cname, instrs, order = None, {}, []
    for line in hlo.splitlines():
        m = _COMP_RE.match(line)
        if m and "{" in line:
            if cname is not None:
                comps[cname] = Computation(cname, instrs, order)
            cname, instrs, order = m.group(2).lstrip("%"), {}, []
            if m.group(1):
                entry = cname
            continue
        if cname is None:
            continue
        if line.strip() == "}":
            comps[cname] = Computation(cname, instrs, order)
            cname, instrs, order = None, {}, []
            continue
        d = _DEF_RE.match(line)
        if not d:
            continue
        name, rhs = d.group(1), d.group(2)
        # result type: leading shape literal(s); tuple results start with '('
        if rhs.startswith("("):
            head = rhs[:rhs.index(")") + 1]
            rest = rhs[len(head):].lstrip()
        else:
            head, _, rest = rhs.partition(" ")
        om = _OPCODE_RE.match(rest)
        opcode = om.group(1) if om else rest.split("(")[0].strip()
        # operand names: inside the first balanced parens after the opcode
        paren = rest.find("(")
        names = []
        if paren >= 0:
            depth, end = 0, len(rest)
            for i in range(paren, len(rest)):
                depth += (rest[i] == "(") - (rest[i] == ")")
                if depth == 0:
                    end = i
                    break
            names = _NAME_RE.findall(rest[paren:end + 1])
        instrs[name] = Instr(name, opcode, _parse_shapes(head), names, line)
        order.append(name)
    if cname is not None:
        comps[cname] = Computation(cname, instrs, order)
    if entry is None and comps:
        entry = next(iter(comps))
    return comps, entry


_CALL_ATTRS = (("calls", True), ("body", False), ("condition", False),
               ("to_apply", True), ("branch_computations", True))


def _callees(line: str):
    """[(callee_name, is_plain_call)]; whiles return (body/cond, False)."""
    out = []
    for attr, plain in _CALL_ATTRS:
        for m in re.finditer(attr + r"=(\{[^}]*\}|%?[\w.\-~!]+)", line):
            val = m.group(1)
            names = (_NAME_RE.findall(val) if val.startswith("{")
                     else [val if val.startswith("%") else "%" + val])
            for n in names:
                out.append((n.lstrip("%"), plain))
    return out


def _multiplicities(comps: dict, entry: str) -> dict:
    """Execution count per computation: topological propagation over the
    call-graph DAG (edges weighted by while trip counts)."""
    edges: dict[str, list] = defaultdict(list)   # caller -> [(callee, w)]
    indeg: dict[str, int] = defaultdict(int)
    for cname, comp in comps.items():
        for iname in comp.order:
            ins = comp.instrs[iname]
            trip = 1
            if ins.opcode == "while":
                tm = _TRIP_RE.search(ins.line)
                trip = int(tm.group(1)) if tm else 1
            for callee, plain in _callees(ins.line):
                if callee in comps:
                    edges[cname].append((callee, 1 if plain else trip))
                    indeg[callee] += 1

    mult: dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    # Kahn's algorithm from roots (entry has indegree 0 in valid HLO)
    queue = [c for c in comps if indeg[c] == 0]
    while queue:
        cname = queue.pop()
        for callee, w in edges.get(cname, ()):  # propagate then release
            mult[callee] += mult[cname] * w
            indeg[callee] -= 1
            if indeg[callee] == 0:
                queue.append(callee)
    return mult


def _fusion_callees(comps: dict) -> set:
    """Computations reached only via fusion `calls=` (register-level)."""
    fused = set()
    for comp in comps.values():
        for ins in comp.instrs.values():
            if ins.opcode == "fusion":
                for callee, _ in _callees(ins.line):
                    fused.add(callee)
    return fused


def _dot_flops(ins: Instr, comp: Computation) -> int:
    k = 1
    m = _LHS_CONTRACT_RE.search(ins.line)
    lhs = comp.instrs.get(ins.operand_names[0]) if ins.operand_names else None
    if m and lhs is not None and lhs.result_shapes:
        dims = lhs.result_shapes[0][1]
        for di in (int(x) for x in m.group(1).split(",") if x):
            if di < len(dims):
                k *= dims[di]
    elif lhs is not None and lhs.result_shapes:
        dims = lhs.result_shapes[0][1]
        k = dims[-1] if dims else 1
    return 2 * ins.result_elems * k


def _conv_flops(ins: Instr, comp: Computation) -> int:
    if len(ins.operand_names) < 2:
        return 2 * ins.result_elems
    ker = comp.instrs.get(ins.operand_names[1])
    if ker is None or not ker.result_shapes:
        return 2 * ins.result_elems
    kdims = ker.result_shapes[0][1]
    kelems = 1
    for d in kdims:
        kelems *= d
    out_ch = kdims[-1] if kdims else 1
    m = _DIMLABELS_RE.search(ins.line)
    if m:
        klabels = m.group(2)
        oi = klabels.find("o")
        if 0 <= oi < len(kdims):
            out_ch = kdims[oi]
    return 2 * ins.result_elems * (kelems // max(out_ch, 1))


def _dus_update_bytes(ins: Instr, comp: Computation) -> int:
    """dynamic-update-slice runs in place: traffic = read+write of the
    update slice, not of the whole buffer."""
    if len(ins.operand_names) >= 2:
        upd = comp.instrs.get(ins.operand_names[1])
        if upd is not None:
            return 2 * upd.result_bytes
    return 2 * ins.result_bytes


_PARAM_IDX_RE = re.compile(r"parameter\((\d+)\)")


def _fusion_sub(ins: Instr, comps: dict) -> Optional[Computation]:
    for callee, _ in _callees(ins.line):
        sub = comps.get(callee)
        if sub is not None:
            return sub
    return None


_PLUMBING = ("bitcast", "copy", "convert")
# "convert" counts as plumbing: XLA-CPU's bf16 legalisation wraps in-place
# DUS updates in full-buffer f32<->bf16 round trips that the TPU backend
# (native bf16) never materialises.


def _unwrap(sub: Computation, name: str, steps: int = 4):
    """Follow bitcast/copy/convert chains to the underlying instr."""
    for _ in range(steps):
        src = sub.instrs.get(name)
        if src is None:
            return None
        if src.opcode in _PLUMBING and src.operand_names:
            name = src.operand_names[0]
            continue
        return src
    return sub.instrs.get(name)


def _dus_roots(sub: Computation) -> list:
    """The effective dynamic-update-slice root(s) of a fused computation
    (unwrapped through plumbing; [] if the fusion is not an in-place DUS)."""
    root = sub.root
    if root is None:
        return []
    cands = ([sub.instrs.get(n) for n in root.operand_names]
             if root.opcode == "tuple" else [root])
    out = []
    for c in cands:
        if c is None:
            return []
        if c.opcode in _PLUMBING:
            c = _unwrap(sub, c.name)
        if c is None or c.opcode != "dynamic-update-slice":
            return []
        out.append(c)
    return out


def _dus_buffer_params(sub: Computation) -> set:
    """Names of fused-computation parameters that are only the *updated
    buffer* of a dynamic-update-slice (aliased in place — not read)."""
    out = set()
    for r in _dus_roots(sub):
        if not r.operand_names:
            continue
        src = _unwrap(sub, r.operand_names[0])
        if src is not None and src.opcode == "parameter":
            out.add(src.name)
    return out


def _fusion_operand_bytes(ins: Instr, comp: Computation,
                          sub: Computation) -> int:
    """Bytes *read* by a fusion: a parameter consumed only through
    dynamic-slice / gather ops inside the fused computation reads the
    slices, not the whole array (scan bodies read one layer's slice of
    each stacked tensor per iteration); a parameter that is only the
    in-place-updated buffer of a DUS is not read at all."""
    # parameter index -> instr name in the fused computation
    pname = {}
    for iname in sub.order:
        m = _PARAM_IDX_RE.search(sub.instrs[iname].line)
        if m:
            pname[int(m.group(1))] = iname
    # uses of each instruction inside the fusion
    uses: dict[str, list] = defaultdict(list)
    for iname in sub.order:
        for on in sub.instrs[iname].operand_names:
            uses[on].append(sub.instrs[iname])
    dus_bufs = _dus_buffer_params(sub)
    total, seen = 0, set()
    for idx, opname in enumerate(ins.operand_names):
        if opname in seen:
            continue
        seen.add(opname)
        src = comp.instrs.get(opname)
        if src is None or src.opcode == "constant":
            continue
        full = src.result_bytes
        pi = pname.get(idx)
        if pi is not None:
            if pi in dus_bufs:
                continue                      # aliased buffer, not a read
            us = uses.get(pi, ())
            if us and all(u.opcode in ("dynamic-slice", "gather")
                          for u in us):
                full = min(full, sum(u.result_bytes for u in us))
        total += full
    return total


# operands at or below this size that are loop parameters / carried tuple
# elements are assumed VMEM-resident across iterations (charged once, not
# per trip) — e.g. the sLSTM recurrent matrices re-read every timestep
_VMEM_RESIDENT = 16 << 20


def _resident_operand_bytes(ins: Instr, comp: Computation) -> int:
    """Bytes of small parameter/GTE operands (VMEM-resident in loops)."""
    out = 0
    for on in dict.fromkeys(ins.operand_names):
        src = comp.instrs.get(on)
        if (src is not None
                and src.opcode in ("parameter", "get-tuple-element")
                and src.result_bytes <= _VMEM_RESIDENT):
            out += src.result_bytes
    return out


def _instr_traffic(ins: Instr, comp: Computation,
                   comps: dict) -> tuple[int, int]:
    """-> (per-execution bytes, loop-resident bytes) of one top-level
    instruction. Resident bytes are charged once regardless of trip
    count (fusion-aware; in-place DUS; slice-reads)."""
    op = ins.opcode
    if op == "dynamic-update-slice":
        return _dus_update_bytes(ins, comp), 0
    if op == "dynamic-slice":
        return 2 * ins.result_bytes, 0
    if op == "fusion":
        sub = _fusion_sub(ins, comps)
        if sub is not None:
            reads = _fusion_operand_bytes(ins, comp, sub)
            res = min(_resident_operand_bytes(ins, comp), reads)
            reads -= res
            dus = _dus_roots(sub)
            if dus:  # in-place: write only the updated slice(s)
                writes = sum(_dus_update_bytes(r, sub) // 2 for r in dus)
                return reads + writes, res
            return reads + ins.result_bytes, res
    ob = 0
    for on in dict.fromkeys(ins.operand_names):
        src = comp.instrs.get(on)
        if src is not None and src.opcode not in ("constant",):
            ob += src.result_bytes
    res = min(_resident_operand_bytes(ins, comp), ob)
    return ob - res + ins.result_bytes, res


@dataclasses.dataclass
class Collective:
    kind: str
    operand_bytes: int     # per-device shard bytes, × multiplicity NOT applied
    result_bytes: int
    group_size: int
    computation: str
    mult: float = 1.0

    @property
    def wire_bytes(self) -> int:
        """Ring-algorithm per-device traffic estimate (one occurrence)."""
        n = max(self.group_size, 1)
        if n == 1:
            return 0
        b = self.operand_bytes
        if self.kind == "all-gather":
            return b * (n - 1)
        if self.kind == "all-reduce":
            return int(2 * b * (n - 1) / n)
        if self.kind in ("reduce-scatter", "all-to-all"):
            return int(b * (n - 1) / n)
        return b  # collective-permute


@dataclasses.dataclass
class HLOProfile:
    flops: float                # scan-aware total (incl. elementwise)
    mxu_flops: float            # dot+conv only
    traffic_bytes: float        # fusion-aware HBM traffic estimate
    operand_bytes: float        # Σ collective operand sizes (brief's term)
    wire_bytes: float           # ring-estimate collective traffic
    by_kind: dict               # kind -> [count, operand_bytes, wire_bytes]
    collectives: list
    trip_counts: dict           # computation -> multiplicity (whiles only)
    # XLA *CPU* has no native bf16 matmul: it materialises fp32 upcasts of
    # bf16 weights/caches (and fp32 shadows of bf16 while-carries). The TPU
    # MXU consumes bf16 directly, so these buffers/moves do not exist on
    # the target. Quantified here so memory numbers can be TPU-adjusted.
    cpu_upcast_bytes: float = 0.0      # one-time buffer bytes (liveness)
    cpu_upcast_traffic: float = 0.0    # multiplicity-weighted R+W bytes

    def summary(self) -> str:
        rows = [f"  {k:<19} n={int(c):<6} operand={ob / 1e6:10.2f}MB "
                f"wire={wb / 1e6:10.2f}MB"
                for k, (c, ob, wb) in sorted(self.by_kind.items())]
        return "\n".join(rows) if rows else "  (no collectives)"


def _group_size(line: str, n_devices: int) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))   # iota groups [G,S]: G groups of S devices
    return n_devices


def profile_module(hlo: str, n_devices: int = 1) -> HLOProfile:
    comps, entry = parse_module(hlo)
    mult = _multiplicities(comps, entry)
    fused = _fusion_callees(comps)

    flops = mxu = traffic = 0.0
    upcast_b = upcast_t = 0.0
    colls: list[Collective] = []
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        top_level = cname not in fused
        for iname in comp.order:
            ins = comp.instrs[iname]
            op = ins.opcode
            # ---- CPU bf16->f32 upcast artifacts (absent on TPU) ----
            # only *stored* tensors (weights, loop-carried caches) count:
            # semantic upcasts (fp32 grad accumulation etc.) are model-
            # requested and exist on TPU too — those operands are compute
            # outputs, not parameters/carries. Converts may be bare or
            # wrapped in a kLoop fusion (convert_fusion).
            if (top_level and ins.result_shapes
                    and ins.result_shapes[0][0] == "f32"
                    and ins.result_bytes >= 1 << 20
                    and op in ("convert", "fusion", "copy")):
                conv = ins if op == "convert" else None
                sub = None
                if op == "fusion":
                    for callee, _ in _callees(ins.line):
                        sub = comps.get(callee)
                        break
                    root = sub.root if sub is not None else None
                    if root is not None and root.opcode == "convert":
                        conv = root
                if conv is not None and conv.operand_names:
                    host = sub if (op == "fusion" and sub) else comp
                    src = host.instrs.get(conv.operand_names[0])
                    if (src is not None and src.result_shapes
                            and src.result_shapes[0][0] == "bf16"
                            and src.opcode in ("parameter",
                                               "get-tuple-element",
                                               "copy", "bitcast")):
                        upcast_b += ins.result_bytes
                        upcast_t += m * (ins.result_bytes
                                         + ins.result_bytes // 2)
            # ---- flops (fusion internals execute; count everywhere) ----
            if op == "dot":
                f = _dot_flops(ins, comp)
                flops += m * f
                mxu += m * f
            elif op == "convolution":
                f = _conv_flops(ins, comp)
                flops += m * f
                mxu += m * f
            elif op in _EW_OPS:
                flops += m * ins.result_elems
            # ---- HBM traffic (top-level ops only) ----
            if top_level and op not in _FREE_OPS:
                per_exec, resident = _instr_traffic(ins, comp, comps)
                traffic += m * per_exec + resident
            # ---- collectives ----
            base = op.replace("-start", "")
            if base in COLLECTIVE_OPS and not op.endswith("-done"):
                opb = sum(comp.instrs[on].result_bytes
                          for on in ins.operand_names
                          if on in comp.instrs)
                if opb == 0:
                    opb = _shapes_bytes(_parse_shapes(
                        ins.line.split(op + "(", 1)[-1]))
                colls.append(Collective(base, opb, ins.result_bytes,
                                        _group_size(ins.line, n_devices),
                                        cname, m))

    by_kind: dict[str, list] = defaultdict(lambda: [0, 0, 0])
    tot_ob = tot_wb = 0.0
    for c in colls:
        e = by_kind[c.kind]
        e[0] += c.mult
        e[1] += c.operand_bytes * c.mult
        e[2] += c.wire_bytes * c.mult
        tot_ob += c.operand_bytes * c.mult
        tot_wb += c.wire_bytes * c.mult

    trips = {c: m for c, m in mult.items() if m > 1}
    return HLOProfile(flops, mxu, traffic, tot_ob, tot_wb,
                      {k: tuple(v) for k, v in by_kind.items()},
                      colls, trips, upcast_b, upcast_t)


# ---------------------------------------------------------------------------
# compatibility shim (older callers)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CollectiveStats:
    collectives: list
    operand_bytes: int
    wire_bytes: int
    by_kind: dict

    def summary(self) -> str:
        rows = [f"  {k:<19} n={int(c):<6} operand={ob / 1e6:10.2f}MB "
                f"wire={wb / 1e6:10.2f}MB"
                for k, (c, ob, wb) in sorted(self.by_kind.items())]
        return "\n".join(rows) if rows else "  (no collectives)"


def parse_collectives(hlo: str, n_devices: int = 1) -> CollectiveStats:
    p = profile_module(hlo, n_devices)
    return CollectiveStats(p.collectives, int(p.operand_bytes),
                           int(p.wire_bytes), p.by_kind)
