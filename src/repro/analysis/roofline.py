"""Three-term roofline from a compiled dry-run artifact (brief §ROOFLINE).

    compute term    = HLO_FLOPs            / (chips × peak_FLOP/s)
    memory term     = HLO_bytes            / (chips × HBM_bw)
    collective term = collective_bytes     / (chips × link_bw)

``cost_analysis()`` of an SPMD executable reports the *per-device* module,
so per-device quantities divided by per-chip rates give exactly the same
seconds as the global formulation above; both views are recorded.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (brief-supplied).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Optional

from .hlo import CollectiveStats, parse_collectives, profile_module


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12        # bf16 per chip
    hbm_bw: float = 819e9             # bytes/s per chip
    link_bw: float = 50e9             # bytes/s per ICI link
    hbm_bytes: float = 16e9           # v5e HBM capacity


V5E = HW()


def model_flops(cfg, shape) -> int:
    """Useful (model) FLOPs per step: 6·N·D train, 2·N·D forward-only,
    with N = active params (MoE: experts scaled by top_k/E)."""
    n = cfg.n_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2 * n * tokens
    # decode: one token per sequence
    return 2 * n * shape.global_batch


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    # per-device quantities from the compiled artifact (scan-aware profile)
    flops_per_device: float
    bytes_per_device: float
    coll_operand_bytes: int
    coll_wire_bytes: int
    # memory_analysis
    argument_bytes: int
    output_bytes: int
    temp_bytes: int
    # model-level
    model_flops_total: int
    by_kind: dict
    # raw XLA cost_analysis numbers (cross-check; while bodies counted ×1)
    flops_xla_raw: float = 0.0
    bytes_xla_raw: float = 0.0
    mxu_flops_per_device: float = 0.0
    # CPU-backend bf16->f32 upcast artifacts (absent on the TPU target);
    # memory/traffic are reported TPU-adjusted, raw kept for audit
    cpu_upcast_bytes: float = 0.0
    cpu_upcast_traffic: float = 0.0
    alias_bytes: int = 0           # donated-buffer aliasing (in==out)
    hw: HW = V5E

    # -- derived terms (seconds) ---------------------------------------------
    @property
    def compute_s(self) -> float:
        return self.flops_per_device / self.hw.peak_flops

    @property
    def memory_s(self) -> float:
        adj = max(self.bytes_per_device - self.cpu_upcast_traffic, 0.0)
        return adj / self.hw.hbm_bw

    @property
    def collective_s(self) -> float:
        return self.coll_operand_bytes / self.hw.link_bw

    @property
    def collective_wire_s(self) -> float:
        return self.coll_wire_bytes / self.hw.link_bw

    @property
    def bound(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Roofline step-time model: max of the three overlappable terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def hlo_flops_total(self) -> float:
        return self.flops_per_device * self.n_devices

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — remat/redundancy waste detector."""
        return (self.model_flops_total / self.hlo_flops_total
                if self.hlo_flops_total else 0.0)

    @property
    def mfu(self) -> float:
        """Model FLOPs over the roofline step time × fleet peak — the
        roofline fraction the brief scores (perfect overlap assumed)."""
        denom = self.step_s * self.n_devices * self.hw.peak_flops
        return self.model_flops_total / denom if denom else 0.0

    @property
    def device_bytes(self) -> int:
        """TPU-adjusted per-device bytes: XLA-CPU's fp32 upcasts of bf16
        params/caches don't exist on the MXU target, and donated buffers
        alias their outputs."""
        raw = (self.argument_bytes + self.output_bytes + self.temp_bytes
               - self.alias_bytes)
        return int(max(raw - self.cpu_upcast_bytes, self.argument_bytes))

    @property
    def fits(self) -> bool:
        return self.device_bytes <= self.hw.hbm_bytes

    def to_dict(self) -> dict:
        d = {k: getattr(self, k) for k in (
            "arch", "shape", "mesh", "n_devices", "flops_per_device",
            "bytes_per_device", "coll_operand_bytes", "coll_wire_bytes",
            "flops_xla_raw", "bytes_xla_raw", "mxu_flops_per_device",
            "cpu_upcast_bytes", "cpu_upcast_traffic", "alias_bytes",
            "argument_bytes", "output_bytes", "temp_bytes",
            "model_flops_total")}
        d["by_kind"] = {k: list(v) for k, v in self.by_kind.items()}
        for k in ("compute_s", "memory_s", "collective_s",
                  "collective_wire_s", "bound", "step_s", "useful_ratio",
                  "mfu", "device_bytes", "fits"):
            d[k] = getattr(self, k)
        return d

    def row(self) -> str:
        return (f"{self.arch:<22} {self.shape:<12} {self.mesh:<6} "
                f"c={self.compute_s:9.4f}s m={self.memory_s:9.4f}s "
                f"x={self.collective_s:9.4f}s -> {self.bound:<10} "
                f"useful={self.useful_ratio:6.3f} mfu={self.mfu:6.3%} "
                f"mem={self.device_bytes / 1e9:6.2f}GB"
                f"{'' if self.fits else ' OVER'}")


def cost_analysis_dict(compiled) -> dict:
    """``compiled.cost_analysis()`` as a dict across jax versions (0.4.x
    returns a one-dict-per-program list)."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def roofline_from_compiled(compiled, *, arch: str, shape, mesh_name: str,
                           n_devices: int, cfg, hw: HW = V5E,
                           hlo_text: Optional[str] = None) -> RooflineReport:
    ca = cost_analysis_dict(compiled)
    ma = compiled.memory_analysis()
    txt = hlo_text if hlo_text is not None else compiled.as_text()
    prof = profile_module(txt, n_devices)
    return RooflineReport(
        arch=arch, shape=shape.name, mesh=mesh_name, n_devices=n_devices,
        flops_per_device=prof.flops,
        bytes_per_device=prof.traffic_bytes,
        coll_operand_bytes=int(prof.operand_bytes),
        coll_wire_bytes=int(prof.wire_bytes),
        flops_xla_raw=float(ca.get("flops", 0.0)),
        bytes_xla_raw=float(ca.get("bytes accessed", 0.0)),
        mxu_flops_per_device=prof.mxu_flops,
        cpu_upcast_bytes=prof.cpu_upcast_bytes,
        cpu_upcast_traffic=prof.cpu_upcast_traffic,
        argument_bytes=int(getattr(ma, "argument_size_in_bytes", 0)),
        output_bytes=int(getattr(ma, "output_size_in_bytes", 0)),
        temp_bytes=int(getattr(ma, "temp_size_in_bytes", 0)),
        alias_bytes=int(getattr(ma, "alias_size_in_bytes", 0)),
        model_flops_total=model_flops(cfg, shape),
        by_kind=prof.by_kind, hw=hw)


# alias used by drivers that already hold the pieces
def roofline_report(**kw) -> RooflineReport:
    return RooflineReport(**kw)


def load_reports(path: str) -> list:
    """Read the dry-run JSONL back into dict rows."""
    rows = []
    with open(path) as f:
        for line in f:
            if line.strip():
                rows.append(json.loads(line))
    return rows
