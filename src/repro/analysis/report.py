"""Render EXPERIMENTS.md tables from the dry-run JSONL files.

    PYTHONPATH=src python -m repro.analysis.report results/dryrun_final.jsonl
"""
from __future__ import annotations

import json
import sys


def load(path: str) -> list:
    with open(path) as f:
        return [json.loads(l) for l in f if l.strip()]


def fmt_row(r: dict) -> str:
    if r["status"] == "skip":
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                f"— skip: {r['reason'].split(':')[0]} |||||||")
    if r["status"] == "error":
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                f"ERROR {r['error'][:40]} |||||||")
    gb = r["device_bytes"] / 1e9
    return ("| {arch} | {shape} | {mesh} | {c:.4f} | {m:.4f} | {x:.4f} | "
            "{bound} | {useful:.3f} | {mfu:.2%} | {gb:.2f}{over} |").format(
        arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
        c=r["compute_s"], m=r["memory_s"], x=r["collective_s"],
        bound=r["bound"], useful=r["useful_ratio"], mfu=r["mfu"],
        gb=gb, over="" if r["fits"] else " ⚠")


HEADER = ("| arch | shape | mesh | compute s | memory s | collective s | "
          "bound | useful | roofline-MFU | GB/dev |\n"
          "|---|---|---|---|---|---|---|---|---|---|")


def roofline_table(rows: list, mesh: str = None) -> str:
    out = [HEADER]
    for r in rows:
        if mesh and r.get("mesh") != mesh:
            continue
        out.append(fmt_row(r))
    return "\n".join(out)


def dryrun_summary(rows: list) -> str:
    ok = [r for r in rows if r["status"] == "ok"]
    skip = [r for r in rows if r["status"] == "skip"]
    err = [r for r in rows if r["status"] == "error"]
    fit = [r for r in ok if r["fits"]]
    lines = [
        f"* cells: {len(rows)} total — {len(ok)} compiled, "
        f"{len(skip)} documented skips, {len(err)} errors",
        f"* memory: {len(fit)}/{len(ok)} compiled cells fit 16 GB/chip "
        "(TPU-adjusted; see notes)",
    ]
    if ok:
        comp = sorted(ok, key=lambda r: -r["compile_s"])[0]
        lines.append(
            f"* slowest compile: {comp['arch']}×{comp['shape']}×"
            f"{comp['mesh']} at {comp['compile_s']:.0f}s")
    by_bound = {}
    for r in ok:
        by_bound[r["bound"]] = by_bound.get(r["bound"], 0) + 1
    lines.append("* dominant terms: " + ", ".join(
        f"{k}: {v}" for k, v in sorted(by_bound.items())))
    return "\n".join(lines)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_final.jsonl"
    rows = load(path)
    print(dryrun_summary(rows))
    for mesh in ("1pod", "2pod"):
        print(f"\n### {mesh}\n")
        print(roofline_table(rows, mesh))


if __name__ == "__main__":
    main()
