"""Chaos benchmark: kill-and-restart cycles through a supervised
sharded serving plane, with a zero-gateway-5xx and bit-identical-
recovery gate (``serve.faults`` / ``serve.supervise``; DESIGN.md §9).

Topology: 2 shard writer processes (checkpoint+WAL ``recover_dir``
each) x 2 zero-copy shm replicas each, supervised, fronted by an
in-process ``RouterService`` — the same plane ``launch/cluster_serve.py
--shards 2 --replicas 2`` boots.  A seeded :class:`FaultPlan` injects:

* **writer kill** — shard 0's writer hard-crashes (``os._exit(23)``)
  the moment its stream version reaches a fixed mid-trickle value;
* **replica kill** — replica (1, 0) hard-crashes on a fixed request
  ordinal.

While the plane degrades and heals, client threads hammer the router
and classify every response: ``ok`` (full coverage), ``degraded``
(partial coverage, explicitly marked), or ``gateway_5xx`` (an error
surfaced to the caller).  A writer thread trickles upserts at shard 0,
recording every op; the batch in flight at the kill errors at the
client but is durable (WAL-before-apply precedes the injected exit),
so the recorded log is exact.

Gates (asserted here and schema-checked by ``benchmarks/validate.py``):

* ``gateway_5xx == 0`` — failures degrade, they never 502;
* ``recovery_s < 30`` — supervisor restarts both victims and the
  router's health shows no down endpoint within the bound;
* ``bit_identical`` — the recovered writer, quiesced at its final
  stream version, answers top-k exactly like an uninterrupted
  in-process control service fed the same preload + recorded ops
  (same stream version, same signatures, same scores);
* ``injected exits`` — the supervisor observed exit code 23 (the
  injected crash, not a bug) for both victims.

Emits the ``serving_faults`` section (``results/chaos.json``).

``run_integrity`` is the fail-silent half (ISSUE 8): seeded bit rot
injected at every persistence surface — an interior WAL record, the
current checkpoint generation, a published shm segment — with the gate
that **every** corruption is detected (CRC frames / manifest checksums
refuse the bytes, never serve them), recovery is bit-identical to an
uninterrupted control, and zero answers were silently wrong along the
way.  The clean-path cost of the defence (checksumming one snapshot's
arrays) is measured against the snapshot-swap latency and bounded.
Emits the ``serving_integrity`` section (``results/integrity.json``).
"""
from __future__ import annotations

import os
import tempfile
import threading
import time

import numpy as np

from repro.data import synthetic
from repro.serve.faults import KILL_EXIT_CODE, Fault, FaultPlan

from .common import print_table, save_json

TOP_K = 8
SHARDS = 2
REPLICAS = 2
CLIENTS = 4
PRELOAD_CHUNKS = 4            # preload stream version per writer
CHECKPOINT_EVERY = 4          # checkpoint covers the preload; trickle
                              # ops land in the WAL tail
KILL_AFTER_OPS = 7            # writer dies on the 7th trickle op
TRICKLE_OPS = 24
REPLICA_KILL_AT = 10          # request ordinal on replica (1, 0)
RECOVERY_BOUND_S = 30.0


def _fault_plan(seed: int) -> FaultPlan:
    kill_sv = PRELOAD_CHUNKS + KILL_AFTER_OPS
    return FaultPlan.build(
        FaultPlan.kill_writer(0, at_stream_version=kill_sv),
        Fault("kill", "request", role="replica", shard=1, replica=0,
              at=REPLICA_KILL_AT),
        seed=seed)


def _wait(cond, timeout: float, what: str) -> float:
    t0 = time.monotonic()
    while time.monotonic() - t0 < timeout:
        if cond():
            return time.monotonic() - t0
        time.sleep(0.05)
    raise TimeoutError(f"{what} not reached within {timeout}s")


def _control_sigs(n: int, seed: int, ops: list) -> tuple:
    """The uninterrupted control: a fresh in-process service given
    shard 0's exact preload and the recorded trickle ops, quiesced —
    what the recovered writer must match bit-for-bit."""
    from repro.core import keys as K
    from repro.core import runs as RS
    from repro.launch.tricluster import load_dataset
    from repro.serve.ranking import RankingPolicy
    from repro.serve.service import TriclusterService

    ctx = load_dataset("movielens", n, seed)
    plan = K.plan_mode_key(ctx.sizes, 0, with_values=False)
    own = RS.shard_of_rows(ctx.tuples, plan, SHARDS) == 0
    tuples = ctx.tuples[own]
    svc = TriclusterService(ctx.sizes, backend="streaming", theta=0.0,
                            delta=None, rho_min=0.0, minsup=0,
                            refresh_interval=60.0,
                            dirty_threshold=1 << 30,
                            policy=RankingPolicy(1.0, 0.0, 0.0),
                            seed=seed or 0x5EED)
    n_own = tuples.shape[0]
    step = -(-max(n_own, 1) // PRELOAD_CHUNKS)
    for lo in range(0, n_own, step):
        svc.add(tuples[lo:lo + step])
    sv = svc.miner.stream_version
    for op in ops:
        rows = np.asarray(op["rows"], dtype=np.int64)
        sv = (svc.upsert(rows) if op["op"] == "upsert"
              else svc.delete(rows))
    with svc:
        svc.refresh()
        hits = svc.query(mode=0, k=TOP_K).hits
    return int(sv), [(int(v.signature[0]), int(v.signature[1]),
                      round(float(s), 12)) for v, s in hits]


def run(scale: float = 0.02, repeat: int = 1, seed: int = 11,
        out_name: str = "chaos.json") -> dict:
    import multiprocessing as mp

    from repro.launch.cluster_serve import _child_replica, _child_writer
    from repro.serve.router import PooledClient, RouterService, Shard
    from repro.serve.supervise import Supervisor

    n = max(2_000, int(1_000_000 * scale))
    sizes = synthetic.movielens_like(n_tuples=4, seed=seed).sizes
    mp_ctx = mp.get_context("spawn")
    tmp = tempfile.mkdtemp(prefix="bench-chaos-")
    plan_json = _fault_plan(seed).to_json()
    base = {"dataset": "movielens", "n_tuples": n, "seed": seed,
            "backend": "streaming", "theta": 0.0, "delta": None,
            "rho_min": 0.0, "minsup": 0, "refresh_interval": 0.05,
            "dirty_threshold": 8, "policy": (1.0, 0.0, 0.0),
            "delta_index": True, "preload_chunks": PRELOAD_CHUNKS,
            "host": "127.0.0.1", "verbose": False, "n_shards": SHARDS,
            "timeout": 180.0, "checkpoint_every": CHECKPOINT_EVERY,
            "health_max_staleness": None, "drain_timeout": 5.0,
            "flag_dir": tmp}
    # the injected faults are one-shot per run: only the FIRST boot of
    # each child carries the plan — a restarted victim must not re-die
    # at the same (replayed) counter value and crash-loop
    boots: dict = {}

    def factory(name, target, cfg):
        def make():
            c = dict(cfg,
                     fault_plan="" if boots.get(name) else plan_json)
            boots[name] = boots.get(name, 0) + 1
            p = mp_ctx.Process(target=target, args=(c,), daemon=True,
                               name=name)
            p.start()
            return p
        return make

    sup = Supervisor(flag_dir=tmp, restart_backoff=0.2, max_restarts=5)
    out = {"shards": SHARDS, "replicas": REPLICAS, "clients": CLIENTS,
           "n_tuples": int(n),
           "writer_kill_sv": PRELOAD_CHUNKS + KILL_AFTER_OPS,
           "replica_kill_at": REPLICA_KILL_AT, "seed": int(seed)}
    router = None
    try:
        shard_specs = []
        for s in range(SHARDS):
            prefix = f"cb{os.getpid()}s{s}"
            wcfg = dict(base, shard=s, shm_prefix=prefix,
                        recover_dir=os.path.join(tmp, f"s{s}"),
                        port_file=os.path.join(tmp, f"w{s}.port"))
            os.makedirs(wcfg["recover_dir"], exist_ok=True)
            sup.add(f"shard-{s}",
                    factory(f"shard-{s}", _child_writer, wcfg))
            rfiles = []
            for r in range(REPLICAS):
                rcfg = dict(base, shard=s, replica=r, shm_prefix=prefix,
                            port_file=os.path.join(tmp,
                                                   f"r{s}.{r}.port"))
                sup.add(f"replica-{s}.{r}",
                        factory(f"replica-{s}.{r}", _child_replica,
                                rcfg))
                rfiles.append(rcfg["port_file"])
            shard_specs.append((wcfg["port_file"], rfiles))
        sup.start()

        from .serving import _wait_port
        shards = []
        for wf, rfiles in shard_specs:
            wp = _wait_port(wf)
            rps = [_wait_port(rf) for rf in rfiles]
            shards.append(Shard(f"http://127.0.0.1:{wp}",
                                [f"http://127.0.0.1:{rp}"
                                 for rp in rps], timeout=30.0))
        router = RouterService(shards, timeout=60.0, retry_base=0.05,
                               retry_cap=0.5, probe_interval=0.2,
                               probe_timeout=2.0)
        router.health()                        # plane fully attached

        # ---- client fan-in: classify every routed response ----------
        stop = threading.Event()
        counts = {"ok": 0, "degraded": 0, "gateway_5xx": 0}
        clock = threading.Lock()

        def client(ci: int):
            rng = np.random.default_rng(seed + 100 + ci)
            while not stop.is_set():
                e = int(rng.integers(0, sizes[0]))
                try:
                    doc = router.query(entity=e, mode=0, k=TOP_K)
                    key = "degraded" if doc.get("degraded") else "ok"
                except Exception:              # noqa: BLE001 — a 5xx
                    key = "gateway_5xx"
                with clock:
                    counts[key] += 1
        threads = [threading.Thread(target=client, args=(i,),
                                    daemon=True) for i in range(CLIENTS)]
        for t in threads:
            t.start()

        # ---- recorded write trickle at shard 0's writer -------------
        w0 = shards[0].writer.base_url
        wcl = PooledClient(w0, timeout=30.0)
        wrng = np.random.default_rng(seed + 1)
        ops, t_kill = [], None
        sv_expect = PRELOAD_CHUNKS
        i = 0
        while len(ops) < TRICKLE_OPS:
            if i % 8 == 7:
                op = {"op": "delete",
                      "rows": wrng.integers(0, sizes,
                                            (1, len(sizes))).tolist()}
            else:
                op = {"op": "upsert",
                      "rows": wrng.integers(0, sizes,
                                            (4, len(sizes))).tolist()}
            i += 1
            try:
                wcl.call(f"/{op['op']}", {"rows": op["rows"]})
            except Exception:                  # noqa: BLE001
                # the killed batch: the client saw the severed
                # connection, but WAL-before-apply precedes the
                # injected exit — the op is durable and MUST be part
                # of the control replay
                assert t_kill is None, "writer died more than once"
                t_kill = time.monotonic()
                ops.append(op)
                sv_expect += 1
                # wait out the supervisor restart + WAL replay, then
                # keep trickling against the recovered writer
                sup.wait_state("shard-0", ("running",), timeout=30.0)
                _wait(lambda: _probe_sv(wcl) >= sv_expect, 60.0,
                      "writer recovery")
                continue
            ops.append(op)
            sv_expect += 1
            time.sleep(0.02)
        assert t_kill is not None, \
            "fault plan never fired: writer survived the trickle"

        # ---- recovery: both victims back, no endpoint down ----------
        def healthy():
            h = router.health()
            return not h.get("down") and not h.get("degraded")
        t_rec = _wait(healthy, 60.0, "full coverage")
        recovery_s = time.monotonic() - t_kill
        _wait(lambda: sup.stats()["children"]["replica-1.0"]["restarts"]
              >= 1, 60.0, "replica restart")
        stop.set()
        for t in threads:
            t.join(timeout=30)

        st = sup.stats()["children"]
        out.update(
            queries=int(sum(counts.values())), **counts,
            recovery_s=float(recovery_s), health_settle_s=float(t_rec),
            writer_restarts=int(st["shard-0"]["restarts"]),
            writer_exit=st["shard-0"]["last_exit"],
            replica_restarts=int(st["replica-1.0"]["restarts"]),
            replica_exit=st["replica-1.0"]["last_exit"],
            trickle_ops=len(ops),
            router=dict(router.resilience_stats(), breakers=None))

        # ---- bit-identity: recovered writer vs uninterrupted control
        wcl.call("/refresh", {})
        h = wcl.call("/health")
        got = wcl.call("/query", {"mode": 0, "k": TOP_K})
        got_sigs = [(int(x["signature"][0]), int(x["signature"][1]),
                     round(float(x["score"]), 12))
                    for x in got["hits"]]
        ctl_sv, ctl_sigs = _control_sigs(n, seed, ops)
        out.update(stream_version_final=int(h["stream_version"]),
                   stream_version_control=int(ctl_sv),
                   bit_identical=bool(got_sigs == ctl_sigs
                                      and h["stream_version"] == ctl_sv))

        # orderly teardown: stop the monitor FIRST, then let /shutdown
        # drain the children to clean exits — terminating them early
        # would SIGTERM mid-drain, which keeps shm segments for a
        # successor that never comes
        sup.stop(terminate=False)
        router.shutdown_backends()
        _wait(lambda: not any(c["alive"] for c in
                              sup.stats()["children"].values()),
              30.0, "children exit")
    finally:
        if router is not None:
            router.close()
        sup.stop(terminate=True)

    # ---- the gates this benchmark exists for ------------------------
    assert out["gateway_5xx"] == 0, \
        f"{out['gateway_5xx']} gateway 5xx leaked through degradation"
    assert out["writer_exit"] == KILL_EXIT_CODE, out["writer_exit"]
    assert out["replica_exit"] == KILL_EXIT_CODE, out["replica_exit"]
    assert out["writer_restarts"] >= 1 and out["replica_restarts"] >= 1
    assert out["recovery_s"] < RECOVERY_BOUND_S, out["recovery_s"]
    assert out["bit_identical"], \
        (out["stream_version_final"], out["stream_version_control"])

    print_table(
        "serving_faults: supervised kill-and-restart chaos cycle",
        ["topology", "queries", "ok", "degraded", "5xx", "recovery_s",
         "restarts", "bit_identical"],
        [[f"{SHARDS}x{REPLICAS}", out["queries"], out["ok"],
          out["degraded"], out["gateway_5xx"],
          f"{out['recovery_s']:.2f}",
          out["writer_restarts"] + out["replica_restarts"],
          out["bit_identical"]]])
    save_json(out_name, {"serving_faults": out})
    return out


def _probe_sv(cl) -> int:
    try:
        return int(cl.call("/health")["stream_version"])
    except Exception:                          # noqa: BLE001 — dead yet
        return -1


# ---------------------------------------------------------------------------
# Corruption chaos (ISSUE 8): injected bit rot at every persistence
# surface, gated on zero silently-wrong answers
# ---------------------------------------------------------------------------

N_CHUNKS = 12                 # stream ops per corruption scenario
WAL_FLIP_SV = N_CHUNKS - 3    # interior: verified records follow it
OVERHEAD_REPS = 9
OVERHEAD_BOUND_PCT = 5.0      # crc cost vs snapshot-swap latency


def _quiesced(sizes, seed, **kw):
    """An in-process service with background refresh effectively off —
    every state transition in the scenarios is explicit."""
    from repro.serve.ranking import RankingPolicy
    from repro.serve.service import TriclusterService
    return TriclusterService(sizes, backend="streaming",
                             refresh_interval=60.0,
                             dirty_threshold=1 << 30,
                             policy=RankingPolicy(1.0, 0.0, 0.0),
                             seed=seed or 0x5EED, **kw)


def _sigs(svc):
    return [(int(v.signature[0]), int(v.signature[1]),
             round(float(s), 12))
            for v, s in svc.query(k=TOP_K).hits]


def _scenario_wal_flip(ctx, chunks, seed, tmp) -> dict:
    """One interior WAL record rots after its CRC was taken.  The
    successor must quarantine the file, replay exactly the verified
    prefix, and answer bit-identically to a control fed that prefix."""
    rec = os.path.join(tmp, "wal")
    os.makedirs(rec, exist_ok=True)
    plan = FaultPlan.build(
        FaultPlan.flip_wal_byte(0, at_stream_version=WAL_FLIP_SV),
        seed=seed)
    vic = _quiesced(ctx.sizes, seed, recover_dir=rec,
                    checkpoint_every=10**9,
                    fault=plan.for_component("writer", 0))
    for c in chunks:
        vic.add(c)
    assert vic.stream_version == len(chunks)   # the victim never knows
    del vic                                    # crash

    successor = _quiesced(ctx.sizes, seed, recover_dir=rec,
                          checkpoint_every=10**9)
    r = dict(successor.recovered or {})
    detected = (r.get("wal_crc_errors", 0) >= 1
                and bool(r.get("wal_quarantined")))
    ctl = _quiesced(ctx.sizes, seed)
    for c in chunks[:WAL_FLIP_SV - 1]:
        ctl.add(c)
    successor.refresh()
    ctl.refresh()
    bit = (_sigs(successor) == _sigs(ctl)
           and successor.stream_version == ctl.stream_version
           == WAL_FLIP_SV - 1)
    out = {"injected": 1, "detected": bool(detected),
           "bit_identical": bool(bit),
           "silent_wrong": 0 if bit and detected else 1,
           "recovered_sv": int(successor.stream_version),
           "replayed_ops": int(r.get("replayed_ops", 0)),
           "quarantined": str(r.get("wal_quarantined", ""))}
    successor.stop()
    ctl.stop()
    return out


def _scenario_ckpt_truncate(ctx, chunks, seed, tmp) -> dict:
    """The current checkpoint generation is truncated on disk after its
    frame was written.  Recovery must refuse it, quarantine it, restore
    the rotated previous generation and replay the WAL tail — data loss
    bounded to the ops between the two generations."""
    rec = os.path.join(tmp, "ckpt")
    os.makedirs(rec, exist_ok=True)
    plan = FaultPlan.build(
        FaultPlan.truncate_checkpoint(0, at_version=2), seed=seed)
    vic = _quiesced(ctx.sizes, seed, recover_dir=rec,
                    checkpoint_every=2,
                    fault=plan.for_component("writer", 0))
    vic.add(chunks[0])
    vic.add(chunks[1])
    vic.refresh()                              # generation 1 (sv=2)
    vic.add(chunks[2])
    vic.add(chunks[3])
    vic.refresh()                              # generation 2 — truncated
    vic.add(chunks[4])                         # WAL tail: sv=5
    assert vic.stats()["checkpoints"] == 2
    del vic                                    # crash

    successor = _quiesced(ctx.sizes, seed, recover_dir=rec,
                          checkpoint_every=10**9)
    r = dict(successor.recovered or {})
    detected = (r.get("checkpoint_quarantined", 0) >= 1
                and r.get("checkpoint_generation") == "previous")
    ctl = _quiesced(ctx.sizes, seed)
    ctl.add(chunks[0])
    ctl.add(chunks[1])
    ctl.add(chunks[4])                         # chunks 2/3 are the loss
    successor.refresh()
    ctl.refresh()
    bit = (_sigs(successor) == _sigs(ctl)
           and successor.stream_version == 5)
    out = {"injected": 1, "detected": bool(detected),
           "bit_identical": bool(bit),
           "silent_wrong": 0 if bit and detected else 1,
           "recovered_sv": int(successor.stream_version),
           "generation": str(r.get("checkpoint_generation", "")),
           "replayed_ops": int(r.get("replayed_ops", 0))}
    successor.stop()
    ctl.stop()
    return out


def _scenario_shm_flip(ctx, chunks, seed, tmp):
    """One aligned word of a published shm segment is inverted after
    the manifest checksums were recorded.  The replica must refuse the
    segment at attach (serving its held snapshot, bit-identical, the
    whole time), escalate, and recover on the next clean publish."""
    if not os.path.isdir("/dev/shm"):
        return None
    from repro.serve.shm import ReplicaService, ShmPublisher

    prefix = f"ci{os.getpid()}"
    plan = FaultPlan.build(FaultPlan.flip_shm_word(0, at_version=2),
                           seed=seed)
    pub = ShmPublisher(prefix, fault=plan.for_component("writer", 0))
    svc = _quiesced(ctx.sizes, seed, publisher=pub)
    rep = None
    try:
        for c in chunks[:4]:
            svc.add(c)
        svc.refresh()                          # v1 published clean
        rep = ReplicaService(prefix, poll_interval=0.005,
                             connect_timeout=60, seqlock_spin_s=0.5,
                             dead_signal_cooldown=0.0,
                             scrub_interval=0.02)
        rep.start(first_snapshot_timeout=60)
        held = _sigs(rep)
        svc.add(chunks[4])
        svc.refresh()                          # v2 — word inverted
        _wait(lambda: rep.resilience_stats()["shm_corruptions"] >= 1,
              30.0, "corrupt segment refused")
        # the silently-wrong-answer counter: while the rotted v2 is
        # refused, every replica answer must be the held v1 snapshot
        wrong = 0
        for _ in range(20):
            if rep.version != 1 or _sigs(rep) != held:
                wrong += 1
        detected = rep.resilience_stats()["shm_corruptions"] >= 1
        svc.add(chunks[5])
        svc.refresh()                          # v3 — clean (fault spent)
        _wait(lambda: rep.version == svc.version, 30.0,
              "clean republish attached")
        bit = _sigs(rep) == _sigs(svc) and wrong == 0
        return {"injected": 1, "detected": bool(detected),
                "bit_identical": bool(bit),
                "silent_wrong": int(wrong),
                "corruptions_seen":
                    int(rep.resilience_stats()["shm_corruptions"]),
                "recovered_version": int(rep.version)}
    finally:
        if rep is not None:
            rep.stop()
        svc.stop()
        pub.close()


def _checksum_overhead(ctx, chunks, seed) -> dict:
    """Clean-path cost of the defence: the median time to checksum one
    snapshot's published arrays (``shm.checksum64`` — the only
    checksum on the swap path; WAL/checkpoint CRC32s are write-side
    and amortised) vs the median snapshot-swap (write + re-mine +
    publish) latency it rides on."""
    from repro.serve.shm import checksum64

    svc = _quiesced(ctx.sizes, seed)
    for c in chunks[:4]:
        svc.add(c)
    svc.refresh()                              # warm the miner
    wrng = np.random.default_rng(seed + 5)
    swap_ms, crc_ms = [], []
    for _ in range(OVERHEAD_REPS):
        rows = wrng.integers(0, ctx.sizes,
                             (4, len(ctx.sizes))).astype(np.int64)
        svc.upsert(rows)
        t0 = time.perf_counter()
        svc.refresh()
        swap_ms.append((time.perf_counter() - t0) * 1e3)
        snap = svc._snap
        idx = snap.index
        arrays = [idx.packed_sigs, idx.any_pairs, snap.querier.scores,
                  np.asarray(snap.ages, np.float64),
                  np.asarray(idx.density, np.float64),
                  np.asarray(idx.gen_count, np.int64),
                  np.asarray(idx.volume, np.float64)]
        for k in range(len(idx.mode_pairs)):
            arrays += [idx.mode_pairs[k], idx.comp_ents[k],
                       idx.comp_bounds[k]]
        # the publish path materialises contiguous arrays whether or
        # not checksums are on — the defence's incremental cost is the
        # checksum pass alone, so that is what the gate times
        arrays = [np.ascontiguousarray(a) for a in arrays]
        t0 = time.perf_counter()
        for a in arrays:
            checksum64(a)
        crc_ms.append((time.perf_counter() - t0) * 1e3)
    svc.stop()
    crc, swap = float(np.median(crc_ms)), float(np.median(swap_ms))
    return {"checksum_ms": crc, "swap_ms": swap,
            "overhead_pct": 100.0 * crc / max(swap, 1e-9)}


def run_integrity(scale: float = 0.02, seed: int = 11,
                  out_name: str = "integrity.json") -> dict:
    from repro.launch.tricluster import load_dataset

    n = max(2_000, int(1_000_000 * scale))
    ctx = load_dataset("movielens", n, seed)
    step = -(-ctx.tuples.shape[0] // N_CHUNKS)
    chunks = [ctx.tuples[lo:lo + step]
              for lo in range(0, ctx.tuples.shape[0], step)][:N_CHUNKS]
    tmp = tempfile.mkdtemp(prefix="bench-integrity-")
    sites = {"wal_interior": _scenario_wal_flip(ctx, chunks, seed, tmp),
             "checkpoint": _scenario_ckpt_truncate(ctx, chunks, seed,
                                                   tmp)}
    shm = _scenario_shm_flip(ctx, chunks, seed, tmp)
    if shm is not None:
        sites["shm"] = shm
    overhead = _checksum_overhead(ctx, chunks, seed)

    out = {"n_tuples": int(ctx.tuples.shape[0]), "seed": int(seed),
           "scale": float(scale),
           "injected": int(sum(s["injected"] for s in sites.values())),
           "detected": int(sum(s["injected"] for s in sites.values()
                               if s["detected"])),
           "silent_wrong": int(sum(s["silent_wrong"]
                                   for s in sites.values())),
           "sites": sites, "checksum_overhead": overhead}

    # ---- the gates this benchmark exists for ------------------------
    assert out["detected"] == out["injected"], out
    assert out["silent_wrong"] == 0, \
        f"{out['silent_wrong']} silently-wrong answers served"
    for name, s in sites.items():
        assert s["detected"], f"{name}: corruption served undetected"
        assert s["bit_identical"], f"{name}: recovery diverged ({s})"
    assert overhead["overhead_pct"] <= OVERHEAD_BOUND_PCT, overhead

    print_table(
        "serving_integrity: injected bit rot detected + recovered",
        ["site", "injected", "detected", "bit_identical",
         "silent_wrong"],
        [[name, s["injected"], s["detected"], s["bit_identical"],
          s["silent_wrong"]] for name, s in sites.items()])
    print(f"  checksum overhead: sum64 {overhead['checksum_ms']:.3f}ms "
          f"/ swap {overhead['swap_ms']:.1f}ms = "
          f"{overhead['overhead_pct']:.2f}% "
          f"(bound {OVERHEAD_BOUND_PCT}%)")
    save_json(out_name, {"serving_integrity": out})
    return out


if __name__ == "__main__":
    run(scale=0.01)
    run_integrity(scale=0.01)
