"""Schema gate for ``results/BENCH_mining.json`` (CI bench-smoke step).

Usage: ``PYTHONPATH=src python -m benchmarks.validate [path]``.
Exits non-zero listing every violation, so a benchmark refactor that
silently stops emitting rows (or emits malformed ones) fails CI instead
of producing an empty perf trajectory.
"""
from __future__ import annotations

import json
import os
import sys

from .common import RESULTS_DIR

BACKENDS = {"batch", "distributed", "streaming", "reference"}
VARIANTS = {"prime", "noac"}
SORT_PATHS = {"lexsort", "packed-lax", "packed-radix"}
ROW_REQUIRED = {"backend": str, "variant": str, "dataset": str,
                "n_tuples": int, "ms": (int, float),
                "tuples_per_s": (int, float)}
STAGE_KEYS = {"stage1_sort_ms", "stage1_segment_ms",
              "stage2_components_ms", "stage3_dedup_ms", "total_ms"}
RADIX_KEYS = {"passes", "digit_widths", "live_bits", "per_pass_ms"}
#: run-store comparison pairs (``core.runs``): each benchmarked variant
#: must carry both sides of each pair, plus the runs_speedup summary.
RUNS_MODES = {"batch": ("in_core", "out_of_core"),
              "distributed": ("incremental", "full_resort")}
RUNS_SPEEDUP_KEYS = ("out_of_core", "incremental_snapshot")
CALIBRATION_KEYS = {"probe": str, "n": int, "ms": (int, float)}
#: windowed device pipeline (``core.windowed``, DESIGN.md §3c): the
#: streamed path must stay bit-identical to the monolithic one, mine a
#: table >= MIN_WINDOWS x the window budget on-device, and at report
#: scale (>= SCALE_FULL) keep >= MIN_WINDOWED_THROUGHPUT x monolithic
#: throughput at equal in-core T while peaking at <= 1/MIN_PEAK_RATIO
#: of the monolithic device allocation.  Below report scale the speed
#: and memory gates relax to sanity bounds (tiny runs are dominated by
#: fixed overheads) but bit-identity and window count always gate.
WINDOWED_KEYS = {"n_tuples": int, "window_budget": int, "n_windows": int,
                 "monolithic_ms": (int, float),
                 "windowed_ms": (int, float),
                 "equal_budget_ms": (int, float),
                 "throughput_ratio": (int, float),
                 "peak_monolithic_bytes": int, "peak_windowed_bytes": int,
                 "peak_ratio": (int, float)}
MIN_WINDOWED_THROUGHPUT = 0.8
MIN_PEAK_RATIO = 2.0
MIN_WINDOWS = 8
#: online serving section (``benchmarks/serving.py``): the load-phase
#: measurements, the swap-consistency proof, and the batched-query
#: comparison (acceptance: ≥ 2× scalar at ≥ 64 entities).
SERVING_KEYS = {"n_tuples": int, "queries": int, "qps": (int, float),
                "p50_ms": (int, float), "p99_ms": (int, float),
                "writer_ops": int, "swaps": int,
                "staleness_ms_mean": (int, float),
                "batch_speedup_at_64": (int, float)}
SERVING_BATCH_KEYS = {"entities": int, "scalar_ms": (int, float),
                      "batch_ms": (int, float), "speedup": (int, float)}
SERVING_MIN_BATCH_SPEEDUP = 2.0
#: sharded serving plane (``benchmarks/serving.py`` serving_scale):
#: delta index maintenance must be bit-identical to the full rebuild,
#: and at report scale (>= SCALE_FULL) also >= MIN_DELTA_SPEEDUP x
#: faster, with the 2x2 replica plane >= MIN_QPS_RATIO x the
#: single-process baseline.  Below report scale the speed gates relax
#: to sanity bounds (tiny runs are noise-dominated) but identity,
#: consistency and read-your-writes always gate.
SCALE_DELTA_KEYS = {"n_tuples": int, "clusters": int,
                    "dirty_clusters": int, "dirty_fraction": (int, float),
                    "full_ms": (int, float), "delta_ms": (int, float),
                    "speedup": (int, float)}
SCALE_LOAD_KEYS = {"queries": int, "qps": (int, float), "write_ops": int}
SCALE_FULL = 0.1
MIN_DELTA_SPEEDUP = 5.0
MIN_QPS_RATIO = 2.5
#: observability section (``benchmarks/serving.py`` serving_obs,
#: DESIGN.md §11): the instrumentation must stay within the overhead
#: budget at report scale (>= SCALE_FULL) — metrics-on query p50 and
#: snapshot-swap latency each <= OBS_OVERHEAD_BOUND_PCT above the
#: metrics-off run.  Below report scale the budget relaxes to a sanity
#: bound (tiny runs are noise-dominated) but the schema, the recorded
#: sample/span evidence and histogram-p99 sanity always gate.
OBS_KEYS = {"scale": (int, float), "n_tuples": int,
            "queries_per_side": int,
            "query_p50_off_ms": (int, float),
            "query_p50_on_ms": (int, float),
            "query_overhead_pct": (int, float),
            "query_p99_exact_ms": (int, float),
            "query_p99_hist_ms": (int, float),
            "swap_off_ms": (int, float), "swap_on_ms": (int, float),
            "swap_overhead_pct": (int, float),
            "on_samples": int, "on_spans": int}
OBS_OVERHEAD_BOUND_PCT = 3.0
OBS_OVERHEAD_RELAXED_PCT = 50.0
#: chaos section (``benchmarks/chaos.py``): kill-and-restart cycles
#: must surface zero gateway 5xx (degradation, never an error page),
#: recover full coverage inside the bound, restart both injected
#: victims with the distinctive injected exit code, and answer
#: bit-identically to the uninterrupted control after recovery.
FAULTS_KEYS = {"shards": int, "replicas": int, "queries": int,
               "ok": int, "degraded": int, "gateway_5xx": int,
               "recovery_s": (int, float), "writer_restarts": int,
               "replica_restarts": int, "trickle_ops": int,
               "stream_version_final": int}
FAULTS_RECOVERY_BOUND_S = 30.0
FAULTS_KILL_EXIT = 23
#: integrity section (``benchmarks/chaos.py`` run_integrity): every
#: injected corruption must be *detected* (never served as a wrong
#: answer), recovery must be bit-identical to the uninterrupted
#: control, and the clean-path checksum pass must cost <= 5% of the
#: snapshot-swap latency it rides on (DESIGN.md §9).
INTEGRITY_KEYS = {"n_tuples": int, "seed": int, "injected": int,
                  "detected": int, "silent_wrong": int}
INTEGRITY_REQUIRED_SITES = {"wal_interior", "checkpoint"}
INTEGRITY_OVERHEAD_KEYS = {"checksum_ms": (int, float),
                           "swap_ms": (int, float),
                           "overhead_pct": (int, float)}
INTEGRITY_OVERHEAD_BOUND_PCT = 5.0


def validate(doc: dict) -> list[str]:
    errs = []
    faults = doc.get("serving_faults")
    if faults is not None:
        errs.extend(_validate_serving_faults(faults))
    integ = doc.get("serving_integrity")
    if integ is not None:
        errs.extend(_validate_serving_integrity(integ))
    # a chaos-only doc (results/chaos.json, results/integrity.json)
    # carries just its fault/integrity section — the mining-row schema
    # does not apply
    chaos_only = (faults is not None or integ is not None) \
        and "rows" not in doc
    if not chaos_only and not isinstance(doc.get("scale"), (int, float)):
        errs.append("missing/invalid top-level 'scale'")
    rows = doc.get("rows")
    if not isinstance(rows, list) or not rows:
        if chaos_only:
            return errs
        return errs + ["'rows' missing or empty"]
    for i, r in enumerate(rows):
        where = f"rows[{i}]"
        for key, typ in ROW_REQUIRED.items():
            if not isinstance(r.get(key), typ) or isinstance(r.get(key),
                                                             bool):
                errs.append(f"{where}: bad '{key}' ({r.get(key)!r})")
                continue
        if isinstance(r.get("ms"), (int, float)) and r["ms"] <= 0:
            errs.append(f"{where}: non-positive ms")
        if isinstance(r.get("n_tuples"), int) and r["n_tuples"] <= 0:
            errs.append(f"{where}: non-positive n_tuples")
        if r.get("backend") not in BACKENDS:
            errs.append(f"{where}: unknown backend {r.get('backend')!r}")
        if r.get("variant") not in VARIANTS:
            errs.append(f"{where}: unknown variant {r.get('variant')!r}")
        if "sort_path" in r and r["sort_path"] not in SORT_PATHS:
            errs.append(f"{where}: bad sort_path {r['sort_path']!r}")
        if "stages" in r:
            missing = STAGE_KEYS - set(r["stages"])
            if missing:
                errs.append(f"{where}: stages missing {sorted(missing)}")
        if "radix" in r:
            missing = RADIX_KEYS - set(r["radix"])
            if missing:
                errs.append(f"{where}: radix missing {sorted(missing)}")
            elif (len(r["radix"]["per_pass_ms"]) != r["radix"]["passes"]
                  or sum(r["radix"]["digit_widths"])
                  != r["radix"]["live_bits"]):
                errs.append(f"{where}: radix pass schedule inconsistent")
    # run-store section: both sides of every comparison pair + summary
    runs_rows = [r for r in rows
                 if r.get("mode") in {m for pair in RUNS_MODES.values()
                                      for m in pair}]
    if runs_rows:
        variants = {r["variant"] for r in runs_rows
                    if isinstance(r.get("variant"), str)}
        for v in variants:
            for backend, pair in RUNS_MODES.items():
                got = {r["mode"] for r in runs_rows
                       if r["variant"] == v and r.get("backend") == backend}
                missing = set(pair) - got
                if missing:
                    errs.append(f"runs section [{v}/{backend}]: missing "
                                f"mode rows {sorted(missing)}")
        sp = doc.get("runs_speedup")
        if not isinstance(sp, dict) or not variants <= set(sp):
            errs.append("missing 'runs_speedup' summary for benchmarked "
                        "variants")
        else:
            for v in variants:
                if not isinstance(sp.get(v), dict):
                    errs.append(f"runs_speedup[{v}] is not a dict")
                    continue
                for k in RUNS_SPEEDUP_KEYS:
                    if not isinstance(sp[v].get(k), (int, float)):
                        errs.append(f"runs_speedup[{v}][{k}] missing")
        cal = doc.get("calibration")
        if not isinstance(cal, dict):
            errs.append("missing 'calibration' probe (fixed cross-PR "
                        "normalisation row)")
        else:
            for k, typ in CALIBRATION_KEYS.items():
                if not isinstance(cal.get(k), typ) or isinstance(
                        cal.get(k), bool):
                    errs.append(f"calibration: bad '{k}' ({cal.get(k)!r})")
            if isinstance(cal.get("ms"), (int, float)) and cal["ms"] <= 0:
                errs.append("calibration: non-positive ms")
    win = doc.get("windowed")
    if win is not None:
        scale = doc.get("scale")
        errs.extend(_validate_windowed(
            win, scale if isinstance(scale, (int, float)) else 0.0))
    srv = doc.get("serving")
    if srv is not None:
        errs.extend(_validate_serving(srv))
    scale_sec = doc.get("serving_scale")
    if scale_sec is not None:
        errs.extend(_validate_serving_scale(scale_sec))
    obs_sec = doc.get("serving_obs")
    if obs_sec is not None:
        errs.extend(_validate_serving_obs(obs_sec))
    paths = {r.get("sort_path") for r in rows}
    if SORT_PATHS & paths:
        if not SORT_PATHS <= paths:
            errs.append("sort-path comparison incomplete: need "
                        "'lexsort', 'packed-lax' and 'packed-radix' rows")
        if not any("radix" in r for r in rows
                   if r.get("sort_path") == "packed-radix"):
            errs.append("no packed-radix row carries the per-pass "
                        "'radix' breakdown")
        for name in ("packed_speedup", "radix_speedup"):
            sp = doc.get(name)
            if not isinstance(sp, dict) or not VARIANTS <= set(sp):
                errs.append(f"missing '{name}' summary for both variants")
            else:
                for v in VARIANTS:
                    for k in ("stage1_sort", "end_to_end"):
                        if not isinstance(sp[v].get(k), (int, float)):
                            errs.append(f"{name}[{v}][{k}] missing")
    return errs


def _validate_windowed(sec, scale) -> list[str]:
    errs = []
    if not isinstance(sec, dict):
        return ["'windowed' section is not a dict"]
    missing = VARIANTS - set(sec)
    if missing:
        errs.append(f"windowed: missing variants {sorted(missing)}")
    full_run = scale >= SCALE_FULL
    for v, w in sec.items():
        if not isinstance(w, dict):
            errs.append(f"windowed[{v}]: not a dict")
            continue
        for key, typ in WINDOWED_KEYS.items():
            if not isinstance(w.get(key), typ) or isinstance(w.get(key),
                                                             bool):
                errs.append(f"windowed[{v}]: bad '{key}' "
                            f"({w.get(key)!r})")
        if w.get("bit_identical") is not True:
            errs.append(f"windowed[{v}]: 'bit_identical' is not True — "
                        "the streamed pipeline diverged from the "
                        "monolithic oracle")
        nw = w.get("n_windows")
        if isinstance(nw, int) and nw < MIN_WINDOWS:
            errs.append(f"windowed[{v}]: only {nw} windows (the gate "
                        f"needs a table >= {MIN_WINDOWS}x the budget)")
        tr = w.get("throughput_ratio")
        if isinstance(tr, (int, float)):
            floor = MIN_WINDOWED_THROUGHPUT if full_run else 0.0
            if tr <= floor:
                errs.append(f"windowed[{v}]: equal-T throughput only "
                            f"{tr:.2f}x monolithic (need > {floor}x at "
                            f"scale={scale})")
        pr = w.get("peak_ratio")
        if isinstance(pr, (int, float)):
            floor = MIN_PEAK_RATIO if full_run else 0.0
            if pr <= floor:
                errs.append(f"windowed[{v}]: peak allocation ratio "
                            f"{pr:.2f}x (monolithic/windowed must be > "
                            f"{floor} at scale={scale})")
    return errs


def _validate_serving(srv) -> list[str]:
    errs = []
    if not isinstance(srv, dict):
        return ["'serving' section is not a dict"]
    for key, typ in SERVING_KEYS.items():
        if not isinstance(srv.get(key), typ) or isinstance(srv.get(key),
                                                           bool):
            errs.append(f"serving: bad '{key}' ({srv.get(key)!r})")
    if srv.get("consistent") is not True:
        errs.append("serving: 'consistent' is not True — a query "
                    "observed a torn/regressing snapshot")
    p50, p99 = srv.get("p50_ms"), srv.get("p99_ms")
    if isinstance(p50, (int, float)) and isinstance(p99, (int, float)) \
            and p50 > p99:
        errs.append("serving: p50_ms > p99_ms")
    batch = srv.get("batch")
    if not isinstance(batch, list) or not batch:
        return errs + ["serving: 'batch' rows missing"]
    for i, b in enumerate(batch):
        for key, typ in SERVING_BATCH_KEYS.items():
            if not isinstance(b.get(key), typ) or isinstance(b.get(key),
                                                             bool):
                errs.append(f"serving.batch[{i}]: bad '{key}' "
                            f"({b.get(key)!r})")
    at64 = [b.get("speedup") for b in batch
            if isinstance(b.get("entities"), int) and b["entities"] >= 64
            and isinstance(b.get("speedup"), (int, float))]
    if not at64:
        errs.append("serving: no batch row with >= 64 entities")
    elif max(at64) < SERVING_MIN_BATCH_SPEEDUP:
        errs.append(f"serving: batched queries only {max(at64):.2f}x "
                    f"scalar at >= 64 entities "
                    f"(need >= {SERVING_MIN_BATCH_SPEEDUP}x)")
    return errs


def _validate_serving_scale(sec) -> list[str]:
    errs = []
    if not isinstance(sec, dict):
        return ["'serving_scale' section is not a dict"]
    scale = sec.get("scale")
    if not isinstance(scale, (int, float)):
        errs.append("serving_scale: missing 'scale'")
        scale = 0.0
    full_run = scale >= SCALE_FULL

    d = sec.get("delta")
    if not isinstance(d, dict):
        errs.append("serving_scale: 'delta' probe missing")
    else:
        for key, typ in SCALE_DELTA_KEYS.items():
            if not isinstance(d.get(key), typ) or isinstance(d.get(key),
                                                             bool):
                errs.append(f"serving_scale.delta: bad '{key}' "
                            f"({d.get(key)!r})")
        if d.get("identical") is not True:
            errs.append("serving_scale.delta: 'identical' is not True — "
                        "the spliced index diverged from the full "
                        "rebuild oracle")
        sp = d.get("speedup")
        if isinstance(sp, (int, float)):
            floor = MIN_DELTA_SPEEDUP if full_run else 1.0
            if sp < floor:
                errs.append(f"serving_scale.delta: speedup {sp:.2f}x "
                            f"< {floor}x (scale={scale})")

    r = sec.get("replica_scaleout")
    if not isinstance(r, dict):
        errs.append("serving_scale: 'replica_scaleout' missing")
        return errs
    for side in ("baseline", "plane"):
        load = r.get(side)
        if not isinstance(load, dict):
            errs.append(f"serving_scale.replica_scaleout: '{side}' "
                        "missing")
            continue
        for key, typ in SCALE_LOAD_KEYS.items():
            if not isinstance(load.get(key), typ) \
                    or isinstance(load.get(key), bool):
                errs.append(f"serving_scale.replica_scaleout.{side}: "
                            f"bad '{key}' ({load.get(key)!r})")
    if r.get("consistent") is not True:
        errs.append("serving_scale.replica_scaleout: 'consistent' is "
                    "not True — a replica answered differently from "
                    "its writer at a pinned version")
    if r.get("read_your_writes") is not True:
        errs.append("serving_scale.replica_scaleout: cross-shard "
                    "read-your-writes not verified")
    ratio = r.get("qps_ratio")
    if not isinstance(ratio, (int, float)) or isinstance(ratio, bool):
        errs.append("serving_scale.replica_scaleout: bad 'qps_ratio'")
    elif full_run and ratio < MIN_QPS_RATIO:
        errs.append(f"serving_scale.replica_scaleout: plane only "
                    f"{ratio:.2f}x baseline qps (need >= "
                    f"{MIN_QPS_RATIO}x at scale >= {SCALE_FULL})")
    elif ratio <= 0:
        errs.append("serving_scale.replica_scaleout: non-positive "
                    "qps_ratio")
    return errs


def _validate_serving_obs(sec) -> list[str]:
    errs = []
    if not isinstance(sec, dict):
        return ["'serving_obs' section is not a dict"]
    for key, typ in OBS_KEYS.items():
        if not isinstance(sec.get(key), typ) or isinstance(sec.get(key),
                                                           bool):
            errs.append(f"serving_obs: bad '{key}' ({sec.get(key)!r})")
    scale = sec.get("scale")
    full_run = isinstance(scale, (int, float)) and scale >= SCALE_FULL
    bound = OBS_OVERHEAD_BOUND_PCT if full_run \
        else OBS_OVERHEAD_RELAXED_PCT
    for which in ("query", "swap"):
        pct = sec.get(f"{which}_overhead_pct")
        if isinstance(pct, (int, float)) and pct > bound:
            errs.append(f"serving_obs: {which} instrumentation overhead "
                        f"{pct:.2f}% > {bound}% budget (scale={scale})")
    # the instrumented side must actually have recorded evidence, and
    # the bucket-derived p99 must be a positive latency
    if isinstance(sec.get("on_samples"), int) and sec["on_samples"] <= 0:
        errs.append("serving_obs: metrics-on run recorded no samples")
    if isinstance(sec.get("on_spans"), int) and sec["on_spans"] <= 0:
        errs.append("serving_obs: metrics-on run recorded no spans")
    p99h = sec.get("query_p99_hist_ms")
    if isinstance(p99h, (int, float)) and p99h <= 0:
        errs.append("serving_obs: non-positive histogram-derived p99")
    return errs


def _validate_serving_faults(sec) -> list[str]:
    errs = []
    if not isinstance(sec, dict):
        return ["'serving_faults' section is not a dict"]
    for key, typ in FAULTS_KEYS.items():
        if not isinstance(sec.get(key), typ) or isinstance(sec.get(key),
                                                           bool):
            errs.append(f"serving_faults: bad '{key}' ({sec.get(key)!r})")
    if sec.get("gateway_5xx") != 0:
        errs.append(f"serving_faults: {sec.get('gateway_5xx')!r} gateway "
                    "5xx leaked through the kill-and-restart cycle "
                    "(failures must degrade, never error)")
    if sec.get("bit_identical") is not True:
        errs.append("serving_faults: 'bit_identical' is not True — the "
                    "recovered writer diverged from the uninterrupted "
                    "control at the same stream version")
    rec = sec.get("recovery_s")
    if isinstance(rec, (int, float)) and rec >= FAULTS_RECOVERY_BOUND_S:
        errs.append(f"serving_faults: recovery took {rec:.1f}s "
                    f"(bound {FAULTS_RECOVERY_BOUND_S}s)")
    for victim in ("writer", "replica"):
        if isinstance(sec.get(f"{victim}_restarts"), int) \
                and sec[f"{victim}_restarts"] < 1:
            errs.append(f"serving_faults: {victim} was never restarted")
        if sec.get(f"{victim}_exit") != FAULTS_KILL_EXIT:
            errs.append(f"serving_faults: {victim} exit "
                        f"{sec.get(f'{victim}_exit')!r} is not the "
                        f"injected kill ({FAULTS_KILL_EXIT})")
    return errs


def _validate_serving_integrity(sec) -> list[str]:
    errs = []
    if not isinstance(sec, dict):
        return ["'serving_integrity' section is not a dict"]
    for key, typ in INTEGRITY_KEYS.items():
        if not isinstance(sec.get(key), typ) or isinstance(sec.get(key),
                                                           bool):
            errs.append(f"serving_integrity: bad '{key}' "
                        f"({sec.get(key)!r})")
    inj, det = sec.get("injected"), sec.get("detected")
    if isinstance(inj, int) and isinstance(det, int):
        if inj < 1:
            errs.append("serving_integrity: no corruption was injected")
        if det != inj:
            errs.append(f"serving_integrity: {inj - det} of {inj} "
                        "injected corruptions went undetected")
    if sec.get("silent_wrong") != 0:
        errs.append(f"serving_integrity: {sec.get('silent_wrong')!r} "
                    "silently-wrong answers served (corruption must be "
                    "detected, never returned)")
    sites = sec.get("sites")
    if not isinstance(sites, dict) or not sites:
        errs.append("serving_integrity: 'sites' missing or empty")
    else:
        missing = INTEGRITY_REQUIRED_SITES - set(sites)
        if missing:
            errs.append(f"serving_integrity: sites missing "
                        f"{sorted(missing)} (shm is optional — needs "
                        "/dev/shm)")
        for name, s in sites.items():
            if not isinstance(s, dict):
                errs.append(f"serving_integrity.sites[{name}]: not a "
                            "dict")
                continue
            if s.get("detected") is not True:
                errs.append(f"serving_integrity.sites[{name}]: "
                            "corruption served undetected")
            if s.get("bit_identical") is not True:
                errs.append(f"serving_integrity.sites[{name}]: recovery "
                            "diverged from the uninterrupted control")
            if s.get("silent_wrong") != 0:
                errs.append(f"serving_integrity.sites[{name}]: "
                            f"{s.get('silent_wrong')!r} silently-wrong "
                            "answers")
    ovh = sec.get("checksum_overhead")
    if not isinstance(ovh, dict):
        errs.append("serving_integrity: 'checksum_overhead' missing")
    else:
        for key, typ in INTEGRITY_OVERHEAD_KEYS.items():
            if not isinstance(ovh.get(key), typ) \
                    or isinstance(ovh.get(key), bool):
                errs.append(f"serving_integrity.checksum_overhead: bad "
                            f"'{key}' ({ovh.get(key)!r})")
        pct = ovh.get("overhead_pct")
        if isinstance(pct, (int, float)) \
                and pct > INTEGRITY_OVERHEAD_BOUND_PCT:
            errs.append(f"serving_integrity: clean-path checksum cost "
                        f"{pct:.2f}% of a snapshot swap (bound "
                        f"{INTEGRITY_OVERHEAD_BOUND_PCT}%)")
    return errs


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    path = argv[0] if argv else os.path.join(RESULTS_DIR,
                                             "BENCH_mining.json")
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"[validate] cannot read {path}: {e}")
        return 1
    errs = validate(doc)
    if errs:
        for e in errs:
            print(f"[validate] {e}")
        print(f"[validate] FAIL: {len(errs)} problem(s) in {path}")
        return 1
    if "rows" not in doc:                     # chaos-only doc
        if "serving_faults" in doc:
            f = doc["serving_faults"]
            print(f"[validate] OK: serving_faults — {f['queries']} "
                  f"queries, {f['degraded']} degraded, 0 gateway 5xx, "
                  f"recovery {f['recovery_s']:.1f}s, "
                  f"bit_identical={f['bit_identical']}")
        if "serving_integrity" in doc:
            g = doc["serving_integrity"]
            print(f"[validate] OK: serving_integrity — "
                  f"{g['detected']}/{g['injected']} corruptions "
                  f"detected over {sorted(g['sites'])}, 0 silent-wrong, "
                  f"checksum overhead "
                  f"{g['checksum_overhead']['overhead_pct']:.2f}%")
        return 0
    n = len(doc["rows"])
    print(f"[validate] OK: {n} rows, scale={doc['scale']}"
          + (f", packed_speedup={doc['packed_speedup']}"
             if "packed_speedup" in doc else "")
          + (f", calibration={doc['calibration']['ms']:.2f}ms"
             if "calibration" in doc else "")
          + (f", windowed@T="
             f"{doc['windowed']['prime']['throughput_ratio']:.2f}x "
             f"peak={doc['windowed']['prime']['peak_ratio']:.1f}x"
             if "windowed" in doc and "prime" in doc["windowed"] else "")
          + (f", serving p50={doc['serving']['p50_ms']:.3f}ms "
             f"batch@64={doc['serving']['batch_speedup_at_64']:.2f}x"
             if "serving" in doc else "")
          + (f", delta={doc['serving_scale']['delta']['speedup']:.1f}x"
             f" plane="
             f"{doc['serving_scale']['replica_scaleout']['qps_ratio']:.1f}x"
             if "serving_scale" in doc else "")
          + (f", obs overhead q="
             f"{doc['serving_obs']['query_overhead_pct']:+.2f}% swap="
             f"{doc['serving_obs']['swap_overhead_pct']:+.2f}%"
             if "serving_obs" in doc else ""))
    return 0


if __name__ == "__main__":
    sys.exit(main())
