"""Paper Table 4: per-stage breakdown + cluster counts on MovieLens-scale
data (100k → 1M tuples) and BibSonomy-like.

The split follows the unified pipeline (DESIGN.md §3): ``sort`` =
per-mode lexicographic sort + segmentation (Stage 1 skeleton), ``comp``
= component operator (hashing/segment aggregation) + gather + signature
mix (Stage 1 hashing + Stage 2 of the paper), ``dedup`` = global
signature sort + density (Stage 3). Measured by running the jit'd
sub-pipelines separately (each includes its own data movement, like the
paper's per-M/R-job wall times include shuffle I/O). Note: revisions
before the unified pipeline attributed hashing to the first column.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import BatchMiner
from repro.core import pipeline as P
from repro.data import synthetic as S

from .common import print_table, save_json, timeit


def _stage_times(miner: BatchMiner, tuples, repeat: int = 3):
    t = jnp.asarray(tuples, jnp.int32)
    n = t.shape[1]

    s1 = jax.jit(lambda tt: [P.sort_mode(tt, k) for k in range(n)])
    sms = s1(t)
    t1, sms = timeit(s1, t, repeat=repeat)

    def s2(tt, sms):
        comps = [P.prime_components(sm, miner._lo[k], miner._hi[k])
                 for k, sm in enumerate(sms)]
        return P.mix_signatures([c.sig_lo for c in comps],
                                [c.sig_hi for c in comps])

    s2j = jax.jit(s2)
    t2, (sig_lo, sig_hi) = timeit(s2j, t, sms, repeat=repeat)

    # stage 3 (global signature sort + density) = full − stage1 − stage2
    full = jax.jit(lambda tt: P.mine_tuples(tt, miner._lo, miner._hi))
    t_all, _ = timeit(full, t, repeat=repeat)
    t3 = max(t_all - t1 - t2, 0.0)
    return t1, t2, t3, t_all


def run(scale: float = 0.2, repeat: int = 3):
    sizes = [("MovieLens100k", int(100_000 * scale)),
             ("MovieLens250k", int(250_000 * scale)),
             ("MovieLens500k", int(500_000 * scale)),
             ("MovieLens1M", int(1_000_000 * scale)),
             ("Bibsonomy", int(816_197 * scale))]
    rows, raw = [], {}
    for name, n in sizes:
        ctx = (S.bibsonomy_like(n_tuples=n, seed=0) if "Bib" in name
               else S.movielens_like(n_tuples=n, seed=0))
        miner = BatchMiner(ctx.sizes)
        t1, t2, t3, t_all = _stage_times(miner, ctx.tuples, repeat)
        res = miner(ctx.tuples)
        n_cl = int(np.asarray(res.is_unique).sum())
        rows.append([name, f"{n:,}", f"{t_all * 1e3:,.0f}",
                     f"{t1 * 1e3:,.0f}", f"{t2 * 1e3:,.0f}",
                     f"{t3 * 1e3:,.0f}", f"{n_cl:,}"])
        raw[name] = {"tuples": n, "total_ms": t_all * 1e3,
                     "sort_ms": t1 * 1e3, "component_ms": t2 * 1e3,
                     "dedup_ms": t3 * 1e3, "clusters": n_cl}
    print_table("Table 4 — stage breakdown (ms)",
                ["dataset", "|I|", "total", "sort", "comp", "dedup",
                 "#clusters"], rows)
    save_json("table4.json", raw)
    return raw


if __name__ == "__main__":
    run()
